"""Zamba2-7B — Mamba2 backbone with shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers (d_model=3584, ssm_state=64, expand=2) with ONE tied-weight
GQA attention+MLP block invoked every 6 layers (13 invocations + 3 tail
mamba layers). 32 heads (kv=32), d_ff=14336 for the shared block MLP,
vocab 32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    shared_attn_every=6,
    rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="arXiv:2411.15242",
)
