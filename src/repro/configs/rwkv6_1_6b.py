"""RWKV-6 "Finch" 1.6B — attention-free linear RNN with data-dependent
decay [arXiv:2404.05892].

24 layers, d_model=2048 (32 heads x 64), channel-mix d_ff=7168, vocab 65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    rwkv_lora_rank=64,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="arXiv:2404.05892",
)
