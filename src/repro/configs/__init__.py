"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full production ModelConfig;
``get_smoke_config(name)`` the reduced same-family variant.
"""
from repro.configs.base import (ModelConfig, OptimizerConfig, RunConfig,
                                ShapeConfig, SHAPES, smoke_variant)

from . import (seamless_m4t_large_v2, zamba2_7b, llama3_405b,
               llama_3_2_vision_11b, qwen1_5_32b, granite_moe_1b_a400m,
               yi_34b, rwkv6_1_6b, qwen1_5_4b, qwen3_moe_30b_a3b,
               paper_models)

ARCH_CONFIGS = {
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "llama-3.2-vision-11b": llama_3_2_vision_11b.CONFIG,
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "yi-34b": yi_34b.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.CONFIG,
    # paper-scale models for the convergence benchmarks
    "paper-mlp": paper_models.MLP_CONFIG,
    "paper-cnn": paper_models.CNN_CONFIG,
    "paper-lm-100m": paper_models.LM_100M_CONFIG,
}

ARCH_NAMES = [n for n in ARCH_CONFIGS if not n.startswith("paper-")]


def get_config(name: str) -> ModelConfig:
    return ARCH_CONFIGS[name]


def get_smoke_config(name: str) -> ModelConfig:
    return smoke_variant(ARCH_CONFIGS[name])

__all__ = ["ModelConfig", "OptimizerConfig", "RunConfig",
           "ShapeConfig", "SHAPES", "smoke_variant", "ARCH_CONFIGS",
           "get_config", "get_smoke_config"]
