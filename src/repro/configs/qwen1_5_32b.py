"""Qwen1.5-32B — dense MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B family].

64 layers, d_model=5120, 40 heads (kv=40 -> MHA), d_ff=27392, vocab 152064,
QKV bias on.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="hf:Qwen/Qwen1.5-0.5B",
)
