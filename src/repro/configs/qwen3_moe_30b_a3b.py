"""Qwen3-30B-A3B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

48 layers, d_model=2048, 32H/4KV GQA (head_dim=128), 128 experts top-8 with
per-expert d_ff=768, vocab 151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    n_experts=128, experts_per_token=8, moe_d_ff=768,
    rope_theta=1000000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="hf:Qwen/Qwen3-30B-A3B",
)
