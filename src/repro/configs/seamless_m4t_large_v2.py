"""SeamlessM4T-Large v2 — speech/text translation backbone [arXiv:2308.11596].

Enc-dec multimodal: 24 transformer layers split 12 encoder + 12 decoder,
d_model=1024, 16 heads (kv=16 -> MHA), d_ff=8192, vocab 256206.  The audio
frontend (mel filterbank + conformer feature extractor) is STUBBED:
input_specs supply precomputed frame embeddings (B, S_enc, 1024).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256206,
    rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="arXiv:2308.11596",
)
