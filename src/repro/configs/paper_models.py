"""CPU-scale stand-ins for the paper's own experiment models (§IV).

The paper trains ResNet-18/34 and DenseNet-121 on CIFAR-10/100.  Those are
GPU-scale CNNs on datasets not available offline; the *claims* being tested
are optimizer-vs-optimizer, so we provide:

* ``MLP_CONFIG``  — 3-layer MLP classifier (interpolation realizable),
* ``CNN_CONFIG``  — small conv net on 32x32x3 synthetic images (the CIFAR
  geometry), channels scaled to CPU budget,
* ``LM_100M_CONFIG`` — a ~100M dense transformer for the end-to-end driver.

MLP/CNN are defined functionally here (they are not transformer LMs); the
synthetic datasets come from ``repro.data.synthetic`` with teacher labels so
the interpolation condition can hold.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class PaperNetConfig:
    name: str
    kind: str                  # mlp | cnn
    in_dim: int = 3072         # 32*32*3
    n_classes: int = 100
    widths: tuple = (512, 512)
    channels: tuple = (32, 64, 128)


MLP_CONFIG = PaperNetConfig(name="paper-mlp", kind="mlp")
CNN_CONFIG = PaperNetConfig(name="paper-cnn", kind="cnn")

LM_100M_CONFIG = ModelConfig(
    name="paper-lm-100m",
    family="dense",
    n_layers=12,
    d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=2048, vocab_size=16384,
    rope_theta=10000.0,
    param_dtype="float32", compute_dtype="float32",
    attn_chunk=2048, remat=False,
    citation="end-to-end driver model (~100M params)",
)


# ----------------------------- MLP ------------------------------------------

def init_mlp_net(cfg: PaperNetConfig, key):
    dims = (cfg.in_dim,) + cfg.widths + (cfg.n_classes,)
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(key, i)
        params.append({"w": jax.random.normal(k, (a, b)) / jnp.sqrt(a),
                       "b": jnp.zeros((b,))})
    return params


def mlp_net_logits(params, x):
    h = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params):
        h = h @ p["w"] + p["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


# ----------------------------- CNN ------------------------------------------

def init_cnn_net(cfg: PaperNetConfig, key):
    params = []
    cin = 3
    for i, cout in enumerate(cfg.channels):
        k = jax.random.fold_in(key, i)
        params.append({"w": jax.random.normal(k, (3, 3, cin, cout))
                       / jnp.sqrt(9 * cin)})
        cin = cout
    k = jax.random.fold_in(key, 99)
    feat = cfg.channels[-1] * (32 // (2 ** len(cfg.channels))) ** 2
    params.append({"w": jax.random.normal(k, (feat, cfg.n_classes))
                   / jnp.sqrt(feat), "b": jnp.zeros((cfg.n_classes,))})
    return params


def cnn_net_logits(params, x):
    """x: (B, 32, 32, 3)."""
    h = x
    for p in params[:-1]:
        h = jax.lax.conv_general_dilated(
            h, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h)
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    p = params[-1]
    return h @ p["w"] + p["b"]


def net_loss(cfg: PaperNetConfig, params, batch):
    """Cross-entropy for either net. batch: {"x": images, "y": labels}."""
    logits = (mlp_net_logits if cfg.kind == "mlp" else cnn_net_logits)(
        params, batch["x"])
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, batch["y"][:, None], 1))


def init_net(cfg: PaperNetConfig, key):
    return (init_mlp_net if cfg.kind == "mlp" else init_cnn_net)(cfg, key)
