"""Granite-3.0-1B-A400M — 32-expert top-8 MoE [hf:ibm-granite/granite-3.0-1b-a400m-base].

24 layers, d_model=1024, 16H/8KV GQA, per-expert d_ff=512, 32 experts top-8,
vocab 49155.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=32, experts_per_token=8, moe_d_ff=512,
    rope_theta=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
