"""Config system: model architecture, optimizer, input shapes, run configs.

Every assigned architecture gets a ``ModelConfig`` in its own module citing
its source. Shapes are the four assigned global input shapes. ``RunConfig``
composes model x shape x mesh x optimizer for the launcher/dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.comm.faults import FaultConfig
from repro.comm.gossip import GossipConfig
from repro.comm.overlap import OverlapConfig
from repro.core.armijo import ArmijoConfig
from repro.core.compression import Compressor
from repro.core.gamma import GammaControllerConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0             # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # beyond-paper perf: explicit expert-parallel shard_map (each model
    # shard routes+computes its local experts on its replicated token set;
    # one psum combines) instead of auto-partitioned gathers — see §Perf.
    moe_expert_parallel: bool = False
    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0    # >0: tied attn block every k ssm layers
    # --- enc-dec ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- VLM ---
    cross_attn_every: int = 0     # >0: one cross-attn layer per k self layers
    n_patches: int = 0
    # --- rwkv ---
    rwkv_lora_rank: int = 64
    # --- attention variants ---
    sliding_window: int = 0       # 0 = full attention
    swa_for_long_context: bool = True  # long_500k uses window if full-attn
    long_context_window: int = 8192
    # --- numerics / impl ---
    seq_parallel: bool = False    # Megatron-SP residual stream (S over model)
    # beyond-paper: int8 self-attention KV cache (per-position absmax
    # scales) — halves the decode shapes' dominant HBM term vs bf16.
    kv_cache_dtype: str = ""      # "" = compute dtype | "int8"
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_chunk: int = 1024        # query-chunked attention above this seq len
    remat: bool = True
    use_pallas: bool = False      # flip on real TPU
    citation: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 multiple so embedding/head tables shard
        over any model-axis size; padded logits are masked in lm_head."""
        return -(-self.vocab_size // 256) * 256

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm" and self.shared_attn_every == 0

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs and memory checks)."""
        D, V = self.d_model, self.vocab_size
        hd = self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)

        def attn_p():
            qp = self.n_heads * hd * D
            kvp = 2 * self.n_kv_heads * hd * D
            op = self.n_heads * hd * D
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return qp + kvp + op + b

        def mlp_p(ff):
            return 3 * D * ff            # SwiGLU gate+up+down

        def mamba_p():
            d_in = self.ssm_expand * D
            nh = d_in // self.ssm_head_dim
            in_proj = D * (2 * d_in + 2 * self.ssm_state + nh)
            conv = (d_in + 2 * self.ssm_state) * self.ssm_conv
            out = d_in * D
            return in_proj + conv + out + 2 * nh + nh  # A, D, dt_bias

        def rwkv_p():
            tm = 4 * D * D + D * D       # r,k,v,g + output
            w_lora = 2 * D * self.rwkv_lora_rank * 5
            cm = 2 * D * self.d_ff       # channel mix
            return tm + w_lora + cm + 6 * D

        per_layer = 0
        n_layers = self.n_layers
        if self.family in ("dense", "vlm"):
            per_layer = attn_p() + mlp_p(self.d_ff) + 2 * D
        elif self.family == "moe":
            per_layer = attn_p() + 2 * D + \
                self.n_experts * 3 * D * self.moe_d_ff + D * self.n_experts
        elif self.family == "ssm" and self.shared_attn_every == 0:
            per_layer = rwkv_p() + 2 * D if self.name.startswith("rwkv") \
                else mamba_p() + 2 * D
        elif self.family == "hybrid":
            per_layer = mamba_p() + 2 * D
        elif self.family == "encdec":
            enc = attn_p() + mlp_p(self.d_ff) + 2 * D
            dec = 2 * attn_p() + mlp_p(self.d_ff) + 3 * D
            return emb + self.n_enc_layers * enc + self.n_dec_layers * dec + D
        total = emb + n_layers * per_layer + D
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (2 * attn_p() + 2 * D)
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn_p() + mlp_p(self.d_ff) + 2 * D  # one tied block
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FederatedConfig:
    """Federated cohort simulation (DESIGN.md §13, repro/fed/).

    ``n_clients`` > 0 turns the train step into a cohort round: each dp
    worker ``vmap``s ``n_clients / W`` simulated clients (per-client EF
    memory, gamma controller, and Armijo step in ``DistOptState.fed``)
    through the compressed exchange, ONE all_gather + ONE psum for the
    whole cohort.  Client participation is sampled host-side per round
    (repro/fed/sampling.py) and enters the batch as a replicated
    ``"participation"`` mask.
    """

    n_clients: int = 0            # 0 = disabled (plain dp training)
    clients_per_round: int = 0    # fixed sampler: 0 -> all clients
    sampling: str = "fixed"       # fixed | bernoulli
    participation_rate: float = 1.0   # bernoulli per-client probability
    straggler_rate: float = 0.0   # drop each selected client with this p
    # "support" divides each coordinate by its nonzero-support count
    # across participants (fed_dropout_avg-style — fixes the dense mean
    # averaging zeros into unsent coordinates); "mean" keeps the
    # zero-averaging dense mean as the reference (repro/fed/aggregate.py)
    aggregation: str = "support"
    # per-client gamma controllers (fixed | linear schedules; the linear
    # ramp advances on each client's OWN participation counter, so
    # clients genuinely carry heterogeneous k_t)
    per_client_gamma: bool = True
    dirichlet_alpha: float = 0.0  # >0: non-IID client data skew
    seed: int = 0                 # sampling stream seed

    @property
    def enabled(self) -> bool:
        return self.n_clients > 0

    def __post_init__(self):
        from repro.fed.aggregate import validate_aggregation
        from repro.fed.sampling import validate_sampler
        validate_sampler(self.sampling)
        validate_aggregation(self.aggregation)
        if self.n_clients < 0:
            raise ValueError(f"n_clients must be >= 0, got {self.n_clients}")
        if not 0 <= self.clients_per_round <= self.n_clients:
            raise ValueError(
                f"clients_per_round={self.clients_per_round} out of range "
                f"for n_clients={self.n_clients}")
        if not 0.0 <= self.participation_rate <= 1.0:
            raise ValueError(f"participation_rate must be in [0, 1], got "
                             f"{self.participation_rate}")
        if not 0.0 <= self.straggler_rate < 1.0:
            raise ValueError(f"straggler_rate must be in [0, 1), got "
                             f"{self.straggler_rate}")


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "csgd_asss"   # csgd_asss | nonadaptive | acgd | sgd | sls | dense
    armijo: ArmijoConfig = ArmijoConfig()
    compressor: Compressor = Compressor()
    # per-round compression-level controller (AdaCGD-style adaptive gamma;
    # repro/core/gamma.py + DESIGN.md §9/§10) — takes effect when
    # ``compressor.max_gamma`` > 0 sizes the ragged wire budget.  The
    # ``ef-coupled`` schedule closes the armijo-coupled observability gap
    # by coupling to the per-worker CompressionTelemetry (EF backlog /
    # decode cosine) that the train step threads through DistOptState.
    gamma_controller: GammaControllerConfig = GammaControllerConfig()
    eta: float = 0.1              # for non-adaptive baselines + acgd
    momentum: float = 0.9         # acgd: Nesterov mu (arXiv 2002.11364)
    ef_dtype: str = "float32"
    ef_host_offload: bool = False  # beyond-paper: EF memory in host RAM
    # beyond-paper: compress per (layer, model-shard) under a nested
    # manual-model shard_map so top_k never gathers the full gradient
    # (same contraction constant — see DESIGN.md §3; §Perf iteration 1).
    shard_local_topk: bool = False
    # beyond-paper (paper §V lists local iterations as future work):
    # Qsparse-local-style — each worker takes `local_steps` uncompressed
    # Armijo-SGD steps on its own microbatches, then the accumulated model
    # delta is EF-compressed and exchanged once.  Divides exchange
    # frequency by local_steps.  Requires microbatches == local_steps.
    local_steps: int = 1
    # transport schedule of the compressed exchange, validated against
    # the repro.comm.transport registry — the ONE source of truth for
    # valid names (DESIGN.md §11/§12): "bucketed" coalesces every leaf
    # into ONE flat packed all_gather + batched kernel launches + ONE
    # dense pmean; "perleaf" is the bit-exact reference schedule (one
    # collective per leaf) kept for parity tests and paired benchmarks;
    # "gossip" is the serverless neighbor-ppermute exchange; "overlap"
    # streams the bucket buffer over a chunked ppermute ring and ships
    # the previous step's payload so the collective hides behind compute
    # (DESIGN.md §14).
    transport: str = "bucketed"
    # gossip/consensus hyper-parameters; only read when transport="gossip"
    gossip: GossipConfig = GossipConfig()
    # overlap ring/staleness knobs; only read when transport="overlap"
    overlap: OverlapConfig = OverlapConfig()
    # federated cohort simulation (DESIGN.md §13): n_clients > 0 vmaps a
    # client cohort above the dp mesh with per-client EF/gamma state and
    # support-weighted aggregation of the decoded top-k payloads
    federated: FederatedConfig = FederatedConfig()
    # downlink direction (DESIGN.md §15): "dense" returns the decoded
    # aggregate as the full f32 mean (bit-exact reference, charged dense
    # bytes per link); "compressed" re-compresses the replicated aggregate
    # through the SAME WireSpec geometry with a server-side EF memory
    # (repro/comm/downlink.py) — no extra collective, the §11 schedule
    # stays ONE all_gather + ONE pmean.
    downlink: str = "dense"
    # ragged §9 valid counts of the downlink payload; fixed | linear only
    # (the server has no Armijo search and no per-worker EF telemetry to
    # couple to)
    downlink_gamma: GammaControllerConfig = GammaControllerConfig()
    # hostile-wire robustness (DESIGN.md §16): seeded fault-injection
    # campaign applied at the gathered-payload boundary.  All rates 0.0
    # (the default) means no injection; the defensive decode verdicts and
    # the step-level circuit breaker stay armed either way.
    faults: FaultConfig = FaultConfig()
    # circuit breaker: a non-finite round (loss or decoded update) skips
    # the parameter write with all carried optimizer state bit-frozen;
    # this many CONSECUTIVE skips raise DivergenceError on the host
    # (repro/core/health.py).  0 disables the gate (legacy behavior:
    # non-finite rounds write through).
    max_consecutive_skips: int = 25

    def __post_init__(self):
        from repro.comm.transport import validate_transport
        validate_transport(self.transport)
        from repro.comm.downlink import MODES as DOWNLINK_MODES
        if self.downlink not in DOWNLINK_MODES:
            raise ValueError(f"unknown downlink mode {self.downlink!r} "
                             f"(want one of {DOWNLINK_MODES})")
        if self.downlink == "compressed":
            if self.downlink_gamma.schedule not in ("fixed", "linear"):
                raise ValueError(
                    "downlink_gamma supports only the open-loop fixed | "
                    "linear schedules — the simulated server has no Armijo "
                    "search or per-worker EF telemetry to couple to "
                    f"(got {self.downlink_gamma.schedule!r})")
            if self.transport in ("gossip", "overlap"):
                raise ValueError(
                    "downlink='compressed' re-compresses a replicated "
                    "global aggregate; transport="
                    f"{self.transport!r} never materializes one "
                    "(gossip mixes neighbors, overlap applies stale "
                    "payloads — DESIGN.md §12/§14/§15)")
            if self.federated.enabled:
                raise ValueError(
                    "downlink='compressed' does not compose with the "
                    "federated cohort yet — the cohort's support-weighted "
                    "aggregate is produced inside the fed worker "
                    "(DESIGN.md §13), not by the §11 transport the "
                    "downlink hooks")
        if self.federated.enabled and self.transport == "gossip":
            raise ValueError(
                "federated cohort simulation does not compose with "
                "transport='gossip' — the cohort has its own one-gather "
                "collective schedule (DESIGN.md §13)")
        if self.federated.enabled and self.transport == "overlap":
            raise ValueError(
                "federated cohort simulation does not compose with "
                "transport='overlap' — the cohort gather carries per-client "
                "rows on its own schedule (DESIGN.md §13/§14)")
        if self.max_consecutive_skips < 0:
            raise ValueError(
                f"max_consecutive_skips must be >= 0 (0 disables the "
                f"breaker), got {self.max_consecutive_skips}")
        if self.faults.enabled:
            if self.kind not in ("csgd_asss", "nonadaptive", "acgd"):
                raise ValueError(
                    f"fault injection corrupts the packed uplink wire "
                    f"(DESIGN.md §16); kind={self.kind!r} ships a dense "
                    f"pmean with no wire to corrupt — use csgd_asss | "
                    f"nonadaptive | acgd")
            if self.downlink == "compressed":
                raise ValueError(
                    "fault injection does not compose with "
                    "downlink='compressed' — the 'faulty' wrapper is a "
                    "stateful transport and the downlink hook requires a "
                    "stateless one (DESIGN.md §15/§16)")
            if self.shard_local_topk:
                raise ValueError(
                    "fault injection does not compose with "
                    "shard_local_topk — fault sites are keyed by whole-"
                    "gradient leaf index, not a model shard's lane set "
                    "(DESIGN.md §16)")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    optimizer: OptimizerConfig = OptimizerConfig()
    multi_pod: bool = False
    microbatches: int = 1          # gradient accumulation per worker
    seq_shard_activations: bool = True   # sequence-parallel residual stream


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (prompt contract:
    2 layers, d_model <= 512, <= 4 experts)."""
    kw = dict(
        n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=256, vocab_size=512, head_dim=32,
        param_dtype="float32", compute_dtype="float32",
        attn_chunk=64, remat=False,
    )
    if cfg.family == "moe":
        # capacity_factor = E/k so C = T (drop-free): smoke tests assert
        # exact decode/forward consistency, which dropping would break.
        kw.update(n_experts=4, experts_per_token=2, moe_d_ff=64,
                  capacity_factor=2.0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=32)
    if cfg.family == "hybrid":
        kw.update(n_layers=5, shared_attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, n_dec_layers=2)
    if cfg.family == "vlm":
        kw.update(n_layers=4, cross_attn_every=2, n_patches=16)
    if cfg.name.startswith("rwkv"):
        kw.update(rwkv_lora_rank=8)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return dataclasses.replace(cfg, **kw)
