"""Llama-3.2-11B-Vision — cross-attention VLM [hf:meta-llama/Llama-3.2-11B-Vision].

40 self-attn layers (d_model=4096, 32H/8KV GQA, d_ff=14336, vocab 128256)
with gated cross-attention layers every 5th layer (8 total) attending to
ViT patch embeddings.  The vision encoder is STUBBED: input_specs supply
patch embeddings (B, n_patches=4096, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    cross_attn_every=5, n_patches=4096,
    rope_theta=500000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)
