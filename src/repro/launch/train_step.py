"""Distributed train/serve step builders — DCSGD-ASSS as a first-class
feature of the runtime (DESIGN.md §4).

``build_train_step``: jit(shard_map(worker_fn)) where the shard_map is
*manual* over the data-parallel axes (('pod','data') or ('data',)) and
*auto* over 'model' (XLA partitions the tensor-parallel math from the
parameter shardings + in-model hints).  Each dp worker:

  grads  <- value_and_grad over its microbatches           (model-axis TP)
  alpha  <- Armijo search on its first microbatch          (Algorithm 3 l.4)
  update <- compress + all-gather sparse over dp axes      (Algorithm 3 l.5-7)

Per-worker optimizer state (EF memory m^(k), alpha^(k)) is stored with a
leading worker axis sharded over the dp mesh axes — per-chip EF memory is
P/|model| as analyzed in DESIGN.md §6.

``build_prefill_step`` / ``build_decode_step``: pure-pjit serving steps with
batch-over-dp, seq-sharded KV caches (flash-decode combine emerges from the
partitioner; see models/attention.py).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.comm.downlink import (DownlinkCtx, DownlinkState,
                                 init_downlink_state)
from repro.comm.faults import FaultCtx, active_faults
from repro.comm.gossip import GossipCtx, GossipState
from repro.comm.overlap import OverlapCtx, OverlapState, init_overlap_state
from repro.comm.topology import build_topology
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.armijo import armijo_search, next_alpha_max, tree_sqnorm
from repro.core.dcsgd import dense_aggregate, worker_compress_aggregate
from repro.core.gamma import gamma_init, gamma_update
from repro.core.health import HealthState, advance_health, all_finite
from repro.core.telemetry import CompressionTelemetry, SearchTelemetry
from repro.fed.clients import (ClientState, cohort_compress_aggregate,
                               init_client_state, local_participation)
from repro.models.registry import Model
from repro.sharding import cache_pspecs, dp_axes_of, param_pspecs

PyTree = Any


class GossipOptState(NamedTuple):
    """Per-worker serverless-mode state (DESIGN.md §12).

    Under ``transport="gossip"`` there is no global mean, so workers'
    models genuinely diverge between rounds: each worker's parameters
    live here with a leading (W,) axis (the replicated ``params`` input
    stays frozen as the common initialization), next to the AdaGossip
    consensus state carried exactly like ``CompressionTelemetry``.
    """

    params: PyTree           # per-worker models: leaves (W, *param_shape)
    state: GossipState       # (W,) adaptive-consensus (v, lr)


class DistOptState(NamedTuple):
    step: jax.Array          # () int32
    alpha_prev: jax.Array    # (W,) per-worker carried step size
    memory: PyTree           # per-worker EF: leaves (W, *param_shape)
    n_evals_ema: jax.Array   # (W,)
    gamma: jax.Array         # (W,) per-worker per-round compression level
    telemetry: CompressionTelemetry  # (W,) per-worker compression health
    cum_eff_bytes: jax.Array         # () cumulative worker-mean eff bytes
    gossip: Any = ()         # GossipOptState under transport="gossip"
    fed: Any = ()            # ClientState when federated.n_clients > 0
                             # (leaves (n_clients, ...) over the dp axes)
    overlap: Any = ()        # OverlapState under transport="overlap"
                             # (leaves (W, ...): carried payload buffers)
    downlink: Any = ()       # DownlinkState under downlink="compressed"
                             # (leaves (W, ...): replicated server EF/gamma)
    velocity: Any = ()       # Nesterov buffers under kind="acgd"
                             # (per-worker leaves (W, *param_shape) f32)
    health: Any = ()         # HealthState: (W,) step-skip / quarantine
                             # counters (DESIGN.md §16) — always present
                             # for new states; () only in legacy pytrees


def _n_workers(mesh) -> int:
    return math.prod(mesh.shape[a] for a in dp_axes_of(mesh))


def init_opt_state(params: PyTree, run_cfg: RunConfig, n_workers: int,
                   abstract: bool = False,
                   stacked_mask: PyTree | None = None) -> DistOptState:
    """``stacked_mask``: the per-leaf stacked flags the worker will pass to
    ``worker_compress_aggregate`` — REQUIRED to match for
    ``transport="overlap"`` (the carried payload buffer's geometry derives
    from it; ``build_train_step`` passes ``model.stacked_mask``).  The
    default reproduces dcsgd's ``leaf.ndim >= 2`` fallback."""
    opt = run_cfg.optimizer
    ef_dt = jnp.dtype(opt.ef_dtype)

    def mem_leaf(p):
        shape = (n_workers,) + tuple(p.shape)
        if abstract:
            return jax.ShapeDtypeStruct(shape, ef_dt)
        return jnp.zeros(shape, ef_dt)

    def gossip_params_leaf(p):
        shape = (n_workers,) + tuple(p.shape)
        if abstract:
            return jax.ShapeDtypeStruct(shape, p.dtype)
        # every worker starts at the common initialization
        return jnp.broadcast_to(p[None], shape).astype(p.dtype)

    fed_on = opt.federated.enabled
    needs_mem = opt.kind in ("csgd_asss", "nonadaptive", "acgd") \
        and not fed_on
    needs_gossip = needs_mem and opt.transport == "gossip"
    needs_overlap = needs_mem and opt.transport == "overlap"
    needs_downlink = needs_mem and opt.downlink == "compressed"
    needs_vel = opt.kind == "acgd" and not fed_on
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
        (lambda s, d: jnp.zeros(s, d))

    def broadcast_w(tree):
        """(W,)-leading replication of an unbatched carried-state pytree
        (the gossip_params_leaf convention)."""
        return jax.tree.map(
            lambda x: (jax.ShapeDtypeStruct((n_workers,) + x.shape, x.dtype)
                       if abstract else
                       jnp.broadcast_to(x[None], (n_workers,) + x.shape)),
            tree)

    def flat_geometry():
        flat_p, treedef = jax.tree.flatten(params)
        flags = ([leaf.ndim >= 2 for leaf in flat_p]
                 if stacked_mask is None
                 else treedef.flatten_up_to(stacked_mask))
        return [p.shape for p in flat_p], flags

    overlap = ()
    if needs_overlap:
        shapes, flags = flat_geometry()
        overlap = broadcast_w(init_overlap_state(
            shapes, flags, opt.compressor, abstract=abstract))
    downlink = ()
    if needs_downlink:
        shapes, flags = flat_geometry()
        downlink = broadcast_w(init_downlink_state(
            shapes, flags, opt.compressor,
            opt.downlink_gamma.resolve(opt.compressor)[0],
            abstract=abstract))
    return DistOptState(
        step=mk((), jnp.int32),
        alpha_prev=(mk((n_workers,), jnp.float32) if abstract else
                    jnp.full((n_workers,), opt.armijo.alpha0, jnp.float32)),
        memory=jax.tree.map(mem_leaf, params) if needs_mem else (),
        n_evals_ema=mk((n_workers,), jnp.float32),
        gamma=(mk((n_workers,), jnp.float32) if abstract else
               jnp.full((n_workers,),
                        gamma_init(opt.gamma_controller, opt.compressor),
                        jnp.float32)),
        telemetry=CompressionTelemetry.init((n_workers,), abstract=abstract),
        cum_eff_bytes=mk((), jnp.float32),
        gossip=(GossipOptState(
            params=jax.tree.map(gossip_params_leaf, params),
            state=GossipState.init((n_workers,), abstract=abstract))
            if needs_gossip else ()),
        fed=(init_client_state(params, opt, opt.federated.n_clients,
                               abstract=abstract) if fed_on else ()),
        overlap=overlap,
        downlink=downlink,
        velocity=(jax.tree.map(
            lambda p: (jax.ShapeDtypeStruct((n_workers,) + tuple(p.shape),
                                            jnp.float32) if abstract else
                       jnp.zeros((n_workers,) + tuple(p.shape),
                                 jnp.float32)),
            params) if needs_vel else ()),
        health=HealthState.init((n_workers,), abstract=abstract),
    )


def opt_state_shardings(opt_state: DistOptState, params: PyTree, mesh,
                        run_cfg: RunConfig) -> DistOptState:
    """Shardings: leading dim over dp axes; remaining dims follow the param
    pspec (so m^(k) is model-sharded exactly like its parameter)."""
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    pspecs = param_pspecs(params)
    if not compat.PARTIAL_AUTO_SAFE:
        # 0.4.x: model-sharded state entering the manual-dp shard_map's
        # scan crashes XLA — keep trailing dims replicated (compat.py).
        pspecs = jax.tree.map(lambda _: P(), pspecs)
    mem_kind = ("pinned_host" if run_cfg.optimizer.ef_host_offload
                else None)

    def mem_sh(ps):
        return compat.named_sharding(mesh, P(dp_spec, *ps),
                                     memory_kind=mem_kind)

    rep = NamedSharding(mesh, P())
    vec = NamedSharding(mesh, P(dp_spec))
    return DistOptState(
        step=rep,
        alpha_prev=vec,
        memory=(jax.tree.map(mem_sh, pspecs)
                if opt_state.memory != () else ()),
        n_evals_ema=vec,
        gamma=vec,
        telemetry=jax.tree.map(lambda _: vec, opt_state.telemetry),
        cum_eff_bytes=rep,
        gossip=(GossipOptState(
            params=jax.tree.map(
                lambda ps: compat.named_sharding(mesh, P(dp_spec, *ps)),
                pspecs),
            state=GossipState(v=vec, lr=vec))
            if opt_state.gossip != () else ()),
        fed=(ClientState(
            memory=jax.tree.map(mem_sh, pspecs),
            gamma=vec, rounds=vec, alpha=vec)
            if opt_state.fed != () else ()),
        overlap=(jax.tree.map(lambda _: vec, opt_state.overlap)
                 if opt_state.overlap != () else ()),
        downlink=(jax.tree.map(lambda _: vec, opt_state.downlink)
                  if opt_state.downlink != () else ()),
        velocity=(jax.tree.map(
            lambda ps: compat.named_sharding(mesh, P(dp_spec, *ps)), pspecs)
            if opt_state.velocity != () else ()),
        health=jax.tree.map(lambda _: vec, opt_state.health),
    )


# ===========================================================================
# train step
# ===========================================================================

def build_train_step(model: Model, run_cfg: RunConfig, mesh):
    """Returns (train_step, in_shardings, batch_sharding).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    opt = run_cfg.optimizer
    if opt.gamma_controller.schedule == "armijo-coupled" and \
            opt.kind not in ("csgd_asss", "sls"):
        raise ValueError(
            f"gamma schedule 'armijo-coupled' needs an Armijo-searching "
            f"optimizer (csgd_asss | sls), got kind={opt.kind!r} — use "
            f"'fixed' or 'linear'")
    if opt.gamma_controller.schedule == "ef-coupled" and \
            opt.kind not in ("csgd_asss", "nonadaptive", "acgd"):
        raise ValueError(
            f"gamma schedule 'ef-coupled' needs a compressing optimizer "
            f"(csgd_asss | nonadaptive | acgd) — only those produce the "
            f"CompressionTelemetry it couples to, got kind={opt.kind!r}")
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]
    W = _n_workers(mesh)
    micro = run_cfg.microbatches

    compressing = opt.kind in ("csgd_asss", "nonadaptive", "acgd")
    acgd_mode = opt.kind == "acgd"
    # hostile-wire robustness (DESIGN.md §16).  faults×downlink,
    # faults×shard_local_topk and faults×dense are rejected by
    # OptimizerConfig.__post_init__ before we ever get here.
    faults_on = opt.faults.enabled
    breaker_on = opt.max_consecutive_skips > 0

    def wrap_faults(t_name, t_ctx, step):
        """Route the exchange through the 'faulty' wrapper transport when
        a fault campaign is configured — the wrapper corrupts the gathered
        payload rows, then runs the inner transport unchanged."""
        if not faults_on:
            return t_name, t_ctx
        return "faulty", FaultCtx(cfg=opt.faults, step=step,
                                  inner=t_name, inner_ctx=t_ctx)
    if acgd_mode and opt.local_steps > 1:
        raise ValueError(
            "kind='acgd' does not compose with local_steps > 1 — the "
            "Nesterov velocity advances once per exchange round, not per "
            "local Armijo step (use kind='csgd_asss' for local steps)")

    downlink_mode = opt.downlink == "compressed"
    if downlink_mode:
        # (gossip/overlap/federated composition is already rejected by
        # OptimizerConfig.__post_init__ — no replicated global aggregate)
        if not compressing:
            raise ValueError(
                f"downlink='compressed' re-compresses the compressed "
                f"exchange's aggregate (DESIGN.md §15); kind={opt.kind!r} "
                f"ships a dense pmean with no server to simulate — use "
                f"csgd_asss | nonadaptive | acgd")
        if opt.shard_local_topk:
            raise ValueError(
                "downlink='compressed' does not compose with "
                "shard_local_topk — the server plan is the whole-gradient "
                "bucket geometry, not a model shard's")
        if opt.local_steps > 1:
            raise ValueError(
                "downlink='compressed' does not compose with "
                "local_steps > 1 yet — the local-steps exchange applies "
                "the dense mean delta directly")

    gossip_mode = opt.transport == "gossip"
    topo = None
    if gossip_mode:
        if opt.kind not in ("csgd_asss", "nonadaptive"):
            raise ValueError(
                f"transport 'gossip' needs a compressing optimizer "
                f"(csgd_asss | nonadaptive), got kind={opt.kind!r}")
        if len(dp) != 1:
            raise ValueError(
                f"transport 'gossip' needs a single data-parallel mesh "
                f"axis (lax.ppermute is single-axis), got {dp!r} — use a "
                f"('data', 'model') mesh, not multi_pod")
        if opt.local_steps > 1:
            raise ValueError(
                "transport 'gossip' does not compose with local_steps > 1")
        if opt.shard_local_topk:
            raise ValueError(
                "transport 'gossip' does not compose with shard_local_topk")
        topo = build_topology(opt.gossip.topology, W)

    overlap_mode = opt.transport == "overlap"
    if overlap_mode:
        if opt.kind not in ("csgd_asss", "nonadaptive"):
            raise ValueError(
                f"transport 'overlap' needs a compressing optimizer "
                f"(csgd_asss | nonadaptive), got kind={opt.kind!r}")
        if opt.shard_local_topk:
            raise ValueError(
                "transport 'overlap' does not compose with "
                "shard_local_topk (the carried payload geometry is the "
                "whole-gradient bucket plan, not a model-shard's)")

    # local_steps consumes exactly one microbatch per local step — a
    # build-time contract, not a traced assert (asserts vanish under
    # `python -O` and would otherwise fail late inside tracing)
    if opt.local_steps > 1 and opt.kind in ("csgd_asss", "nonadaptive") \
            and micro != opt.local_steps:
        raise ValueError(
            f"local_steps={opt.local_steps} requires microbatches == "
            f"local_steps (got microbatches={micro}): each local Armijo "
            f"step consumes exactly one microbatch of the global batch")

    fed = opt.federated
    fed_mode = fed.enabled
    if fed_mode:
        # (transport="gossip" is already rejected by OptimizerConfig)
        if opt.kind not in ("csgd_asss", "nonadaptive"):
            raise ValueError(
                f"federated cohort simulation needs a compressing "
                f"optimizer (csgd_asss | nonadaptive), got "
                f"kind={opt.kind!r}")
        if opt.local_steps > 1:
            raise ValueError(
                "federated cohort simulation does not compose with "
                "local_steps > 1")
        if opt.shard_local_topk:
            raise ValueError(
                "federated cohort simulation does not compose with "
                "shard_local_topk")
        if micro > 1:
            raise ValueError(
                "federated cohort simulation does not compose with "
                "microbatches > 1 (each client IS a batch row group)")
        if fed.n_clients % W:
            raise ValueError(
                f"n_clients={fed.n_clients} must divide evenly over the "
                f"{W} dp workers (each worker vmaps n_clients/W clients)")
        if opt.gamma_controller.schedule not in ("fixed", "linear"):
            raise ValueError(
                f"per-client gamma controllers support the 'fixed' and "
                f"'linear' schedules (each client sees only its own "
                f"participation counter, not the coupled telemetry), got "
                f"{opt.gamma_controller.schedule!r}")

    def local_loss(params, batch):
        loss, _ = model.loss(params, batch)
        return loss

    def _local_steps_worker(params, opt_state, batch, mem, alpha_prev, ema,
                            gamma_prev, tel_prev):
        """H local Armijo-SGD steps, then ONE EF-compressed exchange of the
        accumulated model delta (paper §V future work; Qsparse-local [8])."""
        H = run_cfg.optimizer.local_steps
        # micro == H is enforced at build time (build_train_step above)
        mbs = jax.tree.map(
            lambda x: x.reshape(H, x.shape[0] // H, *x.shape[1:]), batch)

        def one(carry, mb):
            p_loc, amax, ev = carry
            loss, g = jax.value_and_grad(local_loss)(p_loc, mb)
            gsq = tree_sqnorm(g)
            res = armijo_search(lambda p: local_loss(p, mb), p_loc, g,
                                amax, opt.armijo, f0=loss, grad_sqnorm=gsq)
            eta = opt.armijo.a_scale * res.alpha
            p_loc = jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32)
                               - eta * gg.astype(jnp.float32)).astype(p.dtype),
                p_loc, g)
            return (p_loc, next_alpha_max(res.alpha, opt.armijo),
                    ev + res.n_evals.astype(jnp.float32)), (loss, res.alpha)

        amax0 = next_alpha_max(alpha_prev, opt.armijo)
        (p_end, amax_f, evals), (losses, alphas) = jax.lax.scan(
            one, (params, amax0, jnp.float32(0.0)), mbs)

        # per-round gamma from the H-step aggregate search telemetry (or
        # last round's compression telemetry for the ef-coupled schedule)
        gamma_t = gamma_update(
            opt.gamma_controller, opt.compressor, gamma_prev,
            opt_state.step,
            search=SearchTelemetry(alpha=alphas[-1], alpha_prev=alpha_prev,
                                   n_evals=evals / H, n_evals_ema=ema),
            compression=tel_prev)

        # accumulated local update (already eta-scaled) -> EF + exchange
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            params, p_end)
        smask = model.stacked_mask(params)
        if overlap_mode:
            # THE overlap seam (DESIGN.md §14): the exchange ships the
            # carried previous-segment payload, so its ring runs
            # concurrently with this segment's H local Armijo-SGD steps
            ctx = OverlapCtx(
                cfg=opt.overlap,
                state=jax.tree.map(lambda x: x[0], opt_state.overlap))
            t_name, t_ctx = wrap_faults(opt.transport, ctx, opt_state.step)
            updates, new_mem, wire, eff_wire, tel, ov_state = \
                worker_compress_aggregate(
                    delta, mem, jnp.float32(1.0), opt.compressor, dp,
                    stacked_mask=smask, gamma_t=gamma_t,
                    transport=t_name, transport_ctx=t_ctx)
            new_overlap = jax.tree.map(lambda x: x[None], ov_state)
        elif faults_on:
            t_name, t_ctx = wrap_faults(opt.transport, None, opt_state.step)
            updates, new_mem, wire, eff_wire, tel, _ = \
                worker_compress_aggregate(
                    delta, mem, jnp.float32(1.0), opt.compressor, dp,
                    stacked_mask=smask, gamma_t=gamma_t,
                    transport=t_name, transport_ctx=t_ctx)
            new_overlap = opt_state.overlap
        else:
            updates, new_mem, wire, eff_wire, tel = \
                worker_compress_aggregate(
                    delta, mem, jnp.float32(1.0), opt.compressor, dp,
                    stacked_mask=smask, gamma_t=gamma_t,
                    transport=opt.transport)
            new_overlap = opt_state.overlap
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
            params, updates)
        cum_eff = opt_state.cum_eff_bytes + jax.lax.pmean(eff_wire, dp)
        metrics = {
            "loss": jax.lax.pmean(jnp.mean(losses), dp),
            "grad_sqnorm": jnp.float32(0.0),
            "alpha": jax.lax.pmean(alphas[-1], dp),
            "n_evals": jax.lax.pmean(evals / H, dp),
            "wire_bytes": jax.lax.pmean(wire, dp),
            "effective_wire_bytes": jax.lax.pmean(eff_wire, dp),
            "cum_effective_wire_bytes": cum_eff,
            "gamma": jax.lax.pmean(gamma_t, dp),
            "ef_backlog": jax.lax.pmean(tel.ef_backlog, dp),
            "ef_cosine": jax.lax.pmean(tel.cosine, dp),
        }
        if overlap_mode:
            metrics["staleness"] = jax.lax.pmean(
                jnp.float32(opt.overlap.delay)
                * opt_state.overlap.seeded[0], dp)

        # ---- step-level circuit breaker (DESIGN.md §16) -----------------
        health = jax.tree.map(lambda x: x[0], opt_state.health)
        step_ok = jnp.isfinite(metrics["loss"]) & all_finite(updates)
        if breaker_on:
            new_params = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_params, params)
        new_health = advance_health(health, step_ok, opt_state.step,
                                    tel.rows_quarantined)
        metrics["steps_skipped"] = \
            new_health.steps_skipped.astype(jnp.float32)
        metrics["consecutive_skips"] = \
            new_health.consecutive_skips.astype(jnp.float32)
        metrics["last_good_step"] = \
            new_health.last_good_step.astype(jnp.float32)
        metrics["rows_quarantined"] = new_health.rows_quarantined

        new_state = DistOptState(
            step=opt_state.step + 1,
            alpha_prev=(amax_f / opt.armijo.omega)[None],
            memory=jax.tree.map(lambda x: x[None], new_mem),
            n_evals_ema=(0.9 * ema + 0.1 * evals / H)[None],
            gamma=gamma_t[None],
            telemetry=jax.tree.map(lambda x: x[None], tel),
            cum_eff_bytes=cum_eff,
            overlap=new_overlap,
            health=jax.tree.map(lambda x: x[None], new_health),
        )
        if breaker_on:
            frozen = new_state._replace(
                alpha_prev=opt_state.alpha_prev,
                memory=opt_state.memory,
                n_evals_ema=opt_state.n_evals_ema,
                gamma=opt_state.gamma,
                telemetry=opt_state.telemetry,
                overlap=opt_state.overlap)
            new_state = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_state, frozen)
        return new_params, new_state, metrics

    def _federated_worker(params, opt_state, batch):
        """One cohort round (DESIGN.md §13): this worker vmaps its C =
        n_clients/W clients — per-client grad, Armijo step, and gamma —
        then ONE cohort exchange aggregates the participants'
        compressed payloads support-weighted.  Non-participating
        clients' carried state (EF memory, gamma, rounds, alpha) is
        bit-frozen; their compute this round is simulation overhead the
        mask discards, exactly like a sampled-out real client."""
        C = fed.n_clients // W
        fedst = opt_state.fed                     # local leaves (C, ...)
        mask = batch["participation"]             # (n_clients,) replicated
        cbatch = {k: v for k, v in batch.items() if k != "participation"}
        pl = local_participation(mask, dp, C)     # (C,)
        n_part = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)

        def wmean(x_c):
            """Participation-weighted global mean of a per-client (C,)."""
            return jax.lax.psum(jnp.sum(pl * x_c), dp) / n_part

        # ---- per-client gradients (ONE vmap over the local cohort) ------
        losses, grads_c = jax.vmap(
            lambda mb: jax.value_and_grad(local_loss)(params, mb))(cbatch)
        gsq_c = jax.vmap(tree_sqnorm)(grads_c)
        metrics = {"loss": wmean(losses), "grad_sqnorm": wmean(gsq_c),
                   "participants": jnp.sum(mask.astype(jnp.float32))}

        # ---- per-client gamma controllers -------------------------------
        if fed.per_client_gamma:
            # each client's linear ramp advances on its OWN participation
            # counter — heterogeneous k_t across the cohort by design
            gamma_t_c = jax.vmap(
                lambda g, r: gamma_update(opt.gamma_controller,
                                          opt.compressor, g, r))(
                fedst.gamma, fedst.rounds)
        else:
            gamma_t_c = jnp.broadcast_to(
                gamma_update(opt.gamma_controller, opt.compressor,
                             fedst.gamma[0], opt_state.step), (C,))
        gamma_used = jnp.where(pl > 0, gamma_t_c, fedst.gamma)
        metrics["gamma"] = wmean(gamma_used)

        # ---- per-client step sizes --------------------------------------
        if opt.kind == "csgd_asss":
            amax_c = next_alpha_max(fedst.alpha, opt.armijo)
            res = jax.vmap(
                lambda mb, g, f0, gsq, amax: armijo_search(
                    lambda p: local_loss(p, mb), params, g, amax,
                    opt.armijo, f0=f0, grad_sqnorm=gsq))(
                cbatch, grads_c, losses, gsq_c, amax_c)
            alpha_c = res.alpha
            evals_c = res.n_evals.astype(jnp.float32)
            eta_c = jax.vmap(
                lambda g, a: opt.armijo.scale_for(g) * a)(
                gamma_used, alpha_c)
        else:
            alpha_c = jnp.full((C,), opt.eta, jnp.float32)
            evals_c = jnp.zeros((C,), jnp.float32)
            eta_c = jnp.full((C,), opt.eta, jnp.float32)
        metrics["alpha"] = wmean(alpha_c)
        metrics["n_evals"] = wmean(evals_c)

        # ---- the cohort exchange: ONE gather + ONE psum -----------------
        smask = model.stacked_mask(params)
        if faults_on:
            with active_faults(opt.faults, opt_state.step):
                updates, new_mem, wire, eff_wire, quar = \
                    cohort_compress_aggregate(
                        grads_c, fedst.memory, eta_c, opt.compressor, dp,
                        mask, gamma_used, stacked_mask=smask,
                        aggregation=fed.aggregation,
                        return_quarantined=True)
        else:
            updates, new_mem, wire, eff_wire, quar = \
                cohort_compress_aggregate(
                    grads_c, fedst.memory, eta_c, opt.compressor, dp, mask,
                    gamma_used, stacked_mask=smask,
                    aggregation=fed.aggregation, return_quarantined=True)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
            params, updates)

        # ---- step-level circuit breaker (DESIGN.md §16) -----------------
        health = jax.tree.map(lambda x: x[0], opt_state.health)
        step_ok = jnp.isfinite(metrics["loss"]) & all_finite(updates)
        if breaker_on:
            new_params = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_params, params)
        new_health = advance_health(health, step_ok, opt_state.step, quar)
        metrics["steps_skipped"] = \
            new_health.steps_skipped.astype(jnp.float32)
        metrics["consecutive_skips"] = \
            new_health.consecutive_skips.astype(jnp.float32)
        metrics["last_good_step"] = \
            new_health.last_good_step.astype(jnp.float32)
        metrics["rows_quarantined"] = new_health.rows_quarantined

        # wire/eff are cohort-global already (mask-weighted + psum'd)
        cum_eff = opt_state.cum_eff_bytes + eff_wire
        metrics["wire_bytes"] = wire
        metrics["effective_wire_bytes"] = eff_wire
        metrics["cum_effective_wire_bytes"] = cum_eff
        metrics["ef_backlog"] = jnp.float32(0.0)   # no cohort telemetry
        metrics["ef_cosine"] = jnp.float32(1.0)    # (DESIGN.md §13)

        new_state = DistOptState(
            step=opt_state.step + 1,
            alpha_prev=opt_state.alpha_prev,
            memory=(),
            n_evals_ema=opt_state.n_evals_ema,
            gamma=opt_state.gamma,
            telemetry=opt_state.telemetry,
            cum_eff_bytes=cum_eff,
            gossip=opt_state.gossip,
            overlap=opt_state.overlap,
            fed=ClientState(
                memory=new_mem,
                gamma=jnp.where(pl > 0, gamma_t_c, fedst.gamma),
                rounds=fedst.rounds + (pl > 0).astype(jnp.int32),
                alpha=jnp.where(pl > 0, alpha_c, fedst.alpha)),
            health=jax.tree.map(lambda x: x[None], new_health),
        )
        if breaker_on:
            frozen = new_state._replace(fed=opt_state.fed)
            new_state = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_state, frozen)
        return new_params, new_state, metrics

    def worker_fn(params, opt_state, batch):
        if fed_mode:
            return _federated_worker(params, opt_state, batch)
        # squeeze the per-worker leading axis of the optimizer state
        mem = jax.tree.map(lambda x: x[0], opt_state.memory) \
            if opt_state.memory != () else ()
        alpha_prev = opt_state.alpha_prev[0]
        ema = opt_state.n_evals_ema[0]
        gamma_prev = opt_state.gamma[0]
        tel_prev = jax.tree.map(lambda x: x[0], opt_state.telemetry)

        # serverless mode: the replicated ``params`` input is only the
        # common initialization — this worker optimizes ITS model copy
        # from DistOptState.gossip (workers genuinely diverge; the
        # topology's mixing contracts the disagreement each round)
        base_params = params
        if gossip_mode:
            params = jax.tree.map(lambda x: x[0], opt_state.gossip.params)

        # ---- local iterations (Qsparse-local-style, beyond-paper) -------
        if run_cfg.optimizer.local_steps > 1 and \
                opt.kind in ("csgd_asss", "nonadaptive"):
            return _local_steps_worker(params, opt_state, batch, mem,
                                       alpha_prev, ema, gamma_prev,
                                       tel_prev)

        # ---- gradient over microbatches (accumulated) -------------------
        if micro > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(micro, x.shape[0] // micro, *x.shape[1:]),
                batch)
            probe = jax.tree.map(lambda x: x[0], mbs)

            def acc(carry, mb):
                lo, g = jax.value_and_grad(local_loss)(params, mb)
                cl, cg = carry
                return (cl + lo, jax.tree.map(jnp.add, cg, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc, (jnp.float32(0.0), zero_g), mbs)
            loss = loss_sum / micro
            grads = jax.tree.map(lambda g: g / micro, grads)
        else:
            probe = batch
            loss, grads = jax.value_and_grad(local_loss)(params, batch)

        gsq = tree_sqnorm(grads)
        metrics = {"loss": jax.lax.pmean(loss, dp),
                   "grad_sqnorm": jax.lax.pmean(gsq, dp)}

        # ---- step size --------------------------------------------------
        if opt.kind in ("csgd_asss", "sls"):
            amax = next_alpha_max(alpha_prev, opt.armijo)
            res = armijo_search(lambda p: local_loss(p, probe), params,
                                grads, amax, opt.armijo,
                                grad_sqnorm=gsq)
            new_alpha = res.alpha
            new_ema = 0.9 * ema + 0.1 * res.n_evals.astype(jnp.float32)
            metrics["alpha"] = jax.lax.pmean(res.alpha, dp)
            metrics["n_evals"] = jax.lax.pmean(
                res.n_evals.astype(jnp.float32), dp)
        else:
            res = None
            new_alpha = alpha_prev
            new_ema = ema
            metrics["alpha"] = jnp.float32(opt.eta)
            metrics["n_evals"] = jnp.float32(0.0)

        # ---- per-round compression level (gamma controller round) -------
        search_tel = SearchTelemetry(
            alpha=res.alpha, alpha_prev=alpha_prev, n_evals=res.n_evals,
            n_evals_ema=ema) if res is not None else None
        gamma_t = gamma_update(opt.gamma_controller, opt.compressor,
                               gamma_prev, opt_state.step,
                               search=search_tel, compression=tel_prev)
        metrics["gamma"] = jax.lax.pmean(gamma_t, dp)

        if res is not None:
            # a = scale_for(gamma_t): paper's a_scale, re-clamped to
            # zeta(gamma_t) each round under armijo.theory_safe
            eta = opt.armijo.scale_for(gamma_t) * res.alpha
        else:
            eta = jnp.float32(opt.eta)

        # ---- aggregate (compressed or dense) ----------------------------
        if compressing:
            smask = model.stacked_mask(params)
            if acgd_mode:
                # Nesterov round (arXiv 2002.11364 composed with EF —
                # core/acgd.py): the exchange ships the lookahead descent
                # direction mu*v' + g instead of the raw gradient
                vel = jax.tree.map(
                    lambda v, g: opt.momentum * v + g.astype(jnp.float32),
                    jax.tree.map(lambda x: x[0], opt_state.velocity),
                    grads)
                send = jax.tree.map(
                    lambda v, g: opt.momentum * v + g.astype(jnp.float32),
                    vel, grads)
                new_velocity = jax.tree.map(lambda x: x[None], vel)
            else:
                send = grads
                new_velocity = opt_state.velocity
            dl_res = None
            if opt.shard_local_topk and compat.PARTIAL_AUTO_SAFE:
                # per-(layer, model-shard) top_k: nested manual-'model'
                # region so selection runs on the local gradient shard and
                # the only collective stays the small dp packed all-gather.
                pspecs = param_pspecs(params)
                # telemetry_axes: the model shards are ONE worker, so the
                # telemetry sums psum over 'model' before the ratios form
                # (the P() out_spec asserts them replicated; wire/eff are
                # shape-derived and replicated without it)
                inner = compat.shard_map(
                    lambda g, m2, e, gt: worker_compress_aggregate(
                        g, m2, e, opt.compressor, dp, stacked_mask=smask,
                        gamma_t=gt, telemetry_axes=("model",),
                        transport=opt.transport),
                    mesh=None,  # nested: resolve from the trace context
                    in_specs=(pspecs, pspecs, P(), P()),
                    out_specs=(pspecs, pspecs, P(), P(), P()),
                    axis_names={"model"}, check_vma=False)
                updates, new_mem, wire, eff_wire, tel = inner(send, mem,
                                                              eta, gamma_t)
            elif gossip_mode:
                ctx = GossipCtx(
                    topology=topo, cfg=opt.gossip,
                    state=jax.tree.map(lambda x: x[0],
                                       opt_state.gossip.state))
                t_name, t_ctx = wrap_faults(opt.transport, ctx,
                                            opt_state.step)
                updates, new_mem, wire, eff_wire, tel, gos_state = \
                    worker_compress_aggregate(
                        send, mem, eta, opt.compressor, dp,
                        stacked_mask=smask, gamma_t=gamma_t,
                        transport=t_name, transport_ctx=t_ctx)
            elif overlap_mode:
                ctx = OverlapCtx(
                    cfg=opt.overlap,
                    state=jax.tree.map(lambda x: x[0], opt_state.overlap))
                t_name, t_ctx = wrap_faults(opt.transport, ctx,
                                            opt_state.step)
                updates, new_mem, wire, eff_wire, tel, ov_state = \
                    worker_compress_aggregate(
                        send, mem, eta, opt.compressor, dp,
                        stacked_mask=smask, gamma_t=gamma_t,
                        transport=t_name, transport_ctx=t_ctx)
            elif downlink_mode:
                # server round (DESIGN.md §15): advance the downlink gamma
                # schedule, then re-compress the replicated aggregate
                # through the server-side EF — same collectives, the dense
                # return direction becomes packed payload rows
                dl_prev = jax.tree.map(lambda x: x[0], opt_state.downlink)
                dl_gamma = gamma_update(opt.downlink_gamma, opt.compressor,
                                        dl_prev.gamma, opt_state.step)
                ctx = DownlinkCtx(state=DownlinkState(
                    memory=dl_prev.memory, gamma=dl_gamma))
                updates, new_mem, wire, eff_wire, tel, dl_res = \
                    worker_compress_aggregate(
                        send, mem, eta, opt.compressor, dp,
                        stacked_mask=smask, gamma_t=gamma_t,
                        transport=opt.transport, downlink_ctx=ctx)
            elif faults_on:
                # stateless inner (perleaf | bucketed) wrapped by the
                # stateful 'faulty' transport: the SIXTH element is the
                # wrapper's carried state, always () for a stateless inner
                t_name, t_ctx = wrap_faults(opt.transport, None,
                                            opt_state.step)
                updates, new_mem, wire, eff_wire, tel, _ = \
                    worker_compress_aggregate(
                        send, mem, eta, opt.compressor, dp,
                        stacked_mask=smask, gamma_t=gamma_t,
                        transport=t_name, transport_ctx=t_ctx)
            else:
                # covers shard_local_topk on 0.4.x too: there the training
                # body is already manual over 'model' (compat.
                # PARTIAL_AUTO_SAFE) with the model axis replicated, so
                # grads ARE the per-shard local view — re-nesting a
                # manual-'model' shard_map around it SIGFPEs 0.4.x XLA
                # (tests/distributed/test_shard_local_topk.py) and
                # shard-local selection degenerates to the direct call.
                updates, new_mem, wire, eff_wire, tel = \
                    worker_compress_aggregate(
                        send, mem, eta, opt.compressor, dp,
                        stacked_mask=smask, gamma_t=gamma_t,
                        transport=opt.transport)
            new_mem = jax.tree.map(lambda x: x[None], new_mem)
        else:
            updates, wire = dense_aggregate(grads, eta, dp)
            eff_wire = wire
            new_mem = opt_state.memory
            new_velocity = opt_state.velocity
            dl_res = None
            tel = tel_prev              # no compression: health unchanged
        cum_eff = opt_state.cum_eff_bytes + jax.lax.pmean(eff_wire, dp)
        metrics["wire_bytes"] = jax.lax.pmean(wire, dp)
        metrics["effective_wire_bytes"] = jax.lax.pmean(eff_wire, dp)
        if dl_res is not None:
            # replicated by construction (every worker simulates the same
            # server); pmean keeps the metric convention uniform.  The
            # uplink counters above stay uplink-only — these keys carry
            # the return direction, and cum_eff prices both.
            metrics["downlink_wire_bytes"] = jax.lax.pmean(
                dl_res.wire_bytes, dp)
            metrics["downlink_effective_wire_bytes"] = jax.lax.pmean(
                dl_res.eff_wire_bytes, dp)
            cum_eff = cum_eff + jax.lax.pmean(dl_res.eff_wire_bytes, dp)
            new_downlink = jax.tree.map(lambda x: x[None], dl_res.state)
        else:
            new_downlink = opt_state.downlink
        metrics["cum_effective_wire_bytes"] = cum_eff
        metrics["ef_backlog"] = jax.lax.pmean(tel.ef_backlog, dp)
        metrics["ef_cosine"] = jax.lax.pmean(tel.cosine, dp)

        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - u).astype(p.dtype),
            params, updates)

        # ---- step-level circuit breaker (DESIGN.md §16) -----------------
        health = jax.tree.map(lambda x: x[0], opt_state.health)
        step_ok = jnp.isfinite(metrics["loss"])
        if not gossip_mode:
            # the decoded aggregate is replicated (every worker decodes
            # the same gathered payload), so the update check adds no
            # collective; under gossip updates are per-worker by design
            # and the breaker couples through the pmean'd loss alone — a
            # NaN anywhere poisons the mean within one round
            step_ok &= all_finite(updates)
        if breaker_on:
            new_params = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_params, params)
        quar_round = tel.rows_quarantined if compressing \
            else jnp.float32(0.0)
        new_health = advance_health(health, step_ok, opt_state.step,
                                    quar_round)
        metrics["steps_skipped"] = \
            new_health.steps_skipped.astype(jnp.float32)
        metrics["consecutive_skips"] = \
            new_health.consecutive_skips.astype(jnp.float32)
        metrics["last_good_step"] = \
            new_health.last_good_step.astype(jnp.float32)
        quar_metric = new_health.rows_quarantined
        if gossip_mode:
            # per-worker under gossip (each worker verdicts its own
            # neighbor gather) — pmean'd for the replicated metric slot
            quar_metric = jax.lax.pmean(quar_metric, dp)
        metrics["rows_quarantined"] = quar_metric

        if gossip_mode:
            # the per-worker model advances in DistOptState.gossip; the
            # replicated params output stays the frozen initialization
            # (its out_spec asserts replication — diverged values there
            # would be undefined behavior)
            new_gossip = GossipOptState(
                params=jax.tree.map(lambda x: x[None], new_params),
                state=jax.tree.map(lambda x: x[None], gos_state))
            new_params = base_params
        else:
            new_gossip = opt_state.gossip
        if overlap_mode:
            new_overlap = jax.tree.map(lambda x: x[None], ov_state)
            # 1.0 once the carried payload is a real previous step (delay=1
            # applies a one-step-stale aggregate); 0.0 on the warmup step
            # and always under delay=0 (DESIGN.md §14)
            metrics["staleness"] = jax.lax.pmean(
                jnp.float32(opt.overlap.delay)
                * opt_state.overlap.seeded[0], dp)
        else:
            new_overlap = opt_state.overlap
        new_state = DistOptState(
            step=opt_state.step + 1,
            alpha_prev=new_alpha[None],
            memory=new_mem,
            n_evals_ema=new_ema[None],
            gamma=gamma_t[None],
            telemetry=jax.tree.map(lambda x: x[None], tel),
            cum_eff_bytes=cum_eff,
            gossip=new_gossip,
            overlap=new_overlap,
            downlink=new_downlink,
            velocity=new_velocity,
            health=jax.tree.map(lambda x: x[None], new_health),
        )
        if breaker_on:
            # skip-step: step/cum_eff/health advance; every carried
            # optimizer quantity freezes bit-exactly (jnp.where with a
            # replicated scalar predicate — zero collectives, and the
            # taken branch is bit-identical to the unconditional write)
            frozen = new_state._replace(
                alpha_prev=opt_state.alpha_prev,
                memory=opt_state.memory,
                n_evals_ema=opt_state.n_evals_ema,
                gamma=opt_state.gamma,
                telemetry=opt_state.telemetry,
                gossip=opt_state.gossip,
                overlap=opt_state.overlap,
                downlink=opt_state.downlink,
                velocity=opt_state.velocity)
            new_state = jax.tree.map(
                lambda a, b: jnp.where(step_ok, a, b), new_state, frozen)
        return new_params, new_state, metrics

    # ---- specs ------------------------------------------------------------
    lead = P(dp_spec)
    rep = P()

    def batch_spec_of(batch_tree):
        # the cohort participation mask is a global (n_clients,) row every
        # worker reads (each slices its own C clients) — replicated, not
        # batch-sharded like the data leaves
        return {k: (rep if k == "participation" else P(dp_spec))
                for k in batch_tree} if isinstance(batch_tree, dict) else \
            jax.tree.map(lambda _: P(dp_spec), batch_tree)

    def make(params_like, batch_like):
        tel_spec = jax.tree.map(lambda _: lead,
                                CompressionTelemetry.init(abstract=True))
        state_in = DistOptState(
            step=rep, alpha_prev=lead,
            memory=(jax.tree.map(lambda _: lead, params_like)
                    if compressing and not fed_mode else ()),
            n_evals_ema=lead, gamma=lead,
            telemetry=tel_spec, cum_eff_bytes=rep,
            gossip=(GossipOptState(
                params=jax.tree.map(lambda _: lead, params_like),
                state=GossipState(v=lead, lr=lead))
                if gossip_mode else ()),
            fed=(ClientState(
                memory=jax.tree.map(lambda _: lead, params_like),
                gamma=lead, rounds=lead, alpha=lead)
                if fed_mode else ()),
            overlap=(OverlapState(
                payload=lead, dense=lead, eff_wire=lead, seeded=lead)
                if overlap_mode else ()),
            downlink=(DownlinkState(memory=lead, gamma=lead)
                      if downlink_mode and not fed_mode else ()),
            velocity=(jax.tree.map(lambda _: lead, params_like)
                      if acgd_mode and not fed_mode else ()),
            health=HealthState(steps_skipped=lead, consecutive_skips=lead,
                               last_good_step=lead, rows_quarantined=lead))
        metric_keys = ("loss", "grad_sqnorm", "alpha", "n_evals",
                       "wire_bytes", "effective_wire_bytes",
                       "cum_effective_wire_bytes", "ef_backlog",
                       "ef_cosine", "gamma",
                       "steps_skipped", "consecutive_skips",
                       "last_good_step", "rows_quarantined") + \
            (("participants",) if fed_mode else ()) + \
            (("staleness",) if overlap_mode else ()) + \
            (("downlink_wire_bytes", "downlink_effective_wire_bytes")
             if downlink_mode and not fed_mode else ())
        metrics_spec = {k: rep for k in metric_keys}
        # Manual over dp, auto over 'model' (XLA partitions the TP math).
        # On 0.4.x partial-auto shard_map cannot contain a lax.scan
        # (compat.PARTIAL_AUTO_SAFE), so there the body is manual over
        # EVERY axis and the model axis simply replicates the worker math.
        manual = set(dp) if compat.PARTIAL_AUTO_SAFE \
            else set(mesh.axis_names)
        sm = compat.shard_map(
            worker_fn, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: rep, params_like),
                      state_in, batch_spec_of(batch_like)),
            out_specs=(jax.tree.map(lambda _: rep, params_like),
                       state_in, metrics_spec),
            axis_names=manual, check_vma=False)
        # outer jit: model-axis shardings (replicated on 0.4.x — see
        # compat.PARTIAL_AUTO_SAFE)
        pspecs = param_pspecs(params_like)
        if not compat.PARTIAL_AUTO_SAFE:
            pspecs = jax.tree.map(lambda _: P(), pspecs)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        opt_sh = opt_state_shardings(
            init_opt_state(params_like, run_cfg, W, abstract=True,
                           stacked_mask=model.stacked_mask(params_like)),
            params_like, mesh, run_cfg)
        bsh = jax.tree.map(
            lambda s: NamedSharding(mesh, s), batch_spec_of(batch_like),
            is_leaf=lambda x: isinstance(x, P))
        msh = {k: NamedSharding(mesh, P()) for k in metric_keys}
        # donation of pinned_host-backed state trips an XLA SPMD RET_CHECK
        # (side-effecting copy-to-host without sharding); skip it there.
        donate = () if opt.ef_host_offload else (0, 1)
        return jax.jit(sm,
                       in_shardings=(psh, opt_sh, bsh),
                       out_shardings=(psh, opt_sh, msh),
                       donate_argnums=donate)

    return make


# ===========================================================================
# serve steps
# ===========================================================================

def build_prefill_step(model: Model, run_cfg: RunConfig, mesh,
                       shape: ShapeConfig, params_2d: bool = False):
    """Batched prefill under auto pjit: batch over dp, TP from hints.

    ``params_2d``: weights additionally sharded over the data axis (serving
    memory optimization — see sharding.param_pspecs)."""
    dp = dp_axes_of(mesh)
    dp_spec = dp if len(dp) > 1 else dp[0]

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch)
        return logits, cache

    def make(params_like, batch_like):
        pspecs = param_pspecs(params_like, two_d=params_2d)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        bsh = jax.tree.map(lambda _: NamedSharding(mesh, P(dp_spec)),
                           batch_like)
        return jax.jit(prefill_step, in_shardings=(psh, bsh))
    return make


def decode_seq_axes(mesh, shape: ShapeConfig) -> tuple[str, ...]:
    """Cache-seq sharding axes: 'model' normally; every axis for batch=1."""
    if shape.global_batch == 1:
        return tuple(mesh.axis_names)
    return ("model",)


def build_decode_step(model: Model, run_cfg: RunConfig, mesh,
                      shape: ShapeConfig, params_2d: bool = False):
    """One-token serve_step: new token against a seq_len KV cache."""
    dp = dp_axes_of(mesh)
    seq_axes = decode_seq_axes(mesh, shape)

    def serve_step(params, token, cache, cur_len):
        logits, cache = model.decode_step(params, token, cache, cur_len)
        return logits, cache

    def make(params_like, token_like, cache_like):
        pspecs = param_pspecs(params_like, two_d=params_2d)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        dp_spec = dp if len(dp) > 1 else dp[0]
        tok_sh = NamedSharding(
            mesh, P(dp_spec) if shape.global_batch > 1 else P())
        cspecs = cache_pspecs(cache_like,
                              dp if shape.global_batch > 1 else (), seq_axes)
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                           is_leaf=lambda x: isinstance(x, P))
        return jax.jit(serve_step,
                       in_shardings=(psh, tok_sh, csh, NamedSharding(mesh, P())),
                       out_shardings=None)
    return make
