"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = wire_bytes_per_chip / ICI_BW

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per chip and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline results/dryrun.json \
        [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_CONFIGS, SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def active_params(cfg) -> int:
    """Active (per-token) parameters: MoE counts top-k experts only."""
    n = cfg.n_params()
    if cfg.family == "moe":
        inactive = cfg.n_layers * (cfg.n_experts - cfg.experts_per_token) \
            * 3 * cfg.d_model * cfg.moe_d_ff
        n -= inactive
    return n


def model_flops(cfg, shape, n_chips: int) -> float:
    """6*N_active*D per chip (train); fwd-only shapes use 2*N*D."""
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "decode":
        tokens = shape.global_batch          # one token per sequence
    n = active_params(cfg)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens / n_chips


def analyze(rec: dict) -> dict | None:
    """Three roofline terms from the trip-count-aware HLO analysis:

    * compute    = HLO matmul FLOPs per chip / peak bf16
    * memory     = HLO buffer-traffic bytes per chip / HBM bandwidth
      (2x non-fused instruction results; fusion internals never hit HBM)
    * collective = parsed wire bytes per chip / ICI link bandwidth

    Plus MODEL_FLOPS = 6*N_active*D and the useful-compute ratio.  The
    'fits' column is per-chip *persistent* state (compiled argument bytes:
    params + optimizer/EF memory + caches); transient temp bytes come from
    the CPU backend's buffer assignment and are reported separately (the
    TPU compiler re-schedules them under the 16 GB ceiling).
    """
    if rec.get("status") != "ok":
        return None
    cfg = ARCH_CONFIGS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    n_chips = rec.get("n_chips", 256)
    t_compute = rec["flops_per_chip"] / PEAK_FLOPS_BF16
    t_memory = rec["bytes_per_chip"] / HBM_BW
    wire = rec.get("collectives", {}).get("total_wire_bytes", 0.0)
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_chips)
    bound = max(terms.values())
    arg_gb = rec["memory"]["argument_bytes"] / 2**30
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh")},
        "opt": rec.get("opt", "-"),
        "variant": rec.get("variant", ""),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_ratio": (mf / rec["flops_per_chip"]
                         if rec["flops_per_chip"] else 0.0),
        "roofline_step_s": bound,
        "mfu_upper_bound": (mf / PEAK_FLOPS_BF16) / bound if bound else 0.0,
        "hbm_gb": arg_gb,
        "temp_gb": rec["memory"]["temp_bytes"] / 2**30,
        "fits_v5e_16gb": arg_gb < 16.0,
    }


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | opt | compute s | memory s | coll s | "
           "dominant | useful | MFU-UB | HBM GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['opt']} "
                 f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
                 f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
                 f"| {r['useful_ratio']:.2f} | {r['mfu_upper_bound']:.2f} "
                 f"| {r['hbm_gb']:.1f} | {'y' if r['fits_v5e_16gb'] else 'N'} |\n")
    return hdr + body


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="+")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", dest="json_out", default=None)
    args = ap.parse_args()

    rows = []
    for path in args.records:
        with open(path) as f:
            for rec in json.load(f):
                row = analyze(rec)
                if row:
                    rows.append(row)
                elif rec.get("status") not in ("skipped",):
                    print(f"!! {rec.get('arch')} {rec.get('shape')}: "
                          f"{rec.get('status')} {rec.get('error', '')[:120]}")
    md = to_markdown(rows)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
