import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline raw terms.

MUST be run as its own process (the XLA flag above must precede any jax
import — which is why it is the very first statement of the module).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun.json

Outputs one JSON record per combination: compile ok, per-device HLO FLOPs /
bytes (cost_analysis), memory stats, and per-collective wire bytes parsed
from the partitioned HLO.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax

from repro.compat import set_mesh
import jax.numpy as jnp

from repro.comm.faults import FaultConfig
from repro.comm.gossip import GossipConfig
from repro.comm.overlap import OverlapConfig
from repro.comm.topology import TOPOLOGIES
from repro.comm.transport import transport_names
from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.configs.base import (FederatedConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.armijo import ArmijoConfig
from repro.core.compression import Compressor
from repro.core.gamma import GammaControllerConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.train_step import (build_decode_step, build_prefill_step,
                                     build_train_step, init_opt_state)
from repro.models import build_model

# ---------------------------------------------------------------------------
# HLO analysis — computation-structured and TRIP-COUNT AWARE.
#
# XLA's compiled cost_analysis() counts while-loop bodies ONCE (verified
# empirically: a 10-layer scan reports 1 layer of FLOPs), so naive parsing
# undercounts anything inside the layer scan by ~n_layers.  We therefore
# walk the HLO computation graph: per computation we account matmul FLOPs
# (from dot shapes), buffer traffic (2x non-fused instruction result bytes —
# fusion internals never hit HBM) and collective wire bytes; `while` ops
# multiply their body's totals by the trip count recovered from the loop
# condition's s32 constant.  Scan trip counts are exact; the Armijo search
# loop is data-dependent, so the dry-run pins its iteration cap to the
# *expected* evaluation count (~2 per the paper §IV-B and our measured
# 1.7-1.9) — see make_run_config.
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
                "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|\S+)\s+([a-z][\w\-]*)\(")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_ARGS_RE = re.compile(r"dot\(%?([\w.\-]+),")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_SKIP_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "constant",
                   "bitcast", "copy", "after-all", "partition-id",
                   "replica-id", "iota", "broadcast"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _coll_wire(kind: str, nbytes: int, n: int) -> float:
    frac = (n - 1) / max(n, 1)
    if kind == "all-reduce":
        return 2 * nbytes * frac
    if kind == "collective-permute":
        return float(nbytes)
    if kind == "reduce-scatter":
        return float(nbytes * (n - 1))   # result is 1/n of the input
    return nbytes * frac                 # all-gather, all-to-all


def parse_hlo(hlo_text: str, *, ring_schedule: bool = False) -> dict:
    """Trip-count-aware per-chip totals: matmul FLOPs, buffer-traffic bytes,
    collective wire bytes (per kind) — all from the partitioned HLO.

    ``ring_schedule``: the permute ops form a send-right ring (the overlap
    transport, DESIGN.md §14) rather than a neighbor-fanout graph (gossip):
    every one of the ``n_chunks * (W-1)`` hops traverses the SAME physical
    i -> i+1 link (per hop: payload/W of the gathered total, over W-1
    steps), so the per-link figure keeps the FULL permute total instead of
    dividing by the permute count."""
    # ---- split into computations -----------------------------------------
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.rstrip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- instruction result types (for dot operand shapes) ---------------
    types: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                types[m.group(1)] = m.group(2)

    # ---- per-computation local stats --------------------------------------
    local = {}
    for name, lines in comps.items():
        flops = 0.0
        bytes_ = 0.0
        wire: dict[str, float] = {}
        ccount: dict[str, int] = {}
        whiles: list[tuple[str, str]] = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            res_name, res_type, op = m.group(1), m.group(2), m.group(3)
            if op == "while":
                w = _WHILE_RE.search(line)
                if w:
                    whiles.append((w.group(1), w.group(2)))
            cm = _COLL_RE.search(line)
            if cm:
                kind = cm.group(2)
                nb = _tensor_bytes(cm.group(1))
                g = _GROUP_RE.search(line)
                if g:
                    n = len(g.group(1).split(","))
                else:
                    gi = _GROUP_IOTA_RE.search(line)
                    n = int(gi.group(2)) if gi else 2
                wire[kind] = wire.get(kind, 0.0) + _coll_wire(kind, nb, n)
                ccount[kind] = ccount.get(kind, 0) + 1
            if op == "dot":
                dims = _shape_dims(res_type)
                res_n = 1
                for _, ds in dims:
                    for d in ds:
                        res_n *= d
                contract = 1
                a = _DOT_ARGS_RE.search(line)
                c = _DOT_DIMS_RE.search(line)
                if a and c and a.group(1) in types:
                    lhs_dims = _shape_dims(types[a.group(1)])
                    if lhs_dims:
                        ds = lhs_dims[0][1]
                        for ci in (int(x) for x in c.group(1).split(",") if x):
                            if ci < len(ds):
                                contract *= ds[ci]
                flops += 2.0 * res_n * contract
            if op not in _SKIP_BYTES_OPS:
                bytes_ += 2.0 * _tensor_bytes(res_type)
        local[name] = dict(flops=flops, bytes=bytes_, wire=wire,
                           counts=ccount, whiles=whiles)

    # ---- trip counts from loop conditions ---------------------------------
    def trip(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        best = 1
        for line in lines:
            m = _CONST_RE.search(line)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # ---- recursive totals --------------------------------------------------
    memo: dict[str, dict] = {}

    def total(name: str) -> dict:
        if name in memo:
            return memo[name]
        base = local.get(name, dict(flops=0, bytes=0, wire={}, counts={},
                                    whiles=[]))
        agg = dict(flops=float(base["flops"]), bytes=float(base["bytes"]),
                   wire=dict(base["wire"]), counts=dict(base["counts"]))
        memo[name] = agg   # break cycles defensively
        for cond, body in base["whiles"]:
            t = trip(cond)
            sub = total(body)
            agg["flops"] += t * sub["flops"]
            agg["bytes"] += t * sub["bytes"]
            for k, v in sub["wire"].items():
                agg["wire"][k] = agg["wire"].get(k, 0.0) + t * v
            for k, v in sub["counts"].items():
                agg["counts"][k] = agg["counts"].get(k, 0) + t * v
        return agg

    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else ""
    agg = total(entry)
    out = dict(agg["wire"])
    out["total_wire_bytes"] = sum(agg["wire"].values())
    out["counts"] = agg["counts"]
    # per-LINK bytes: collective-permute totals count every neighbor
    # direction (the gossip transport issues ``degree`` of them per
    # exchange), so the per-step figure comparable across transports
    # divides the permute total by the permute count — one link's
    # payload — while the star-shaped collectives pass through unchanged.
    # The ring schedule is the exception: its permutes all share one
    # physical link, so the full total IS the per-link figure.
    perm = out.get("collective-permute", 0.0)
    n_perm = agg["counts"].get("collective-permute", 0)
    per_link_perm = perm if ring_schedule else \
        (perm / n_perm if n_perm else 0.0)
    out["wire_bytes_per_link"] = (out["total_wire_bytes"] - perm) \
        + per_link_perm
    return {
        "collectives": out,
        "hlo_matmul_flops": agg["flops"],
        "hlo_traffic_bytes": agg["bytes"],
    }


# ---------------------------------------------------------------------------
# per-combination lowering
# ---------------------------------------------------------------------------

def make_run_config(cfg, shape, opt_kind="csgd_asss", gamma=0.01,
                    microbatches=None, ef_host_offload=False,
                    ef_dtype="float32", shard_local_topk=False,
                    local_steps=1, transport="bucketed", topology="ring",
                    n_clients=0, aggregation="support",
                    overlap_chunks=1, overlap_delay=1,
                    downlink="dense", downlink_gamma=0.0, faults=None):
    if microbatches is None:
        microbatches = 4 if shape.kind == "train" else 1
    if n_clients:
        microbatches = 1   # each client IS a batch row group
    # max_backtracks=2 pins the Armijo while loop's HLO trip-count constant
    # to the paper's expected ~2 condition evaluations per step (we measure
    # 1.7-1.9 on real runs), so the trip-count-aware roofline charges the
    # search its EXPECTED cost.  Execution semantics on TPU are unchanged
    # apart from the iteration cap (dynamic early exit still applies).
    return RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(
            kind=opt_kind, armijo=ArmijoConfig(max_backtracks=2),
            compressor=Compressor(gamma=gamma),
            ef_host_offload=ef_host_offload, ef_dtype=ef_dtype,
            shard_local_topk=shard_local_topk, local_steps=local_steps,
            transport=transport,
            gossip=GossipConfig(topology=topology),
            overlap=OverlapConfig(n_chunks=overlap_chunks,
                                  delay=overlap_delay),
            federated=FederatedConfig(n_clients=n_clients,
                                      aggregation=aggregation),
            downlink=downlink,
            downlink_gamma=GammaControllerConfig(gamma0=downlink_gamma),
            faults=faults if faults is not None else FaultConfig()),
        microbatches=microbatches)


def federate_input_specs(batch_like, n_clients: int):
    """Reshape abstract batch specs to the cohort layout: every data leaf
    (B, ...) -> (n_clients, B/n_clients, ...) + the participation row."""
    out = {}
    for k, v in batch_like.items():
        assert v.shape[0] % n_clients == 0, \
            f"batch dim {v.shape[0]} must divide across {n_clients} clients"
        out[k] = jax.ShapeDtypeStruct(
            (n_clients, v.shape[0] // n_clients) + tuple(v.shape[1:]),
            v.dtype)
    out["participation"] = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    return out


def adapt_for_shape(cfg, shape: ShapeConfig):
    """long_500k on pure full-attention archs -> sliding-window variant
    (DESIGN.md §5); returns (cfg, variant_note)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        if not cfg.swa_for_long_context:
            return None, "skipped (full attention, no SWA variant)"
        return dataclasses.replace(
            cfg, sliding_window=cfg.long_context_window), \
            f"sliding_window={cfg.long_context_window}"
    if shape.name == "long_500k" and cfg.family in ("hybrid", "encdec"):
        # hybrid/encdec attention sub-blocks also get the window at 500k
        return dataclasses.replace(
            cfg, sliding_window=cfg.long_context_window), \
            f"attn blocks windowed @{cfg.long_context_window}"
    return cfg, ""


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              opt_kind: str = "csgd_asss", gamma: float = 0.01,
              microbatches: int | None = None, ef_host_offload: bool = False,
              ef_dtype: str = "float32", shard_local_topk: bool = False,
              seq_parallel: bool = False, params_2d: bool = False,
              moe_ep: bool = False, capacity_factor: float = None,
              kv_int8: bool = False, local_steps: int = 1,
              transport: str = "bucketed", topology: str = "ring",
              n_clients: int = 0, aggregation: str = "support",
              overlap_chunks: int = 1, overlap_delay: int = 1,
              downlink: str = "dense", downlink_gamma: float = 0.0,
              faults=None, keep_hlo: bool = False) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "opt": opt_kind if shape_name == "train_4k" else "-",
           "gamma": gamma,
           "flags": {"shard_local_topk": shard_local_topk,
                     "params_2d": params_2d,
                     "moe_ep": moe_ep,
                     "ef_dtype": ef_dtype,
                     "ef_host_offload": ef_host_offload,
                     "seq_parallel": seq_parallel,
                     "microbatches": microbatches,
                     "transport": transport,
                     "topology": topology,
                     "overlap_chunks": overlap_chunks,
                     "overlap_delay": overlap_delay,
                     "downlink": downlink}}
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    cfg, note = adapt_for_shape(cfg0, shape)
    rec["variant"] = note
    if cfg is None:
        rec["status"] = "skipped"
        return rec

    if seq_parallel:
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if moe_ep:
        cfg = dataclasses.replace(cfg, moe_expert_parallel=True)
    if capacity_factor is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    run = make_run_config(cfg, shape, opt_kind, gamma, microbatches,
                          ef_host_offload, ef_dtype, shard_local_topk,
                          local_steps, transport, topology,
                          n_clients, aggregation,
                          overlap_chunks, overlap_delay,
                          downlink, downlink_gamma, faults)
    n_chips = mesh.size

    with set_mesh(mesh):
        key_like = jax.ShapeDtypeStruct((2,), jnp.uint32)
        params_like = jax.eval_shape(model.init, key_like)
        rec["n_params"] = int(sum(x.size for x in jax.tree.leaves(params_like)))

        if shape.kind == "train":
            from repro.sharding import dp_axes_of
            import math as _m
            W = _m.prod(mesh.shape[a] for a in dp_axes_of(mesh))
            batch_like = model.input_specs(shape)
            if n_clients:
                batch_like = federate_input_specs(batch_like, n_clients)
            opt_like = init_opt_state(
                params_like, run, W, abstract=True,
                stacked_mask=model.stacked_mask(params_like))
            step = build_train_step(model, run, mesh)(params_like, batch_like)
            lowered = step.lower(params_like, opt_like, batch_like)
            if opt_kind in ("csgd_asss", "nonadaptive", "acgd"):
                # per-direction split (DESIGN.md §15): the collectives
                # parsed from HLO below carry only the UPLINK — the
                # downlink is physically simulated (replicated compute,
                # no collective), so its per-link bytes are accounted
                # from the same static plan the server uses
                from repro.comm.downlink import (dense_downlink_bytes,
                                                 downlink_plan,
                                                 downlink_wire_bytes)
                flat_p, treedef = jax.tree.flatten(params_like)
                flags = treedef.flatten_up_to(
                    model.stacked_mask(params_like))
                plan = downlink_plan([p.shape for p in flat_p], flags,
                                     run.optimizer.compressor)
                dense_b = dense_downlink_bytes([p.shape for p in flat_p])
                rec["downlink"] = {
                    "mode": downlink,
                    "bytes_per_link": (downlink_wire_bytes(plan)
                                       if downlink == "compressed"
                                       else dense_b),
                    "dense_bytes_per_link": dense_b,
                }
        elif shape.kind == "prefill":
            batch_like = model.input_specs(shape)
            step = build_prefill_step(model, run, mesh, shape,
                                      params_2d=params_2d)(
                params_like, batch_like)
            lowered = step.lower(params_like, batch_like)
        else:  # decode
            B, S = shape.global_batch, shape.seq_len
            if cfg.family == "encdec":
                cache_like = jax.eval_shape(
                    lambda: model.init_cache(B, S, s_enc=S // 2))
            else:
                cache_like = jax.eval_shape(lambda: model.init_cache(B, S))
            token_like = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            step = build_decode_step(model, run, mesh, shape,
                                     params_2d=params_2d)(
                params_like, token_like, cache_like)
            lowered = step.lower(params_like, token_like, cache_like,
                                 jnp.int32(S - 1))
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        from repro.compat import cost_analysis
        ca = cost_analysis(compiled)
        # raw XLA numbers (per-device, while-bodies counted ONCE — kept as
        # diagnostics; the trip-count-aware numbers below are authoritative)
        rec["xla_flops_body_once"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_body_once"] = float(ca.get("bytes accessed", 0.0))
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
            "host_argument_bytes": int(ma.host_argument_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        hlo = compiled.as_text()
        parsed = parse_hlo(hlo, ring_schedule=(transport == "overlap"))
        rec["collectives"] = parsed["collectives"]
        rec["flops_per_chip"] = parsed["hlo_matmul_flops"]
        rec["bytes_per_chip"] = parsed["hlo_traffic_bytes"]
        if keep_hlo:
            rec["hlo_len"] = len(hlo)
        rec["n_chips"] = n_chips
        rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="csgd_asss",
                    choices=["csgd_asss", "nonadaptive", "acgd", "sgd",
                             "dense", "sls"])
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ef-host-offload", action="store_true")
    ap.add_argument("--ef-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--shard-local-topk", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--params-2d", action="store_true",
                    help="serving: shard weights over data axis too")
    ap.add_argument("--moe-ep", action="store_true",
                    help="explicit expert-parallel MoE shard_map")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 self-attention KV cache")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--transport", default="bucketed",
                    choices=list(transport_names()),
                    help="compressed-exchange schedule (DESIGN.md §11/§12)")
    ap.add_argument("--topology", default="ring",
                    choices=sorted(TOPOLOGIES),
                    help="gossip mixing graph (transport=gossip)")
    ap.add_argument("--overlap-chunks", type=int,
                    default=OverlapConfig.n_chunks,
                    help="transport=overlap: ring chunk count (DESIGN.md "
                         "§14); the per-link accounting charges the FULL "
                         "permute total — every hop shares one link")
    ap.add_argument("--overlap-delay", type=int,
                    default=OverlapConfig.delay, choices=[0, 1],
                    help="transport=overlap: 1 = ship the previous step's "
                         "payload (double-buffered), 0 = synchronous")
    ap.add_argument("--n-clients", type=int, default=0,
                    help="> 0: lower the federated cohort train step "
                         "(n-clients/W vmapped clients per dp worker)")
    ap.add_argument("--aggregation", default="support",
                    choices=["support", "mean"],
                    help="cohort aggregation (federated mode)")
    ap.add_argument("--downlink", default="dense",
                    choices=["dense", "compressed"],
                    help="aggregate return direction (DESIGN.md §15): "
                         "compressed = server-side EF re-compression, "
                         "accounted per link in the record's 'downlink' "
                         "block (no collective — it is simulated)")
    ap.add_argument("--downlink-gamma", type=float, default=0.0,
                    help="downlink compression level (0 = uplink gamma)")
    # ---- hostile-wire robustness (DESIGN.md §16) ----
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-bitflip", type=float, default=0.0,
                    help="per-row wire bit-flip probability — lowers the "
                         "train step through the 'faulty' transport wrapper "
                         "so the injected-HLO collective schedule can be "
                         "audited")
    ap.add_argument("--fault-count", type=float, default=0.0,
                    help="per-row corrupt ragged-count probability")
    ap.add_argument("--fault-nonfinite", type=float, default=0.0,
                    help="per-row NaN/Inf scale-or-value probability")
    ap.add_argument("--fault-zero-row", type=float, default=0.0,
                    help="per-row whole-row zeroing probability")
    ap.add_argument("--fault-worker", type=int, default=-1,
                    help="gathered row-slot to target (-1 = all)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    records = []
    for arch, shape in combos:
        try:
            rec = lower_one(arch, shape, multi_pod=args.multi_pod,
                            opt_kind=args.opt, gamma=args.gamma,
                            microbatches=args.microbatches,
                            ef_host_offload=args.ef_host_offload,
                            ef_dtype=args.ef_dtype,
                            shard_local_topk=args.shard_local_topk,
                            seq_parallel=args.seq_parallel,
                            params_2d=args.params_2d,
                            moe_ep=args.moe_ep,
                            capacity_factor=args.capacity_factor,
                            kv_int8=args.kv_int8,
                            local_steps=args.local_steps,
                            transport=args.transport,
                            topology=args.topology,
                            n_clients=args.n_clients,
                            aggregation=args.aggregation,
                            overlap_chunks=args.overlap_chunks,
                            overlap_delay=args.overlap_delay,
                            downlink=args.downlink,
                            downlink_gamma=args.downlink_gamma,
                            faults=FaultConfig(
                                seed=args.fault_seed,
                                p_bitflip=args.fault_bitflip,
                                p_count=args.fault_count,
                                p_nonfinite=args.fault_nonfinite,
                                p_zero_row=args.fault_zero_row,
                                worker=args.fault_worker))
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": arch, "shape": shape, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        status = rec["status"]
        colls = rec.get("collectives", {})
        dl = rec.get("downlink", {})
        down = (f"down/link={dl['bytes_per_link']:.3e} "
                if dl else "")
        print(f"[{status:7s}] {arch:24s} {shape:12s} "
              f"flops/chip={rec.get('flops_per_chip', 0):.3e} "
              f"wire={colls.get('total_wire_bytes', 0):.3e} "
              f"up/link={colls.get('wire_bytes_per_link', 0):.3e} "
              f"{down}"
              f"compile={rec.get('compile_s', 0)}s", flush=True)
        records.append(rec)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")


if __name__ == "__main__":
    main()
