"""Serving driver: batched prefill + autoregressive decode on a mesh.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --smoke \
        --mesh 4x2 --batch 8 --ctx 64 --gen 16

Production decode shapes (decode_32k / long_500k) are exercised via the
dry-run; this driver runs *real* batched generation on the (CPU-simulated)
mesh with the same sharded cache layout.
"""
from __future__ import annotations

import argparse
import math
import time

import jax

from repro.compat import set_mesh
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.sharding import dp_axes_of


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return jax.make_mesh(dims, axes, devices=jax.devices()[:math.prod(dims)])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--params-2d", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = parse_mesh(args.mesh)
    dp = dp_axes_of(mesh)
    B, CTX, GEN = args.batch, args.ctx, args.gen
    shape = ShapeConfig("serve", CTX + GEN, B, "decode")

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        from repro.sharding import param_pspecs
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           param_pspecs(params, two_d=args.params_2d))
        params = jax.device_put(params, psh)

        key = jax.random.PRNGKey(7)
        batch = {"tokens": jax.random.randint(key, (B, CTX), 0,
                                              cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["image_embed"] = jax.random.normal(
                key, (B, cfg.n_patches, cfg.d_model))
        if cfg.family == "encdec":
            batch["src_embed"] = jax.random.normal(key, (B, 32, cfg.d_model))
        dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
        batch = jax.device_put(batch, jax.tree.map(
            lambda _: NamedSharding(mesh, P(dp_spec)), batch))

        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, capacity=CTX + GEN))(params,
                                                                  batch)
        print(f"[{cfg.name}] prefill {B}x{CTX} on mesh {args.mesh}: "
              f"{time.time()-t0:.2f}s")

        decode = jax.jit(model.decode_step)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(GEN - 1):
            logits, cache = decode(params, tok, cache, jnp.int32(CTX + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        dt = (time.time() - t0) / max(GEN - 1, 1)
        gen = jnp.concatenate(out, axis=1)
        print(f"decoded {GEN} tokens/request @ {dt*1e3:.1f} ms/step")
        for i in range(min(B, 4)):
            print(f"  req{i}: {list(map(int, gen[i]))[:16]}")


if __name__ == "__main__":
    main()
