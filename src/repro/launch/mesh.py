"""Production mesh definitions.

A function, not a module-level constant: importing this module never touches
jax device state.  Single pod = 256 v5e chips as (data=16, model=16);
multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16) — the DCSGD
worker set is the (pod, data) axes product.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~4 links/chip on v5e)
