"""Training driver: DCSGD-ASSS on a device mesh, with checkpointing.

CPU-scale entry point (the production mesh path is exercised by dryrun.py):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --smoke \
        --steps 50 --mesh 4x2 --opt csgd_asss --gamma 0.05

Runs real steps on the (forced-host) mesh, logs loss/alpha/wire-bytes, and
writes checkpoints.  ``--arch paper-lm-100m`` is the ~100M end-to-end run.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.comm.faults import FaultConfig
from repro.comm.gossip import GossipConfig
from repro.comm.overlap import OverlapConfig
from repro.comm.topology import TOPOLOGIES
from repro.comm.transport import transport_names
from repro.configs import get_config, get_smoke_config
from repro.configs.base import (FederatedConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core.armijo import ArmijoConfig
from repro.core.compression import Compressor
from repro.core.gamma import GammaControllerConfig
from repro.core.health import check_divergence
from repro.data.synthetic import TokenPipeline
from repro.fed.sampling import participation_mask
from repro.launch.train_step import (build_train_step, init_opt_state,
                                     opt_state_shardings)
from repro.models import build_model
from repro.sharding import dp_axes_of, param_shardings


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"),
                             devices=jax.devices()[:math.prod(dims)])
    return jax.make_mesh(dims, ("pod", "data", "model"),
                         devices=jax.devices()[:math.prod(dims)])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant of --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="2x1")
    ap.add_argument("--opt", default="csgd_asss",
                    choices=["csgd_asss", "nonadaptive", "acgd", "sgd",
                             "dense", "sls"])
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--compress-method", default="topk",
                    choices=["topk", "block_topk", "none"],
                    help="block_topk = fused Pallas kernel path")
    # ---- adaptive per-round compression (DESIGN.md §9) ----
    ap.add_argument("--max-gamma", type=float, default=0.0,
                    help="> 0: static ragged-wire budget; gamma becomes "
                         "the per-round initial level")
    ap.add_argument("--gamma-schedule", default="fixed",
                    choices=["fixed", "linear", "armijo-coupled",
                             "ef-coupled"],
                    help="per-round gamma controller (core/gamma.py); "
                         "ef-coupled couples to the EF backlog telemetry "
                         "(DESIGN.md §10)")
    ap.add_argument("--gamma-min", type=float, default=0.0,
                    help="controller floor (0 = gamma/8)")
    ap.add_argument("--gamma-ramp-steps", type=int, default=1000,
                    help="linear schedule: steps from gamma to max-gamma")
    # defaults come from the dataclass so the CLI can never drift from
    # the calibrated controller defaults (core/gamma.py)
    ap.add_argument("--ef-target", type=float,
                    default=GammaControllerConfig.ef_target,
                    help="ef-coupled: backlog ratio ||m'||/||g|| the "
                         "hysteresis band centers on")
    ap.add_argument("--ef-band", type=float,
                    default=GammaControllerConfig.ef_band,
                    help="ef-coupled: band half-width (grow above "
                         "target+band, shrink below target-band)")
    ap.add_argument("--theory-safe", action="store_true",
                    help="clamp the step scale to zeta(gamma_t) = "
                         "sigma*gamma/(2-gamma) each round")
    ap.add_argument("--no-kernel", action="store_true",
                    help="block_topk via pure jnp (kernel escape hatch)")
    ap.add_argument("--eta", type=float, default=0.1)
    ap.add_argument("--momentum", type=float, default=0.9,
                    help="acgd: Nesterov mu (arXiv 2002.11364); heavy-ball "
                         "momentum for single-node CSGD lives in "
                         "repro.core.csgd")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--value-bits", type=int, default=32,
                    choices=[32, 16, 8, 4],
                    help="wire value width (DESIGN.md §8 packed format)")
    ap.add_argument("--ef-dtype", default="float32")
    # choices come from the transport registry (repro/comm/transport.py)
    # so the CLI can never drift from the actual registered schedules
    ap.add_argument("--transport", default="bucketed",
                    choices=list(transport_names()),
                    help="compressed-exchange schedule (DESIGN.md §11/§12): "
                         "bucketed = ONE flat packed all_gather + batched "
                         "launches; perleaf = one collective per leaf "
                         "(bit-exact reference); gossip = serverless "
                         "neighbor-ppermute consensus exchange; overlap = "
                         "chunked-ring, double-buffered exchange "
                         "(DESIGN.md §14)")
    # ---- overlapped exchange (transport=overlap, DESIGN.md §14) ----
    ap.add_argument("--overlap-chunks", type=int,
                    default=OverlapConfig.n_chunks,
                    help="ring chunk count: the payload crosses each link "
                         "as n_chunks independent collective_permute hops "
                         "per ring step")
    ap.add_argument("--overlap-delay", type=int,
                    default=OverlapConfig.delay, choices=[0, 1],
                    help="1 = double-buffered: ship the PREVIOUS step's "
                         "payload so the collective overlaps this step's "
                         "compute; 0 = synchronous (bit-exact vs bucketed)")
    # ---- gossip / consensus (transport=gossip, DESIGN.md §12) ----
    ap.add_argument("--topology", default=GossipConfig.topology,
                    choices=sorted(TOPOLOGIES),
                    help="gossip mixing graph over the dp workers")
    ap.add_argument("--consensus-lr", type=float,
                    default=GossipConfig.consensus_lr,
                    help="numerator of the AdaGossip adaptive consensus "
                         "step (capped at --consensus-lr-max)")
    ap.add_argument("--consensus-beta", type=float,
                    default=GossipConfig.beta,
                    help="EMA decay of the gossip-error second moment")
    ap.add_argument("--consensus-lr-max", type=float,
                    default=GossipConfig.lr_max,
                    help="consensus step cap (the fixed-step baseline)")
    ap.add_argument("--shard-local-topk", action="store_true")
    # ---- compressed downlink (DESIGN.md §15) ----
    ap.add_argument("--downlink", default="dense",
                    choices=["dense", "compressed"],
                    help="return direction of the aggregate: 'dense' ships "
                         "the full f32 mean (bit-exact reference); "
                         "'compressed' re-compresses it through the same "
                         "wire format with server-side error feedback — "
                         "no extra collective")
    ap.add_argument("--downlink-gamma", type=float, default=0.0,
                    help="downlink compression level (0 = the uplink "
                         "compressor's gamma)")
    ap.add_argument("--downlink-gamma-schedule", default="fixed",
                    choices=["fixed", "linear"],
                    help="open-loop downlink gamma schedule (the simulated "
                         "server has no telemetry to couple to)")
    # ---- federated cohort simulation (DESIGN.md §13) ----
    ap.add_argument("--n-clients", type=int, default=0,
                    help="> 0: federated cohort simulation — vmap "
                         "n-clients/W simulated clients per dp worker, "
                         "each with its own non-IID shard, EF memory and "
                         "gamma controller")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="fixed-size sampling: participants per round "
                         "(0 = all clients)")
    ap.add_argument("--client-sampling", default="fixed",
                    choices=["fixed", "bernoulli"],
                    help="per-round participation sampler (fed/sampling.py)")
    ap.add_argument("--participation-rate", type=float, default=1.0,
                    help="bernoulli sampling: per-client participation "
                         "probability")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="probability a sampled client drops out "
                         "(straggler model, applied after sampling)")
    ap.add_argument("--aggregation", default="support",
                    choices=["support", "mean"],
                    help="cohort aggregation: 'support' divides each "
                         "coordinate by its nonzero-support count; 'mean' "
                         "is the zero-averaging dense-pmean reference")
    ap.add_argument("--dirichlet-alpha", type=float, default=0.0,
                    help="> 0: non-IID client shards via per-client "
                         "Dirichlet(alpha) unigram tilt (data/synthetic.py)")
    ap.add_argument("--fed-seed", type=int, default=0,
                    help="seed for participation sampling + client shards")
    # ---- hostile-wire robustness (DESIGN.md §16) ----
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the (seed, step, worker)-deterministic "
                         "fault-injection stream")
    ap.add_argument("--fault-bitflip", type=float, default=0.0,
                    help="per-row probability of flipping one random wire "
                         "bit in the gathered payload")
    ap.add_argument("--fault-count", type=float, default=0.0,
                    help="per-row probability of a truncated/overflowed "
                         "ragged count header")
    ap.add_argument("--fault-nonfinite", type=float, default=0.0,
                    help="per-row probability of a NaN/Inf scale or value "
                         "field")
    ap.add_argument("--fault-zero-row", type=float, default=0.0,
                    help="per-row probability of zeroing the whole row "
                         "(dropped-worker model: decodes as a VALID empty "
                         "contribution)")
    ap.add_argument("--fault-worker", type=int, default=-1,
                    help="gathered row-slot to target (-1 = all workers)")
    ap.add_argument("--fault-start-step", type=int, default=0,
                    help="first step of the fault burst")
    ap.add_argument("--fault-steps", type=int, default=-1,
                    help="burst length in steps (-1 = open-ended)")
    ap.add_argument("--no-quarantine", action="store_true",
                    help="disable the defensive decode verdicts (corrupt "
                         "rows flow into the mean; the step-level breaker "
                         "is the only remaining defense)")
    ap.add_argument("--max-consecutive-skips", type=int,
                    default=OptimizerConfig.max_consecutive_skips,
                    help="step-level circuit breaker: this many consecutive "
                         "non-finite (skipped) rounds raise "
                         "DivergenceError naming the last good step "
                         "(0 disables the gate)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="JSON metrics log")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = parse_mesh(args.mesh)
    dp = dp_axes_of(mesh)
    W = math.prod(mesh.shape[a] for a in dp)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    run = RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(
            kind=args.opt, armijo=ArmijoConfig(theory_safe=args.theory_safe),
            compressor=Compressor(gamma=args.gamma,
                                  method=args.compress_method,
                                  value_bits=args.value_bits,
                                  use_kernel=not args.no_kernel,
                                  max_gamma=args.max_gamma),
            gamma_controller=GammaControllerConfig(
                schedule=args.gamma_schedule,
                gamma_min=args.gamma_min,
                ramp_steps=args.gamma_ramp_steps,
                ef_target=args.ef_target,
                ef_band=args.ef_band),
            eta=args.eta, momentum=args.momentum, ef_dtype=args.ef_dtype,
            shard_local_topk=args.shard_local_topk,
            local_steps=args.local_steps,
            transport=args.transport,
            gossip=GossipConfig(topology=args.topology,
                                consensus_lr=args.consensus_lr,
                                beta=args.consensus_beta,
                                lr_max=args.consensus_lr_max),
            overlap=OverlapConfig(n_chunks=args.overlap_chunks,
                                  delay=args.overlap_delay),
            federated=FederatedConfig(
                n_clients=args.n_clients,
                clients_per_round=args.clients_per_round,
                sampling=args.client_sampling,
                participation_rate=args.participation_rate,
                straggler_rate=args.straggler_rate,
                aggregation=args.aggregation,
                dirichlet_alpha=args.dirichlet_alpha,
                seed=args.fed_seed),
            downlink=args.downlink,
            downlink_gamma=GammaControllerConfig(
                schedule=args.downlink_gamma_schedule,
                gamma0=args.downlink_gamma),
            faults=FaultConfig(seed=args.fault_seed,
                               p_bitflip=args.fault_bitflip,
                               p_count=args.fault_count,
                               p_nonfinite=args.fault_nonfinite,
                               p_zero_row=args.fault_zero_row,
                               worker=args.fault_worker,
                               start_step=args.fault_start_step,
                               n_steps=args.fault_steps,
                               quarantine=not args.no_quarantine),
            max_consecutive_skips=args.max_consecutive_skips),
        microbatches=args.microbatches)

    with set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        opt_state = init_opt_state(params, run, W,
                                   stacked_mask=model.stacked_mask(params))
        opt_state = jax.device_put(
            opt_state, opt_state_shardings(opt_state, params, mesh, run))

        start = 0
        if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
            (params, opt_state), meta = ckpt.restore(
                args.ckpt_dir, (params, opt_state))
            start = meta.get("step", 0)
            print(f"resumed from step {start}")

        fed = run.optimizer.federated
        bspec = NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0]))
        rep_sh = NamedSharding(mesh, P())
        if fed.enabled:
            if args.global_batch % fed.n_clients:
                raise SystemExit(
                    f"--global-batch {args.global_batch} must divide "
                    f"evenly across --n-clients {fed.n_clients}")
            # one shard-aware pipeline per client: client c IS shard c of
            # the (seed, step, shard)-deterministic stream, Dirichlet-
            # tilted per client when --dirichlet-alpha > 0
            cpipes = [TokenPipeline(
                vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                global_batch=args.global_batch, seed=fed.seed,
                n_shards=fed.n_clients, shard=c,
                dirichlet_alpha=fed.dirichlet_alpha)
                for c in range(fed.n_clients)]

            def make_batch(step):
                rows = [p.batch_with_aux(step, cfg) for p in cpipes]
                b = {k: jnp.stack([r[k] for r in rows]) for k in rows[0]}
                b["participation"] = participation_mask(
                    fed.n_clients, step, seed=fed.seed, mode=fed.sampling,
                    clients_per_round=fed.clients_per_round,
                    rate=fed.participation_rate,
                    straggler_rate=fed.straggler_rate)
                return b
        else:
            pipe = TokenPipeline(vocab_size=cfg.vocab_size,
                                 seq_len=args.seq_len,
                                 global_batch=args.global_batch)

            def make_batch(step):
                return pipe.batch_with_aux(step, cfg)

        def put_batch(b):
            return {k: jax.device_put(
                v, rep_sh if k == "participation" else bspec)
                for k, v in b.items()}

        step_fn = None
        log = []
        t_start = time.time()
        for step in range(start, args.steps):
            batch = put_batch(make_batch(step))
            if step_fn is None:
                step_fn = build_train_step(model, run, mesh)(params, batch)
                t0 = time.time()
                step_fn = step_fn.lower(params, opt_state, batch).compile()
                print(f"compiled train_step in {time.time()-t0:.1f}s")
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if run.optimizer.max_consecutive_skips > 0:
                # host-side breaker: DivergenceError is a typed Python
                # exception, impossible to raise from inside jit
                check_divergence(
                    {"step": step,
                     "consecutive_skips": metrics["consecutive_skips"],
                     "last_good_step": metrics["last_good_step"]},
                    run.optimizer.max_consecutive_skips)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = round(time.time() - t_start, 1)
                log.append(m)
                down = (f"down={m['downlink_effective_wire_bytes']:.3e}B "
                        if "downlink_effective_wire_bytes" in m else "")
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"alpha={m['alpha']:.4g} evals={m['n_evals']:.2f} "
                      f"up={m['wire_bytes']:.3e}B "
                      f"eff={m.get('effective_wire_bytes', 0.0):.3e}B "
                      f"{down}"
                      f"cum={m.get('cum_effective_wire_bytes', 0.0):.3e}B "
                      f"gamma={m.get('gamma', args.gamma):.4g} "
                      f"backlog={m.get('ef_backlog', 0.0):.3g} "
                      f"cos={m.get('ef_cosine', 1.0):.3f}"
                      + (f" skips={m['steps_skipped']:.0f}"
                         f" quar={m['rows_quarantined']:.0f}"
                         if m.get("steps_skipped", 0.0)
                         or m.get("rows_quarantined", 0.0) else ""),
                      flush=True)
            if args.ckpt_dir and step and step % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step, (params, opt_state),
                          metadata={"step": step})
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                      metadata={"step": args.steps})
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
