"""Pytree checkpointing: npz-based, step-managed, restart-safe.

Layout::

    <dir>/step_<N>/
        manifest.json      (treedef + leaf dtypes/shapes + metadata)
        arrays.npz         (flattened leaves, keyed leaf_<i>)
        COMMITTED          (written last -> partial checkpoints are ignored)

No external deps (orbax is not available offline).  Works for params,
optimizer state and data-pipeline cursors alike.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _treedef_repr(tree) -> str:
    return str(jax.tree.structure(tree))


def save(directory: str, step: int, tree: PyTree,
         metadata: dict | None = None, keep: int = 3) -> str:
    """Atomically save ``tree`` at ``step``; prunes to ``keep`` newest."""
    path = os.path.join(directory, f"step_{step:010d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf)
              for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": _treedef_repr(tree),
        "dtypes": [str(np.asarray(leaf).dtype) for leaf in leaves],
        "shapes": [list(np.asarray(leaf).shape) for leaf in leaves],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(tmp, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    _prune(directory, keep)
    return path


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_like: PyTree,
            step: int | None = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``tree_like`` (shapes are verified)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        (manifest["n_leaves"], len(leaves_like))
    leaves = []
    for i, like in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        expect = tuple(getattr(like, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), manifest["metadata"]
