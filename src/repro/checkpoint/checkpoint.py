"""Pytree checkpointing: npz-based, step-managed, restart-safe.

Layout::

    <dir>/step_<N>/
        manifest.json      (treedef + leaf dtypes/shapes + metadata)
        arrays.npz         (flattened leaves, keyed leaf_<i>)
        COMMITTED          (written last -> partial checkpoints are ignored)

Crash-safety (DESIGN.md §16): every file lands via tmp-file +
``os.replace`` and the whole step directory is assembled under a ``.tmp``
suffix, renamed into place only after the COMMITTED marker exists — a
kill at ANY point leaves either the previous committed checkpoint or a
``.tmp`` directory that discovery ignores.  The previous checkpoint is
never touched while the new one is being written, and ``restore`` falls
back to the next older committed step (with a logged warning) when the
newest one turns out to be corrupt on disk.

No external deps (orbax is not available offline).  Works for params,
optimizer state and data-pipeline cursors alike.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import zipfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

logger = logging.getLogger(__name__)

# exactly the errors a torn/corrupt on-disk checkpoint produces: missing
# files, truncated npz (zipfile/EOF), garbage json, missing leaf keys.
# AssertionError is deliberately NOT here — a skeleton/shape mismatch is
# a caller bug, not disk corruption, and must propagate.
CORRUPTION_ERRORS = (OSError, ValueError, zipfile.BadZipFile, KeyError,
                     EOFError)


def _treedef_repr(tree) -> str:
    return str(jax.tree.structure(tree))


def _write_atomic(path: str, writer) -> None:
    """Write via ``writer(tmp_path)`` then ``os.replace`` into place, so
    a crash mid-write never leaves a half-written file at ``path``."""
    tmp = path + ".tmp"
    writer(tmp)
    os.replace(tmp, path)


def save(directory: str, step: int, tree: PyTree,
         metadata: dict | None = None, keep: int = 3) -> str:
    """Atomically save ``tree`` at ``step``; prunes to ``keep`` newest.

    The previous committed checkpoint stays intact (and discoverable)
    until this one's COMMITTED marker is in place."""
    path = os.path.join(directory, f"step_{step:010d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf)
              for i, leaf in enumerate(leaves)}
    def write_arrays(p):
        # np.savez appends ".npz" to bare paths — hand it a file object
        # so the tmp-file name survives for os.replace
        with open(p, "wb") as f:
            np.savez(f, **arrays)

    _write_atomic(os.path.join(tmp, "arrays.npz"), write_arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": _treedef_repr(tree),
        "dtypes": [str(np.asarray(leaf).dtype) for leaf in leaves],
        "shapes": [list(np.asarray(leaf).shape) for leaf in leaves],
        "metadata": metadata or {},
    }

    def write_manifest(p):
        with open(p, "w") as f:
            json.dump(manifest, f, indent=1)

    _write_atomic(os.path.join(tmp, "manifest.json"), write_manifest)

    def write_marker(p):
        with open(p, "w") as f:
            f.write("ok")

    _write_atomic(os.path.join(tmp, "COMMITTED"), write_marker)
    if os.path.exists(path):
        # re-saving the SAME step: the old dir must move out of the way
        # (dir-over-dir rename is not atomic); park it under .old first
        # so a crash between the two renames still leaves a committed
        # copy discoverable by the fallback scan below
        old = path + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(path, old)
        os.rename(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.rename(tmp, path)
    _prune(directory, keep)
    return path


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                not name.endswith(".old") and \
                os.path.exists(os.path.join(directory, name, "COMMITTED")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _load_step(directory: str, step: int, tree_like: PyTree):
    """Load one committed step; raises CORRUPTION_ERRORS on torn files
    and AssertionError on a skeleton mismatch (which must propagate)."""
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        (manifest["n_leaves"], len(leaves_like))
    leaves = []
    for i, like in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        expect = tuple(getattr(like, "shape", arr.shape))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), manifest["metadata"]


def restore(directory: str, tree_like: PyTree,
            step: int | None = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``tree_like`` (shapes are verified).

    With ``step=None`` (resume-from-latest), a checkpoint whose files
    turn out corrupt on disk is skipped with a logged warning and the
    next older committed step is tried — a torn write must not strand an
    otherwise-resumable run.  An explicitly requested ``step`` raises
    instead of silently answering with different data.
    """
    if step is not None:
        return _load_step(directory, step, tree_like)
    candidates = sorted(all_steps(directory), reverse=True)
    if not candidates:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    last_err = None
    for s in candidates:
        try:
            return _load_step(directory, s, tree_like)
        except AssertionError:
            raise                      # caller bug, not disk corruption
        except CORRUPTION_ERRORS as e:
            logger.warning(
                "checkpoint step_%010d in %s is corrupt (%s: %s) — "
                "falling back to the next older committed step",
                s, directory, type(e).__name__, e)
            last_err = e
    raise FileNotFoundError(
        f"every committed checkpoint in {directory} is corrupt "
        f"(last error: {type(last_err).__name__}: {last_err})")
