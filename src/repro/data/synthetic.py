"""Deterministic, shard-aware synthetic data pipelines.

Three generators:

* ``TokenPipeline``      — LM token streams with Zipfian unigram structure +
  an order-2 Markov mixing so the loss has learnable signal; deterministic
  per (seed, step, shard), so every dp worker slices its own batch shard
  without coordination and restarts are reproducible from the step counter.
* ``interpolated_regression`` — the paper's Fig-4 setup: `<a_i, x*> = b_i`
  exactly (interpolation holds by construction).
* ``teacher_classification`` — images/labels from a fixed random teacher so
  an over-parameterized student can interpolate (paper's NN experiments).

Non-IID federated shards (DESIGN.md §13): ``TokenPipeline.
dirichlet_alpha`` tilts each shard's unigram distribution by a
Dirichlet-weighted reweighting keyed ONLY on ``(seed, shard)`` — never
on ``step`` or ``n_shards`` — so the ``(seed, step, shard)``
determinism contract extends verbatim to heterogeneous clients and
survives n_shards refactors (pinned in tests/test_property.py).
``dirichlet_label_shards`` is the classic label-skew partitioner for
the classification generators.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

# SeedSequence domain tag for the per-shard Dirichlet tilt stream —
# independent of the per-(seed, step, shard) batch streams.
_DIRICHLET_TAG = 0xD161_C4E7


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    # > 0: non-IID shards — per-shard Dirichlet(alpha) reweighting of the
    # zipf unigrams, keyed on (seed, shard) only.  alpha -> inf recovers
    # the IID zipf stream; small alpha concentrates each shard's mass on
    # a few shard-specific symbols (federated label/feature skew).
    dirichlet_alpha: float = 0.0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def unigram_probs(self) -> np.ndarray:
        """This shard's unigram distribution: zipf, Dirichlet-tilted when
        ``dirichlet_alpha`` > 0.  A pure function of (seed, shard,
        dirichlet_alpha, vocab_size) — step- and n_shards-independent by
        construction, which is what makes the determinism regression in
        tests/test_property.py hold for non-IID shards."""
        V = self.vocab_size
        probs = 1.0 / np.arange(1, V + 1)
        probs /= probs.sum()
        if self.dirichlet_alpha > 0:
            trng = np.random.default_rng(np.random.SeedSequence(
                [self.seed, _DIRICHLET_TAG, self.shard]))
            # gamma weights ~ the un-normalized Dirichlet sample; the
            # floor guards tiny-alpha underflow to an all-zero draw
            w = np.maximum(trng.gamma(self.dirichlet_alpha, 1.0, size=V),
                           1e-300)
            probs = probs * w
            probs /= probs.sum()
        return probs

    def batch(self, step: int) -> dict:
        """Deterministic batch for (step, shard). CPU-side numpy; returns
        int32 tokens (local_batch, seq_len)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        B, S, V = self.local_batch, self.seq_len, self.vocab_size
        probs = self.unigram_probs()
        base = rng.choice(V, size=(B, S), p=probs)
        # order-2 structure: with prob .5, token t = (t-1 + t-2) % V
        mix = rng.random((B, S)) < 0.5
        for t in range(2, S):
            base[:, t] = np.where(mix[:, t],
                                  (base[:, t - 1] + base[:, t - 2]) % V,
                                  base[:, t])
        return {"tokens": jnp.asarray(base, jnp.int32)}

    def batch_with_aux(self, step: int, cfg) -> dict:
        """Adds the stubbed modality inputs required by vlm/encdec archs."""
        b = self.batch(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed + 7, step, self.shard]))
        if cfg.family == "vlm":
            b["image_embed"] = jnp.asarray(
                rng.standard_normal((self.local_batch, cfg.n_patches,
                                     cfg.d_model), dtype=np.float32))
        if cfg.family == "encdec":
            b["src_embed"] = jnp.asarray(
                rng.standard_normal((self.local_batch, self.seq_len,
                                     cfg.d_model), dtype=np.float32))
        return b


def interpolated_regression(n: int, d: int, *, feature_std: float = 1.0,
                            seed: int = 0):
    """Paper Fig. 4: least squares with an exact interpolant.

    Returns (A (n,d), b (n,), x_star (d,)). Features ~ N(0, feature_std^2).
    """
    rng = np.random.default_rng(seed)
    x_star = rng.standard_normal(d)
    A = rng.standard_normal((n, d)) * feature_std
    b = A @ x_star
    return (jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32),
            jnp.asarray(x_star, jnp.float32))


def regression_batch(A, b, batch_size: int, step: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    idx = rng.integers(0, A.shape[0], batch_size)
    return A[idx], b[idx]


def teacher_classification(n: int, *, n_classes: int = 100, seed: int = 0,
                           image: bool = True):
    """32x32x3 inputs with labels from a fixed random linear teacher —
    realizable, so interpolation can hold for an over-parameterized net."""
    rng = np.random.default_rng(seed)
    if image:
        x = rng.standard_normal((n, 32, 32, 3)).astype(np.float32)
        feats = x.reshape(n, -1)
    else:
        x = rng.standard_normal((n, 3072)).astype(np.float32)
        feats = x
    W = rng.standard_normal((feats.shape[1], n_classes)) / np.sqrt(feats.shape[1])
    y = np.argmax(feats @ W, axis=1)
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def class_batch(x, y, batch_size: int, step: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    idx = rng.integers(0, x.shape[0], batch_size)
    return {"x": x[idx], "y": y[idx]}


def dirichlet_label_shards(labels, n_shards: int, alpha: float,
                           seed: int = 0) -> np.ndarray:
    """Classic federated label-skew partition: for each class, shard
    proportions ~ Dirichlet(alpha * 1) decide how its samples split.

    Returns ``shard_of`` (n,) int32 — a complete partition (every index
    lands on exactly one shard).  Small ``alpha`` concentrates each
    class on few shards (strong non-IID); large ``alpha`` approaches the
    uniform IID split.  Deterministic in (labels, n_shards, alpha, seed).
    """
    labels = np.asarray(labels)
    if n_shards <= 0:
        raise ValueError(f"n_shards must be positive, got {n_shards}")
    if alpha <= 0:
        raise ValueError(f"alpha must be positive, got {alpha}")
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, _DIRICHLET_TAG]))
    shard_of = np.empty(labels.shape[0], np.int32)
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_shards, alpha))
        # largest-remainder apportionment of len(idx) samples to shards
        quota = p * len(idx)
        counts = np.floor(quota).astype(np.int64)
        short = len(idx) - counts.sum()
        if short:
            counts[np.argsort(quota - counts)[::-1][:short]] += 1
        bounds = np.cumsum(counts)[:-1]
        for s, chunk in enumerate(np.split(idx, bounds)):
            shard_of[chunk] = s
    return shard_of
