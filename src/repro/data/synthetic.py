"""Deterministic, shard-aware synthetic data pipelines.

Three generators:

* ``TokenPipeline``      — LM token streams with Zipfian unigram structure +
  an order-2 Markov mixing so the loss has learnable signal; deterministic
  per (seed, step, shard), so every dp worker slices its own batch shard
  without coordination and restarts are reproducible from the step counter.
* ``interpolated_regression`` — the paper's Fig-4 setup: `<a_i, x*> = b_i`
  exactly (interpolation holds by construction).
* ``teacher_classification`` — images/labels from a fixed random teacher so
  an over-parameterized student can interpolate (paper's NN experiments).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> dict:
        """Deterministic batch for (step, shard). CPU-side numpy; returns
        int32 tokens (local_batch, seq_len)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        B, S, V = self.local_batch, self.seq_len, self.vocab_size
        # zipf unigrams
        ranks = np.arange(1, V + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        base = rng.choice(V, size=(B, S), p=probs)
        # order-2 structure: with prob .5, token t = (t-1 + t-2) % V
        mix = rng.random((B, S)) < 0.5
        for t in range(2, S):
            base[:, t] = np.where(mix[:, t],
                                  (base[:, t - 1] + base[:, t - 2]) % V,
                                  base[:, t])
        return {"tokens": jnp.asarray(base, jnp.int32)}

    def batch_with_aux(self, step: int, cfg) -> dict:
        """Adds the stubbed modality inputs required by vlm/encdec archs."""
        b = self.batch(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed + 7, step, self.shard]))
        if cfg.family == "vlm":
            b["image_embed"] = jnp.asarray(
                rng.standard_normal((self.local_batch, cfg.n_patches,
                                     cfg.d_model), dtype=np.float32))
        if cfg.family == "encdec":
            b["src_embed"] = jnp.asarray(
                rng.standard_normal((self.local_batch, self.seq_len,
                                     cfg.d_model), dtype=np.float32))
        return b


def interpolated_regression(n: int, d: int, *, feature_std: float = 1.0,
                            seed: int = 0):
    """Paper Fig. 4: least squares with an exact interpolant.

    Returns (A (n,d), b (n,), x_star (d,)). Features ~ N(0, feature_std^2).
    """
    rng = np.random.default_rng(seed)
    x_star = rng.standard_normal(d)
    A = rng.standard_normal((n, d)) * feature_std
    b = A @ x_star
    return (jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32),
            jnp.asarray(x_star, jnp.float32))


def regression_batch(A, b, batch_size: int, step: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    idx = rng.integers(0, A.shape[0], batch_size)
    return A[idx], b[idx]


def teacher_classification(n: int, *, n_classes: int = 100, seed: int = 0,
                           image: bool = True):
    """32x32x3 inputs with labels from a fixed random linear teacher —
    realizable, so interpolation can hold for an over-parameterized net."""
    rng = np.random.default_rng(seed)
    if image:
        x = rng.standard_normal((n, 32, 32, 3)).astype(np.float32)
        feats = x.reshape(n, -1)
    else:
        x = rng.standard_normal((n, 3072)).astype(np.float32)
        feats = x
    W = rng.standard_normal((feats.shape[1], n_classes)) / np.sqrt(feats.shape[1])
    y = np.argmax(feats @ W, axis=1)
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def class_batch(x, y, batch_size: int, step: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    idx = rng.integers(0, x.shape[0], batch_size)
    return {"x": x[idx], "y": y[idx]}
