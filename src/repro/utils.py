"""Small shared utilities: sharding hints, tree helpers, dtype handling."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

PyTree = Any

# Symbolic axis names used throughout the model code; resolved against the
# active mesh at trace time.  "dp" = all data-parallel axes present
# (('pod','data') or ('data',)), "tp" = the tensor/model axis.
DP = "dp"
TP = "tp"


def _active_axes() -> tuple[tuple[str, ...], str | None]:
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return (), None
    manual = set(mesh.manual_axes)
    names = [a for a in mesh.axis_names if a not in manual]
    dp = tuple(a for a in names if a in ("pod", "data", "replica"))
    tp = "model" if "model" in names else None
    return dp, tp


def hint(x: jax.Array, *spec: Any) -> jax.Array:
    """Sharding constraint with symbolic axes; no-op without a mesh.

    spec entries: None, "dp", "tp", or ("dp","tp"). Axes not present in the
    current (non-manual) mesh are dropped, so the same model code runs on a
    bare CPU, inside a manual-over-data shard_map, or under full-auto pjit.
    """
    mesh = compat.get_abstract_mesh()
    if mesh is not None and mesh.manual_axes \
            and not compat.PARTIAL_AUTO_SAFE:
        return x  # see compat.PARTIAL_AUTO_SAFE
    dp, tp = _active_axes()
    if not dp and tp is None:
        return x

    def resolve(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            axes: list[str] = []
            for s in e:
                r = resolve(s)
                if r is None:
                    continue
                axes.extend(r if isinstance(r, tuple) else (r,))
            return tuple(axes) or None
        if e == DP:
            return dp or None
        if e == TP:
            return tp
        return e if e in (list(dp) + [tp]) else None

    resolved = tuple(resolve(e) for e in spec)
    if all(e is None for e in resolved):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x


def cast_tree(tree: PyTree, dtype) -> PyTree:
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def tree_size(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def split_like(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))
