"""Per-round client participation sampling (DESIGN.md §13).

Masks are built HOST-SIDE with numpy, exactly like the data pipelines in
``repro.data.synthetic``: every mask is a pure function of
``(seed, round_idx)`` through a ``np.random.SeedSequence``, so every
process derives the identical mask without coordination and restarts
reproduce the same participation history from the step counter alone.
The mask then enters the train step as a replicated batch input — the
cohort exchange never needs a collective to agree on who participated.

Two samplers (``SAMPLERS``):

* ``fixed``     — exactly ``clients_per_round`` distinct clients,
  uniformly without replacement (the classic FedAvg sampler).
* ``bernoulli`` — each client participates independently with
  probability ``rate`` (partial-participation analyses, e.g.
  arXiv 2002.11364 §4).

``straggler_rate`` then drops each *selected* client independently —
the sampled-but-never-reported straggler model.  A round that ends with
zero participants raises :class:`ZeroParticipationError` instead of
letting a 0/0 aggregate turn into silent NaN updates downstream.
"""
from __future__ import annotations

import numpy as np

SAMPLERS = ("fixed", "bernoulli")

# SeedSequence domain tags: keep the sampling stream independent of the
# data streams (which key on [seed, step, shard]) and of each other.
_SAMPLE_TAG = 0x5ED5_A3B1
_STRAGGLER_TAG = 0x57A6_6E12


class ZeroParticipationError(ValueError):
    """No client survived sampling + straggler dropout this round."""


def validate_sampler(mode: str) -> None:
    if mode not in SAMPLERS:
        raise ValueError(f"unknown client sampler {mode!r} "
                         f"(want one of {SAMPLERS})")


def participation_mask(n_clients: int, round_idx: int, *, seed: int = 0,
                       mode: str = "fixed", clients_per_round: int = 0,
                       rate: float = 1.0,
                       straggler_rate: float = 0.0) -> np.ndarray:
    """The (n_clients,) float32 0/1 participation mask for one round.

    Deterministic in ``(seed, round_idx)`` and every config argument;
    independent of process, device count, or call order.  ``fixed`` mode
    selects exactly ``clients_per_round`` clients (0 -> all); bernoulli
    mode selects each with probability ``rate``.  Raises
    :class:`ZeroParticipationError` when nobody participates.
    """
    validate_sampler(mode)
    if n_clients <= 0:
        raise ValueError(f"n_clients must be positive, got {n_clients}")
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, round_idx, _SAMPLE_TAG]))
    mask = np.zeros((n_clients,), np.float32)
    if mode == "fixed":
        k = clients_per_round or n_clients
        if not 0 < k <= n_clients:
            raise ValueError(
                f"clients_per_round={clients_per_round} out of range "
                f"for n_clients={n_clients}")
        mask[rng.choice(n_clients, size=k, replace=False)] = 1.0
    else:  # bernoulli
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"participation rate must be in [0, 1], "
                             f"got {rate}")
        mask[rng.random(n_clients) < rate] = 1.0
    if straggler_rate:
        if not 0.0 <= straggler_rate < 1.0:
            raise ValueError(f"straggler_rate must be in [0, 1), "
                             f"got {straggler_rate}")
        srng = np.random.default_rng(
            np.random.SeedSequence([seed, round_idx, _STRAGGLER_TAG]))
        mask *= (srng.random(n_clients) >= straggler_rate)
    if mask.sum() == 0:
        raise ZeroParticipationError(
            f"round {round_idx}: no participating clients "
            f"(mode={mode!r}, clients_per_round={clients_per_round}, "
            f"rate={rate}, straggler_rate={straggler_rate}) — a 0/0 "
            f"aggregate would emit NaN updates; resample with a higher "
            f"rate or lower straggler_rate")
    return mask
