"""Sparsity-aware aggregation of decoded top-k client payloads
(DESIGN.md §13, ``fed_dropout_avg``-style).

The dense pmean the dp exchange applies divides every coordinate's sum
by the FULL worker count — with top-k payloads that averages implicit
zeros into every coordinate a client never sent, shrinking the update by
roughly the per-coordinate sparsity (the defect called out in
ROADMAP.md).  With 8 homogeneous workers and EF the bias is survivable;
with hundreds of partially-participating clients it collapses the
effective step size.

``aggregation="support"`` fixes it: each coordinate's sum is divided by
its **nonzero-support count** — how many *participating* clients shipped
a nonzero decoded value there.  Support is computed from the decoded
values themselves, so block-padding clamp entries and masked-beyond-k_t
tails (both decode to exactly 0.0) never count, and no extra wire field
is needed.  Coordinates nobody sent get 0 (no update), not 0/0.

``aggregation="mean"`` keeps the zero-averaging dense mean as the
reference.  When every participant sends every coordinate the two are
the same division on the same operands — bit-exact, pinned in
``tests/test_compression.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.leafmath import scatter_layers

AGGREGATIONS = ("support", "mean")


def validate_aggregation(name: str) -> None:
    if name not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {name!r} "
                         f"(want one of {AGGREGATIONS})")


def scatter_with_support(vals: jax.Array, idx: jax.Array,
                         weights: jax.Array, L: int, d: int
                         ) -> tuple[jax.Array, jax.Array]:
    """Scatter (N, L, k) decoded client rows into a dense (L, d) sum and
    its per-coordinate support count.

    ``weights``: (N,) 0/1 participation — non-participants contribute to
    neither.  Support counts clients with a NONZERO decoded value at the
    coordinate, so decode-to-zero entries (ragged tails, padding clamps,
    values quantized to zero) are invisible, matching what receivers
    actually apply.
    """
    w = weights.astype(jnp.float32).reshape(-1, 1, 1)
    total = scatter_layers(vals * w, idx, L, d, jnp.float32)
    nonzero = (vals != 0.0).astype(jnp.float32) * w
    support = scatter_layers(nonzero, idx, L, d, jnp.float32)
    return total, support


def support_weighted_mean(total: jax.Array,
                          support: jax.Array) -> jax.Array:
    """total / support where supported, 0 elsewhere (never 0/0)."""
    return jnp.where(support > 0.0,
                     total / jnp.maximum(support, 1.0),
                     jnp.zeros_like(total))


def zero_averaged_mean(total: jax.Array,
                       n_participants: jax.Array) -> jax.Array:
    """The dense-pmean reference: unsent coordinates average as zeros."""
    n = jnp.maximum(jnp.asarray(n_participants, jnp.float32), 1.0)
    return total / n


def aggregate_decoded(vals: jax.Array, idx: jax.Array, weights: jax.Array,
                      L: int, d: int, n_participants: jax.Array,
                      aggregation: str) -> jax.Array:
    """One leaf's aggregated (L, d) update from all N decoded client rows.

    When support equals ``n_participants`` at every coordinate (every
    participant sent every coordinate — gamma at budget, 32-bit values)
    the two modes perform the identical division and agree bit-exactly.
    """
    validate_aggregation(aggregation)
    total, support = scatter_with_support(vals, idx, weights, L, d)
    if aggregation == "support":
        return support_weighted_mean(total, support)
    return zero_averaged_mean(total, n_participants)
