"""Per-client optimizer state and the vmap'd cohort compressed exchange
(DESIGN.md §13).

Each dp worker simulates ``C = n_clients / W`` clients by ``vmap``-ing
the EXISTING selection/encode stage (``repro.core.leafmath.
select_and_encode`` + ``repro.comm.bucket.encode_buckets`` — the same
§8/§9/§11 wire math every transport runs) over a per-client leading
axis, then moves every client's payload on the SAME O(1) collective
schedule the bucketed dp transport uses:

* ONE flat ``all_gather`` of the (C, total_words) client payload block —
  gathered to (W*C, total_words), i.e. the whole cohort's ragged
  payloads ride one fixed-shape collective exactly like heterogeneous
  per-worker k_t does on the dp path;
* ONE ``psum`` carrying the concatenated participation-weighted dense
  small leaves AND the effective-byte counter (always exactly one
  all_reduce, dense leaves or not).

Client IDs map to gather rows as ``worker * C + c`` (``lax.axis_index``
over the dp axes is row-major, matching ``all_gather`` stacking), so the
host-built participation mask — replicated, never sharded — indexes the
gathered decode directly and no collective is ever needed to agree on
who participated.

The cohort forces ``use_kernel=False``: the Pallas EF kernels run in
interpret mode off-TPU and do not batch under ``vmap``; the pure-jnp
selection path is bit-compatible wire-wise and vmaps freely (the wire
pack/unpack codec dispatches to its jnp reference off-TPU already).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.comm import faults
from repro.comm.bucket import (build_bucket_plan, decode_buckets,
                               encode_buckets)
from repro.comm.exchange import check_bucket_payload, gather_packed
from repro.core.gamma import gamma_init
from repro.core.leafmath import (dp_index, dp_size, scatter_layers,
                                 select_and_encode)
from .aggregate import aggregate_decoded, validate_aggregation

PyTree = Any
AxisNames = Sequence[str] | str


class ClientState(NamedTuple):
    """Per-client carried optimizer state, leaves client-leading.

    Stored in ``DistOptState.fed`` with GLOBAL (n_clients, ...) leaves
    sharded over the dp axes on dim 0; inside the worker each field is
    the local (C, ...) slice.  Only participating clients advance: EF
    memory, gamma, the round counter, and the carried Armijo step of a
    non-participant are bit-frozen through the round.
    """

    memory: PyTree        # per-client EF: leaves (C, *param_shape)
    gamma: jax.Array      # (C,) per-client per-round compression level
    rounds: jax.Array     # (C,) int32 participation counter (drives the
                          # per-client linear gamma schedule)
    alpha: jax.Array      # (C,) per-client carried Armijo step size


def init_client_state(params: PyTree, opt, n_clients: int,
                      abstract: bool = False) -> ClientState:
    """Initial :class:`ClientState` with (n_clients, ...) leaves.

    ``opt`` duck-types :class:`repro.configs.base.OptimizerConfig`
    (reads ``ef_dtype``, ``armijo.alpha0``, ``gamma_controller``,
    ``compressor``).
    """
    ef_dt = jnp.dtype(opt.ef_dtype)

    def mem_leaf(p):
        shape = (n_clients,) + tuple(p.shape)
        if abstract:
            return jax.ShapeDtypeStruct(shape, ef_dt)
        return jnp.zeros(shape, ef_dt)

    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
        (lambda s, d: jnp.zeros(s, d))
    return ClientState(
        memory=jax.tree.map(mem_leaf, params),
        gamma=(mk((n_clients,), jnp.float32) if abstract else
               jnp.full((n_clients,),
                        gamma_init(opt.gamma_controller, opt.compressor),
                        jnp.float32)),
        rounds=mk((n_clients,), jnp.int32),
        alpha=(mk((n_clients,), jnp.float32) if abstract else
               jnp.full((n_clients,), opt.armijo.alpha0, jnp.float32)),
    )


def local_participation(mask: jax.Array, dp_axes: AxisNames | None,
                        n_local: int) -> jax.Array:
    """This worker's (C,) slice of the replicated (W*C,) cohort mask."""
    m = jnp.asarray(mask, jnp.float32)
    if dp_axes is None:
        return m
    w = dp_index(dp_axes)
    return jax.lax.dynamic_slice_in_dim(m, w * n_local, n_local)


def per_client_wire_bytes(plan) -> int:
    """Static uplink bytes ONE participating client transmits per round:
    its flat packed payload plus its dense small leaves (f32)."""
    dense = sum(_size(ln.shape) for ln in plan.leaves if ln.dense)
    return plan.total_words * 4 + dense * 4


def _size(shape: Sequence[int]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def cohort_compress_aggregate(
    grads: PyTree,
    memory: PyTree,
    eta_c: jax.Array,
    comp,
    dp_axes: AxisNames | None,
    participation: jax.Array,
    gamma_c: jax.Array | None = None,
    *,
    stacked_mask: PyTree | None = None,
    aggregation: str = "support",
    impl: str | None = None,
    return_quarantined: bool = False,
) -> tuple:
    """The cohort round: per-client select/encode under ``vmap``, ONE
    gather of every client's payload, support-weighted decode.

    ``grads`` / ``memory``: leaves client-leading ``(C, *shape)`` — this
    worker's local cohort.  ``eta_c``: per-client step sizes ``(C,)`` (a
    scalar broadcasts).  ``participation``: the REPLICATED global
    ``(W*C,)`` 0/1 mask from :func:`repro.fed.sampling.
    participation_mask` — client ``w*C + c`` is worker w's c-th row.
    ``gamma_c``: per-client traced compression levels ``(C,)`` (adaptive
    compressors; heterogeneous per-client k_t ride the same fixed-shape
    gather via the §9 valid-count headers).  ``dp_axes=None`` runs the
    whole cohort collective-free on one device (W=1).

    Returns ``(updates, new_memory, wire_bytes, effective_wire_bytes)``:
    ``updates`` is the aggregated dense tree (leaves ``(*shape,)``, the
    same on every worker), ``new_memory`` the per-client EF tree —
    participants recycle ``acc - decode(own payload)`` exactly like the
    dp path (own rows sliced from the gathered decode), non-participants
    are untouched.  ``wire_bytes`` prices the semantic uplink: only
    participants transmit, so it is ``n_participants *``
    :func:`per_client_wire_bytes`; ``effective_wire_bytes`` is the
    participant sum of per-client §9 ragged byte costs.

    Decoded rows pass the §16 validity verdict (quarantined rows carry
    zero mass, so the nonzero-support division excludes them
    automatically; under ``aggregation="mean"`` they degrade toward zero
    instead), and a client whose OWN row was quarantined keeps its EF
    memory frozen for the round exactly like a non-participant — the
    payload never reached the cohort intact.  With
    ``return_quarantined`` a fifth element is appended: this worker's
    f32 count of quarantined gathered rows this round.
    """
    validate_aggregation(aggregation)
    # vmap-safe selection: see module docstring
    comp = dataclasses.replace(comp, use_kernel=False)
    W = dp_size(dp_axes) if dp_axes is not None else 1
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(memory)
    if not flat_g:
        raise ValueError("empty gradient tree")
    C = flat_g[0].shape[0]
    N = W * C
    if stacked_mask is None:
        flat_s = [leaf.ndim - 1 >= 2 for leaf in flat_g]
    else:
        flat_s = treedef.flatten_up_to(stacked_mask)
    part = jnp.asarray(participation, jnp.float32)
    if part.shape != (N,):
        raise ValueError(f"participation mask is {part.shape}, cohort "
                         f"has {N} clients ({W} workers x {C})")
    eta_c = jnp.broadcast_to(jnp.asarray(eta_c, jnp.float32), (C,))
    if gamma_c is None:
        gamma_c = jnp.full((C,), comp.gamma if comp.adaptive else 0.0,
                           jnp.float32)

    shapes = [g.shape[1:] for g in flat_g]
    plan = build_bucket_plan(shapes, flat_s, comp)
    lanes = plan.leaves
    n = len(lanes)
    pl = local_participation(part, dp_axes, C)           # (C,)
    n_part = jnp.sum(part)                               # replicated scalar

    # ---- per-client selection + encode, ONE vmap over the cohort --------
    def encode_one(gs, ms, eta, gamma_t):
        sel = select_and_encode(list(gs), list(ms), flat_s, eta, comp,
                                gamma_t, plan)
        payload = (encode_buckets(plan, sel.enc_rows, impl=impl)
                   if plan.total_words else jnp.zeros((0,), jnp.uint32))
        accs, dense_accs = [], []
        eff = jnp.float32(0.0)
        for lane, g, m in zip(lanes, gs, ms):
            if lane.dense:
                accs.append(None)
                dense_accs.append(m.astype(jnp.float32)
                                  + eta * g.astype(jnp.float32))
                eff = eff + jnp.float32(_size(lane.shape) * 4)
            else:
                accs.append(sel.acc2[lane.index])        # (L, d) f32
                dense_accs.append(None)
                spec = lane.spec
                if spec.ragged:
                    eff = eff + jnp.float32(lane.L) * \
                        spec.effective_row_bytes(sel.counts[lane.index])
                else:
                    eff = eff + jnp.float32(lane.L * spec.row_bytes)
        return payload, accs, dense_accs, eff

    payload_c, acc_c, dense_c, eff_c = jax.vmap(encode_one)(
        tuple(flat_g), tuple(flat_m), eta_c, gamma_c)

    # ---- ONE gather: the whole cohort's payload block -------------------
    decoded = [None] * n
    verdicts = [None] * n
    if plan.total_words:
        check_bucket_payload(payload_c[0], plan, comp)
        if dp_axes is None:
            all_pay = payload_c                          # (N, total_words)
        else:
            all_pay = gather_packed(payload_c, dp_axes).reshape(
                N, plan.total_words)
        if faults.guards_active():
            decoded, verdicts = decode_buckets(plan, all_pay, impl=impl,
                                               with_verdicts=True)
        else:
            decoded = decode_buckets(plan, all_pay, impl=impl)

    w_idx = dp_index(dp_axes) if dp_axes is not None else 0

    updates: list = [None] * n
    new_mem: list = [None] * n

    # ---- dense small leaves + eff counter: ONE psum ---------------------
    # (dense rows reach every participant in full, so support equals
    # n_participants at every coordinate and both aggregations are the
    # same division — one code path, bit-consistent with "support")
    dense_ids = list(plan.dense_ids)
    vec_parts = []
    for i in dense_ids:
        acc = dense_c[i]                                 # (C, *shape)
        wl = pl.reshape((C,) + (1,) * (acc.ndim - 1))
        vec_parts.append(jnp.sum(acc * wl, axis=0).reshape(-1))
        keep = wl > 0.0
        new_mem[i] = jnp.where(keep, 0.0, flat_m[i].astype(jnp.float32)
                               ).astype(flat_m[i].dtype)
    vec_parts.append((pl @ eff_c).reshape(1))
    vec = jnp.concatenate(vec_parts)
    if dp_axes is not None:
        vec = jax.lax.psum(vec, dp_axes)
    eff_wire = vec[-1]
    off = 0
    for i in dense_ids:
        size = _size(lanes[i].shape)
        updates[i] = (vec[off:off + size]
                      / jnp.maximum(n_part, 1.0)).reshape(lanes[i].shape)
        off += size

    # ---- compressed leaves: support-weighted aggregate + per-client EF --
    quar = jnp.float32(0.0)
    for lane in lanes:
        if lane.dense:
            continue
        i, L, d = lane.index, lane.L, lane.d
        vals, idx = decoded[i]                           # (N, L, k)
        agg = aggregate_decoded(vals, idx, part, L, d, n_part, aggregation)
        updates[i] = agg.reshape(lane.shape)
        # own decode sliced from the gather — no second decode, exactly
        # the dp-path EF contract (_consume_decoded_leaf)
        own_vals = jax.lax.dynamic_slice_in_dim(vals, w_idx * C, C, 0)
        own_idx = jax.lax.dynamic_slice_in_dim(idx, w_idx * C, C, 0)
        own_dense = jax.vmap(
            lambda v, ix: scatter_layers(v, ix, L, d, jnp.float32))(
            own_vals, own_idx)                           # (C, L, d)
        m3 = flat_m[i].astype(jnp.float32).reshape(C, L, d)
        keep = pl.reshape(C, 1, 1) > 0.0
        if verdicts[i] is not None:
            # a quarantined own row freezes that client's EF for the
            # round, like a non-participant (§16)
            own_ok = jax.lax.dynamic_slice_in_dim(
                verdicts[i], w_idx * C, C, 0)            # (C, L)
            keep = keep & own_ok[:, :, None]
            quar = quar + jnp.sum(
                1.0 - verdicts[i].astype(jnp.float32))
        r = jnp.where(keep, acc_c[i] - own_dense, m3)
        new_mem[i] = r.reshape(flat_m[i].shape).astype(flat_m[i].dtype)

    wire = n_part * jnp.float32(per_client_wire_bytes(plan))
    out = (treedef.unflatten(updates), treedef.unflatten(new_mem),
           wire, eff_wire)
    return out + (quar,) if return_quarantined else out
