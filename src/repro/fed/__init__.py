"""Federated cohort simulation tier (DESIGN.md §13).

A cohort layer ABOVE the dp mesh: each data-parallel worker ``vmap``s
``C = n_clients / W`` simulated clients through the existing §8/§9
compressed exchange, so one 8-device host stands in for hundreds of
heterogeneous federated clients per round.

* :mod:`repro.fed.sampling`  — host-side deterministic participation
  masks (Bernoulli / fixed-size sampling, straggler dropout).
* :mod:`repro.fed.aggregate` — sparsity-aware support-weighted
  aggregation of decoded top-k payloads (``fed_dropout_avg``-style),
  with the dense zero-averaging mean retained as the reference.
* :mod:`repro.fed.clients`   — per-client EF memory / gamma / Armijo
  state and the cohort exchange itself (ONE all_gather + ONE psum for
  the whole cohort, regardless of client count).
"""
from .aggregate import (AGGREGATIONS, aggregate_decoded,
                        scatter_with_support, support_weighted_mean,
                        zero_averaged_mean)
from .clients import (ClientState, cohort_compress_aggregate,
                      init_client_state, local_participation,
                      per_client_wire_bytes)
from .sampling import (SAMPLERS, ZeroParticipationError,
                       participation_mask)

__all__ = [
    "AGGREGATIONS", "SAMPLERS", "ClientState", "ZeroParticipationError",
    "aggregate_decoded", "cohort_compress_aggregate", "init_client_state",
    "local_participation", "participation_mask", "per_client_wire_bytes",
    "scatter_with_support", "support_weighted_mean", "zero_averaged_mean",
]
