"""Version-compat shims for JAX APIs that moved between 0.4.x and 0.5+.

Every version-sensitive JAX lookup in the codebase lives HERE and only here
(DESIGN.md §7).  The rest of the code is written against the *new* API
surface (``jax.set_mesh``, ``jax.shard_map(axis_names=..., check_vma=...)``,
``jax.sharding.get_abstract_mesh``, ``jax.lax.axis_size``) and this module
backfills it on JAX 0.4.x:

* :func:`set_mesh`            — ``jax.set_mesh`` | ``with mesh:`` + own ctx
* :func:`get_abstract_mesh`   — returns a :class:`MeshView` (mesh + manual
                                axes) or None; on 0.4.x the view is tracked
                                by this module's context stack, which
                                :func:`shard_map` and :func:`set_mesh` push
* :func:`shard_map`           — new keyword API on top of
                                ``jax.experimental.shard_map`` (``axis_names``
                                -> ``auto`` complement, ``check_vma`` ->
                                ``check_rep``)
* :func:`axis_size`           — ``jax.lax.axis_size`` | ``lax.psum(1, ax)``
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
from typing import Any, Callable, Iterable, Sequence

import jax

_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")

#: On 0.4.x XLA, partially-manual shard_map does not compose with
#: ``lax.scan``: any operand sharded over an *auto* axis reaching a scan
#: inside the manual body (via with_sharding_constraint or an input's
#: committed sharding) trips ``Check failed: sharding.IsManualSubgroup()``.
#: When False, utils.hint() drops hints inside manual regions and the
#: train step keeps params/EF-memory replicated over the auto 'model'
#: axis (pure-pjit serving paths keep full TP either way).
PARTIAL_AUTO_SAFE = _HAS_JAX_SHARD_MAP


@dataclasses.dataclass(frozen=True)
class MeshView:
    """Uniform view of the active mesh for trace-time introspection.

    ``mesh`` is the underlying concrete ``jax.sharding.Mesh`` (or the native
    abstract mesh on new JAX); ``manual_axes`` is the set of axis names that
    are manual (shard_map-bound) at the current trace point — model code uses
    it to drop axes that must not appear in sharding hints.
    """

    mesh: Any
    manual_axes: frozenset = frozenset()

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def shape(self) -> dict[str, int]:
        return dict(self.mesh.shape)


# Trace-time context for 0.4.x, pushed by set_mesh / shard_map below.
_ACTIVE: contextvars.ContextVar[MeshView | None] = \
    contextvars.ContextVar("repro_active_mesh", default=None)


def _as_mesh(mesh) -> Any:
    return mesh.mesh if isinstance(mesh, MeshView) else mesh


def get_abstract_mesh() -> MeshView | None:
    """The mesh visible at the current trace point, or None outside any."""
    if _HAS_GET_ABSTRACT_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not getattr(m, "axis_names", ()):
            return None
        return MeshView(m, frozenset(getattr(m, "manual_axes", ()) or ()))
    view = _ACTIVE.get()
    if view is not None:
        return view
    from jax._src import mesh as _mesh_lib
    phys = _mesh_lib.thread_resources.env.physical_mesh
    if phys.empty:
        return None
    return MeshView(phys)


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(mesh):`` — the new-JAX ``jax.set_mesh`` everywhere.

    On 0.4.x this both enters the classic ``with mesh:`` context (so bare
    ``PartitionSpec`` sharding constraints resolve) and pushes the mesh onto
    this module's view stack for :func:`get_abstract_mesh`.
    """
    if _HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
        return
    token = _ACTIVE.set(MeshView(_as_mesh(mesh)))
    try:
        with _as_mesh(mesh):
            yield mesh
    finally:
        _ACTIVE.reset(token)


def shard_map(f: Callable, *, mesh=None, in_specs, out_specs,
              axis_names: Iterable[str], check_vma: bool = False) -> Callable:
    """New-style ``jax.shard_map`` keyword API on every supported JAX.

    ``axis_names`` is the set of mesh axes this shard_map is *manual* over;
    the remaining axes stay auto (XLA-partitioned).  ``mesh=None`` resolves
    the mesh from the surrounding :func:`set_mesh` / shard_map context
    (nested use).
    """
    manual = frozenset(axis_names)
    if mesh is None:
        view = get_abstract_mesh()
        if view is None:
            raise ValueError("shard_map: no mesh given and none active")
        mesh = view
    base = _as_mesh(mesh)

    if _HAS_JAX_SHARD_MAP:
        return jax.shard_map(f, mesh=base, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=check_vma)

    from jax.experimental.shard_map import shard_map as _shard_map

    outer = _ACTIVE.get()
    outer_manual = outer.manual_axes if outer is not None else frozenset()
    auto = frozenset(base.axis_names) - manual - outer_manual

    @functools.wraps(f)
    def wrapped(*args):
        token = _ACTIVE.set(MeshView(base, manual | outer_manual))
        try:
            return f(*args)
        finally:
            _ACTIVE.reset(token)

    return _shard_map(wrapped, base, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def named_sharding(mesh, spec, memory_kind: str | None = None):
    """NamedSharding with a best-effort ``memory_kind``.

    Backends disagree on which memory kinds exist ("device" is not a valid
    kind on the 0.4.x CPU backend); when the requested kind is not
    addressable, fall back to the device default rather than erroring.
    """
    from jax.sharding import NamedSharding
    if memory_kind is None:
        return NamedSharding(_as_mesh(mesh), spec)
    try:
        return NamedSharding(_as_mesh(mesh), spec, memory_kind=memory_kind)
    except ValueError:
        return NamedSharding(_as_mesh(mesh), spec)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every JAX (0.4.x
    returns a one-element list of per-device dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def axis_size(axes: str | Sequence[str]):
    """Size of one mapped mesh axis (or the product over several)."""
    if isinstance(axes, str):
        axes = (axes,)
    if _HAS_AXIS_SIZE:
        n = 1
        for ax in axes:
            n = n * jax.lax.axis_size(ax)
        return n
    # psum of a Python literal folds to a static int on 0.4.x
    return jax.lax.psum(1, tuple(axes))
