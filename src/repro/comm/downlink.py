"""Compressed downlink — server-side re-compression of the aggregate
(DESIGN.md §15).

The uplink is bit-packed to the byte (§8/§9/§11) but the aggregate
historically returned to every worker as a dense mean — in the paper's
communication model that direction costs full dense bytes per link.  This
module closes the loop: after the bucketed gather decodes, the
(deterministic, replicated) mean update is pushed through the SAME
per-leaf :class:`~repro.comm.wire.WireSpec` geometry with its own
**server-side** error-feedback memory, and each worker applies
``decode(downlink payload)`` instead of the dense mean.

Because the gathered aggregate is bit-identical on every worker, the
server is *physically simulated*: every worker runs the identical
compress/EF computation and no extra collective is issued (the §11
schedule stays ONE all_gather + ONE pmean — HLO-pinned).  What changes is
the *accounted* downlink direction: ``downlink="dense"`` charges the full
dense aggregate bytes, ``downlink="compressed"`` charges the packed
payload rows (ragged §9 counts at the downlink gamma).

The server residual ``M_s' = (M_s + mean) - decode(payload)`` is carried
in :class:`DownlinkState` (threaded through ``DistOptState.downlink``)
so what the downlink compression drops this round is recycled into the
next round's broadcast — the bidirectional-EF construction of
"Acceleration for Compressed Gradient Descent" (arXiv 2002.11364) and
AdaCGD (arXiv 2211.00188).  The decode semantics reuse
:func:`repro.comm.wire.roundtrip_rows` (launch-free, bit-exact vs a
literal decode of the packed payload), batched across same-spec leaves
exactly like the overlap transport's delay-1 EF roundtrip.

Leaves the uplink ships dense (below ``min_compress_size``) return dense
on the downlink too, charged at the actual shipped f32 itemsize.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import wire as wire_fmt
from repro.comm.bucket import BucketPlan, build_bucket_plan
from repro.core.leafmath import compress_leaf, leaf_count, scatter_layers

__all__ = [
    "DownlinkState",
    "DownlinkCtx",
    "DownlinkResult",
    "MODES",
    "downlink_plan",
    "server_memory_size",
    "init_downlink_state",
    "dense_downlink_bytes",
    "downlink_wire_bytes",
    "apply_downlink",
]

MODES = ("dense", "compressed")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DownlinkState:
    """Server-side carried state, replicated across workers.

    ``memory``: the server EF residual, one flat f32 vector holding the
    compressed leaves' (L, d) rows back to back in tree order (dense
    leaves have no server memory — their aggregate returns exact).
    ``gamma``: the downlink gamma_t this round's ragged counts were
    masked at (advanced by the train step's downlink GammaController
    round before the exchange; carried here so restarts resume the
    schedule where it left off).
    """

    memory: jax.Array   # (server_size,) f32
    gamma: jax.Array    # () f32


@dataclasses.dataclass(frozen=True)
class DownlinkCtx:
    """This round's (traced) server state, handed to
    ``worker_compress_aggregate(downlink_ctx=...)``."""

    state: DownlinkState


class DownlinkResult(NamedTuple):
    """Trailing return element of a downlink-enabled exchange."""

    state: DownlinkState
    wire_bytes: jax.Array       # () f32 — static downlink budget
    eff_wire_bytes: jax.Array   # () f32 — ragged content at downlink gamma


def downlink_plan(shapes, stacked, comp) -> BucketPlan:
    """The downlink reuses the uplink's §11 plan verbatim: same per-leaf
    (L, d) geometry, same WireSpecs, same dense/compressed split."""
    return build_bucket_plan([tuple(s) for s in shapes], list(stacked), comp)


def server_memory_size(plan: BucketPlan) -> int:
    """Flat f32 words of server EF memory: sum of L*d over compressed
    leaves."""
    return sum(ln.L * ln.d for ln in plan.leaves if not ln.dense)


def init_downlink_state(shapes, stacked, comp, gamma0: float,
                        abstract: bool = False) -> DownlinkState:
    """Fresh (unbatched) server state for a gradient pytree with flat leaf
    ``shapes`` and per-leaf ``stacked`` flags — the SAME flags the worker
    passes to ``worker_compress_aggregate`` (``stacked_mask``), or the
    server memory offsets will not line up (the exchange raises at trace
    time on any size mismatch)."""
    plan = downlink_plan(shapes, stacked, comp)
    size = server_memory_size(plan)
    if abstract:
        return DownlinkState(
            memory=jax.ShapeDtypeStruct((size,), jnp.float32),
            gamma=jax.ShapeDtypeStruct((), jnp.float32))
    return DownlinkState(memory=jnp.zeros((size,), jnp.float32),
                         gamma=jnp.float32(gamma0))


def dense_downlink_bytes(shapes) -> float:
    """Per-link bytes the DENSE downlink charges: the full f32 aggregate
    of every leaf (the reference the compressed downlink must beat)."""
    total = 0
    for s in shapes:
        n = 1
        for x in tuple(s):
            n *= int(x)
        total += n
    return float(total * jnp.dtype(jnp.float32).itemsize)


def downlink_wire_bytes(plan: BucketPlan) -> float:
    """Static per-link downlink budget under ``downlink="compressed"``:
    packed payload rows for compressed leaves + dense f32 for the rest."""
    total = 0.0
    f32 = jnp.dtype(jnp.float32).itemsize
    for ln in plan.leaves:
        if ln.dense:
            n = 1
            for s in ln.shape:
                n *= int(s)
            total += n * f32
        else:
            total += ln.L * ln.spec.row_bytes
    return float(total)


def apply_downlink(flat_updates, flat_s, comp, state: DownlinkState):
    """One server round over the decoded mean updates (flat, tree order).

    ``flat_updates``: the transport's f32 mean updates (dense leaves'
    pmean included).  Returns ``(new_updates, new_state, wire, eff)``
    where ``new_updates[i] = decode(server payload_i)`` for compressed
    leaves (dense leaves pass through exact), ``new_state`` carries the
    server EF residual, and the byte counters describe the downlink
    direction per link (static budget / ragged content at
    ``state.gamma``).  Pure and replicated: every worker computes the
    identical result, so no collective is issued.
    """
    plan = downlink_plan([u.shape for u in flat_updates], flat_s, comp)
    lanes = plan.leaves
    n = len(lanes)
    size = server_memory_size(plan)
    if state.memory.shape != (size,):
        raise ValueError(
            f"DownlinkState.memory shape {state.memory.shape} does not "
            f"match the plan's server size (({size},)) — init the state "
            "with the same leaf shapes/stacked_mask/compressor the worker "
            "uses (see init_downlink_state)")

    f32 = jnp.dtype(jnp.float32).itemsize
    acc = [None] * n          # (L, d) server accumulators
    rows = [None] * n         # (vals, idx, counts) per compressed leaf
    counts = [None] * n
    mem_off = 0
    for ln in lanes:
        if ln.dense:
            continue
        i, L, d = ln.index, ln.L, ln.d
        u2 = flat_updates[i].astype(jnp.float32).reshape(L, d)
        m2 = state.memory[mem_off:mem_off + L * d].reshape(L, d)
        mem_off += L * d
        acc[i] = m2 + u2
        vals, idx, _ = compress_leaf(acc[i], comp, ln.stacked)
        counts[i] = leaf_count(comp, ln.spec, state.gamma, d)
        rows[i] = (vals, idx,
                   None if counts[i] is None
                   else jnp.broadcast_to(counts[i], (L,)))

    # decode(encode(...)) semantics without packed words, batched across
    # same-spec leaves (ONE launch-free roundtrip per spec group — the
    # overlap transport's delay-1 EF pattern, comm/overlap.py)
    own_rt = [None] * n
    by_spec: dict = {}
    for ln in lanes:
        if not ln.dense:
            by_spec.setdefault(ln.spec, []).append(ln)
    for gspec, group in by_spec.items():
        vals = jnp.concatenate([rows[l.index][0] for l in group])
        idxs = jnp.concatenate([rows[l.index][1] for l in group])
        cts = None
        if gspec.ragged:
            cts = jnp.concatenate([
                rows[l.index][2] if rows[l.index][2] is not None
                else jnp.full((l.L,), gspec.full_count, jnp.int32)
                for l in group])
        rv, ri = wire_fmt.roundtrip_rows(vals, idxs, gspec, counts=cts)
        off = 0
        for l in group:
            own_rt[l.index] = (rv[off:off + l.L], ri[off:off + l.L])
            off += l.L

    # per-leaf consumers, tree order (deterministic f32 byte accumulation,
    # matching the uplink counters' convention)
    new_updates = list(flat_updates)
    mem_parts = []
    wire = jnp.float32(0.0)
    eff = jnp.float32(0.0)
    for ln in lanes:
        i = ln.index
        if ln.dense:
            u = flat_updates[i]
            nbytes = jnp.float32(u.size * f32)
            wire = wire + nbytes
            eff = eff + nbytes
            continue
        spec, L, d = ln.spec, ln.L, ln.d
        dv, di = own_rt[i]
        dec = scatter_layers(dv, di, L, d, jnp.float32)
        mem_parts.append((acc[i] - dec).reshape(-1))
        new_updates[i] = dec.reshape(flat_updates[i].shape)
        wire = wire + jnp.float32(L * spec.row_bytes)
        eff = eff + (jnp.float32(L) * spec.effective_row_bytes(counts[i])
                     if spec.ragged else jnp.float32(L * spec.row_bytes))

    new_memory = (jnp.concatenate(mem_parts) if mem_parts
                  else jnp.zeros((0,), jnp.float32))
    new_state = DownlinkState(memory=new_memory, gamma=state.gamma)
    return new_updates, new_state, wire, eff
