"""Serverless gossip exchange over the bucketed wire format (DESIGN.md §12).

The third registered transport.  Same selection, same EF arithmetic, and
the same ONE flat uint32 payload buffer as ``transport="bucketed"``
(DESIGN.md §8/§9/§11) — but no worker ever sees the whole fleet: the
buffer moves by ``degree`` neighbor ``ppermute``\\ s along a fixed
:class:`~repro.comm.topology.Topology` instead of one ``all_gather``,
dense small leaves ride the same buffer bitcast to uint32 instead of a
``pmean``, and each worker averages only itself plus its neighbors with
the uniform Metropolis weight ``1/(degree+1)``.

Per round, per worker ``i`` with mixing row ``w_ij``:

1. select/encode ``acc_i = m_i + eta_i * g_i`` at the static budget —
   byte-identical payload to the bucketed transport
   (:func:`repro.core.leafmath.select_and_encode`);
2. exchange payloads with the ``degree`` neighbors (``ppermute`` per
   direction — ``degree x payload`` bytes on each worker's uplink, vs
   ``(W-1) x payload`` for the gather);
3. decode self + neighbors, form the consensus mix
   ``mix_i = sum_j w_ij decode(p_j)`` and the gossip error
   ``e_i = mix_i - decode(p_i)``;
4. EF residual exactly as centralized: ``m_i' = acc_i - decode(p_i)``
   (wire distortion recycles locally; the EF memory is BIT-IDENTICAL to
   the bucketed transport on identical inputs — pinned in
   tests/distributed/test_gossip_exchange.py);
5. AdaGossip-style adaptive consensus step (arXiv 2404.05919, scalar
   variant): ``v' = beta v + (1-beta) mean(e_i^2)`` and
   ``lr_t = min(lr_max, consensus_lr / (sqrt(v') + eps))`` — large
   consensus disagreement throttles the mixing step the way the
   gamma controller throttles compression;
6. this worker's update is ``decode(p_i) + lr_t * e_i`` — with
   ``lr_t == 1`` exactly the Metropolis-weighted neighborhood mean.

``(v, lr)`` thread through ``DistOptState.gossip`` the way
``CompressionTelemetry`` threads through ``DistOptState.telemetry``.
Per-worker parameter copies (workers now genuinely diverge) live next to
them — see ``launch/train_step.py``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.leafmath import scatter_layers, select_and_encode
from repro.core.telemetry import TelemetrySums, sparse_own_sums
from . import faults
from .bucket import build_bucket_plan, decode_buckets, encode_buckets
from .exchange import check_bucket_payload
from .topology import TOPOLOGIES, Topology
from .transport import register_transport


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    """Static gossip/consensus hyper-parameters (``OptimizerConfig.gossip``).

    ``consensus_lr`` is the numerator of the adaptive consensus step;
    ``beta``/``eps`` shape the second-moment EMA of the gossip error;
    ``lr_max`` caps the step (the cap is what the fixed-step CHOCO-style
    baseline would use — with a tiny ``v`` the adaptive step saturates
    there instead of diverging).
    """

    topology: str = "ring"
    consensus_lr: float = 1.0
    beta: float = 0.9
    eps: float = 1e-8
    lr_max: float = 1.0

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            want = " | ".join(f"'{t}'" for t in sorted(TOPOLOGIES))
            raise ValueError(f"unknown topology {self.topology!r} "
                             f"(want {want})")
        if not 0.0 <= self.beta < 1.0:
            raise ValueError(f"gossip beta must be in [0, 1), "
                             f"got {self.beta}")
        for field in ("consensus_lr", "eps", "lr_max"):
            if getattr(self, field) <= 0.0:
                raise ValueError(f"gossip {field} must be > 0, "
                                 f"got {getattr(self, field)}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GossipState:
    """Carried adaptive-consensus state, one scalar pair per worker."""

    v: jax.Array    # EMA second moment of the gossip error
    lr: jax.Array   # last applied consensus step (reporting/telemetry)

    @classmethod
    def init(cls, batch_shape: tuple[int, ...] = (), abstract: bool = False):
        """Neutral start: zero moment — the first round's step is simply
        ``min(lr_max, consensus_lr / eps) -> lr_max`` for any sane eps."""
        def leaf(v):
            if abstract:
                return jax.ShapeDtypeStruct(batch_shape, jnp.float32)
            return jnp.full(batch_shape, v, jnp.float32)
        return cls(v=leaf(0.0), lr=leaf(0.0))


@dataclasses.dataclass(frozen=True)
class GossipCtx:
    """Everything the gossip exchange needs beyond the shared interface:
    the static topology + config, and the carried (traced) state."""

    topology: Topology
    cfg: GossipConfig
    state: GossipState


def _single_axis(dp_axes) -> str:
    axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
    if len(axes) != 1:
        raise ValueError(
            "gossip transport needs a single data-parallel mesh axis "
            f"(lax.ppermute is single-axis), got {axes!r}")
    return axes[0]


@register_transport("gossip", stateful=True, description=(
    "serverless neighbor-ppermute exchange with Metropolis consensus "
    "averaging and an AdaGossip-style adaptive consensus step"))
def gossip_exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t,
                    W, *, ctx: GossipCtx):
    """Steps 4-6 of Algorithm 3 with a gossip consensus round in place of
    the global mean — see the module docstring for the per-round math."""
    axis = _single_axis(dp_axes)
    topo = ctx.topology
    if topo.n != W:
        raise ValueError(f"topology {topo.name!r} is built for {topo.n} "
                         f"workers but the dp axis has {W}")
    deg = topo.degree
    plan = build_bucket_plan([g.shape for g in flat_g], flat_s, comp)
    lanes = plan.leaves
    n = len(lanes)
    sel = select_and_encode(flat_g, flat_m, flat_s, eta, comp, gamma_t,
                            plan)

    # ---- ONE flat buffer: packed payload + bitcast dense small leaves.
    # Dense leaves cannot pmean here (gossip has no global collective by
    # contract — the HLO pin is ZERO all_reduce), so their f32 accumulators
    # ride the same uint32 buffer and mix like everything else.
    dense_ids = list(plan.dense_ids)
    dense_acc = [None] * n
    for i in dense_ids:
        dense_acc[i] = flat_m[i].astype(jnp.float32) \
            + eta * flat_g[i].astype(jnp.float32)
    parts = []
    if plan.total_words:
        payload = encode_buckets(plan, sel.enc_rows)
        check_bucket_payload(payload, plan, comp)
        parts.append(payload)
    if dense_ids:
        dense_cat = jnp.concatenate(
            [dense_acc[i].reshape(-1) for i in dense_ids])
        parts.append(jax.lax.bitcast_convert_type(dense_cat, jnp.uint32))
    buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    # ---- degree ppermutes of the ONE buffer (self row first) -----------
    rows = [buf] + [jax.lax.ppermute(buf, axis, perm)
                    for perm in topo.perms]
    all_rows = jnp.stack(rows)                    # (degree+1, words)

    decoded = [None] * n
    verdicts = [None] * n
    if plan.total_words:
        if faults.guards_active():
            decoded, verdicts = decode_buckets(
                plan, all_rows[:, :plan.total_words], with_verdicts=True)
        else:
            decoded = decode_buckets(plan, all_rows[:, :plan.total_words])
    mix_dense = [None] * n
    if dense_ids:
        dcat = jax.lax.bitcast_convert_type(
            all_rows[:, plan.total_words:], jnp.float32)
        mix_cat = jnp.sum(dcat, axis=0) / (deg + 1)
        off = 0
        for i in dense_ids:
            size = dense_acc[i].size
            mix_dense[i] = mix_cat[off:off + size].reshape(
                dense_acc[i].shape)
            off += size

    # ---- per-leaf consumers, ORIGINAL tree order: EF residual and byte /
    # telemetry accounting use the identical formulas (and f32 accumulation
    # order) as the centralized transports — wire bytes are PER LINK; the
    # uplink total is degree x wire (examples/distributed_training.py).
    new_mem = [None] * n
    own_upd = [None] * n    # decode(own payload), dense f32
    gerr = [None] * n       # mix - decode(own): the consensus correction
    wire = jnp.float32(0.0)
    eff_wire = jnp.float32(0.0)
    sums = TelemetrySums.zero()
    err_sq = jnp.float32(0.0)
    n_tot = 0
    for lane, g, m in zip(lanes, flat_g, flat_m):
        i = lane.index
        if lane.dense:
            acc = dense_acc[i]
            own_upd[i], gerr[i] = acc, mix_dense[i] - acc
            new_mem[i] = jnp.zeros_like(m)
            nbytes = jnp.float32(acc.size * acc.dtype.itemsize)
            wire = wire + nbytes
            eff_wire = eff_wire + nbytes
            sums = sums.add_dense(acc, g)
            err_sq = err_sq + jnp.sum(gerr[i] * gerr[i])
            n_tot += acc.size
            continue
        spec, L, d = lane.spec, lane.L, lane.d
        g_vals, g_idx = decoded[i]                # (degree+1, L, k)
        total = scatter_layers(g_vals, g_idx, L, d, jnp.float32)
        if verdicts[i] is None:
            mix = total / (deg + 1)
        else:
            # §16 quarantine: invalid neighbor rows arrive zeroed; the
            # Metropolis denominator shrinks to the valid-row count.
            # Quarantine guarantees zero total when support is zero, so
            # /max(s,1) answers 0 without the fed helper's extra `where`
            # pass (bit-exact to /(deg+1) on a clean wire)
            n_valid = jnp.sum(verdicts[i].astype(jnp.float32), axis=0)
            mix = total / jnp.maximum(n_valid[:, None], 1.0)
        own_vals, own_idx = g_vals[0], g_idx[0]
        own_dense = scatter_layers(own_vals, own_idx, L, d, jnp.float32)
        e = mix - own_dense
        if sel.use_fused:
            r = sel.resid[i] + (sel.sent[i] - own_dense)
        else:
            r = sel.acc2[i] - own_dense
        quar = jnp.float32(0.0)
        if verdicts[i] is not None:
            # own row (slot 0) quarantined: freeze this leaf's EF
            own_ok = verdicts[i][0]                          # (L,)
            m2f = m.astype(jnp.float32).reshape(L, d)
            r = jnp.where(own_ok[:, None], r, m2f)
            quar = jnp.float32(verdicts[i].size) - jnp.sum(n_valid)
        new_mem[i] = r.reshape(m.shape).astype(m.dtype)
        own_upd[i], gerr[i] = own_dense, e
        wire = wire + jnp.float32(L * spec.row_bytes)
        eff_wire = eff_wire + (
            jnp.float32(L) * spec.effective_row_bytes(sel.counts[i])
            if spec.ragged else jnp.float32(L * spec.row_bytes))
        own_sq, own_dot = sparse_own_sums(own_vals, own_idx, sel.g2f[i])
        sums = sums.add(g_sq=sel.leaf_g_sq[i], acc_sq=sel.leaf_acc_sq[i],
                        resid_sq=jnp.sum(r * r), own_sq=own_sq,
                        own_dot_g=own_dot, quar_rows=quar)
        err_sq = err_sq + jnp.sum(e * e)
        n_tot += L * d

    # ---- AdaGossip adaptive consensus step (scalar second moment) ------
    cfg, state = ctx.cfg, ctx.state
    # float(n_tot): a static Python int here can exceed int32 on
    # billion-parameter trees, which jnp would reject as a traced operand
    v_new = cfg.beta * state.v \
        + (1.0 - cfg.beta) * (err_sq / float(n_tot))
    lr_t = jnp.minimum(jnp.float32(cfg.lr_max),
                       cfg.consensus_lr / (jnp.sqrt(v_new) + cfg.eps))
    updates = []
    for lane, g in zip(lanes, flat_g):
        i = lane.index
        u = own_upd[i] + lr_t * gerr[i]
        updates.append(u if lane.dense else u.reshape(g.shape))
    return (updates, new_mem, wire, eff_wire, sums,
            GossipState(v=v_new, lr=lr_t))


def gossip_mix(tree, topo: Topology, axis_name: str, lr: float = 1.0):
    """One UNCOMPRESSED gossip round on a pytree of per-worker values
    (inside a shard_map manual over ``axis_name``):

        x_i' = x_i + (lr / (degree+1)) * sum_{j in N(i)} (x_j - x_i)

    The difference form makes a constant tree a fixed point BIT-EXACTLY
    (every ``x_j - x_i`` is literally zero) and matches
    :meth:`Topology.mix_reference` term for term.  Used by the consensus
    contraction tests and as the plain-parameter-averaging building block.
    """
    w = lr / (topo.degree + 1)

    def mix_leaf(x):
        acc = None
        for perm in topo.perms:
            delta = jax.lax.ppermute(x, axis_name, perm) - x
            acc = delta if acc is None else acc + delta
        if acc is None:
            return x
        return x + jnp.asarray(w, x.dtype) * acc

    return jax.tree.map(mix_leaf, tree)
