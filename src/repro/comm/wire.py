"""Bit-packed compressed-gradient wire format (DESIGN.md §8).

This module makes ``Compressor.wire_bytes`` *physically real*: the sparse
(values, indices) pairs that DCSGD exchanges per layer row are encoded into
one contiguous ``uint32`` payload whose byte length IS the accounted wire
cost, and that payload is what crosses the mesh axis in
``dcsgd.worker_compress_aggregate``.

Row layout (all sizes static given a :class:`WireSpec`)::

    [ header | index section | value section ]        (uint32 words)

* **header** — 1 word iff ``value_bits <= 8``: the f32 bits of the per-row
  absmax quantization scale (``compression.quant_scale``).  16/32-bit
  values are self-describing; no header.
* **index section** — k fields of ``index_bits`` each, bit-packed
  little-endian within words (kernels/ref.py layout), zero-padded to a
  whole word.  ``block_topk`` rows store *block-local* 16-bit indices: the
  wire ships exactly ``k_b`` entries per block in block order, so entry j
  belongs to block ``j // k_b`` and only ``idx % block`` needs encoding.
  Exact ``topk`` rows store flat indices (16-bit when d fits, else 32).
* **value section** — k fields of ``value_bits`` each: raw f32 bits (32),
  bfloat16 bits (16), or two's-complement absmax-scaled integers (8/4).

Decoding is the exact inverse; for quantized values the dequantized floats
equal ``Compressor.quantize_values`` bit-for-bit (shared scale formula), so
the error-feedback residual taken against the decoded payload preserves the
telescoping identity exactly — see tests/test_property.py.

The field<->word conversion dispatches through ``kernels/ops.pack_fields``
/ ``unpack_fields`` ({ref, pallas-interpret, pallas-tpu} per
kernels/dispatch.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

def _quant_helpers():
    # repro.core.dcsgd imports this package, so the core import must stay
    # function-local to keep `import repro.core` / `import repro.comm`
    # both cycle-free.
    from repro.core.compression import QMAX, quant_scale
    return QMAX, quant_scale

WORD_BYTES = 4
VALUE_BITS = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static description of one leaf row's packed payload."""

    k: int             # wire entries per row
    d: int             # dense row length the indices address
    value_bits: int    # 4 | 8 | 16 | 32
    index_bits: int    # 16 | 32
    local: bool        # True: indices are block-local (block_topk rows)
    block: int = 0     # block width when local
    k_b: int = 0       # entries per block when local

    def __post_init__(self):
        if self.value_bits not in VALUE_BITS:
            raise ValueError(f"unsupported value_bits {self.value_bits}")
        if self.index_bits not in (16, 32):
            raise ValueError(f"unsupported index_bits {self.index_bits}")
        if self.local and self.block > (1 << 16):
            raise ValueError("block-local 16-bit indices need block <= 2^16")

    @classmethod
    def for_row(cls, comp, d: int) -> "WireSpec | None":
        """Spec for one layer row of size d under ``comp`` (a
        :class:`~repro.core.compression.Compressor` or duck-type thereof).
        None when the row ships dense (no packed payload)."""
        k = comp.sparse_k(d)
        if k >= d:
            return None
        if comp.method == "block_topk":
            return cls(k=k, d=d, value_bits=comp.value_bits,
                       index_bits=16 if comp.block <= (1 << 16) else 32,
                       local=comp.block <= (1 << 16),
                       block=comp.block, k_b=comp.block_k())
        return cls(k=k, d=d, value_bits=comp.value_bits,
                   index_bits=16 if d <= (1 << 16) else 32, local=False)

    # ---- static layout ----------------------------------------------------
    @property
    def header_words(self) -> int:
        return 1 if self.value_bits <= 8 else 0

    @property
    def index_words(self) -> int:
        return -(-self.k * self.index_bits // 32)

    @property
    def value_words(self) -> int:
        return -(-self.k * self.value_bits // 32)

    @property
    def row_words(self) -> int:
        return self.header_words + self.index_words + self.value_words

    @property
    def row_bytes(self) -> int:
        return self.row_words * WORD_BYTES

    def _local_base(self) -> jax.Array:
        """Flat-index base of each entry's block, (k,) int32."""
        return (jnp.arange(self.k, dtype=jnp.int32) // self.k_b) * self.block


def encode_rows(vals: jax.Array, idx: jax.Array, spec: WireSpec, *,
                impl: str | None = None) -> jax.Array:
    """Encode (R, k) f32 values + (R, k) int32 flat indices into the packed
    (R, row_words) uint32 payload."""
    R, k = vals.shape
    assert k == spec.k, (k, spec.k)
    vals = vals.astype(jnp.float32)
    parts = []

    # -- values (+ header) --------------------------------------------------
    if spec.value_bits <= 8:
        QMAX, quant_scale = _quant_helpers()
        qmax = QMAX[spec.value_bits]
        scale = quant_scale(vals, qmax)                       # (R, 1) f32
        q = jnp.clip(jnp.round(vals / scale), -qmax, qmax).astype(jnp.int32)
        vfields = q.astype(jnp.uint32)  # two's complement, masked on pack
        parts.append(lax.bitcast_convert_type(scale, jnp.uint32))
    elif spec.value_bits == 16:
        vfields = lax.bitcast_convert_type(vals.astype(jnp.bfloat16),
                                           jnp.uint16).astype(jnp.uint32)
    else:
        vfields = lax.bitcast_convert_type(vals, jnp.uint32)

    # -- indices ------------------------------------------------------------
    if spec.local:
        ifields = (idx - spec._local_base()[None, :]).astype(jnp.uint32)
    else:
        ifields = idx.astype(jnp.uint32)

    parts.append(ops.pack_fields(ifields, spec.index_bits, impl=impl))
    parts.append(ops.pack_fields(vfields, spec.value_bits, impl=impl))
    payload = jnp.concatenate(parts, axis=-1)
    assert payload.shape == (R, spec.row_words), \
        (payload.shape, spec.row_words)
    return payload


def decode_rows(payload: jax.Array, spec: WireSpec, *,
                impl: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Decode a packed (R, row_words) uint32 payload back to
    ((R, k) f32 dequantized values, (R, k) int32 flat indices)."""
    R, words = payload.shape
    assert words == spec.row_words, (words, spec.row_words)
    off = spec.header_words
    iw, vw = spec.index_words, spec.value_words
    ifields = ops.unpack_fields(payload[:, off:off + iw], spec.k,
                                spec.index_bits, impl=impl)
    vfields = ops.unpack_fields(payload[:, off + iw:off + iw + vw], spec.k,
                                spec.value_bits, impl=impl)

    if spec.local:
        idx = ifields.astype(jnp.int32) + spec._local_base()[None, :]
    else:
        idx = ifields.astype(jnp.int32)

    if spec.value_bits <= 8:
        scale = lax.bitcast_convert_type(payload[:, :1], jnp.float32)
        q = vfields.astype(jnp.int32)
        q = jnp.where(q >= (1 << (spec.value_bits - 1)),
                      q - (1 << spec.value_bits), q)
        vals = q.astype(jnp.float32) * scale
    elif spec.value_bits == 16:
        vals = lax.bitcast_convert_type(
            vfields.astype(jnp.uint16), jnp.bfloat16).astype(jnp.float32)
    else:
        vals = lax.bitcast_convert_type(vfields, jnp.float32)
    return vals, idx
