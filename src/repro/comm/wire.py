"""Bit-packed compressed-gradient wire format (DESIGN.md §8).

This module makes ``Compressor.wire_bytes`` *physically real*: the sparse
(values, indices) pairs that DCSGD exchanges per layer row are encoded into
one contiguous ``uint32`` payload whose byte length IS the accounted wire
cost, and that payload is what crosses the mesh axis in
``dcsgd.worker_compress_aggregate``.

Row layout (all sizes static given a :class:`WireSpec`)::

    [ header | index section | value section ]        (uint32 words)

* **header** — up to two words.  Word 0 iff the spec is **ragged**
  (adaptive compressors, DESIGN.md §9): the per-row valid count — the
  per-block valid ``k_b_t`` for block-local rows, the row valid ``k_t``
  for flat rows.  Decode honors the count regardless of what the invalid
  tail fields contain, so the fixed ``k_max`` buffer is ragged-in-content.
  Next word iff ``value_bits <= 8``: the f32 bits of the per-row absmax
  quantization scale (``compression.quant_scale``).  16/32-bit values are
  self-describing; no scale word.
* **index section** — k fields of ``index_bits`` each, bit-packed
  little-endian within words (kernels/ref.py layout), zero-padded to a
  whole word.  ``block_topk`` rows store *block-local* 16-bit indices: the
  wire ships exactly ``k_b`` entries per block in block order, so entry j
  belongs to block ``j // k_b`` and only ``idx % block`` needs encoding.
  Exact ``topk`` rows store flat indices (16-bit when d fits, else 32).
* **value section** — k fields of ``value_bits`` each: raw f32 bits (32),
  bfloat16 bits (16), or two's-complement absmax-scaled integers (8/4).

Decoding is the exact inverse; for quantized values the dequantized floats
equal ``Compressor.quantize_values`` bit-for-bit (shared scale formula), so
the error-feedback residual taken against the decoded payload preserves the
telescoping identity exactly — see tests/test_property.py.

The field<->word conversion dispatches through ``kernels/ops.pack_fields``
/ ``unpack_fields`` ({ref, pallas-interpret, pallas-tpu} per
kernels/dispatch.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

def _quant_helpers():
    # repro.core.dcsgd imports this package, so the core import must stay
    # function-local to keep `import repro.core` / `import repro.comm`
    # both cycle-free.
    from repro.core.compression import QMAX, quant_scale
    return QMAX, quant_scale

WORD_BYTES = 4
VALUE_BITS = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Static description of one leaf row's packed payload."""

    k: int             # wire entries per row (k_max for ragged specs)
    d: int             # dense row length the indices address
    value_bits: int    # 4 | 8 | 16 | 32
    index_bits: int    # 16 | 32
    local: bool        # True: indices are block-local (block_topk rows)
    block: int = 0     # block width when local
    k_b: int = 0       # entries per block when local
    ragged: bool = False  # True: count header word, decode honors it (§9)

    def __post_init__(self):
        if self.value_bits not in VALUE_BITS:
            raise ValueError(f"unsupported value_bits {self.value_bits}")
        if self.index_bits not in (16, 32):
            raise ValueError(f"unsupported index_bits {self.index_bits}")
        if self.local and self.block > (1 << 16):
            raise ValueError("block-local 16-bit indices need block <= 2^16")

    @classmethod
    def for_row(cls, comp, d: int) -> "WireSpec | None":
        """Spec for one layer row of size d under ``comp`` (a
        :class:`~repro.core.compression.Compressor` or duck-type thereof).
        None when the row ships dense (no packed payload)."""
        k = comp.sparse_k(d)
        if k >= d:
            return None
        ragged = bool(getattr(comp, "adaptive", False))
        if comp.method == "block_topk":
            local = comp.block <= (1 << 16)
            if ragged and not local:
                # block_topk wire entries are per-block magnitude-sorted,
                # so the ragged valid mask must be the per-block prefix
                # (count_period = k_b) — only expressible for block-local
                # rows.  A whole-row prefix over block-ordered entries
                # would drop later blocks wholesale.
                raise ValueError(
                    "adaptive (max_gamma) block_topk needs block <= 2^16 "
                    "(block-local indices carry the per-block count mask)")
            return cls(k=k, d=d, value_bits=comp.value_bits,
                       index_bits=16 if local else 32, local=local,
                       block=comp.block, k_b=comp.block_k(), ragged=ragged)
        return cls(k=k, d=d, value_bits=comp.value_bits,
                   index_bits=16 if d <= (1 << 16) else 32, local=False,
                   ragged=ragged)

    # ---- static layout ----------------------------------------------------
    @property
    def header_words(self) -> int:
        return (1 if self.ragged else 0) + (1 if self.value_bits <= 8 else 0)

    @property
    def count_period(self) -> int:
        """Field-index period of the valid mask: position j on the wire is
        valid iff ``j % count_period < count`` — k_b for block-local rows
        (per-block prefix), k for flat rows (row prefix)."""
        return self.k_b if self.local else self.k

    @property
    def n_blocks(self) -> int:
        """Blocks per row (1 for flat rows)."""
        return self.k // self.k_b if self.local else 1

    @property
    def full_count(self) -> int:
        """The count value that marks every entry valid."""
        return self.k_b if self.local else self.k

    def valid_entries(self, count) -> jax.Array:
        """Total valid wire entries per row for a (traced) count."""
        return jnp.asarray(count, jnp.int32) * self.n_blocks

    def effective_row_bytes(self, count) -> jax.Array:
        """Traced byte cost of one row if only the valid fields shipped
        (header + bit-packed valid index/value fields, word-padded) — the
        ragged collective this format is an upper-bound stand-in for."""
        valid = self.valid_entries(count)
        iw = (valid * self.index_bits + 31) // 32
        vw = (valid * self.value_bits + 31) // 32
        return ((self.header_words + iw + vw) * WORD_BYTES).astype(
            jnp.float32)

    @property
    def index_words(self) -> int:
        return -(-self.k * self.index_bits // 32)

    @property
    def value_words(self) -> int:
        return -(-self.k * self.value_bits // 32)

    @property
    def row_words(self) -> int:
        return self.header_words + self.index_words + self.value_words

    @property
    def row_bytes(self) -> int:
        return self.row_words * WORD_BYTES

    def _local_base(self) -> jax.Array:
        """Flat-index base of each entry's block, (k,) int32."""
        return (jnp.arange(self.k, dtype=jnp.int32) // self.k_b) * self.block


def field_mask(k: int, counts: jax.Array, period: int) -> jax.Array:
    """(R, k) ragged validity mask: field j of a row is valid iff
    ``j % period < count`` — per-block prefix for block-local rows
    (period = k_b), plain prefix for flat rows (period = k)."""
    pos = jnp.arange(k, dtype=jnp.int32) % jnp.int32(period)
    return pos[None, :] < jnp.asarray(counts, jnp.int32).reshape(-1, 1)


def row_fields(vals: jax.Array, idx: jax.Array, spec: WireSpec, *,
               counts: jax.Array | None = None):
    """Encode-side field construction — the codec half shared bit-for-bit
    by :func:`encode_rows` and the bucketed transport (comm/bucket.py).

    Returns ``(header, ifields, vfields, counts)``: ``header`` is the
    (R, header_words) uint32 header columns (count word, then scale word;
    None when the spec has no header), ``ifields``/``vfields`` the (R, k)
    unpacked uint32 field sections, and ``counts`` the normalized (R,)
    int32 valid counts (None for non-ragged specs).  Values beyond the
    count are zeroed *before* the quantization scale; the field sections
    are NOT yet count-masked — per-leaf packing masks them inside the
    kernels (:func:`repro.kernels.ops.pack_fields`), the bucketed path
    applies :func:`field_mask` before its batched stream pack.
    """
    R, k = vals.shape
    assert k == spec.k, (k, spec.k)
    vals = vals.astype(jnp.float32)
    header = []
    if spec.ragged:
        if counts is None:
            counts = jnp.full((R,), spec.full_count, jnp.int32)
        counts = jnp.broadcast_to(
            jnp.asarray(counts, jnp.int32).reshape(-1), (R,))
        vals = jnp.where(field_mask(k, counts, spec.count_period), vals, 0.0)
        header.append(counts.astype(jnp.uint32)[:, None])
    else:
        counts = None

    # -- values (+ scale header) --------------------------------------------
    if spec.value_bits <= 8:
        QMAX, quant_scale = _quant_helpers()
        qmax = QMAX[spec.value_bits]
        scale = quant_scale(vals, qmax)                       # (R, 1) f32
        q = jnp.clip(jnp.round(vals / scale), -qmax, qmax).astype(jnp.int32)
        vfields = q.astype(jnp.uint32)  # two's complement, masked on pack
        header.append(lax.bitcast_convert_type(scale, jnp.uint32))
    elif spec.value_bits == 16:
        vfields = lax.bitcast_convert_type(vals.astype(jnp.bfloat16),
                                           jnp.uint16).astype(jnp.uint32)
    else:
        vfields = lax.bitcast_convert_type(vals, jnp.uint32)

    # -- indices ------------------------------------------------------------
    if spec.local:
        ifields = (idx - spec._local_base()[None, :]).astype(jnp.uint32)
    else:
        ifields = idx.astype(jnp.uint32)
    header = jnp.concatenate(header, axis=-1) if header else None
    return header, ifields, vfields, counts


def encode_rows(vals: jax.Array, idx: jax.Array, spec: WireSpec, *,
                counts: jax.Array | None = None,
                impl: str | None = None) -> jax.Array:
    """Encode (R, k) f32 values + (R, k) int32 flat indices into the packed
    (R, row_words) uint32 payload.

    ``counts`` (ragged specs): (R,) or scalar int32 per-row valid count —
    per-block ``k_b_t`` for block-local rows, row ``k_t`` for flat rows
    (:attr:`WireSpec.count_period` is the mask period either way).  Wire
    entries are magnitude-sorted per period by construction, so masking a
    suffix IS selecting the per-round top-k_t.  Values beyond the count
    are zeroed *before* the quantization scale, and both field sections
    are masked inside the pack kernels; omitted counts mean "all valid".
    """
    R, _ = vals.shape
    header, ifields, vfields, counts = row_fields(vals, idx, spec,
                                                  counts=counts)
    period = spec.count_period if spec.ragged else 0
    parts = ([header] if header is not None else [])
    parts.append(ops.pack_fields(ifields, spec.index_bits, counts=counts,
                                 period=period, impl=impl))
    parts.append(ops.pack_fields(vfields, spec.value_bits, counts=counts,
                                 period=period, impl=impl))
    payload = jnp.concatenate(parts, axis=-1)
    assert payload.shape == (R, spec.row_words), \
        (payload.shape, spec.row_words)
    return payload


def fields_to_rows(ifields: jax.Array, vfields: jax.Array,
                   scale_words: jax.Array | None,
                   counts: jax.Array | None, spec: WireSpec):
    """Decode-side field interpretation — the codec half shared bit-for-bit
    by :func:`decode_rows` and the bucketed transport (comm/bucket.py).

    ``ifields``/``vfields``: (R, k) unpacked uint32 field sections,
    already count-masked for ragged specs; ``scale_words``: (R, 1) uint32
    f32 scale bits (sub-byte value widths only); ``counts``: (R,) int32
    (ragged specs only).  Returns ((R, k) f32 values, (R, k) int32 flat
    indices).
    """
    if spec.local:
        idx = ifields.astype(jnp.int32) + spec._local_base()[None, :]
    else:
        idx = ifields.astype(jnp.int32)

    if spec.value_bits <= 8:
        scale = lax.bitcast_convert_type(scale_words, jnp.float32)
        q = vfields.astype(jnp.int32)
        q = jnp.where(q >= (1 << (spec.value_bits - 1)),
                      q - (1 << spec.value_bits), q)
        vals = q.astype(jnp.float32) * scale
    elif spec.value_bits == 16:
        vals = lax.bitcast_convert_type(
            vfields.astype(jnp.uint16), jnp.bfloat16).astype(jnp.float32)
    else:
        vals = lax.bitcast_convert_type(vfields, jnp.float32)
    if spec.ragged:
        # belt-and-braces on top of the unpack mask: masked fields decode
        # to exactly 0.0 already (zero bits are 0 in every value format)
        vals = jnp.where(field_mask(spec.k, counts, spec.count_period),
                         vals, 0.0)
    return vals, idx


def roundtrip_rows(vals: jax.Array, idx: jax.Array, spec: WireSpec, *,
                   counts: jax.Array | None = None):
    """``decode_rows(encode_rows(vals, idx, ...))`` value semantics WITHOUT
    materializing packed words — bit-exact by construction, launch-free.

    The encode side quantizes/masks through :func:`row_fields` and the
    decode side reinterprets through :func:`fields_to_rows`; composing the
    two directly skips the pack/unpack kernels in between (every packed
    field round-trips its low ``value_bits``/``index_bits`` exactly, and
    ``fields_to_rows``'s two's-complement fold maps the un-truncated
    int32 quantized fields to the same values the truncated wire fields
    decode to).  The overlap transport (DESIGN.md §14) uses this for the
    CURRENT-step EF residual while the collective still carries the
    previous step's payload: no second unpack launch set, and the
    residual equals ``acc - decode(own payload)`` bit-for-bit — pinned by
    tests against a literal decode of the carried payload.
    """
    header, ifields, vfields, counts = row_fields(vals, idx, spec,
                                                  counts=counts)
    if spec.ragged:
        # the pack kernels zero invalid fields; reproduce that here so
        # masked entries decode to value 0.0 at the block-base index,
        # exactly like the wire
        m = field_mask(spec.k, counts, spec.count_period)
        ifields = jnp.where(m, ifields, 0)
        vfields = jnp.where(m, vfields, 0)
    scale_words = header[:, -1:] if spec.value_bits <= 8 else None
    return fields_to_rows(ifields, vfields, scale_words, counts, spec)


def row_verdict(payload: jax.Array, spec: WireSpec, vals: jax.Array,
                idx: jax.Array) -> jax.Array:
    """Per-row validity verdict of a decoded payload (DESIGN.md §16).

    ``payload``: the (R, row_words) uint32 rows the fields came from;
    ``vals``/``idx``: their decode.  Returns (R,) bool — True iff the row
    is safe to aggregate:

    * every decoded value is finite — checked per element for the
      bitcast widths (16/32-bit NaN/Inf rides the value fields
      directly); for sub-byte quantized widths finiteness is implied by
      the scale word alone (``vals = q * scale`` with ``|q| < 2^bits``),
      so the per-element sweep is replaced by one per-row scale bound:
      ``|scale| <= f32_max / 2^(bits-1)`` — rejecting NaN/Inf scales AND
      the absurd-magnitude finite scales whose dequantized product would
      overflow to Inf (an honest encoder's scale is ``max|row| / q_max``,
      many orders of magnitude under the bound),
    * the count header (ragged specs) is in ``[0, full_count]`` — a
      truncated/overflowed count would otherwise unmask garbage tail
      fields as live values,
    * every index is in ``[0, d)`` or carries value 0 (padding/masked
      entries legitimately hold clamped or zero indices; a *live* value
      at an out-of-range index means a corrupt index section even though
      the scatter-add would silently drop it).

    Honest encodes satisfy all of it by construction, so the verdict is
    identically True on a clean wire — the quarantine path below is then
    a bit-exact no-op (the faults-off guarantee).  The element checks
    are deliberately fused into ONE reduction pass: this runs on every
    decode, guarded by the bench_diff 1.05x guarded-vs-unguarded gate.
    """
    ok_elems = (idx >= 0) & (idx < spec.d) | (vals == 0.0)
    if spec.value_bits > 8:
        ok_elems &= jnp.isfinite(vals)
    ok = jnp.all(ok_elems, axis=-1)
    if spec.ragged:
        counts = payload[:, 0].astype(jnp.int32)
        ok &= (counts >= 0) & (counts <= spec.full_count)
    if spec.value_bits <= 8:
        scale = lax.bitcast_convert_type(
            payload[:, spec.header_words - 1], jnp.float32)
        q_max = float(1 << (spec.value_bits - 1))
        ok &= jnp.abs(scale) <= float(jnp.finfo(jnp.float32).max) / q_max
    return ok


def quarantine_rows(vals: jax.Array, idx: jax.Array,
                    verdict: jax.Array):
    """Zero invalid rows out of a decode: values -> 0.0, indices -> 0, so
    a quarantined row scatter-adds exactly nothing anywhere.  Valid rows
    pass through bit-untouched (including any harmless out-of-range
    padding indices the scatter drops), keeping the faults-off path
    bit-exact.  The caller adjusts the aggregation denominator from the
    verdict (support-weighted division, fed/aggregate.py)."""
    keep = verdict[:, None]
    return (jnp.where(keep, vals, 0.0),
            jnp.where(keep, idx, jnp.int32(0)))


def decode_rows(payload: jax.Array, spec: WireSpec, *,
                impl: str | None = None, return_counts: bool = False):
    """Decode a packed (R, row_words) uint32 payload back to
    ((R, k) f32 dequantized values, (R, k) int32 flat indices).

    Ragged specs: the valid count is read from each row's own header word
    and honored on decode — fields beyond it come back as value 0 at a
    clamped in-bounds index, whatever the payload tail contains (the
    fixed-buffer / ragged-content contract, DESIGN.md §9).  Rows gathered
    from different workers may carry different counts.  With
    ``return_counts`` the (R,) counts are returned as a third element.
    """
    R, words = payload.shape
    assert words == spec.row_words, (words, spec.row_words)
    off = spec.header_words
    counts = None
    period = 0
    if spec.ragged:
        counts = payload[:, 0].astype(jnp.int32)
        period = spec.count_period
    iw, vw = spec.index_words, spec.value_words
    ifields = ops.unpack_fields(payload[:, off:off + iw], spec.k,
                                spec.index_bits, counts=counts,
                                period=period, impl=impl)
    vfields = ops.unpack_fields(payload[:, off + iw:off + iw + vw], spec.k,
                                spec.value_bits, counts=counts,
                                period=period, impl=impl)
    scale_words = payload[:, off - 1:off] if spec.value_bits <= 8 else None
    vals, idx = fields_to_rows(ifields, vfields, scale_words, counts, spec)
    if return_counts:
        return vals, idx, counts
    return vals, idx
