"""Overlapped compressed exchange — chunked ring + delay-1 double buffer
(DESIGN.md §14).

``transport="overlap"`` keeps the bucketed transport's selection, wire
format, EF contract, and byte accounting (DESIGN.md §8/§9/§11) but takes
the collective off the step's critical path two ways:

1. **Chunked ring streaming** — the ONE flat bucketed all_gather becomes
   ``n_chunks * (W-1)`` ``ppermute`` ring steps (``comm/ring.py``),
   bit-identical and byte-identical, but split into small
   dependency-free collectives that interleave with compute and with the
   decode of already-arrived chunks.
2. **One-step-stale aggregation** (``delay=1``, the default) — the step
   ships the PREVIOUS step's encoded payload (carried in
   :class:`OverlapState`, threaded through ``DistOptState.overlap`` like
   gossip's), so the collective's operands are ready the moment the step
   starts: XLA can schedule the entire ring concurrently with this
   step's backward/Armijo/selection compute.  The aggregate applied at
   step t is the mean of step t-1's payloads.

**What stays current under staleness.**  Selection, encoding, the EF
residual, and the telemetry sums always describe THIS step's
accumulator: the residual is ``acc - decode(own CURRENT payload)``
(via :func:`repro.comm.wire.roundtrip_rows` — launch-free and bit-exact
against a literal decode of the carried payload), so the telescoping EF
identity holds per worker regardless of when the aggregate lands, and
the ef-coupled gamma controller keeps reading same-step compressor
health.  Only the applied mean and the ``effective_wire_bytes`` report
(which describes the buffer actually on the wire this step) are one
step old.

``delay=0`` degenerates to the bucketed schedule over the ring:
BIT-EXACT vs ``transport="bucketed"`` in updates, EF memory, wire and
effective bytes (telemetry to <= 8 ulp) — the pinned parity contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.comm import faults
from repro.comm import wire as wire_fmt
from repro.comm.bucket import (BucketPlan, build_bucket_plan, decode_buckets,
                               encode_buckets)
from repro.comm.exchange import check_bucket_payload, gather_packed
from repro.comm.transport import register_transport
from repro.core.leafmath import (dp_index, scatter_layers, select_and_encode)
from repro.core.telemetry import TelemetrySums, sparse_own_sums

__all__ = [
    "OverlapConfig",
    "OverlapState",
    "OverlapCtx",
    "init_overlap_state",
    "overlap_exchange",
]


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """Static knobs of the overlap transport (``--overlap-*`` CLI flags).

    ``n_chunks``: word-aligned ring sections per gather axis (clamped to
    the buffer length; more chunks = finer compute/comm interleaving,
    more collective launches).  ``delay``: 0 = ship this step's payload
    (bit-exact bucketed parity mode), 1 = ship the carried previous
    payload (the overlapped mode; aggregate lands one step late).
    """

    n_chunks: int = 1
    delay: int = 1

    def __post_init__(self):
        if self.n_chunks < 1:
            raise ValueError(
                f"overlap n_chunks must be >= 1, got {self.n_chunks}")
        if self.delay not in (0, 1):
            raise ValueError(
                f"overlap delay must be 0 or 1, got {self.delay}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OverlapState:
    """Double-buffered carried state, per worker (DESIGN.md §14).

    ``payload``/``dense``: the §11 bucket buffer and concatenated dense
    accumulators this worker encoded LAST step — the operands of this
    step's collective at ``delay=1``.  ``eff_wire``: the
    effective-byte count of that carried payload (computed at encode
    time, reported when the buffer actually ships).  ``seeded``: 0.0
    until the first encode lands in the buffer; drives the warm-up
    convention (the initial zero payload decodes to a zero update) and
    the ``staleness`` metric.
    """

    payload: jax.Array    # (total_words,) uint32 — §11 bucket buffer
    dense: jax.Array      # (dense_size,) f32 — concatenated dense accs
    eff_wire: jax.Array   # () f32 — effective bytes of `payload`
    seeded: jax.Array     # () f32 — 1.0 once a real payload is carried


@dataclasses.dataclass(frozen=True)
class OverlapCtx:
    """Static config + this worker's carried (traced) state."""

    cfg: OverlapConfig
    state: OverlapState


def _zero_payload_eff_bytes(plan: BucketPlan) -> float:
    """Effective bytes of the all-zero §11 buffer the warm-up step ships:
    ragged rows decode count 0 (header words only count as effective),
    non-ragged rows always ship full rows, dense leaves ship dense."""
    eff = 0.0
    for lane in plan.leaves:
        if lane.dense:
            eff += float(jnp.prod(jnp.asarray(lane.shape))) * 4.0
        elif lane.spec.ragged:
            eff += lane.L * float(lane.spec.effective_row_bytes(0))
        else:
            eff += lane.L * lane.spec.row_bytes
    return eff


def init_overlap_state(shapes, stacked, comp, abstract: bool = False
                       ) -> OverlapState:
    """Fresh (unbatched, per-worker) carried state for a gradient pytree
    with flat leaf ``shapes`` and per-leaf ``stacked`` flags — the SAME
    flags the worker passes to ``worker_compress_aggregate``
    (``stacked_mask``), or the payload geometry will not line up (the
    exchange raises at trace time on any mismatch).
    """
    plan = build_bucket_plan([tuple(s) for s in shapes], list(stacked), comp)
    dense_size = 0
    for lane in plan.leaves:
        if lane.dense:
            n = 1
            for s in lane.shape:
                n *= int(s)
            dense_size += n
    if abstract:
        return OverlapState(
            payload=jax.ShapeDtypeStruct((plan.total_words,), jnp.uint32),
            dense=jax.ShapeDtypeStruct((dense_size,), jnp.float32),
            eff_wire=jax.ShapeDtypeStruct((), jnp.float32),
            seeded=jax.ShapeDtypeStruct((), jnp.float32))
    return OverlapState(
        payload=jnp.zeros((plan.total_words,), jnp.uint32),
        dense=jnp.zeros((dense_size,), jnp.float32),
        eff_wire=jnp.float32(_zero_payload_eff_bytes(plan)),
        seeded=jnp.float32(0.0))


@register_transport("overlap", stateful=True, description=(
    "chunked-ring, double-buffered exchange: the collective ships the "
    "previous step's payload concurrently with this step's compute"))
def overlap_exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t,
                     W, *, ctx: OverlapCtx):
    """Bucketed semantics on an overlapped schedule (DESIGN.md §14).

    At ``delay=1`` the collective (ring + dense pmean) consumes only
    ``ctx.state`` — data-ready at step start, schedulable concurrently
    with every current-step op; EF/telemetry stay current via the
    launch-free own-payload roundtrip.  At ``delay=0`` the own rows come
    off the gathered decode exactly like the bucketed consumer, making
    this a bit-exact drop-in (the pinned parity mode).
    """
    cfg, state = ctx.cfg, ctx.state
    stale = cfg.delay == 1
    plan = build_bucket_plan([g.shape for g in flat_g], flat_s, comp)
    lanes = plan.leaves
    n = len(lanes)

    sel = select_and_encode(flat_g, flat_m, flat_s, eta, comp, gamma_t,
                            plan)
    use_fused = sel.use_fused

    # ---- CURRENT-step buffers (next step's collective operands) ---------
    payload = jnp.zeros((0,), jnp.uint32)
    if plan.total_words:
        payload = encode_buckets(plan, sel.enc_rows)
        check_bucket_payload(payload, plan, comp)
    if state.payload.shape != payload.shape:
        raise ValueError(
            f"OverlapState.payload shape {state.payload.shape} does not "
            f"match the bucket plan's ({payload.shape}) — init the state "
            "with the same leaf shapes/stacked_mask/compressor the worker "
            "uses (see init_overlap_state)")

    dense_ids = list(plan.dense_ids)
    dense_acc = [None] * n
    for i in dense_ids:
        dense_acc[i] = flat_m[i].astype(jnp.float32) \
            + eta * flat_g[i].astype(jnp.float32)
    dense_cat = (jnp.concatenate([dense_acc[i].reshape(-1)
                                  for i in dense_ids])
                 if dense_ids else jnp.zeros((0,), jnp.float32))
    if state.dense.shape != dense_cat.shape:
        raise ValueError(
            f"OverlapState.dense shape {state.dense.shape} does not match "
            f"the plan's concatenated dense size ({dense_cat.shape})")

    # ---- the collective ships the carried (stale) or current buffer -----
    ship_pay = state.payload if stale else payload
    ship_dense = state.dense if stale else dense_cat

    decoded = [None] * n
    verdicts = [None] * n
    if plan.total_words:
        all_pay = gather_packed(ship_pay, dp_axes,
                                ring_chunks=cfg.n_chunks)  # (W, total)
        if faults.guards_active():
            decoded, verdicts = decode_buckets(plan, all_pay,
                                               with_verdicts=True)
        else:
            decoded = decode_buckets(plan, all_pay)

    dense_mean = [None] * n
    if dense_ids:
        mean_cat = jax.lax.pmean(ship_dense, dp_axes)
        off = 0
        for i in dense_ids:
            size = dense_acc[i].size
            dense_mean[i] = mean_cat[off:off + size].reshape(
                dense_acc[i].shape)
            off += size

    # delay=1 EF roundtrip, batched across same-spec leaves: row_fields /
    # fields_to_rows are strictly row-wise (per-row scale, per-row mask),
    # so the common many-identical-lanes case shares ONE launch set
    # instead of one per leaf — bit-identical per row, and the per-leaf
    # dispatch overhead that would otherwise price the stale mode above
    # the bucketed baseline disappears
    own_rt = [None] * n
    if stale:
        by_spec: dict = {}
        for lane in lanes:
            if not lane.dense:
                by_spec.setdefault(lane.spec, []).append(lane)
        for gspec, group in by_spec.items():
            vals = jnp.concatenate([sel.enc_rows[l.index][0] for l in group])
            idxs = jnp.concatenate([sel.enc_rows[l.index][1] for l in group])
            counts = None
            if gspec.ragged:
                counts = jnp.concatenate([
                    jnp.broadcast_to(
                        jnp.asarray(c, jnp.int32).reshape(-1), (l.L,))
                    if (c := sel.enc_rows[l.index][2]) is not None
                    else jnp.full((l.L,), gspec.full_count, jnp.int32)
                    for l in group])
            rv, ri = wire_fmt.roundtrip_rows(vals, idxs, gspec,
                                             counts=counts)
            off = 0
            for l in group:
                own_rt[l.index] = (rv[off:off + l.L], ri[off:off + l.L])
                off += l.L

    # ---- per-leaf consumers, ORIGINAL tree order (bucketed parity:
    # identical f32 accumulation order for bytes and telemetry sums)
    updates, new_mem = [], []
    wire = jnp.float32(0.0)
    cur_eff = jnp.float32(0.0)
    sums = TelemetrySums.zero()
    w_idx = dp_index(dp_axes)
    for lane, g, m in zip(lanes, flat_g, flat_m):
        i = lane.index
        if lane.dense:
            acc = dense_acc[i]
            updates.append(dense_mean[i])
            new_mem.append(jnp.zeros_like(m))
            nbytes = jnp.float32(acc.size * acc.dtype.itemsize)
            wire = wire + nbytes
            cur_eff = cur_eff + nbytes
            sums = sums.add_dense(acc, g)
            continue
        spec, L, d = lane.spec, lane.L, lane.d
        g_vals, g_idx = decoded[i]
        total = scatter_layers(g_vals, g_idx, L, d, jnp.float32)
        if verdicts[i] is None:
            mean_dense = total / W
        else:
            # §16 quarantine: invalid gathered rows arrive zeroed; divide
            # by the per-layer valid-row count instead of W (the fed
            # support-weighted division — bit-exact to /W when clean)
            from repro.fed.aggregate import support_weighted_mean
            n_valid = jnp.sum(verdicts[i].astype(jnp.float32), axis=0)
            mean_dense = support_weighted_mean(total, n_valid[:, None])

        # EF against the CURRENT own payload: at delay=0 the gathered
        # buffer IS current — slice own rows exactly like the bucketed
        # consumer; at delay=1 the gather carries old rows, so roundtrip
        # the encoder's own fields instead (bit-exact, launch-free —
        # and never wire-corrupted, so no own-row quarantine applies)
        if stale:
            own_vals, own_idx = own_rt[i]
        else:
            own_vals = jax.lax.dynamic_index_in_dim(g_vals, w_idx, 0,
                                                    keepdims=False)
            own_idx = jax.lax.dynamic_index_in_dim(g_idx, w_idx, 0,
                                                   keepdims=False)
        own_dense = scatter_layers(own_vals, own_idx, L, d, jnp.float32)
        if use_fused:
            r = sel.resid[i] + (sel.sent[i] - own_dense)
        else:
            r = sel.acc2[i] - own_dense
        quar = jnp.float32(0.0)
        if verdicts[i] is not None:
            if not stale:
                # own row quarantined at the wire: freeze this leaf's EF
                own_ok = jax.lax.dynamic_index_in_dim(
                    verdicts[i], w_idx, 0, keepdims=False)       # (L,)
                m2f = m.astype(jnp.float32).reshape(L, d)
                r = jnp.where(own_ok[:, None], r, m2f)
            quar = jnp.sum(1.0 - verdicts[i].astype(jnp.float32))

        updates.append(mean_dense.reshape(g.shape))
        new_mem.append(r.reshape(m.shape).astype(m.dtype))
        wire = wire + jnp.float32(L * spec.row_bytes)
        cur_eff = cur_eff + (
            jnp.float32(L) * spec.effective_row_bytes(sel.counts[i])
            if spec.ragged else jnp.float32(L * spec.row_bytes))
        own_sq, own_dot = sparse_own_sums(own_vals, own_idx, sel.g2f[i])
        sums = sums.add(g_sq=sel.leaf_g_sq[i], acc_sq=sel.leaf_acc_sq[i],
                        resid_sq=jnp.sum(r * r), own_sq=own_sq,
                        own_dot_g=own_dot, quar_rows=quar)

    # wire bytes are static per plan (the full buffer crosses the wire
    # every step, carried or not); effective bytes describe the buffer
    # actually shipped THIS step — the carried one under delay=1
    eff_out = state.eff_wire if stale else cur_eff
    new_state = OverlapState(payload=payload, dense=dense_cat,
                             eff_wire=cur_eff, seeded=jnp.float32(1.0))
    return updates, new_mem, wire, eff_out, sums, new_state
