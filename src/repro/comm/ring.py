"""Chunked ring all-gather over the packed bucket buffer (DESIGN.md §14).

``gather_packed`` (comm/exchange.py) moves the whole §11 bucket buffer in
ONE ``lax.all_gather``.  That is optimal for collective *count* but the
gather sits serially between backward and the update: nothing downstream
can start until every byte has landed.  This module re-expresses the same
gather as a **ring schedule** — ``W-1`` send-right ``ppermute`` steps per
chunk over ``n_chunks`` word-aligned sections of the buffer — which

* moves the SAME total bytes per link as the flat gather
  ((W-1)/W of the gathered buffer), and
* breaks the transfer into many small dependency-free collectives, so an
  overlap-capable runtime can interleave them with compute (and with the
  decode of already-arrived chunks).

Bit-exactness vs ``lax.all_gather`` is pinned by parity tests: ppermute
only relabels device placement, so the assembled ``(W, total_words)``
buffer is an exact copy of every worker's payload in axis-index order.

Multi-axis dp meshes gather as a **ring of rings**: the innermost axis
first (matching the row-major stacking of ``lax.all_gather`` over an
axis tuple), then each outer axis over the enlarged block, so the final
``reshape(-1, total_words)`` reproduces ``gather_packed``'s row order.

The pure-Python scheduling pieces (``chunk_table``, ``step_source``) are
shared with ``ring_gather_reference``, a NumPy simulator used by the
single-device hypothesis property in tests/test_property.py — the SPMD
path and the reference cannot drift apart on chunk/source arithmetic.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

__all__ = [
    "chunk_table",
    "step_source",
    "n_permutes",
    "ring_all_gather",
    "ring_gather_reference",
]


def chunk_table(total_words: int, n_chunks: int) -> tuple[tuple[int, int], ...]:
    """Word-aligned ``(offset, length)`` sections covering ``[0, total_words)``.

    ``n_chunks`` is clamped to ``[1, total_words]`` (a chunk must hold at
    least one word); the first ``total_words % n`` chunks get one extra
    word, so non-divisible splits stay contiguous and exhaustive.
    """
    if total_words < 0:
        raise ValueError(f"total_words must be >= 0, got {total_words}")
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    if total_words == 0:
        return ()
    n = min(n_chunks, total_words)
    base, rem = divmod(total_words, n)
    table = []
    off = 0
    for c in range(n):
        ln = base + (1 if c < rem else 0)
        table.append((off, ln))
        off += ln
    return tuple(table)


def step_source(i, s: int, size: int):
    """Origin worker of the chunk held by worker ``i`` after ring step ``s``.

    Send-right ring (``j -> (j+1) % size``): after ``s`` hops, worker
    ``i`` holds the chunk that started at ``(i - s) % size``.  ``i`` may
    be a traced ``axis_index``; ``s``/``size`` are static Python ints.
    """
    return (i - s) % size


def n_permutes(axis_sizes: Sequence[int], total_words: int,
               n_chunks: int) -> int:
    """Exact number of ``collective_permute`` ops ``ring_all_gather`` emits.

    Innermost axis first; each axis of size ``A > 1`` contributes
    ``chunks_eff * (A - 1)`` permutes where ``chunks_eff`` is ``n_chunks``
    clamped to the block length at that stage (the block grows by the
    inner axes' sizes as the ring-of-rings proceeds outward).
    """
    total = 0
    words = total_words
    for size in reversed(tuple(axis_sizes)):
        if words > 0 and size > 1:
            total += len(chunk_table(words, n_chunks)) * (size - 1)
        words *= size
    return total


def _ring_axis_gather(vec: jax.Array, axis: str, n_chunks: int) -> jax.Array:
    """All-gather flat ``vec`` along one mesh axis via a chunked ring.

    Returns ``(A, len(vec))`` with row ``a`` holding axis-index ``a``'s
    vector — identical to ``lax.all_gather(vec, axis)``.
    """
    size = int(compat.axis_size(axis))
    if size == 1:
        return vec[None]
    i = jax.lax.axis_index(axis)
    out = jnp.zeros((size,) + vec.shape, vec.dtype)
    # own block lands at the (traced) own row; every remote block arrives
    # over the ring below.
    out = jax.lax.dynamic_update_slice(out, vec[None], (i, jnp.int32(0)))
    perm = [(j, (j + 1) % size) for j in range(size)]
    for off, ln in chunk_table(vec.shape[0], n_chunks):
        buf = vec[off:off + ln]
        for s in range(1, size):
            buf = jax.lax.ppermute(buf, axis, perm)
            src = step_source(i, s, size)
            out = jax.lax.dynamic_update_slice(
                out, buf[None], (src, jnp.int32(off)))
    return out


def ring_all_gather(payload: jax.Array, dp_axes, n_chunks: int = 1
                    ) -> jax.Array:
    """Drop-in for ``gather_packed``: ``(total_words,)`` -> ``(W, total_words)``.

    Streams the buffer in ``n_chunks`` sections over ``W-1`` ppermute
    ring steps per axis instead of one flat all_gather; the result is
    bit-identical (row ``w`` = worker ``w``'s payload, rows ordered by
    ``lax.axis_index(dp_axes)`` exactly like the all_gather stacking).
    """
    axes = (dp_axes,) if isinstance(dp_axes, str) else tuple(dp_axes)
    words = payload.shape[0]
    block = payload
    # ring of rings: innermost axis first so the final row order matches
    # the row-major (outer, ..., inner) stacking of the flat all_gather.
    for axis in reversed(axes):
        block = _ring_axis_gather(block.reshape(-1), axis, n_chunks)
    return block.reshape(-1, words)


def ring_gather_reference(bufs: np.ndarray, n_chunks: int) -> np.ndarray:
    """NumPy simulator of the single-axis ring schedule (no collectives).

    ``bufs``: ``(W, total_words)`` — worker ``w``'s payload in row ``w``.
    Simulates the exact send-right schedule (same ``chunk_table`` /
    ``step_source`` arithmetic as the SPMD path) and returns the
    per-worker assembled buffers, shape ``(W, W, total_words)``.  Raises
    if any (worker, row, word) slot is written twice or left unwritten —
    the property test's guarantee that the schedule covers the buffer
    exactly once.
    """
    bufs = np.asarray(bufs)
    W, total_words = bufs.shape
    out = np.zeros((W, W, total_words), dtype=bufs.dtype)
    written = np.zeros((W, W, total_words), dtype=np.int32)
    for w in range(W):  # own block, written up front like the SPMD path
        out[w, w] = bufs[w]
        written[w, w] += 1
    for off, ln in chunk_table(total_words, n_chunks):
        hold = bufs[:, off:off + ln].copy()  # hold[w] = chunk at worker w
        for s in range(1, W):
            # send right: worker w's new buffer came from worker w-1
            hold = np.roll(hold, 1, axis=0)
            for w in range(W):
                src = step_source(w, s, W)
                out[w, src, off:off + ln] = hold[w]
                written[w, src, off:off + ln] += 1
    if total_words and W > 1 and not (written == 1).all():
        bad = int((written != 1).sum())
        raise AssertionError(
            f"ring schedule wrote {bad} slots != exactly once "
            f"(W={W}, n_chunks={n_chunks}, total_words={total_words})")
    return out
