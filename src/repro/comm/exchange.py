"""Packed payload exchange over the data-parallel mesh axes.

The only collective the compressed path issues per leaf is an ``all_gather``
of the fixed-size packed payload built by :mod:`repro.comm.wire` — W * L *
``WireSpec.row_bytes`` bytes cross the mesh axis, nothing else.  The
byte-accounting contract (``Compressor.wire_bytes`` == payload bytes) is
enforced at trace time by :func:`check_payload`.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .wire import WireSpec

AxisNames = Sequence[str] | str


def check_payload(payload: jax.Array, spec: WireSpec, comp, d: int) -> None:
    """Trace-time guarantee that the buffer about to cross the mesh axis is
    exactly the bytes ``Compressor.wire_bytes`` accounts for.  Shapes and
    dtypes are static, so a violation fails at trace/compile time, not at
    runtime on some worker.  Raises (not assert): the contract must hold
    under ``python -O`` too."""
    if payload.dtype != jnp.uint32:
        raise ValueError(f"payload must be uint32, got {payload.dtype}")
    if payload.shape[-1] != spec.row_words:
        raise ValueError(f"payload row is {payload.shape[-1]} words, "
                         f"spec says {spec.row_words}")
    accounted = comp.wire_bytes(d)
    physical = spec.row_bytes
    if physical != accounted:
        raise ValueError(
            f"wire accounting drift: payload row is {physical} B but "
            f"Compressor.wire_bytes({d}) = {accounted} B")


def effective_payload_bytes(payload: jax.Array, spec: WireSpec) -> jax.Array:
    """Traced count of *useful* bytes in a gathered/encoded (… , row_words)
    payload: for ragged specs, each row's valid-count header word prices
    the row at what a truly ragged collective would ship
    (``WireSpec.effective_row_bytes``); non-ragged payloads are fully
    useful.  This is the runtime counterpart of the static
    ``check_payload`` contract — the budget stays the trace-time bound,
    this is the per-step metric under it."""
    rows = payload.reshape(-1, payload.shape[-1])
    if not spec.ragged:
        return jnp.float32(rows.shape[0] * spec.row_bytes)
    counts = rows[:, 0].astype(jnp.int32)
    return jnp.sum(spec.effective_row_bytes(counts))


def gather_packed(payload: jax.Array, dp_axes: AxisNames) -> jax.Array:
    """All-gather one worker's (L, row_words) payload over the dp axes ->
    (W, L, row_words) with the worker axis flattened across multi-axis
    meshes (('pod','data') gathers as (pod, data, ...))."""
    gathered = jax.lax.all_gather(payload, dp_axes)
    if isinstance(dp_axes, (tuple, list)) and len(dp_axes) > 1:
        gathered = gathered.reshape(-1, *payload.shape)
    return gathered
