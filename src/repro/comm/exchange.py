"""Packed payload exchange over the data-parallel mesh axes.

The only collective the compressed path issues is an ``all_gather`` of
packed payload words built by :mod:`repro.comm.wire` — per leaf on the
reference transport (W * L * ``WireSpec.row_bytes`` bytes per leaf), ONE
flat buffer for the whole pytree on the default bucketed transport
(:mod:`repro.comm.bucket`, DESIGN.md §11).  The byte-accounting contract
(``Compressor.wire_bytes`` == payload bytes, with no padding word ever
riding the collective) is enforced at trace time by :func:`check_payload`
/ :func:`check_bucket_payload`.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .wire import WireSpec

AxisNames = Sequence[str] | str


def check_payload(payload: jax.Array, spec: WireSpec, comp, d: int) -> None:
    """Trace-time guarantee that the buffer about to cross the mesh axis is
    exactly the bytes ``Compressor.wire_bytes`` accounts for.  Shapes and
    dtypes are static, so a violation fails at trace/compile time, not at
    runtime on some worker.  Raises (not assert): the contract must hold
    under ``python -O`` too."""
    if payload.dtype != jnp.uint32:
        raise ValueError(f"payload must be uint32, got {payload.dtype}")
    if payload.shape[-1] != spec.row_words:
        raise ValueError(f"payload row is {payload.shape[-1]} words, "
                         f"spec says {spec.row_words}")
    accounted = comp.wire_bytes(d)
    physical = spec.row_bytes
    if physical != accounted:
        raise ValueError(
            f"wire accounting drift: payload row is {physical} B but "
            f"Compressor.wire_bytes({d}) = {accounted} B")


def check_bucket_payload(payload: jax.Array, plan, comp) -> None:
    """Bucket-geometry counterpart of :func:`check_payload` (DESIGN.md
    §11): the ONE flat uint32 buffer about to cross the mesh axis is
    exactly the bytes the per-leaf accounting sums to — the bucketed
    transport ships the same per-leaf payload rows back to back, never a
    padding word.  ``plan`` is a :class:`repro.comm.bucket.BucketPlan`.
    All quantities are static, so violations fail at trace time."""
    if payload.dtype != jnp.uint32:
        raise ValueError(f"payload must be uint32, got {payload.dtype}")
    if payload.shape != (plan.total_words,):
        raise ValueError(f"bucket payload is {payload.shape}, plan says "
                         f"({plan.total_words},)")
    words = 0
    for lane in plan.leaves:
        if lane.dense:
            continue
        accounted = comp.wire_bytes(lane.d)
        if lane.spec.row_bytes != accounted:
            raise ValueError(
                f"wire accounting drift: leaf {lane.index} payload row is "
                f"{lane.spec.row_bytes} B but Compressor.wire_bytes"
                f"({lane.d}) = {accounted} B")
        if lane.word_off != words:
            raise ValueError(
                f"bucket offset drift: leaf {lane.index} at word "
                f"{lane.word_off}, expected {words}")
        words += lane.words
    if words != plan.total_words:
        raise ValueError(f"bucket plan sums to {words} words, "
                         f"total_words says {plan.total_words}")


def effective_payload_bytes(payload: jax.Array, spec: WireSpec) -> jax.Array:
    """Traced count of *useful* bytes in a gathered/encoded (… , row_words)
    payload: for ragged specs, each row's valid-count header word prices
    the row at what a truly ragged collective would ship
    (``WireSpec.effective_row_bytes``); non-ragged payloads are fully
    useful.  This is the runtime counterpart of the static
    ``check_payload`` contract — the budget stays the trace-time bound,
    this is the per-step metric under it."""
    rows = payload.reshape(-1, payload.shape[-1])
    if not spec.ragged:
        return jnp.float32(rows.shape[0] * spec.row_bytes)
    # the gathered header word is worker-controlled garbage until proven
    # otherwise — decode_rows tolerates any bit pattern (the count mask
    # clamps), so the byte metric must too: an unclamped hostile count
    # would inflate effective_wire_bytes beyond the static budget
    counts = jnp.clip(rows[:, 0].astype(jnp.int32), 0, spec.full_count)
    return jnp.sum(spec.effective_row_bytes(counts))


def gather_packed(payload: jax.Array, dp_axes: AxisNames, *,
                  ring_chunks: int | None = None) -> jax.Array:
    """All-gather one worker's (L, row_words) payload over the dp axes ->
    (W, L, row_words) with the worker axis flattened across multi-axis
    meshes (('pod','data') gathers as (pod, data, ...)).

    ``ring_chunks``: when set, the gather is carried by the chunked
    ppermute ring schedule of :func:`repro.comm.ring.ring_all_gather`
    (DESIGN.md §14) instead of one flat ``lax.all_gather`` — bit-identical
    result, same total bytes per link, but split into ``n_chunks * (W-1)``
    small dependency-free collectives an overlap-capable runtime can hide
    behind compute."""
    if ring_chunks is not None:
        from repro.comm.ring import ring_all_gather
        flat = ring_all_gather(payload.reshape(-1), dp_axes, ring_chunks)
        return flat.reshape(-1, *payload.shape)
    gathered = jax.lax.all_gather(payload, dp_axes)
    if isinstance(dp_axes, (tuple, list)) and len(dp_axes) > 1:
        gathered = gathered.reshape(-1, *payload.shape)
    return gathered
