"""Bucketed payload transport for the compressed exchange (DESIGN.md §11).

``dcsgd.worker_compress_aggregate`` historically looped over pytree leaves
in Python, issuing one packed ``all_gather`` and one pack/unpack kernel
pair PER LEAF — dozens of latency-bound collectives and tiny launches per
step on the registry's transformer configs.  This module coalesces the
transport while leaving selection, EF, and all per-leaf numerics
untouched:

* :func:`build_bucket_plan` — a **trace-time** plan (pure Python over
  static leaf shapes): every compressible leaf gets a :class:`LeafLane`
  (its (L, d) row geometry, :class:`~repro.comm.wire.WireSpec`, and word
  offset into one flat wire buffer), and lanes sharing a field layout
  (``index_bits``; ``value_bits``/``block``/``k_b``/``ragged`` are
  compressor-wide) group into at most two :class:`Bucket`\\ s.
* :func:`encode_buckets` — per-leaf field construction (the exact
  :func:`repro.comm.wire.row_fields` math: scales, quantization, ragged
  value masking), then ONE ``wire_pack`` launch per bucket field section
  via the word-aligned stream reflow
  (:func:`repro.kernels.ops.pack_fields_stream`), then per-leaf assembly
  of the **exact** per-leaf payload rows into one flat ``(total_words,)``
  uint32 buffer.  No padding word ever crosses the wire: the buffer's
  byte length is the same per-leaf ``Compressor.wire_bytes`` sum the old
  loop shipped (enforced by ``exchange.check_bucket_payload``).
* :func:`decode_buckets` — the inverse: slice each gathered leaf segment
  by the plan's offset table, ONE ``wire_unpack`` launch per bucket
  section, then per-leaf interpretation
  (:func:`repro.comm.wire.fields_to_rows`) honoring each row's own ragged
  valid count.  The per-leaf ``(W, L, k)`` results are bit-identical to
  per-leaf :func:`~repro.comm.wire.decode_rows` on per-leaf gathers.

The step's collective schedule then is O(1): ONE ``all_gather`` of the
flat buffer (every bucket rides the same collective) plus ONE ``pmean``
of the concatenated dense small leaves — down from one per leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import faults
from . import wire as wire_fmt
from .wire import WireSpec


def plan_geometry(shape: Sequence[int], stacked: bool) -> tuple[int, int]:
    """(L, d) per-layer row view of a leaf shape — mirrors
    ``dcsgd._leaf_2d`` exactly (stacked leaves: leading axis = layers)."""
    shape = tuple(shape)
    size = 1
    for s in shape:
        size *= s
    if stacked and len(shape) >= 2:
        return shape[0], size // shape[0]
    return 1, size


@dataclasses.dataclass(frozen=True)
class LeafLane:
    """Trace-time transport geometry of one gradient-pytree leaf."""

    index: int                 # position in the flattened pytree
    shape: tuple[int, ...]
    L: int                     # payload rows (layers; 1 when unstacked)
    d: int                     # dense row length the indices address
    stacked: bool
    dense: bool                # ships uncompressed (pmean), no payload
    spec: WireSpec | None = None
    word_off: int = 0          # first word of this leaf's payload segment

    @property
    def words(self) -> int:
        """Flat words this leaf contributes to the wire buffer."""
        return 0 if self.dense else self.L * self.spec.row_words


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Leaves whose packed field sections share one launch geometry.

    ``value_bits``/``block``/``k_b``/``ragged`` are properties of the one
    Compressor governing the tree, so the only layout split left is the
    index width — at most two buckets ever exist (16- and 32-bit
    indices)."""

    index_bits: int
    leaf_ids: tuple[int, ...]  # tree-order indices of member leaves


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static transport plan for one gradient pytree under one Compressor."""

    leaves: tuple[LeafLane, ...]
    buckets: tuple[Bucket, ...]
    total_words: int           # flat wire-buffer length (== sum lane.words)

    @property
    def compressed_ids(self) -> tuple[int, ...]:
        return tuple(ln.index for ln in self.leaves if not ln.dense)

    @property
    def dense_ids(self) -> tuple[int, ...]:
        return tuple(ln.index for ln in self.leaves if ln.dense)

    @property
    def n_gathers(self) -> int:
        """Collectives the compressed transport issues per step: every
        bucket rides ONE flat all_gather (0 when nothing compresses)."""
        return 1 if self.total_words else 0


def build_bucket_plan(shapes: Sequence[Sequence[int]],
                      stacked: Sequence[bool], comp) -> BucketPlan:
    """Build the trace-time plan for leaves of the given ``shapes`` under
    Compressor ``comp``.  The dense/compressed split mirrors
    ``worker_compress_aggregate`` exactly; segment offsets follow tree
    order, so the flat buffer is the in-order concatenation of the same
    per-leaf payloads the per-leaf transport ships."""
    lanes: list[LeafLane] = []
    by_bits: dict[int, list[int]] = {}
    word_off = 0
    for i, (shape, st) in enumerate(zip(shapes, stacked)):
        L, d = plan_geometry(shape, st)
        if comp.ships_dense(d):
            lanes.append(LeafLane(i, tuple(shape), L, d, st, True))
            continue
        spec = WireSpec.for_row(comp, d)
        lanes.append(LeafLane(i, tuple(shape), L, d, st, False, spec,
                              word_off))
        word_off += L * spec.row_words
        by_bits.setdefault(spec.index_bits, []).append(i)
    buckets = tuple(Bucket(bits, tuple(ids))
                    for bits, ids in by_bits.items())
    return BucketPlan(tuple(lanes), buckets, word_off)


# ---------------------------------------------------------------------------
# batched field-section codec (one launch per bucket section)
# ---------------------------------------------------------------------------

def _pack_sections(group, bits: int, impl):
    """One stream-pack launch for a group of (leaf_id, (L, k) fields,
    words_per_row) sections -> {leaf_id: (L, words_per_row) words}.

    Each section is zero-padded to whole words per row first, so the
    concatenated field stream is word-aligned and the packed stream
    splits back into each leaf's exact section words."""
    streams, sizes = [], []
    F = max(1, 32 // bits)
    for _, fields, w in group:
        L, k = fields.shape
        pad = w * F - k
        if pad:
            fields = jnp.pad(fields, ((0, 0), (0, pad)))
        streams.append(fields.reshape(-1))
        sizes.append(L * w)
    words = ops.pack_fields_stream(jnp.concatenate(streams), bits,
                                   impl=impl)
    out, off = {}, 0
    for (leaf_id, fields, w), n in zip(group, sizes):
        out[leaf_id] = words[off:off + n].reshape(fields.shape[0], w)
        off += n
    return out


def _unpack_sections(group, bits: int, impl):
    """Inverse of :func:`_pack_sections`: (leaf_id, (R, w) section words,
    k) groups -> {leaf_id: (R, k) fields} via one stream-unpack launch."""
    streams = [words.reshape(-1) for _, words, _ in group]
    fields = ops.unpack_fields_stream(jnp.concatenate(streams), bits,
                                      impl=impl)
    F = max(1, 32 // bits)
    out, off = {}, 0
    for leaf_id, words, k in group:
        R, w = words.shape
        out[leaf_id] = fields[off:off + R * w * F].reshape(R, w * F)[:, :k]
        off += R * w * F
    return out


def encode_buckets(plan: BucketPlan, rows, *,
                   impl: str | None = None) -> jax.Array:
    """Encode every compressed leaf's (vals, idx, counts) into the flat
    (total_words,) uint32 wire buffer.

    ``rows``: sequence aligned with ``plan.leaves`` — ``(vals (L, k) f32,
    idx (L, k) i32, counts (L,) i32 | None)`` per compressed leaf, None
    for dense lanes.  The per-row math (ragged value masking before the
    quantization scale, scales, field construction) is
    :func:`repro.comm.wire.row_fields` — shared bit-for-bit with
    ``encode_rows``; the ragged count mask the per-leaf kernels apply
    in-launch is applied here to the field sections before the batched
    stream pack (identical fields either way).
    """
    secs: dict[int, tuple] = {}
    for ln in plan.leaves:
        if ln.dense:
            continue
        vals, idx, counts = rows[ln.index]
        header, ifields, vfields, counts = wire_fmt.row_fields(
            vals, idx, ln.spec, counts=counts)
        if ln.spec.ragged:
            valid = wire_fmt.field_mask(ln.spec.k, counts,
                                        ln.spec.count_period)
            ifields = jnp.where(valid, ifields, jnp.uint32(0))
            vfields = jnp.where(valid, vfields, jnp.uint32(0))
        secs[ln.index] = (header, ifields, vfields)

    lanes = {ln.index: ln for ln in plan.leaves}
    iwords: dict[int, jax.Array] = {}
    vwords: dict[int, jax.Array] = {}
    for b in plan.buckets:
        iwords.update(_pack_sections(
            [(i, secs[i][1], lanes[i].spec.index_words) for i in b.leaf_ids],
            b.index_bits, impl))
        # value_bits is compressor-wide, so the value sections of every
        # bucket share one width; keep the launch per bucket so the two
        # stream shapes stay tied to the bucket geometry
        vwords.update(_pack_sections(
            [(i, secs[i][2], lanes[i].spec.value_words) for i in b.leaf_ids],
            lanes[b.leaf_ids[0]].spec.value_bits, impl))

    segments = []
    for ln in plan.leaves:
        if ln.dense:
            continue
        header = secs[ln.index][0]
        parts = ([header] if header is not None else [])
        parts += [iwords[ln.index], vwords[ln.index]]
        seg = jnp.concatenate(parts, axis=-1)
        assert seg.shape == (ln.L, ln.spec.row_words), \
            (seg.shape, ln.L, ln.spec.row_words)
        segments.append(seg.reshape(-1))
    payload = jnp.concatenate(segments)
    assert payload.shape == (plan.total_words,)
    return payload


def decode_buckets(plan: BucketPlan, gathered: jax.Array, *,
                   impl: str | None = None, with_verdicts: bool = False):
    """Decode an all-gathered (W, total_words) flat buffer back to
    per-leaf ((W, L, k) f32 values, (W, L, k) i32 flat indices) pairs —
    a list aligned with ``plan.leaves`` (None for dense lanes), each
    bit-identical to per-leaf ``decode_rows`` of a per-leaf gather.

    Ragged rows are decoded by their OWN header count (workers carry
    heterogeneous k_t); the count mask the per-leaf kernels apply
    in-launch is applied per leaf after the batched stream unpack.

    This is the wire boundary: an active fault-injection campaign
    (comm/faults.py) corrupts each lane's gathered rows here, before
    unpack.  With ``with_verdicts`` a second aligned list of per-lane
    ``(W, L)`` bool validity verdicts (DESIGN.md §16) is returned and
    invalid rows come back already quarantined (zero value at index 0);
    on a clean wire every verdict is True and the decode is bit-exact
    vs ``with_verdicts=False``.
    """
    W = gathered.shape[0]
    lanes = {ln.index: ln for ln in plan.leaves}
    pay: dict[int, jax.Array] = {}
    for ln in plan.leaves:
        if ln.dense:
            continue
        seg = gathered[:, ln.word_off:ln.word_off + ln.words]
        rows = seg.reshape(W * ln.L, ln.spec.row_words)
        pay[ln.index] = faults.maybe_corrupt(rows, ln.spec, ln.index, ln.L)

    ifields: dict[int, jax.Array] = {}
    vfields: dict[int, jax.Array] = {}
    for b in plan.buckets:
        igroup, vgroup = [], []
        for i in b.leaf_ids:
            spec = lanes[i].spec
            off = spec.header_words
            igroup.append((i, pay[i][:, off:off + spec.index_words],
                           spec.k))
            vgroup.append((i, pay[i][:, off + spec.index_words:
                                     off + spec.index_words
                                     + spec.value_words], spec.k))
        ifields.update(_unpack_sections(igroup, b.index_bits, impl))
        vfields.update(_unpack_sections(
            vgroup, lanes[b.leaf_ids[0]].spec.value_bits, impl))

    out = [None] * len(plan.leaves)
    verdicts = [None] * len(plan.leaves)
    by_spec: dict = {}
    for ln in plan.leaves:
        if ln.dense:
            continue
        spec, i = ln.spec, ln.index
        counts = pay[i][:, 0].astype(jnp.int32) if spec.ragged else None
        ifld, vfld = ifields[i], vfields[i]
        if spec.ragged:
            valid = wire_fmt.field_mask(spec.k, counts, spec.count_period)
            ifld = jnp.where(valid, ifld, jnp.uint32(0))
            vfld = jnp.where(valid, vfld, jnp.uint32(0))
        off = spec.header_words
        scale_words = pay[i][:, off - 1:off] if spec.value_bits <= 8 \
            else None
        vals, idx = wire_fmt.fields_to_rows(ifld, vfld, scale_words,
                                            counts, spec)
        if with_verdicts:
            by_spec.setdefault(spec, []).append((ln, vals, idx))
        else:
            out[i] = (vals.reshape(W, ln.L, spec.k),
                      idx.reshape(W, ln.L, spec.k))
    if not with_verdicts:
        return out
    # verdict + quarantine batch per WireSpec group, not per lane: every
    # lane with the same row layout rides ONE fused launch (same
    # coalescing argument as the bucket gather itself), keeping the §16
    # guards inside the 1.05x guarded-vs-unguarded bench gate.  Row order
    # is tree order within the concatenation, so slicing back per lane is
    # bit-exact vs the per-lane calls.
    for spec, members in by_spec.items():
        if len(members) > 1:
            cat_pay = jnp.concatenate(
                [pay[ln.index] for ln, _, _ in members])
            cat_vals = jnp.concatenate([v for _, v, _ in members])
            cat_idx = jnp.concatenate([x for _, _, x in members])
        else:
            ln0 = members[0][0]
            cat_pay, cat_vals, cat_idx = (pay[ln0.index], members[0][1],
                                          members[0][2])
        v = wire_fmt.row_verdict(cat_pay, spec, cat_vals, cat_idx)
        cat_vals, cat_idx = wire_fmt.quarantine_rows(cat_vals, cat_idx, v)
        off = 0
        for ln, _, _ in members:
            rows = W * ln.L
            verdicts[ln.index] = v[off:off + rows].reshape(W, ln.L)
            out[ln.index] = (
                cat_vals[off:off + rows].reshape(W, ln.L, spec.k),
                cat_idx[off:off + rows].reshape(W, ln.L, spec.k))
            off += rows
    return out, verdicts
