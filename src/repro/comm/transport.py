"""Transport registry: the ONE source of truth for exchange schedules.

A *transport* is the schedule that moves one round's compressed payload
across the data-parallel workers inside ``worker_compress_aggregate``
(repro/core/dcsgd.py).  Historically the valid-name set lived in three
places at once — an ``if/else`` in dcsgd, a ``choices=`` list in the
training CLI, and the config docstring — so a new transport silently
passed config validation until the call failed deep inside the worker
body.  This module centralizes the names, the dispatch, and the error
message; ``OptimizerConfig.transport``, the ``--transport`` CLI flag,
and dcsgd all validate against this registry and nothing else.

The exchange interface (DESIGN.md §12)
--------------------------------------

Every registered exchange function is called with the flattened gradient
pytree and must implement steps 4-6 of Algorithm 3 for the whole tree::

    fn(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t, W)
        -> (updates, new_mem, wire_bytes, effective_wire_bytes, sums)

where ``flat_g`` / ``flat_m`` are lists of gradient / EF-memory leaves,
``flat_s`` the per-leaf stacked flags, ``comp`` the
:class:`~repro.core.compression.Compressor`, ``gamma_t`` the traced
per-round compression level (or None), and ``W`` the dp worker count.
``updates`` / ``new_mem`` are leaf lists in the same order; ``sums`` is a
:class:`~repro.core.telemetry.TelemetrySums` (the caller finalizes it).

*Stateful* transports (``stateful=True``, e.g. the gossip exchange)
additionally take a ``ctx`` keyword (transport-specific context: mixing
topology + consensus config + carried state) and return a sixth element,
the new carried state::

    fn(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t, W, ctx=ctx)
        -> (updates, new_mem, wire, eff_wire, sums, new_state)

``worker_compress_aggregate`` mirrors this arity: it returns a 5-tuple
for stateless transports and a 6-tuple for stateful ones.
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Transport:
    """One registered exchange schedule."""

    name: str
    exchange: Callable
    stateful: bool = False      # takes ctx=..., returns new state as 6th
    description: str = ""


_REGISTRY: dict[str, Transport] = {}


def register_transport(name: str, *, stateful: bool = False,
                       description: str = ""):
    """Decorator: register an exchange function under ``name``.

    The decorated function must satisfy the module-docstring interface.
    Registration is idempotent per name only for the identical function
    (re-import safety); a second, different function is a bug.
    """
    def deco(fn: Callable) -> Callable:
        prev = _REGISTRY.get(name)
        if prev is not None and prev.exchange is not fn:
            raise ValueError(f"transport {name!r} already registered")
        _REGISTRY[name] = Transport(name, fn, stateful, description)
        return fn
    return deco


def _ensure_registered() -> None:
    """Import the modules that register the built-in transports.

    Lazy so this module stays import-cycle-free: dcsgd registers
    ``bucketed``/``perleaf`` at its import, ``repro.comm.gossip``
    registers ``gossip``.  By the time any *call* into the registry
    happens those imports are cheap no-ops or resolve cleanly.
    """
    import repro.comm.faults      # noqa: F401  (registers "faulty")
    import repro.comm.gossip      # noqa: F401  (registers "gossip")
    import repro.comm.overlap     # noqa: F401  (registers "overlap")
    import repro.core.dcsgd       # noqa: F401  (registers "bucketed"/"perleaf")


def transport_names() -> tuple[str, ...]:
    """Sorted valid transport names — feeds CLI ``choices=`` and errors."""
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def unknown_transport_message(name: str) -> str:
    """THE error text for an invalid transport name, used verbatim by
    config validation and dcsgd dispatch so the two can never drift."""
    want = " | ".join(f"'{n}'" for n in transport_names())
    return f"unknown transport {name!r} (want {want})"


def get_transport(name: str) -> Transport:
    """Resolve a registered transport; raises the canonical ValueError."""
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(unknown_transport_message(name)) from None


def validate_transport(name: str) -> str:
    """Config-time validation hook (``OptimizerConfig.__post_init__``)."""
    get_transport(name)
    return name
