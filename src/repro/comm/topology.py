"""Gossip topologies: static mixing matrices and neighbor schedules.

The gossip transport (repro/comm/gossip.py, DESIGN.md §12) replaces the
star-shaped ``all_gather`` with point-to-point ``ppermute``\\ s along the
edges of a fixed communication graph.  This module builds that graph at
TRACE TIME as pure Python/NumPy: a :class:`Topology` is a set of
*neighbor directions*, each a full permutation of the ``n`` workers
(circulant shifts for ring/exponential graphs, row/column shifts for the
torus), so one ``jax.lax.ppermute`` per direction delivers every
worker's payload to exactly one neighbor.

Mixing weights are uniform Metropolis weights on the resulting
``degree``-regular graph: ``W_ij = 1/(degree+1)`` for every edge and for
the self loop.  Every constructor checks, at build time, that the
resulting matrix is symmetric, doubly stochastic, and (for ``n > 1``)
has a strictly positive spectral gap — a broken topology fails before
anything is traced (tests/test_property.py pins these invariants for
W in {4, 8, 16}).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

Perm = tuple[tuple[int, int], ...]   # ((src, dst), ...) — one ppermute


@dataclasses.dataclass(frozen=True)
class Topology:
    """A fixed gossip graph over ``n`` workers.

    ``perms`` holds one full ``(src, dst)`` permutation per neighbor
    direction; deduplicated, so ``degree == len(perms)`` distinct
    neighbors per worker (the graphs here are vertex-transitive, so the
    degree is uniform).  ``n == 1`` is the degenerate self-only graph
    (used by single-worker benches); it has no edges and mixing is the
    identity.
    """

    name: str
    n: int
    perms: tuple[Perm, ...]

    @property
    def degree(self) -> int:
        return len(self.perms)

    @property
    def mix_weight(self) -> float:
        """Uniform Metropolis weight of every edge and the self loop."""
        return 1.0 / (self.degree + 1)

    def neighbors(self, i: int) -> tuple[int, ...]:
        """Workers whose payload worker ``i`` receives (one per perm)."""
        out = []
        for perm in self.perms:
            for src, dst in perm:
                if dst == i:
                    out.append(src)
        return tuple(out)

    def mixing_matrix(self) -> np.ndarray:
        """The (n, n) float64 doubly-stochastic mixing matrix ``M``:
        ``M[i, j]`` is the weight of worker ``j``'s value in worker
        ``i``'s mix — ``(I + sum_d P_d) / (degree + 1)`` with
        ``P_d[dst, src] = 1`` for direction ``d``."""
        m = np.eye(self.n, dtype=np.float64)
        for perm in self.perms:
            for src, dst in perm:
                m[dst, src] += 1.0
        return m / (self.degree + 1)

    def mix_reference(self, z, lr: float = 1.0):
        """Collective-free reference of ONE gossip round on stacked
        per-worker values ``z`` with shape ``(n, ...)``:

            z_i' = z_i + (lr / (degree+1)) * sum_j in N(i) (z_j - z_i)

        Written in the difference form so a constant ``z`` is a fixed
        point BIT-EXACTLY (every ``z_j - z_i`` is literally zero) — the
        same form :func:`repro.comm.gossip.gossip_mix` uses on-device.
        Works on NumPy and jnp arrays alike (pure indexing/arithmetic).
        """
        acc = None
        for perm in self.perms:
            src = np.empty(self.n, dtype=np.int64)
            for s, d in perm:
                src[d] = s
            delta = z[src] - z
            acc = delta if acc is None else acc + delta
        if acc is None:
            return z
        w = np.asarray(lr / (self.degree + 1), dtype=np.asarray(z).dtype) \
            if isinstance(z, np.ndarray) else lr / (self.degree + 1)
        return z + w * acc

    def spectral_gap(self) -> float:
        """``1 - max_{lambda != 1} |lambda(M)|`` (0 for ``n == 1``)."""
        if self.n == 1:
            return 0.0
        lam = np.linalg.eigvalsh(self.mixing_matrix())
        return float(1.0 - max(abs(lam[0]), abs(lam[-2])))


def _shift_perm(n: int, s: int) -> Perm:
    """Circulant shift: worker ``i`` sends to ``(i + s) mod n``."""
    return tuple((i, (i + s) % n) for i in range(n))


def _checked(topo: Topology) -> Topology:
    """Build-time invariants: perms are permutations, matrix symmetric,
    doubly stochastic, spectral gap > 0 (connected, non-bipartite-safe
    thanks to the self loop weight)."""
    for perm in topo.perms:
        srcs = {s for s, _ in perm}
        dsts = {d for _, d in perm}
        if srcs != set(range(topo.n)) or dsts != set(range(topo.n)):
            raise ValueError(f"{topo.name}: direction is not a "
                             f"permutation of {topo.n} workers: {perm}")
    m = topo.mixing_matrix()
    if not np.array_equal(m, m.T):
        raise ValueError(f"{topo.name}({topo.n}): mixing matrix is not "
                         f"symmetric")
    ones = np.ones(topo.n)
    if not (np.allclose(m @ ones, ones) and np.allclose(ones @ m, ones)):
        raise ValueError(f"{topo.name}({topo.n}): mixing matrix is not "
                         f"doubly stochastic")
    if topo.n > 1 and topo.n <= 4096 and topo.spectral_gap() <= 0.0:
        raise ValueError(f"{topo.name}({topo.n}): zero spectral gap — "
                         f"gossip would not mix")
    return topo


def _dedup(perms: list[Perm]) -> tuple[Perm, ...]:
    seen, out = set(), []
    for p in perms:
        if p not in seen:
            seen.add(p)
            out.append(p)
    return tuple(out)


def ring(n: int) -> Topology:
    """Bidirectional ring: neighbors at +-1 (degree 2; 1 for ``n <= 2``)."""
    if n < 1:
        raise ValueError(f"ring: need n >= 1, got {n}")
    perms = [] if n == 1 else _dedup([_shift_perm(n, 1), _shift_perm(n, -1)])
    return _checked(Topology("ring", n, perms))


def _torus_dims(n: int) -> tuple[int, int]:
    """Largest factor pair r x c with r <= c (r as close to sqrt(n) as
    the factorization allows)."""
    r = int(math.isqrt(n))
    while r > 1 and n % r:
        r -= 1
    return r, n // r


def torus(n: int) -> Topology:
    """2-D torus on an ``r x c`` factorization of ``n`` (row-major):
    neighbors at +-1 within the row (wraparound at ``c``) and +-1 across
    rows (circulant shift by ``c``).  ``n`` prime degrades to a ring."""
    if n < 1:
        raise ValueError(f"torus: need n >= 1, got {n}")
    if n == 1:
        return _checked(Topology("torus", 1, ()))
    r, c = _torus_dims(n)
    if r == 1:
        return _checked(Topology("torus", n,
                                 _dedup([_shift_perm(n, 1),
                                         _shift_perm(n, -1)])))

    def row_shift(s: int) -> Perm:
        return tuple((i * c + j, i * c + (j + s) % c)
                     for i in range(r) for j in range(c))

    perms = _dedup([row_shift(1), row_shift(-1),
                    _shift_perm(n, c), _shift_perm(n, -c)])
    return _checked(Topology("torus", n, perms))


def exp_graph(n: int) -> Topology:
    """Symmetric (static) exponential graph: neighbors at +-2**j hops for
    ``2**j < n`` — O(log n) degree, O(log n)-step information spread."""
    if n < 1:
        raise ValueError(f"exp: need n >= 1, got {n}")
    perms: list[Perm] = []
    j = 1
    while j < n:
        perms += [_shift_perm(n, j), _shift_perm(n, -j)]
        j *= 2
    return _checked(Topology("exp", n, _dedup(perms)))


#: Name -> constructor; the single source of truth for ``--topology``.
TOPOLOGIES = {"ring": ring, "torus": torus, "exp": exp_graph}


def build_topology(name: str, n: int) -> Topology:
    try:
        make = TOPOLOGIES[name]
    except KeyError:
        want = " | ".join(f"'{t}'" for t in sorted(TOPOLOGIES))
        raise ValueError(f"unknown topology {name!r} (want {want})") \
            from None
    return make(n)
