"""Seeded hostile-wire fault injection + the "faulty" transport wrapper
(DESIGN.md §16).

The §8/§9 wire format is only *garbage-tolerant* by construction
(index/count clamping); this module makes hostility a first-class, fully
reproducible test axis.  A :class:`FaultConfig` names per-fault-class
rates and a deterministic ``(seed, step, lane, row)`` keying; the
injector corrupts **gathered payload rows at the wire boundary** — after
the collective, before decode — exactly where a flaky NIC, a bad DMA, or
a crashed peer would.  Fault classes:

* ``bitflip``    — XOR one uniformly-chosen bit anywhere in the row
  (header, scale, index or value words).
* ``count``      — replace a ragged count header with ``0xFFFFFFFF``
  (decodes to -1: truncated) or ``2*full_count + 7`` (overflowed, would
  unmask garbage tail fields as live values).
* ``nonfinite``  — write NaN/Inf bit patterns into the first value word
  (f32/bf16 rows) or the quantization-scale word (sub-byte rows), the
  spot where corruption poisons every dequantized value.
* ``zero_row``   — zero the whole row: a dropped/silent worker.  Note an
  all-zero row *decodes cleanly* (count 0, scale 0, values 0) — it
  degrades the aggregate rather than poisoning it, so the verdict layer
  deliberately does NOT quarantine it.

Injection is wired through the §12 transport registry as a *wrapper*
transport: ``transport="faulty"`` takes a :class:`FaultCtx` naming the
wrapped inner transport (bucketed / perleaf / gossip / overlap) and its
own ctx, and simply runs the inner exchange inside the
:func:`active_faults` trace-time context — the decode paths
(comm/bucket.py, dcsgd's per-leaf reference) consult the context via
:func:`maybe_corrupt`.  With no active context ``maybe_corrupt`` is a
Python-level identity, so the faults-off step traces byte-identical HLO
(zero added collectives, zero added ops — the bit-exactness guarantee).

The **verdict layer** (``wire.row_verdict`` + quarantine) is independent
of injection and on by default; :func:`guards_active` returns False only
inside :func:`guards_disabled` (the unguarded bench/divergence-pin path)
or when the active ``FaultConfig`` sets ``quarantine=False``.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.comm.transport import get_transport, register_transport

_RATE_FIELDS = ("p_bitflip", "p_count", "p_nonfinite", "p_zero_row")

# f32 / packed-bf16-pair bit patterns for the nonfinite fault class
_F32_NAN = 0x7FC00000
_F32_INF = 0x7F800000
_BF16_NAN_PAIR = 0x7FC07FC0
_BF16_INF_PAIR = 0x7F807F80


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static, hashable description of one injection campaign.

    ``worker`` targets one slot of the gathered leading axis (the dp
    worker for all_gather transports, the cohort client slot for fed,
    ring slot for gossip — slot 0 is self); ``-1`` targets every row.
    ``start_step``/``n_steps`` bound the burst (``n_steps=-1``: open
    ended).  ``quarantine=False`` keeps injecting but disables the
    verdict layer — the "what if we had no guards" ablation arm.
    """

    seed: int = 0
    p_bitflip: float = 0.0
    p_count: float = 0.0
    p_nonfinite: float = 0.0
    p_zero_row: float = 0.0
    worker: int = -1
    start_step: int = 0
    n_steps: int = -1
    quarantine: bool = True

    def __post_init__(self):
        for f in _RATE_FIELDS:
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultConfig.{f} must be in [0, 1], "
                                 f"got {v!r}")
        if self.start_step < 0:
            raise ValueError("FaultConfig.start_step must be >= 0")

    @property
    def enabled(self) -> bool:
        """True iff any fault class has a nonzero rate."""
        return any(getattr(self, f) > 0.0 for f in _RATE_FIELDS)


@dataclasses.dataclass(frozen=True)
class FaultCtx:
    """``transport_ctx`` of the "faulty" wrapper transport: the campaign,
    the traced round index, and the wrapped inner transport (+ its own
    ctx when the inner transport is itself stateful)."""

    cfg: FaultConfig
    step: object               # traced int32 round index
    inner: str                 # wrapped transport name
    inner_ctx: object = None   # inner transport's own ctx (stateful only)


# ---------------------------------------------------------------------------
# trace-time context plumbing
# ---------------------------------------------------------------------------
# Set while the jitted exchange traces (worker_fn traces once per compile,
# so a with-block around the inner exchange call scopes exactly the decode
# sites we want).  Never touched at runtime.

@dataclasses.dataclass
class _ActiveFaults:
    cfg: FaultConfig
    step: object


_ACTIVE: list[_ActiveFaults] = []
_GUARDS_OFF: list[bool] = []


@contextlib.contextmanager
def active_faults(cfg: FaultConfig, step):
    """Trace-time scope: decode sites reached inside inject faults keyed
    on ``(cfg.seed, step, lane, row)``."""
    _ACTIVE.append(_ActiveFaults(cfg, step))
    try:
        yield
    finally:
        _ACTIVE.pop()


@contextlib.contextmanager
def guards_disabled():
    """Trace-time scope: disable the decode verdict/quarantine layer (the
    unguarded bench arm and the pinned no-quarantine divergence test)."""
    _GUARDS_OFF.append(True)
    try:
        yield
    finally:
        _GUARDS_OFF.pop()


def guards_active() -> bool:
    """Should decode sites compute verdicts and quarantine?  True by
    default (defensive decode is always on); False inside
    :func:`guards_disabled` or when an active campaign opts out."""
    if _GUARDS_OFF:
        return False
    if _ACTIVE and not _ACTIVE[-1].cfg.quarantine:
        return False
    return True


def injection_active() -> bool:
    return bool(_ACTIVE) and _ACTIVE[-1].cfg.enabled


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

def maybe_corrupt(rows: jax.Array, spec, lane: int,
                  rows_per_worker: int) -> jax.Array:
    """Corrupt gathered payload ``rows`` ((R, row_words) uint32) per the
    active campaign; Python-level identity when none is active.

    Randomness is ``fold_in(fold_in(key(seed), lane), step)`` then
    per-row uniform draws — deterministic in ``(seed, step, lane, row)``
    and independent of mesh shape, so the same campaign replays exactly
    across (8,) and (4,2) meshes.  ``rows_per_worker`` maps row index to
    gathered slot (``row // rows_per_worker``) for ``cfg.worker``
    targeting.
    """
    if not _ACTIVE:
        return rows
    st = _ACTIVE[-1]
    cfg = st.cfg
    if not cfg.enabled:
        return rows
    R, words = rows.shape
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), lane)
    key = jax.random.fold_in(key, jnp.asarray(st.step, jnp.int32))
    u = jax.random.uniform(key, (R, 6))
    kf = jax.random.split(key, 2)

    step = jnp.asarray(st.step, jnp.int32)
    in_window = step >= cfg.start_step
    if cfg.n_steps >= 0:
        in_window &= step < cfg.start_step + cfg.n_steps
    if cfg.worker >= 0:
        slot = jnp.arange(R, dtype=jnp.int32) // rows_per_worker
        active = in_window & (slot == cfg.worker)
    else:
        active = jnp.broadcast_to(in_window, (R,))

    # -- bitflip: XOR one uniform bit of one uniform word ------------------
    if cfg.p_bitflip > 0.0:
        hit = active & (u[:, 0] < cfg.p_bitflip)
        w_sel = jax.random.randint(kf[0], (R,), 0, words)
        b_sel = jax.random.randint(kf[1], (R,), 0, 32)
        flip = jnp.where(hit, jnp.uint32(1) << b_sel.astype(jnp.uint32),
                         jnp.uint32(0))
        col = jnp.arange(words, dtype=jnp.int32)[None, :] == w_sel[:, None]
        rows = rows ^ jnp.where(col, flip[:, None], jnp.uint32(0))

    # -- count header: truncated (-1) or overflowed ------------------------
    if cfg.p_count > 0.0 and spec.ragged:
        hit = active & (u[:, 1] < cfg.p_count)
        bad = jnp.where(u[:, 2] < 0.5,
                        jnp.uint32(0xFFFFFFFF),
                        jnp.uint32(2 * spec.full_count + 7))
        rows = rows.at[:, 0].set(jnp.where(hit, bad, rows[:, 0]))

    # -- nonfinite value/scale word ----------------------------------------
    if cfg.p_nonfinite > 0.0:
        hit = active & (u[:, 3] < cfg.p_nonfinite)
        if spec.value_bits <= 8:
            # poison the quantization scale: every dequantized value NaNs
            tw = spec.header_words - 1
            bad = jnp.where(u[:, 4] < 0.5, jnp.uint32(_F32_NAN),
                            jnp.uint32(_F32_INF))
        else:
            # poison the first value word (live whenever count >= 1)
            tw = spec.header_words + spec.index_words
            if spec.value_bits == 16:
                bad = jnp.where(u[:, 4] < 0.5, jnp.uint32(_BF16_NAN_PAIR),
                                jnp.uint32(_BF16_INF_PAIR))
            else:
                bad = jnp.where(u[:, 4] < 0.5, jnp.uint32(_F32_NAN),
                                jnp.uint32(_F32_INF))
        rows = rows.at[:, tw].set(jnp.where(hit, bad, rows[:, tw]))

    # -- dropped worker: whole row zeroed (wins over the others) -----------
    if cfg.p_zero_row > 0.0:
        hit = active & (u[:, 5] < cfg.p_zero_row)
        rows = jnp.where(hit[:, None], jnp.uint32(0), rows)

    return rows


# ---------------------------------------------------------------------------
# the wrapper transport
# ---------------------------------------------------------------------------

@register_transport(
    "faulty", stateful=True,
    description="fault-injection wrapper: runs the FaultCtx.inner "
                "transport with seeded wire corruption active (§16)")
def faulty_exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t,
                    W, *, ctx: FaultCtx):
    """Run ``ctx.inner``'s exchange inside the injection scope.

    Stateless inner transports are padded with an empty ``()`` carried
    state so the wrapper keeps the uniform stateful 6-tuple arity.
    """
    if ctx.inner == "faulty":
        raise ValueError("faulty transport cannot wrap itself")
    inner = get_transport(ctx.inner)
    with active_faults(ctx.cfg, ctx.step):
        if inner.stateful:
            if ctx.inner_ctx is None:
                raise ValueError(
                    f"faulty wrapper around stateful transport "
                    f"{ctx.inner!r} needs FaultCtx.inner_ctx")
            return inner.exchange(flat_g, flat_m, flat_s, eta, comp,
                                  dp_axes, gamma_t, W, ctx=ctx.inner_ctx)
        out = inner.exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes,
                             gamma_t, W)
        return (*out, ())
