"""Compressed communication: the bit-packed wire format, the packed
payload exchange that makes ``wire_bytes`` the literal bytes on the mesh
(DESIGN.md §8), the bucketed transport that coalesces the per-leaf
exchange into O(1) collectives and launches (DESIGN.md §11), and the
transport registry + serverless gossip exchange (DESIGN.md §12).

Import order matters below: ``gossip`` imports ``transport``/``topology``
/``bucket``, and nothing here imports ``repro.core`` at package level
(``repro.core.dcsgd`` imports THIS package — the registry's lazy
``_ensure_registered`` is what closes the loop at call time)."""
from .bucket import (BucketPlan, build_bucket_plan, decode_buckets,
                     encode_buckets)
from .exchange import check_bucket_payload, check_payload, gather_packed
from .wire import WireSpec, decode_rows, encode_rows
from .transport import (Transport, get_transport, register_transport,
                        transport_names, unknown_transport_message,
                        validate_transport)
from .topology import TOPOLOGIES, Topology, build_topology
from .gossip import GossipConfig, GossipCtx, GossipState, gossip_mix

__all__ = ["WireSpec", "encode_rows", "decode_rows", "check_payload",
           "check_bucket_payload", "gather_packed", "BucketPlan",
           "build_bucket_plan", "encode_buckets", "decode_buckets",
           "Transport", "register_transport", "get_transport",
           "transport_names", "unknown_transport_message",
           "validate_transport", "Topology", "TOPOLOGIES",
           "build_topology", "GossipConfig", "GossipState", "GossipCtx",
           "gossip_mix"]
