"""Compressed communication: the bit-packed wire format, the packed
payload exchange that makes ``wire_bytes`` the literal bytes on the mesh
(DESIGN.md §8), and the bucketed transport that coalesces the per-leaf
exchange into O(1) collectives and launches (DESIGN.md §11)."""
from .bucket import (BucketPlan, build_bucket_plan, decode_buckets,
                     encode_buckets)
from .exchange import check_bucket_payload, check_payload, gather_packed
from .wire import WireSpec, decode_rows, encode_rows

__all__ = ["WireSpec", "encode_rows", "decode_rows", "check_payload",
           "check_bucket_payload", "gather_packed", "BucketPlan",
           "build_bucket_plan", "encode_buckets", "decode_buckets"]
