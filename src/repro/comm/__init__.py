"""Compressed communication: the bit-packed wire format and the packed
payload exchange that make ``wire_bytes`` the literal bytes on the mesh
(DESIGN.md §8)."""
from .exchange import check_payload, gather_packed
from .wire import WireSpec, decode_rows, encode_rows

__all__ = ["WireSpec", "encode_rows", "decode_rows", "check_payload",
           "gather_packed"]
