"""Per-leaf selection/encode math shared by every transport.

``repro.core.dcsgd`` historically owned these helpers privately; the
gossip transport (repro/comm/gossip.py) needs the identical selection,
scatter, and EF-residual primitives but must not import dcsgd (dcsgd
imports ``repro.comm``, which imports gossip — a cycle).  This module is
the neutral home: pure leaf math with no knowledge of any collective
schedule.  dcsgd re-exports these under its old underscore names, so the
numerics — and therefore the transport parity contracts — are untouched.

:func:`select_and_encode` is the whole-tree selection stage of the
bucketed wire pipeline (DESIGN.md §11 steps before the gather): fused or
unfused per-leaf compression at the static budget, per-round valid
counts (§9), and the ``(vals, idx, counts)`` rows ``encode_buckets``
consumes.  Both the bucketed all_gather transport and the gossip
ppermute transport run this exact stage, which is what makes their EF
memories and byte counters bit-identical on identical inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ops
from .compression import Compressor, block_extract_sparse

AxisNames = Sequence[str] | str


def dp_size(dp_axes: AxisNames):
    return compat.axis_size(dp_axes)


def dp_index(dp_axes: AxisNames):
    """This worker's row in the all-gathered leading axis (lax.axis_index
    handles axis tuples row-major, matching all_gather's stacking order)."""
    axes = dp_axes if isinstance(dp_axes, str) else tuple(dp_axes)
    return jax.lax.axis_index(axes)


def per_layer_topk(acc2d: jax.Array, k: int):
    """Batched exact top-k over the last axis. acc2d: (L, d)."""
    mag = jnp.abs(acc2d)
    _, idx = jax.lax.top_k(mag, k)                     # (L, k)
    vals = jnp.take_along_axis(acc2d, idx, axis=1)     # (L, k)
    return vals, idx.astype(jnp.int32)


def scatter_layers(vals: jax.Array, idx: jax.Array, L: int, d: int,
                   dtype) -> jax.Array:
    """Scatter (L, k) or gathered (W, L, k) sparse pairs into a dense
    (L, d) accumulator — the W axis (workers), when present, sums into
    the same layer rows."""
    if vals.ndim not in (2, 3):
        raise ValueError(f"expected (L, k) or (W, L, k), got {vals.shape}")
    vals = vals.reshape(-1, L, vals.shape[-1])
    idx = idx.reshape(vals.shape)
    W, _, k = vals.shape
    lidx = jnp.broadcast_to(jnp.arange(L)[None, :, None], (W, L, k))
    dense = jnp.zeros((L, d), dtype)
    return dense.at[lidx, idx].add(vals.astype(dtype))


def leaf_2d(x: jax.Array, stacked: bool) -> jax.Array:
    """(L, d) per-layer view of a leaf (L = 1 when unstacked)."""
    if stacked and x.ndim >= 2:
        return x.reshape(x.shape[0], -1)
    return x.reshape(1, -1)


def compress_leaf(acc: jax.Array, comp: Compressor, stacked: bool):
    """Per-leaf sparse compression. Returns (vals, idx, (L, d)) flat layout."""
    flat = leaf_2d(acc, stacked)
    L, d = flat.shape
    if comp.method == "block_topk" and d >= comp.min_compress_size:
        # block-local selection, batched over layers
        vals, idx = block_extract_sparse(flat, comp)
        return vals, idx, (L, d)
    vals, idx = per_layer_topk(flat, comp.k_for(d))
    return vals, idx, (L, d)


def leaf_count(comp: Compressor, spec, gamma_t, d: int):
    """Per-round valid count for one leaf's rows (DESIGN.md §9): the
    per-block ``k_b_t`` for block-local rows, the row ``k_t`` for flat
    rows.  None for non-ragged specs."""
    if not spec.ragged:
        return None
    return comp.block_k_t(gamma_t) if spec.local \
        else comp.k_t_for(d, gamma_t)


@dataclasses.dataclass
class Selection:
    """Whole-tree selection-stage outputs, indexed by leaf position.

    Entries are ``None`` for leaves the field doesn't apply to (dense
    leaves everywhere; ``acc2`` on the fused path, ``sent``/``resid`` on
    the unfused path).
    """

    use_fused: bool
    g2f: list          # (L, d) f32 gradient views (compressed leaves)
    acc2: list         # unfused: (L, d) f32 accumulator
    sent: list         # fused: kept entries ...
    resid: list        # ... and EF residual pair
    leaf_g_sq: list
    leaf_acc_sq: list
    enc_rows: list     # (vals, idx, counts) per compressed leaf
    counts: list       # scalar per-round count (ragged specs)


def select_and_encode(flat_g, flat_m, flat_s, eta, comp: Compressor,
                      gamma_t, plan) -> Selection:
    """The batched selection stage every bucketed-wire transport shares
    (DESIGN.md §11): ONE fused-EF two-pass launch pair over every
    kernel-path leaf, per-leaf selection at the static budget, per-round
    valid counts, and the encode rows for ``encode_buckets``.  Selection
    is per leaf BY DESIGN — the contraction constant is per layer row;
    only the collective schedule differs between transports.
    """
    use_fused = comp.method == "block_topk" and comp.use_kernel
    lanes = plan.leaves
    n = len(lanes)
    comp_ids = list(plan.compressed_ids)
    sel = Selection(use_fused, *([None] * n for _ in range(8)))
    if use_fused and comp_ids:
        ms = [leaf_2d(flat_m[i], flat_s[i]).astype(jnp.float32)
              for i in comp_ids]
        gs = [leaf_2d(flat_g[i], flat_s[i]).astype(jnp.float32)
              for i in comp_ids]
        # one pass-1 + one pass-2 launch for ALL leaves; thresholds stay
        # at the BUDGET level exactly as in the per-leaf path
        outs = ops.fused_ef_compress_batched(
            ms, gs, eta, comp.geometry_gamma, comp.block, telemetry=True)
        for i, g2, (s, r, _, moments) in zip(comp_ids, gs, outs):
            sel.g2f[i], sel.sent[i], sel.resid[i] = g2, s, r
            # NB: the batched kernel's per-leaf outputs are bit-identical
            # to per-leaf launches, but THIS reduce may fuse differently
            # in the two programs — XLA does not pin f32 reduction order
            # across program shapes, so telemetry parity is a few-ulp
            # contract while every other output is bit-exact (DESIGN §11)
            sel.leaf_g_sq[i] = jnp.sum(moments[:, 0])
            sel.leaf_acc_sq[i] = jnp.sum(moments[:, 1])
    for i in comp_ids:
        lane = lanes[i]
        if use_fused:
            vals, idx = block_extract_sparse(sel.sent[i], comp)
        else:
            g2 = leaf_2d(flat_g[i], flat_s[i]).astype(jnp.float32)
            a2 = leaf_2d(flat_m[i], flat_s[i]).astype(jnp.float32) \
                + eta * g2
            sel.g2f[i], sel.acc2[i] = g2, a2
            sel.leaf_g_sq[i] = jnp.sum(g2 * g2)
            sel.leaf_acc_sq[i] = jnp.sum(a2 * a2)
            vals, idx, _ = compress_leaf(a2, comp, flat_s[i])
        sel.counts[i] = leaf_count(comp, lane.spec, gamma_t, lane.d)
        sel.enc_rows[i] = (vals, idx,
                           None if sel.counts[i] is None
                           else jnp.broadcast_to(sel.counts[i], (lane.L,)))
    return sel
