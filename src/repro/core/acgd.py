"""ACGD — accelerated (Nesterov-momentum) compressed gradient descent.

"Acceleration for Compressed Gradient Descent in Distributed and
Federated Optimization" (Li, Kovalev, Qian, Richtárik — arXiv
2002.11364) shows Nesterov acceleration composes with gradient
compression.  The paper analyzes unbiased compressors; our wire ships
biased top-k/block-top-k selections, so — as everywhere else in this
repo — the compression error is recycled through error feedback
(EF-SGDm-style, cf. ``CSGDConfig.momentum``'s heavy-ball precedent):

    v_t   = mu * v_{t-1} + g_t                 (momentum buffer)
    d_t   = mu * v_t + g_t                     (Nesterov lookahead)
    acc   = m_{t-1} + eta * d_t                (EF accumulator)
    sent, m_t = compress(acc), acc - sent      (wire + residual)
    x_t   = x_{t-1} - sent

Unlike CSGD-ASSS there is no Armijo search — the step size is the fixed
``eta`` (the accelerated family trades the paper's adaptive step for
momentum), but the AdaCGD gamma controller still drives the per-round
compression level (``fixed``/``linear``/``ef-coupled``; armijo-coupled
has no search to couple to and is rejected).  The golden suite pins this
kind against scaled-step CSGD on the interpolated quadratic
(tests/test_acgd.py); the distributed runtime exposes it as
``kind="acgd"`` with the Nesterov velocity carried per worker in
``DistOptState.velocity``, composing with the compressed downlink's
server-side EF (DESIGN.md §15).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .compression import (Compressor, tree_effective_wire_bytes,
                          tree_wire_bytes)
from .gamma import GammaControllerConfig, gamma_init, gamma_update
from .telemetry import CompressionTelemetry, TelemetrySums

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AcgdConfig:
    compressor: Compressor = Compressor()
    gamma_ctrl: GammaControllerConfig = GammaControllerConfig()
    eta: float = 0.1                # fixed step size
    momentum: float = 0.9           # Nesterov mu
    ef_dtype: str = "float32"

    def __post_init__(self):
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError(f"momentum must be in [0, 1), got "
                             f"{self.momentum}")
        if self.gamma_ctrl.schedule == "armijo-coupled":
            raise ValueError("acgd has no Armijo search for the "
                             "armijo-coupled gamma schedule to couple to "
                             "— use fixed | linear | ef-coupled")

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class AcgdState(NamedTuple):
    step: jax.Array          # int32
    memory: PyTree           # error-feedback m_t, shaped like params
    velocity: PyTree         # Nesterov momentum buffer v_t
    gamma: jax.Array         # per-round compression level gamma_t
    telemetry: CompressionTelemetry  # last round's compression health
    cum_eff_bytes: jax.Array         # cumulative effective wire bytes


class AcgdAux(NamedTuple):
    loss: jax.Array
    eta: jax.Array
    grad_sqnorm: jax.Array
    gamma: jax.Array
    wire_bytes: jax.Array
    eff_wire_bytes: jax.Array
    telemetry: CompressionTelemetry
    cum_eff_bytes: jax.Array


class ACGD:
    """Single-node ACGD (arXiv 2002.11364 composed with EF)."""

    def __init__(self, cfg: AcgdConfig):
        self.cfg = cfg

    def init(self, params: PyTree) -> AcgdState:
        ef_dt = jnp.dtype(self.cfg.ef_dtype)
        zeros = lambda dt: jax.tree.map(
            lambda p: jnp.zeros(p.shape, dt), params)
        return AcgdState(
            step=jnp.int32(0),
            memory=zeros(ef_dt),
            velocity=zeros(jnp.float32),
            gamma=gamma_init(self.cfg.gamma_ctrl, self.cfg.compressor),
            telemetry=CompressionTelemetry.init(),
            cum_eff_bytes=jnp.float32(0.0),
        )

    def step(
        self,
        loss_fn: Callable[[PyTree], jax.Array],
        params: PyTree,
        state: AcgdState,
    ) -> tuple[PyTree, AcgdState, AcgdAux]:
        cfg = self.cfg
        comp = cfg.compressor
        mu = cfg.momentum
        loss, grads = jax.value_and_grad(loss_fn)(params)
        gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                  for g in jax.tree.leaves(grads))

        gamma_t = gamma_update(cfg.gamma_ctrl, comp, state.gamma,
                               state.step, compression=state.telemetry)
        eta = jnp.float32(cfg.eta)

        vel = jax.tree.map(
            lambda v, g: mu * v + g.astype(jnp.float32),
            state.velocity, grads)
        descent = jax.tree.map(
            lambda v, g: mu * v + g.astype(jnp.float32), vel, grads)

        sums = TelemetrySums.zero()
        flat_m, treedef = jax.tree.flatten(state.memory)
        flat_d = treedef.flatten_up_to(descent)
        flat_g = treedef.flatten_up_to(grads)
        pairs = []
        for m, d, g in zip(flat_m, flat_d, flat_g):
            gf = g.astype(jnp.float32)
            acc = m.astype(jnp.float32) + eta * d
            sent, resid = comp.compress_dense(
                acc, gamma_t=gamma_t if comp.adaptive else None)
            sums = sums.add(g_sq=jnp.sum(gf * gf),
                            acc_sq=jnp.sum(acc * acc),
                            resid_sq=jnp.sum(resid * resid),
                            own_sq=jnp.sum(sent * sent),
                            own_dot_g=jnp.sum(sent * gf))
            pairs.append((sent, resid))
        sent = treedef.unflatten([p[0] for p in pairs])
        resid = treedef.unflatten([p[1] for p in pairs])
        telemetry = sums.finalize()

        new_params = jax.tree.map(
            lambda p, s: (p.astype(jnp.float32) - s).astype(p.dtype),
            params, sent)
        wire = jnp.float32(tree_wire_bytes(params, comp))
        eff = tree_effective_wire_bytes(params, comp, gamma_t) \
            if comp.adaptive else wire
        cum_eff = state.cum_eff_bytes + eff
        new_state = AcgdState(
            step=state.step + 1,
            memory=jax.tree.map(
                lambda r, m: r.astype(m.dtype), resid, state.memory),
            velocity=vel,
            gamma=gamma_t,
            telemetry=telemetry,
            cum_eff_bytes=cum_eff,
        )
        aux = AcgdAux(loss=loss, eta=eta, grad_sqnorm=gsq, gamma=gamma_t,
                      wire_bytes=wire, eff_wire_bytes=eff,
                      telemetry=telemetry, cum_eff_bytes=cum_eff)
        return new_params, new_state, aux


def acgd(cfg: AcgdConfig | None = None) -> ACGD:
    return ACGD(cfg or AcgdConfig())
