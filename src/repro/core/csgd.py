"""CSGD-ASSS — Compressed SGD with Armijo Step-Size Search and Scaling.

Paper Algorithm 2, single-process semantics (the distributed version is in
``dcsgd.py``).  The optimizer is exposed optax-style, except that — being a
line-search method — ``update`` needs the sampled batch's loss function:

    opt = csgd_asss(CSGDConfig(...))
    state = opt.init(params)
    (params, state, aux) = opt.step(loss_fn, params, state)

where ``loss_fn(params) -> scalar`` is ``f_{i_t}`` closed over the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .armijo import ArmijoConfig, armijo_search, next_alpha_max, tree_sqnorm
from .compression import Compressor, tree_effective_wire_bytes, tree_wire_bytes
from .gamma import GammaControllerConfig, gamma_init, gamma_update
from .telemetry import CompressionTelemetry, SearchTelemetry, TelemetrySums
from . import error_feedback as ef

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CSGDConfig:
    #: None = no line search: the fixed-step compressed baseline (Aji &
    #: Heafield) — ``eta`` below is the step size (cf. NonAdaptiveCSGD).
    armijo: ArmijoConfig | None = ArmijoConfig()
    compressor: Compressor = Compressor()
    #: per-round compression-level controller (AdaCGD-style; core/gamma.py)
    gamma_ctrl: GammaControllerConfig = GammaControllerConfig()
    eta: float = 0.1                # fixed step when armijo is None
    ef_dtype: str = "float32"       # float32 | bfloat16 | int8
    use_scaling: bool = True        # False reproduces the divergent variant
    # beyond-paper (paper §V lists momentum as future work): heavy-ball
    # velocity accumulated BEFORE compression — EF-SGDm style, the error
    # feedback recycles what compression drops from the momentum update.
    momentum: float = 0.0

    def __post_init__(self):
        if self.armijo is None and \
                self.gamma_ctrl.schedule == "armijo-coupled":
            raise ValueError("armijo-coupled gamma schedule needs the "
                             "Armijo search (armijo=None)")

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class CSGDState(NamedTuple):
    step: jax.Array          # int32
    alpha_prev: jax.Array    # alpha_{t-1} (per-worker in DCSGD)
    memory: PyTree           # error-feedback m_t, shaped like params
    n_evals_ema: jax.Array   # running mean of Armijo fwd evals (telemetry)
    gamma: jax.Array         # per-round compression level gamma_t
    telemetry: CompressionTelemetry  # last round's compression health
    cum_eff_bytes: jax.Array         # cumulative effective wire bytes
    velocity: PyTree = ()    # heavy-ball state (momentum > 0 only)


class StepAux(NamedTuple):
    loss: jax.Array
    alpha: jax.Array
    eta: jax.Array
    n_evals: jax.Array
    grad_sqnorm: jax.Array
    accepted: jax.Array
    gamma: jax.Array             # the gamma_t this round compressed at
    wire_bytes: jax.Array        # static payload budget (notional, 1 node)
    eff_wire_bytes: jax.Array    # ragged-content bytes at gamma_t
    telemetry: CompressionTelemetry  # this round's compression health
    cum_eff_bytes: jax.Array         # run total incl. this step


def _ef_to_dense(memory, dtype=jnp.float32):
    def leaf(m):
        if isinstance(m, ef.QuantizedEF):
            return ef.dequantize_ef(m, dtype)
        return m.astype(dtype)
    return jax.tree.map(leaf, memory,
                        is_leaf=lambda x: isinstance(x, ef.QuantizedEF))


def _ef_from_dense(memory_dense, ef_dtype: str):
    if ef_dtype == "int8":
        return jax.tree.map(ef.quantize_ef, memory_dense)
    return jax.tree.map(lambda m: m.astype(jnp.dtype(ef_dtype)), memory_dense)


class CSGD:
    """Algorithm 2. Also covers the non-adaptive baseline via armijo=None."""

    def __init__(self, cfg: CSGDConfig):
        self.cfg = cfg

    def init(self, params: PyTree) -> CSGDState:
        if self.cfg.ef_dtype == "int8":
            memory = ef.init_ef_quantized(params)
        else:
            memory = ef.init_ef(params, jnp.dtype(self.cfg.ef_dtype))
        vel = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params) if self.cfg.momentum else ())
        alpha0 = self.cfg.armijo.alpha0 if self.cfg.armijo is not None \
            else self.cfg.eta
        return CSGDState(
            step=jnp.int32(0),
            alpha_prev=jnp.float32(alpha0),
            memory=memory,
            n_evals_ema=jnp.float32(0.0),
            gamma=gamma_init(self.cfg.gamma_ctrl, self.cfg.compressor),
            telemetry=CompressionTelemetry.init(),
            cum_eff_bytes=jnp.float32(0.0),
            velocity=vel,
        )

    # ------------------------------------------------------------------
    def step(
        self,
        loss_fn: Callable[[PyTree], jax.Array],
        params: PyTree,
        state: CSGDState,
    ) -> tuple[PyTree, CSGDState, StepAux]:
        cfg = self.cfg
        comp = cfg.compressor
        loss, grads = jax.value_and_grad(loss_fn)(params)
        gsq = tree_sqnorm(grads)

        # --- Armijo search with alpha_max = omega * alpha_{t-1} (step 3) ---
        if cfg.armijo is not None:
            alpha_max = next_alpha_max(state.alpha_prev, cfg.armijo)
            res = armijo_search(loss_fn, params, grads, alpha_max,
                                cfg.armijo, f0=loss, grad_sqnorm=gsq)
            alpha, n_evals, accepted = res.alpha, res.n_evals, res.accepted
        else:  # fixed-step baseline (armijo=None): eta is the step size
            alpha = jnp.float32(cfg.eta)
            n_evals = jnp.int32(0)
            accepted = jnp.bool_(True)

        # --- per-round compression level (controller round, step t) -------
        gamma_t = gamma_update(
            cfg.gamma_ctrl, comp, state.gamma, state.step,
            search=SearchTelemetry(alpha=alpha, alpha_prev=state.alpha_prev,
                                   n_evals=n_evals,
                                   n_evals_ema=state.n_evals_ema),
            compression=state.telemetry)

        if cfg.armijo is None:
            eta = alpha
        elif cfg.use_scaling:
            # a = scale_for(gamma_t): the paper's a_scale, re-clamped to
            # zeta(gamma_t) each round under theory_safe
            eta = cfg.armijo.scale_for(gamma_t) * alpha
        else:
            eta = alpha                              # a = 1 -> divergence

        # --- (optional) heavy-ball velocity, pre-compression --------------
        if cfg.momentum:
            vel = jax.tree.map(
                lambda v, g: cfg.momentum * v + g.astype(jnp.float32),
                state.velocity, grads)
            descent = vel
        else:
            vel = state.velocity
            descent = grads

        # --- compressed descent with error feedback (steps 6-8) -----------
        mem = _ef_to_dense(state.memory)
        sums = TelemetrySums.zero()

        def leaf_update(m, g, sums):
            gf = g.astype(m.dtype)
            acc = m + eta * gf
            sent, resid = comp.compress_dense(
                acc, gamma_t=gamma_t if comp.adaptive else None)
            # single-node semantics: decode(own) IS the dense `sent`
            sums = sums.add(g_sq=jnp.sum(gf * gf),
                            acc_sq=jnp.sum(acc * acc),
                            resid_sq=jnp.sum(resid * resid),
                            own_sq=jnp.sum(sent * sent),
                            own_dot_g=jnp.sum(sent * gf))
            return sent, resid, sums

        flat_m, treedef = jax.tree.flatten(mem)
        flat_g = treedef.flatten_up_to(descent)
        pairs = []
        for m, g in zip(flat_m, flat_g):
            s, r, sums = leaf_update(m, g, sums)
            pairs.append((s, r))
        sent = treedef.unflatten([p[0] for p in pairs])
        resid = treedef.unflatten([p[1] for p in pairs])
        telemetry = sums.finalize()

        new_params = jax.tree.map(
            lambda p, s: (p.astype(jnp.float32) - s).astype(p.dtype),
            params, sent)
        wire = jnp.float32(tree_wire_bytes(params, comp))
        eff = tree_effective_wire_bytes(params, comp, gamma_t) \
            if comp.adaptive else wire
        cum_eff = state.cum_eff_bytes + eff
        new_state = CSGDState(
            step=state.step + 1,
            alpha_prev=alpha,
            memory=_ef_from_dense(resid, cfg.ef_dtype),
            n_evals_ema=0.9 * state.n_evals_ema +
            0.1 * n_evals.astype(jnp.float32),
            gamma=gamma_t,
            telemetry=telemetry,
            cum_eff_bytes=cum_eff,
            velocity=vel,
        )
        aux = StepAux(loss=loss, alpha=alpha, eta=eta,
                      n_evals=n_evals, grad_sqnorm=gsq,
                      accepted=accepted, gamma=gamma_t,
                      wire_bytes=wire, eff_wire_bytes=eff,
                      telemetry=telemetry, cum_eff_bytes=cum_eff)
        return new_params, new_state, aux


def csgd_asss(cfg: CSGDConfig | None = None) -> CSGD:
    return CSGD(cfg or CSGDConfig())
