"""Gradient compression operators.

The paper (§II-C) uses the biased per-layer ``top_k`` operator with memory
feedback [Aji & Heafield '17; Stich et al. '18].  Faithful details:

* compression is applied **per layer** (per pytree leaf);
* leaves with fewer than ``min_compress_size`` (=1000, §IV-A) parameters are
  transmitted uncompressed;
* ``gamma = k/d`` is the compression *ratio*; ``k = max(1, round(gamma*d))``.

Two selection strategies are provided:

* ``topk``      — exact per-leaf magnitude top-k (``jax.lax.top_k``), faithful.
* ``block_topk``— TPU-native two-pass block-local threshold selection (the
                  Pallas-kernel path, see ``repro/kernels/ef_topk.py``); k is
                  achieved in expectation, the EF identity stays exact.

Both return a :class:`Sparse` pair (values, indices) — this is what travels
over the wire in the distributed algorithm, so communicated bytes are
``k * (bytes(val) + bytes(idx))`` instead of ``d * bytes(val)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

#: Leaves smaller than this are not compressed (paper §IV-A, following [8]).
MIN_COMPRESS_SIZE = 1000

#: Integer quantization range per sub-byte/byte value width (symmetric).
QMAX = {8: 127.0, 4: 7.0}


def quant_scale(vals: jax.Array, qmax: float) -> jax.Array:
    """Per-row absmax quantization scale — THE scale formula, shared
    bit-for-bit by :meth:`Compressor.quantize_values` and the packed wire
    codec (repro/comm/wire.py) so dequantized values agree exactly."""
    return jnp.max(jnp.abs(vals), axis=-1, keepdims=True) / qmax + 1e-30


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Sparse:
    """A compressed tensor: flat values + flat int32 indices into the leaf."""

    values: jax.Array   # (k,) or (workers, k) after all_gather
    indices: jax.Array  # (k,) int32
    shape: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))

    @property
    def nbytes_wire(self) -> int:
        return self.values.size * self.values.dtype.itemsize + \
            self.indices.size * self.indices.dtype.itemsize


def leaf_k(d: int, gamma: float) -> int:
    """Number of kept components for a leaf of size d at ratio gamma."""
    if d < MIN_COMPRESS_SIZE:
        return d
    return max(1, int(round(gamma * d)))


# ---------------------------------------------------------------------------
# exact per-leaf top_k (paper-faithful)
# ---------------------------------------------------------------------------

def topk_select(x: jax.Array, k: int) -> Sparse:
    """Exact magnitude top-k of a tensor, flattened. Biased operator (3)."""
    flat = x.reshape(-1)
    if k >= flat.size:
        return Sparse(flat, jnp.arange(flat.size, dtype=jnp.int32), x.shape)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    return Sparse(flat[idx], idx, x.shape)


def sparse_to_dense(s: Sparse, dtype=None) -> jax.Array:
    """Scatter a Sparse (possibly (workers,k) stacked) back to dense."""
    d = 1
    for n in s.shape:
        d *= n
    vals = s.values.reshape(-1)
    idx = s.indices.reshape(-1)
    dense = jnp.zeros((d,), dtype or vals.dtype).at[idx].add(vals)
    return dense.reshape(s.shape)


# ---------------------------------------------------------------------------
# block-local threshold selection (TPU-native path; jnp reference impl —
# the Pallas kernel in repro/kernels/ef_topk.py implements the same math)
# ---------------------------------------------------------------------------

def block_threshold(x: jax.Array, gamma: float, block: int = 1024) -> jax.Array:
    """Per-tensor magnitude threshold t such that ~gamma*d entries survive.

    Two-pass scheme: block-local exact top-k_b (k_b = ceil(gamma*block)) then
    the global threshold is the k-th largest among the kept candidates.  The
    result keeps between gamma*d and min(1, 2*gamma)*d entries (each block
    contributes at most k_b, at least the global top-k survive).
    """
    flat = jnp.abs(x.reshape(-1))
    d = flat.size
    pad = (-d) % block
    flat = jnp.pad(flat, (0, pad), constant_values=0.0)
    blocks = flat.reshape(-1, block)
    k_b = max(1, int(-(-gamma * block // 1)))  # ceil
    cand, _ = jax.lax.top_k(blocks, k_b)       # (nb, k_b) block-local top
    cand = cand.reshape(-1)
    k = leaf_k(d, gamma)
    k = min(k, cand.size)
    kth, _ = jax.lax.top_k(cand, k)
    return kth[-1]


def threshold_select(x: jax.Array, tau: jax.Array) -> jax.Array:
    """Dense masked selection |x| >= tau (keeps layout; no gather)."""
    return jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))


def block_extract_sparse(x2d: jax.Array, comp: "Compressor"):
    """Wire pairs via exact per-block top-k_b — THE block extraction used
    by every block_topk path (compress_sparse, compress_leaf, and the
    fused-kernel path in dcsgd).

    x2d: (L, d) per-layer rows; blocks never span layers.  Returns
    (vals, idx), each (L, nb*k_b), idx flat into [0, d) (clamped — padding
    positions carry zero values).
    """
    L, d = x2d.shape
    block = comp.block
    pad = (-d) % block
    blocks = jnp.pad(x2d, ((0, 0), (0, pad))).reshape(L, -1, block)
    nb = blocks.shape[1]
    k_b = comp.block_k()
    _, bidx = jax.lax.top_k(jnp.abs(blocks), k_b)          # (L, nb, k_b)
    base = (jnp.arange(nb, dtype=jnp.int32) * block)[None, :, None]
    idx = (bidx.astype(jnp.int32) + base).reshape(L, -1)
    idx = jnp.minimum(idx, d - 1)
    vals = jnp.take_along_axis(blocks, bidx, axis=2).reshape(L, -1)
    return vals, idx


# ---------------------------------------------------------------------------
# Compressor objects
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """Per-leaf compression policy. ``gamma`` is the paper's k/d.

    ``value_bits`` (32|16|8|4, beyond-paper): quantize the transmitted
    top-k *values* on the wire (absmax-scaled); the error-feedback residual
    is computed against the quantized values, so the EF telescoping
    identity is preserved exactly and quantization error is recycled like
    any other compression error.  Transmitted bytes follow the bit-packed
    wire format (DESIGN.md §8, repro/comm/wire.py): per-row header +
    bit-packed index and value sections, so at 8 bits an entry costs
    1 B of value + 2 B of block-local index instead of 4+4 B.

    ``use_kernel``: route the ``block_topk`` hot path through the fused
    Pallas two-pass kernels (repro/kernels/ef_topk.py, dispatched by
    repro/kernels/dispatch.py).  Escape hatch: False falls back to the
    pure-jnp composition.

    ``max_gamma`` (adaptive compression, DESIGN.md §9): when > 0 the wire
    geometry — payload buffers, WireSpec, ``wire_bytes`` — is sized for
    ``max_gamma`` (the static *budget* ``k_max``), while the compression
    level actually applied each round is a **traced** per-round ``gamma_t``
    passed to :meth:`compress_dense` / ``dcsgd.worker_compress_aggregate``.
    Entries ranked beyond the per-round ``k_t <= k_max`` are masked to zero
    and their wire fields zeroed behind a valid-count header word, so the
    payload is ragged-in-content inside a fixed buffer and the static
    ``wire_bytes`` invariant survives as an upper bound; the runtime
    ``effective_wire_bytes`` metric counts what a ragged collective would
    ship.  ``gamma`` stays the *initial* (and non-adaptive) ratio.
    """

    gamma: float = 0.01
    method: str = "topk"            # topk | block_topk | none
    block: int = 1024
    min_compress_size: int = MIN_COMPRESS_SIZE
    value_bits: int = 32
    use_kernel: bool = True
    max_gamma: float = 0.0          # > 0: adaptive budget (DESIGN.md §9)

    @property
    def adaptive(self) -> bool:
        """True when the wire carries per-round valid counts (ragged)."""
        return self.max_gamma > 0.0

    @property
    def geometry_gamma(self) -> float:
        """The gamma that sizes every static buffer/payload (the budget)."""
        return self.max_gamma if self.adaptive else self.gamma

    def k_for(self, d: int) -> int:
        if self.method == "none" or d < self.min_compress_size:
            return d
        return max(1, int(round(self.geometry_gamma * d)))

    def block_k(self) -> int:
        """k_b: entries kept per ``block``-wide block (block_topk)."""
        return max(1, int(round(self.geometry_gamma * self.block)))

    # -- per-round (traced) selection counts, clamped into the budget -------
    def k_t_for(self, d: int, gamma_t: jax.Array) -> jax.Array:
        """Traced per-round k_t for a flat row of size d: round(gamma_t*d)
        clamped into [1, k_max] so the static buffer always fits."""
        k_max = self.k_for(d)
        return jnp.clip(jnp.round(jnp.asarray(gamma_t, jnp.float32) * d),
                        1, k_max).astype(jnp.int32)

    def block_k_t(self, gamma_t: jax.Array) -> jax.Array:
        """Traced per-round per-block valid count, in [1, block_k()]."""
        return jnp.clip(
            jnp.round(jnp.asarray(gamma_t, jnp.float32) * self.block),
            1, self.block_k()).astype(jnp.int32)

    def sparse_k(self, d: int) -> int:
        """Actual number of (value, index) pairs on the wire for a leaf
        of size d — ``block_topk`` ships exactly k_b per (padded) block."""
        k = self.k_for(d)
        if k == d:
            return d
        if self.method == "block_topk":
            nb = -(-d // self.block)
            return nb * self.block_k()
        return k

    def ships_dense(self, d: int) -> bool:
        """True when a row of size d ships uncompressed (pmean, no packed
        payload): no compression method, below the §IV-A size cutoff, or
        block padding pushing the wire entry count past d.  THE
        dense-vs-compressed predicate — shared by both transports of
        ``worker_compress_aggregate`` and ``comm.bucket.build_bucket_plan``
        so the per-leaf and bucketed schedules can never classify a leaf
        differently."""
        return (self.method == "none" or d < self.min_compress_size
                or self.sparse_k(d) >= d)

    def quantize_values(self, vals: jax.Array) -> jax.Array:
        """Simulate wire quantization (returns dequantized f32 values —
        what the receivers reconstruct). Scale is per (leading dims) row.

        Bit-for-bit identical to an encode->decode round trip through the
        packed wire codec (repro/comm/wire.py), which shares this math.
        """
        if self.value_bits >= 32:
            return vals
        if self.value_bits == 16:
            return vals.astype(jnp.bfloat16).astype(vals.dtype)
        qmax = QMAX[self.value_bits]
        scale = quant_scale(vals, qmax)
        q = jnp.clip(jnp.round(vals / scale), -qmax, qmax)
        return (q * scale).astype(vals.dtype)

    @property
    def value_bytes(self) -> int:
        """Nominal per-entry value bytes, rounded up (4-bit packs two
        entries per byte; exact accounting lives in :meth:`wire_bytes`)."""
        return {32: 4, 16: 2, 8: 1, 4: 1}[self.value_bits]

    # -- dense-in dense-out (single-node semantics; update rule (6)) --------
    def compress_dense(self, x: jax.Array,
                       gamma_t: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
        """Returns (top_k(x) as dense, residual x - top_k(x)).

        ``gamma_t`` (adaptive compressors only): traced per-round ratio;
        selection runs at the static ``k_max`` budget and entries ranked
        beyond ``k_t = round(gamma_t * d)`` are masked into the residual.
        """
        d = x.size
        if self.method == "none" or d < self.min_compress_size:
            return x, jnp.zeros_like(x)
        if gamma_t is not None and self.adaptive:
            return self._compress_dense_ragged(x, gamma_t)
        if self.method == "topk":
            s = topk_select(x, self.k_for(d))
            if self.value_bits < 32:
                s = Sparse(self.quantize_values(s.values), s.indices,
                           s.shape)
            dense = sparse_to_dense(s, x.dtype)
        elif self.method == "block_topk":
            if self.use_kernel:
                # fused Pallas path: pass-1 per-block stats, pass-2 fused
                # split (1 read + 2 writes) — see repro/kernels/ef_topk.py.
                # Both passes see the same flattened block layout.
                from repro.kernels import ops
                flat = x.reshape(-1)
                tau = ops.block_topk_threshold(flat, self.block_k(),
                                               self.block)
                dense, resid = ops.threshold_split_blocks(
                    flat, tau.reshape(-1, 1), self.block)
                return dense.reshape(x.shape), resid.reshape(x.shape)
            tau = block_threshold(x, self.gamma, self.block)
            dense = threshold_select(x, tau)
        else:
            raise ValueError(f"unknown compression method {self.method!r}")
        return dense, x - dense

    def _compress_dense_ragged(self, x: jax.Array, gamma_t: jax.Array
                               ) -> tuple[jax.Array, jax.Array]:
        """Budget-shaped selection masked to the traced per-round count.

        Both methods produce magnitude-sorted candidates (``lax.top_k``
        sorts descending), so "the first k_t" IS exact top-k_t (flat rows)
        / per-block top-k_b_t (block rows) — the mask only zeroes values,
        never moves them, and masked entries fall into the residual.
        """
        d = x.size
        if self.method == "topk":
            # lax.top_k directly (not topk_select): its k == d early path
            # returns UNSORTED values, and the prefix mask needs the
            # magnitude-descending order.
            flat = x.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), self.k_for(d))
            idx = idx.astype(jnp.int32)
            k_t = self.k_t_for(d, gamma_t)
            pos = jnp.arange(idx.shape[-1], dtype=jnp.int32)
            vals = jnp.where(pos < k_t, flat[idx], 0.0)
            if self.value_bits < 32:
                vals = self.quantize_values(vals)       # scale sees valid only
            dense = sparse_to_dense(Sparse(vals, idx, x.shape), x.dtype)
        elif self.method == "block_topk":
            vals, idx = block_extract_sparse(x.reshape(1, -1), self)
            k_b = self.block_k()
            pos = jnp.arange(vals.shape[-1], dtype=jnp.int32)
            vals = jnp.where(pos % k_b < self.block_k_t(gamma_t), vals, 0.0)
            dense = jnp.zeros((d,), jnp.float32).at[idx.reshape(-1)].add(
                vals.reshape(-1)).astype(x.dtype).reshape(x.shape)
        else:
            raise ValueError(f"unknown compression method {self.method!r}")
        return dense, x - dense

    # -- sparse wire format (distributed semantics; Algorithm 3) ------------
    def compress_sparse(self, x: jax.Array) -> Sparse:
        d = x.size
        if self.method in ("none",) or d < self.min_compress_size:
            flat = x.reshape(-1)
            return Sparse(flat, jnp.arange(d, dtype=jnp.int32), x.shape)
        if self.method == "block_topk":
            # block-local exact top-k_b: hardware-aligned, fixed wire size.
            vals, idx = block_extract_sparse(x.reshape(1, -1), self)
            return Sparse(vals.reshape(-1), idx.reshape(-1), x.shape)
        return topk_select(x, self.k_for(d))

    def wire_bytes(self, x_size: int, itemsize: int = 4) -> int:
        """Bytes on the wire for one leaf row — the LITERAL byte length of
        the ``uint32`` payload that ``worker_compress_aggregate`` builds and
        all-gathers over the dp mesh axes (asserted there at trace time).

        Compressed rows follow the bit-packed wire format (DESIGN.md §8):
        per-row header word (sub-byte value quantization only) + bit-packed
        index section (16-bit block-local indices for ``block_topk``) +
        bit-packed value section.  Uncompressed leaves ship dense.
        """
        k = self.sparse_k(x_size)
        # uncompressed leaves ship dense — including rows where block
        # padding pushes nb*k_b past d at large gamma (dcsgd pmean branch)
        if k >= x_size:
            return x_size * itemsize
        from repro.comm.wire import WireSpec  # local import: no cycle
        return WireSpec.for_row(self, x_size).row_bytes

    def leaf_wire_bytes(self, shape: tuple[int, ...],
                        itemsize: int = 4) -> int:
        """Wire bytes for one leaf, mirroring ``worker_compress_aggregate``
        exactly: leaves with ndim >= 2 are scan-stacked and compressed
        *per layer* (the dense/sparse cutoff and the block padding both
        apply to the per-layer size d, not the whole leaf)."""
        L, d = _leaf_geometry(shape)
        return L * self.wire_bytes(d, itemsize)


def _leaf_geometry(shape: tuple[int, ...]) -> tuple[int, int]:
    """(L, d) per-layer view of a leaf shape — THE stacked-leaf convention
    of ``worker_compress_aggregate`` (ndim >= 2: leading axis = layers),
    shared by the static and the effective byte accounting."""
    if len(shape) >= 2:
        L = shape[0]
        d = 1
        for n in shape[1:]:
            d *= n
        return L, d
    return 1, (shape[0] if shape else 1)


def tree_wire_bytes(tree: PyTree, comp: Compressor, itemsize: int = 4) -> int:
    """Total communicated bytes per worker per step for a gradient pytree."""
    return sum(comp.leaf_wire_bytes(leaf.shape, itemsize)
               for leaf in jax.tree.leaves(tree))


def leaf_effective_wire_bytes(comp: Compressor, shape: tuple[int, ...],
                              gamma_t: jax.Array,
                              itemsize: int = 4) -> jax.Array:
    """Traced per-round *useful* wire bytes for one leaf at ``gamma_t`` —
    what a truly ragged collective would ship: the header plus only the
    ``k_t`` valid (index, value) fields, bit-packed (DESIGN.md §9).  For
    non-adaptive compressors this equals :meth:`Compressor.leaf_wire_bytes`
    exactly; dense-shipping leaves cost their dense bytes either way.
    """
    L, d = _leaf_geometry(shape)
    if comp.sparse_k(d) >= d:
        return jnp.float32(L * d * itemsize)
    from repro.comm.wire import WireSpec  # local import: no cycle
    spec = WireSpec.for_row(comp, d)
    if not spec.ragged:
        return jnp.float32(L * spec.row_bytes)
    count = comp.block_k_t(gamma_t) if spec.local \
        else comp.k_t_for(d, gamma_t)
    return jnp.float32(L) * spec.effective_row_bytes(count)


def tree_effective_wire_bytes(tree: PyTree, comp: Compressor,
                              gamma_t: jax.Array,
                              itemsize: int = 4) -> jax.Array:
    """Traced per-round effective bytes for a gradient pytree (the runtime
    counterpart of :func:`tree_wire_bytes`, which stays the static upper
    bound the payload buffers are sized for)."""
    return sum(leaf_effective_wire_bytes(comp, leaf.shape, gamma_t, itemsize)
               for leaf in jax.tree.leaves(tree))


def contraction_gamma(x: jax.Array, compressed: jax.Array) -> jax.Array:
    """Empirical 1 - ||x - C(x)||^2/||x||^2 (Lemma 7 effective gamma)."""
    num = jnp.sum((x - compressed) ** 2)
    den = jnp.sum(x ** 2) + 1e-30
    return 1.0 - num / den
