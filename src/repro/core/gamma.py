"""Per-round compression-level controller (AdaCGD-style adaptive gamma).

The paper fixes the compression ratio ``gamma`` for the whole run and only
adapts the *step size* to the trajectory; AdaCGD (Makarenko et al.,
"Adaptive Compression for Communication-Efficient Distributed Training")
shows the compression level itself should adapt per round.  Controllers
here are pure functions of (previous gamma, typed telemetry structs from
the round that just finished — ``core/telemetry.py``) and lower into the
train step like everything else.

Schedules (``GammaControllerConfig.schedule``):

* ``fixed``          — gamma_t = gamma0 forever (the paper's setting).
* ``linear``         — ramp gamma0 -> gamma_max over ``ramp_steps`` steps:
                       coarse-to-fine, cheap wire early when gradients are
                       large and any descent direction helps, full budget
                       near convergence.
* ``armijo-coupled`` — multiplicative feedback on the line search
                       (:class:`~repro.core.telemetry.SearchTelemetry`):
                       grow gamma (send more) when the search struggles
                       (``n_evals_ema`` above ``evals_hi`` or the accepted
                       alpha collapsed vs the previous round), shrink when
                       it accepts immediately.  CAVEAT (DESIGN.md §9/§10):
                       the search runs on the *uncompressed* gradient, so
                       this controller cannot sense over-compression —
                       ``gamma_min`` is its only safety rail.
* ``ef-coupled``     — multiplicative feedback on the compressor's own
                       distortion (:class:`~repro.core.telemetry.
                       CompressionTelemetry`), the signal Armijo cannot
                       see.  The EF backlog ratio ``||m'||/||g||`` is held
                       inside a hysteresis band around ``ef_target``:
                       above ``ef_target + ef_band`` the error feedback is
                       accumulating mass faster than it drains —
                       over-compressed, grow gamma; below ``ef_target -
                       ef_band`` with a healthy decode cosine
                       (>= ``cos_floor``) the wire budget is slack —
                       shrink gamma; inside the band, hold.  A
                       non-finite backlog (diverging EF memory) always
                       grows.

Theory coupling is free: ``ArmijoConfig.zeta(gamma_t)`` is the per-round
scaling bound ``a <= sigma*gamma/(2-gamma)``, and with
``ArmijoConfig.theory_safe`` the step scale is re-clamped to the *current*
gamma_t each round (see ``ArmijoConfig.scale_for``).

Every returned gamma_t lives in ``[gamma_min, gamma_max]`` where gamma_max
never exceeds the compressor's static wire budget
(``Compressor.geometry_gamma``) — the payload buffer is sized once, at
trace time, for the budget; gamma_t only changes the *valid* entry count
inside it (the ragged packed payload, DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .telemetry import CompressionTelemetry, SearchTelemetry

SCHEDULES = ("fixed", "linear", "armijo-coupled", "ef-coupled")


@dataclasses.dataclass(frozen=True)
class GammaControllerConfig:
    """Config for the per-round gamma controller.

    Zeros mean "derive from the compressor": gamma0 defaults to
    ``Compressor.gamma``, gamma_max to the compressor's static budget
    (``geometry_gamma``), gamma_min to ``gamma0 / 8``.
    """

    schedule: str = "fixed"       # fixed | linear | armijo- | ef-coupled
    gamma0: float = 0.0           # initial gamma_t (0 -> compressor.gamma)
    gamma_min: float = 0.0        # floor (0 -> gamma0 / 8)
    gamma_max: float = 0.0        # ceiling (0 -> compressor budget)
    ramp_steps: int = 1000        # linear: steps from gamma0 to gamma_max
    grow: float = 1.5             # coupled: multiplicative grow
    shrink: float = 0.9           # coupled: multiplicative shrink
    evals_hi: float = 3.0         # armijo: grow when n_evals_ema above
    evals_lo: float = 2.0         # armijo: shrink allowed only below
    alpha_collapse: float = 0.5   # armijo: grow when alpha < c*alpha_prev
    # --- ef-coupled (DESIGN.md §10): hysteresis band on the EF backlog.
    # Defaults calibrated on the golden interpolated quadratic (healthy
    # steady-state backlog ~0.07, over-compressed ~0.25-0.35): grow above
    # target+band = 0.23, shrink below target-band = 0.07.
    ef_target: float = 0.15       # backlog ||m'||/||g|| the band centers on
    ef_band: float = 0.08         # half-width: grow above target+band,
                                  # shrink below target-band
    cos_floor: float = 0.0        # shrink only while cos(decode, g) >= this

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown gamma schedule {self.schedule!r} "
                             f"(want one of {SCHEDULES})")
        if self.schedule == "ef-coupled" and self.ef_band >= self.ef_target:
            raise ValueError(
                f"ef-coupled hysteresis band must sit above zero backlog: "
                f"ef_band={self.ef_band} >= ef_target={self.ef_target} "
                f"would make the shrink threshold non-positive")

    def resolve(self, comp) -> tuple[float, float, float]:
        """(gamma0, gamma_min, gamma_max) with compressor defaults filled
        in; gamma_max is clipped to the compressor's static budget."""
        budget = comp.geometry_gamma
        g0 = self.gamma0 or comp.gamma
        gmax = min(self.gamma_max or budget, budget)
        gmin = self.gamma_min or g0 / 8.0
        if gmin > gmax:
            # an inverted [gmin, gmax] band would make every
            # jnp.clip(gamma, gmin, gmax) in gamma_update silently return
            # gmax — the user asked for a floor the wire cannot carry
            raise ValueError(
                f"gamma_min={gmin} exceeds the resolved gamma_max={gmax} "
                f"(compressor budget {budget}): the controller band is "
                f"inverted and jnp.clip would pin gamma to gamma_max — "
                f"lower gamma_min or raise the compressor's "
                f"gamma/max_gamma budget")
        g0 = min(max(g0, gmin), gmax)
        return g0, gmin, gmax


def gamma_init(cfg: GammaControllerConfig, comp) -> jax.Array:
    """Initial gamma_t for the optimizer state."""
    g0, _, _ = cfg.resolve(comp)
    return jnp.float32(g0)


def gamma_update(
    cfg: GammaControllerConfig,
    comp,
    gamma: jax.Array,
    step: jax.Array,
    *,
    search: SearchTelemetry | None = None,
    compression: CompressionTelemetry | None = None,
) -> jax.Array:
    """One controller round: gamma_{t} from gamma_{t-1} and the typed
    telemetry of the round that just finished.  Pure and traced — the
    schedule string is static, everything else lowers to jnp.

    ``search`` feeds ``armijo-coupled``; ``compression`` feeds
    ``ef-coupled``; ``fixed``/``linear`` need neither.
    """
    g0, gmin, gmax = cfg.resolve(comp)
    if cfg.schedule == "fixed":
        return jnp.float32(g0) * jnp.ones_like(jnp.asarray(gamma))
    if cfg.schedule == "linear":
        frac = jnp.clip(step.astype(jnp.float32) / max(cfg.ramp_steps, 1),
                        0.0, 1.0)
        return jnp.clip(g0 + (gmax - g0) * frac, gmin, gmax)

    if cfg.schedule == "ef-coupled":
        if compression is None:
            raise ValueError("ef-coupled schedule needs the round's "
                             "CompressionTelemetry")
        backlog = jnp.asarray(compression.ef_backlog, jnp.float32)
        cosine = jnp.asarray(compression.cosine, jnp.float32)
        over = jnp.logical_or(backlog > cfg.ef_target + cfg.ef_band,
                              ~jnp.isfinite(backlog))
        slack = jnp.logical_and(backlog < cfg.ef_target - cfg.ef_band,
                                cosine >= cfg.cos_floor)
        factor = jnp.where(over, cfg.grow,
                           jnp.where(slack, cfg.shrink, 1.0))
        return jnp.clip(jnp.asarray(gamma, jnp.float32) * factor,
                        gmin, gmax)

    # armijo-coupled
    if search is None:
        raise ValueError("armijo-coupled schedule needs the round's "
                         "SearchTelemetry")
    alpha = jnp.asarray(search.alpha, jnp.float32)
    alpha_prev = jnp.asarray(search.alpha_prev, jnp.float32)
    ema = jnp.asarray(search.n_evals_ema, jnp.float32)
    nev = jnp.asarray(search.n_evals, jnp.float32)
    struggling = jnp.logical_or(ema > cfg.evals_hi,
                                alpha < cfg.alpha_collapse * alpha_prev)
    instant = jnp.logical_and(nev <= 1.0, ema < cfg.evals_lo)
    factor = jnp.where(struggling, cfg.grow,
                       jnp.where(instant, cfg.shrink, 1.0))
    return jnp.clip(jnp.asarray(gamma, jnp.float32) * factor, gmin, gmax)
