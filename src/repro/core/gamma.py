"""Per-round compression-level controller (AdaCGD-style adaptive gamma).

The paper fixes the compression ratio ``gamma`` for the whole run and only
adapts the *step size* to the trajectory; AdaCGD (Makarenko et al.,
"Adaptive Compression for Communication-Efficient Distributed Training")
shows the compression level itself should adapt per round.  The Armijo
state already carries exactly the signals such a controller needs — the
accepted ``alpha`` vs its predecessor, the running mean of
stopping-condition evaluations, acceptance of the first trial — so the
controller is a pure function of (previous gamma, this round's search
telemetry) and lowers into the train step like everything else.

Schedules (``GammaControllerConfig.schedule``):

* ``fixed``          — gamma_t = gamma0 forever (the paper's setting).
* ``linear``         — ramp gamma0 -> gamma_max over ``ramp_steps`` steps:
                       coarse-to-fine, cheap wire early when gradients are
                       large and any descent direction helps, full budget
                       near convergence.
* ``armijo-coupled`` — multiplicative feedback on the line search: grow
                       gamma (send more) when the search struggles
                       (``n_evals_ema`` above ``evals_hi`` or the accepted
                       alpha collapsed vs the previous round), shrink when
                       it accepts immediately (first trial accepted and the
                       eval EMA below ``evals_lo``).  A struggling search
                       means the compressed direction has drifted from the
                       true gradient — spend wire; an instantly-accepting
                       one means compression is not the binding constraint
                       — save wire.

Theory coupling is free: ``ArmijoConfig.zeta(gamma_t)`` is the per-round
scaling bound ``a <= sigma*gamma/(2-gamma)``, and with
``ArmijoConfig.theory_safe`` the step scale is re-clamped to the *current*
gamma_t each round (see ``ArmijoConfig.scale_for``).

Every returned gamma_t lives in ``[gamma_min, gamma_max]`` where gamma_max
never exceeds the compressor's static wire budget
(``Compressor.geometry_gamma``) — the payload buffer is sized once, at
trace time, for the budget; gamma_t only changes the *valid* entry count
inside it (the ragged packed payload, DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

SCHEDULES = ("fixed", "linear", "armijo-coupled")


@dataclasses.dataclass(frozen=True)
class GammaControllerConfig:
    """Config for the per-round gamma controller.

    Zeros mean "derive from the compressor": gamma0 defaults to
    ``Compressor.gamma``, gamma_max to the compressor's static budget
    (``geometry_gamma``), gamma_min to ``gamma0 / 8``.
    """

    schedule: str = "fixed"       # fixed | linear | armijo-coupled
    gamma0: float = 0.0           # initial gamma_t (0 -> compressor.gamma)
    gamma_min: float = 0.0        # floor (0 -> gamma0 / 8)
    gamma_max: float = 0.0        # ceiling (0 -> compressor budget)
    ramp_steps: int = 1000        # linear: steps from gamma0 to gamma_max
    grow: float = 1.5             # armijo-coupled: multiplicative grow
    shrink: float = 0.9           # armijo-coupled: multiplicative shrink
    evals_hi: float = 3.0         # grow when n_evals_ema rises above this
    evals_lo: float = 2.0         # shrink allowed only below this EMA
    alpha_collapse: float = 0.5   # grow when alpha < collapse * alpha_prev

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown gamma schedule {self.schedule!r} "
                             f"(want one of {SCHEDULES})")

    def resolve(self, comp) -> tuple[float, float, float]:
        """(gamma0, gamma_min, gamma_max) with compressor defaults filled
        in; gamma_max is clipped to the compressor's static budget."""
        budget = comp.geometry_gamma
        g0 = self.gamma0 or comp.gamma
        gmax = min(self.gamma_max or budget, budget)
        gmin = self.gamma_min or g0 / 8.0
        g0 = min(max(g0, gmin), gmax)
        return g0, gmin, gmax


def gamma_init(cfg: GammaControllerConfig, comp) -> jax.Array:
    """Initial gamma_t for the optimizer state."""
    g0, _, _ = cfg.resolve(comp)
    return jnp.float32(g0)


def gamma_update(
    cfg: GammaControllerConfig,
    comp,
    gamma: jax.Array,
    step: jax.Array,
    *,
    alpha: jax.Array | None = None,
    alpha_prev: jax.Array | None = None,
    n_evals: jax.Array | None = None,
    n_evals_ema: jax.Array | None = None,
) -> jax.Array:
    """One controller round: gamma_{t} from gamma_{t-1} and the search
    telemetry of the round that just finished.  Pure and traced — the
    schedule string is static, everything else lowers to jnp.
    """
    g0, gmin, gmax = cfg.resolve(comp)
    if cfg.schedule == "fixed":
        return jnp.float32(g0) * jnp.ones_like(jnp.asarray(gamma))
    if cfg.schedule == "linear":
        frac = jnp.clip(step.astype(jnp.float32) / max(cfg.ramp_steps, 1),
                        0.0, 1.0)
        return jnp.clip(g0 + (gmax - g0) * frac, gmin, gmax)

    # armijo-coupled
    if alpha is None or alpha_prev is None or n_evals is None \
            or n_evals_ema is None:
        raise ValueError("armijo-coupled schedule needs alpha, alpha_prev, "
                         "n_evals and n_evals_ema")
    alpha = jnp.asarray(alpha, jnp.float32)
    alpha_prev = jnp.asarray(alpha_prev, jnp.float32)
    ema = jnp.asarray(n_evals_ema, jnp.float32)
    nev = jnp.asarray(n_evals, jnp.float32)
    struggling = jnp.logical_or(ema > cfg.evals_hi,
                                alpha < cfg.alpha_collapse * alpha_prev)
    instant = jnp.logical_and(nev <= 1.0, ema < cfg.evals_lo)
    factor = jnp.where(struggling, cfg.grow,
                       jnp.where(instant, cfg.shrink, 1.0))
    return jnp.clip(jnp.asarray(gamma, jnp.float32) * factor, gmin, gmax)
