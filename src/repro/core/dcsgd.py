"""DCSGD-ASSS — distributed building blocks (paper Algorithm 3, appendix §VIII).

These functions run *inside* a ``jax.shard_map`` body that is manual over the
data-parallel mesh axes (``('pod','data')`` or ``('data',)``) and auto over
``'model'``.  Each data-parallel worker:

  1. computes its local gradient (done by the caller),
  2. runs its own Armijo search on its local batch -> per-worker ``eta^(k)``,
  3. forms ``acc = m^(k) + eta^(k) * grad^(k)`` per leaf,
  4. compresses ``acc`` to a (values, indices) pair and encodes it into a
     bit-packed ``uint32`` payload (repro/comm/wire.py, DESIGN.md §8),
  5. **all-gathers the packed payload** over the dp axes (this replaces the
     dense all-reduce; the payload's byte length IS ``wire_bytes`` — the
     paper's communication saving made physically real),
  6. decodes every worker's payload and applies the dense mean of the
     contributions,
  7. keeps ``m^(k) = acc - decode(own payload)`` locally (step 7 of
     Algorithm 3) — so wire quantization error and tie-dropped entries are
     recycled through the error feedback.

Leaves below the compression size threshold are aggregated densely
(``pmean``), matching §IV-A ("layers with less than 1000 parameters are not
compressed").

Scan-stacked leaves (leading axis = layers) are compressed **per layer**
(axis-0-batched top_k), matching the paper's per-layer compression.

Transport is **bucketed** by default (DESIGN.md §11): steps 4-6 coalesce
across the whole pytree into one flat packed all_gather, one batched
pack/unpack launch per bucket section, one batched fused-EF launch pair,
and one pmean of the concatenated dense leaves — the per-leaf schedule
above survives as ``transport="perleaf"``, the bit-exact reference.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.comm import faults
from repro.comm import wire as wire_fmt
from repro.comm.bucket import (build_bucket_plan, decode_buckets,
                               encode_buckets)
from repro.comm.exchange import (check_bucket_payload, check_payload,
                                 gather_packed)
from repro.comm.transport import get_transport, register_transport
from repro.kernels import ops
from .compression import Compressor, block_extract_sparse
from .leafmath import compress_leaf, select_and_encode
# the leaf math lives in repro.core.leafmath (shared with the gossip
# transport); the historical underscore names stay importable from here
from .leafmath import (dp_size as _dp_size, dp_index as _dp_index,
                       per_layer_topk as _per_layer_topk,
                       scatter_layers as _scatter_layers,
                       leaf_2d as _leaf_2d, leaf_count as _leaf_count)
from .telemetry import CompressionTelemetry, TelemetrySums, sparse_own_sums

PyTree = Any
AxisNames = Sequence[str] | str


def worker_compress_aggregate(
    grads: PyTree,
    memory: PyTree,
    eta: jax.Array,
    comp: Compressor,
    dp_axes: AxisNames,
    stacked_mask: PyTree | None = None,
    gamma_t: jax.Array | None = None,
    telemetry_axes: AxisNames | None = None,
    transport: str = "bucketed",
    transport_ctx: Any | None = None,
    downlink_ctx: Any | None = None,
) -> tuple:
    """Steps 3-7 of Algorithm 3 for a whole gradient pytree.

    Returns ``(mean_update, new_memory, wire_bytes, effective_wire_bytes,
    telemetry)`` where ``mean_update`` is the dense averaged compressed
    update (to subtract from params), ``wire_bytes`` counts this worker's
    transmitted payload-buffer bytes this step (the static budget), and
    ``telemetry`` is this worker's :class:`CompressionTelemetry` for the
    round (EF backlog, decode cosine, relative decode error, empirical
    contraction — DESIGN.md §10).  Its dense reductions are fused into the
    Pallas EF block-stats pass on the kernel path; the decoded-side sums
    touch only the k wire entries.

    ``transport`` (DESIGN.md §11): ``"bucketed"`` (default) coalesces the
    exchange into ONE flat packed ``all_gather`` for every compressed
    leaf, one batched ``wire_pack``/``wire_unpack`` launch per bucket
    field section, one batched fused-EF two-pass launch pair for every
    kernel-path leaf, and ONE ``pmean`` of the concatenated dense small
    leaves.  ``"perleaf"`` is the reference schedule (one collective and
    one launch set per leaf) the bucketed path is regression-pinned
    against: updates, memory, and byte counters bit-exact, telemetry to
    <= 8 ulp (XLA reduction order across programs — DESIGN.md §11).

    ``telemetry_axes``: extra manual mesh axes this call's inputs are
    sharded over WITHOUT being separate dp workers (the nested
    shard-local-topk 'model' region): the telemetry sums are psum'd over
    them before the ratios form, so the returned telemetry describes the
    worker's whole gradient, not one shard's slice.  The updates/memory/
    byte outputs are unaffected (selection stays shard-local by design).

    ``gamma_t`` (adaptive compressors, DESIGN.md §9): this worker's traced
    per-round compression level.  Selection still runs at the static
    ``k_max`` budget — the all-gathered buffer never changes shape — but
    entries ranked beyond ``k_t`` are masked behind the payload's
    valid-count header, receivers decode only the valid prefix (workers
    may carry *different* k_t), the masked entries recycle through the EF
    residual, and ``effective_wire_bytes`` reports what a ragged
    collective would have shipped.  For non-adaptive compressors the two
    byte counts coincide.

    ``transport_ctx``: transport-specific context, REQUIRED by stateful
    transports (``"gossip"``: a :class:`repro.comm.gossip.GossipCtx`) and
    rejected by stateless ones.  Stateful transports make this function
    return a SIXTH element, the transport's new carried state.

    ``downlink_ctx`` (DESIGN.md §15): a
    :class:`repro.comm.downlink.DownlinkCtx` carrying the server-side EF
    state — the replicated decoded mean is re-compressed through the same
    §8/§9 wire format before workers apply it (``decode(downlink
    payload)`` instead of the dense mean), with no extra collective.
    Only composes with the stateless global-aggregate transports
    (bucketed/perleaf); appends a trailing
    :class:`~repro.comm.downlink.DownlinkResult` element ``(new server
    state, downlink wire bytes, downlink effective bytes)``.
    """
    tp = get_transport(transport)
    if tp.stateful and transport_ctx is None:
        raise ValueError(f"transport {transport!r} is stateful and needs "
                         "transport_ctx")
    if not tp.stateful and transport_ctx is not None:
        raise ValueError(f"transport {transport!r} is stateless; "
                         "transport_ctx must be None")
    if downlink_ctx is not None and tp.stateful:
        raise ValueError(
            f"downlink_ctx needs a replicated global aggregate to "
            f"re-compress; transport {transport!r} is stateful "
            "(gossip/overlap have no single server-side mean)")
    W = _dp_size(dp_axes)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(memory)
    if stacked_mask is None:
        flat_s = [leaf.ndim >= 2 for leaf in flat_g]
    else:
        flat_s = treedef.flatten_up_to(stacked_mask)

    if comp.adaptive and gamma_t is None:
        gamma_t = jnp.float32(comp.gamma)
    if tp.stateful:
        updates, new_mem, wire, eff_wire, sums, new_state = tp.exchange(
            flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t, W,
            ctx=transport_ctx)
    else:
        updates, new_mem, wire, eff_wire, sums = tp.exchange(
            flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t, W)
    if telemetry_axes is not None:
        # sums are additive; ratios are not — reduce BEFORE finalizing
        sums = jax.tree.map(lambda x: jax.lax.psum(x, telemetry_axes), sums)
    dl_result = None
    if downlink_ctx is not None:
        from repro.comm.downlink import DownlinkResult, apply_downlink
        updates, dl_state, down_wire, down_eff = apply_downlink(
            updates, flat_s, comp, downlink_ctx.state)
        dl_result = DownlinkResult(dl_state, down_wire, down_eff)
    out = (treedef.unflatten(updates), treedef.unflatten(new_mem), wire,
           eff_wire, sums.finalize())
    if tp.stateful:
        out = out + (new_state,)
    return out + (dl_result,) if dl_result is not None else out


def _consume_decoded_leaf(g, m, g2f, g_vals, g_idx, spec, L, d, count, W,
                          dp_axes, use_fused, sent, resid, acc2,
                          verdict=None):
    """Post-gather per-leaf consumer — THE definition of the transport
    parity contract, shared by both schedules: the mean update, this
    worker's EF residual (own rows sliced from the gathered decode — no
    second decode of the own payload), the byte costs, and the
    decoded-side telemetry sums.

    Returns ``(upd, mem_leaf, wire_add, eff_add, resid_sq, own_sq,
    own_dot_g, quar_rows)``; masked-beyond-k_t entries are absent from
    the decoded own rows, so — like quantization error and tie drops —
    they land in the residual.

    ``verdict`` ((W, L) bool, DESIGN.md §16): per-row decode validity.
    Invalid rows arrive already quarantined (zero mass), so the mean's
    denominator switches from W to the per-layer valid-row count — the
    fed support-weighted division, bit-exact to ``/ W`` when every row
    is valid — and an invalid OWN row freezes this leaf's EF residual
    for the round (the payload never reached anyone intact; re-sending
    the whole accumulator next round is the EF-correct response).
    """
    total = _scatter_layers(g_vals, g_idx, L, d, jnp.float32)
    if verdict is None:
        mean_dense = total / W
    else:
        # the §13 support-weighted division without its 0/0 `where`:
        # quarantined rows scatter zero mass, so an all-invalid layer has
        # an all-zero total and /max(s,1) already answers 0 — one fewer
        # (L, d) pass on the always-on clean path (1.05x bench gate)
        n_valid = jnp.sum(verdict.astype(jnp.float32), axis=0)     # (L,)
        mean_dense = total / jnp.maximum(n_valid[:, None], 1.0)
    wire_add = jnp.float32(L * spec.row_bytes)
    eff_add = (jnp.float32(L) * spec.effective_row_bytes(count)
               if spec.ragged else jnp.float32(L * spec.row_bytes))
    w_idx = _dp_index(dp_axes)
    own_vals = jax.lax.dynamic_index_in_dim(g_vals, w_idx, 0,
                                            keepdims=False)
    own_idx = jax.lax.dynamic_index_in_dim(g_idx, w_idx, 0, keepdims=False)
    own_dense = _scatter_layers(own_vals, own_idx, L, d, jnp.float32)
    if use_fused:
        r = resid + (sent - own_dense)
    else:
        r = acc2 - own_dense
    quar = jnp.float32(0.0)
    if verdict is not None:
        own_ok = jax.lax.dynamic_index_in_dim(verdict, w_idx, 0,
                                              keepdims=False)       # (L,)
        m2f = m.astype(jnp.float32).reshape(L, d)
        r = jnp.where(own_ok[:, None], r, m2f)
        quar = jnp.float32(verdict.size) - jnp.sum(n_valid)
    # telemetry: the decoded-side sums touch only the k wire entries;
    # sum m'^2 fuses into the residual's own materialization above
    leaf_own_sq, leaf_dot = sparse_own_sums(own_vals, own_idx, g2f)
    return (mean_dense.reshape(g.shape), r.reshape(m.shape).astype(m.dtype),
            wire_add, eff_add, jnp.sum(r * r), leaf_own_sq, leaf_dot, quar)


@register_transport("perleaf", description=(
    "reference schedule: one packed all_gather + one launch set per leaf"))
def _perleaf_exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t,
                      W):
    """Reference transport: one packed all_gather + one launch set PER
    LEAF (plus one pmean per dense leaf).  The bucketed transport is
    regression-pinned bit-exact against this path."""
    use_fused = comp.method == "block_topk" and comp.use_kernel
    updates, new_mem = [], []
    wire = jnp.float32(0.0)
    eff_wire = jnp.float32(0.0)
    sums = TelemetrySums.zero()
    for leaf_i, (g, m, stacked) in enumerate(zip(flat_g, flat_m, flat_s)):
        g2 = _leaf_2d(g, stacked)
        L, d = g2.shape
        if comp.ships_dense(d):
            acc = m.astype(jnp.float32) + eta * g.astype(jnp.float32)
            upd = jax.lax.pmean(acc, dp_axes)
            updates.append(upd)
            new_mem.append(jnp.zeros_like(m))
            wire = wire + jnp.float32(acc.size * acc.dtype.itemsize)
            eff_wire = eff_wire + jnp.float32(acc.size * acc.dtype.itemsize)
            sums = sums.add_dense(acc, g)
            continue
        g2f = g2.astype(jnp.float32)
        if use_fused:
            # fused two-pass Pallas path (DESIGN.md §3): pass 1 streams
            # (m, g) once for the per-block k_b-th |m + eta*g| statistic
            # AND the dense telemetry moments (sum g^2, sum acc^2) on the
            # same resident tile; pass 2 streams them again and writes
            # (sent, m') — the accumulator never round-trips through HBM.
            m2 = _leaf_2d(m, stacked).astype(jnp.float32)
            # threshold at the BUDGET level (geometry_gamma == max_gamma
            # for adaptive compressors): block_extract_sparse below pulls
            # exactly block_k() budget entries per block, and any
            # per-round k_t mask is applied at encode time
            sent, resid, _, moments = ops.fused_ef_compress(
                m2, g2f, eta, comp.geometry_gamma, comp.block,
                telemetry=True)
            leaf_g_sq = jnp.sum(moments[:, 0])
            leaf_acc_sq = jnp.sum(moments[:, 1])
            # per-block top-k_b of |sent| recovers the kept wire entries
            # (>= k_b survive the threshold; ties beyond k_b are dropped
            # from the wire and recycled into m' below)
            vals, idx = block_extract_sparse(sent, comp)
        else:
            acc2 = _leaf_2d(m, stacked).astype(jnp.float32) + eta * g2f
            leaf_g_sq = jnp.sum(g2f * g2f)
            leaf_acc_sq = jnp.sum(acc2 * acc2)
            vals, idx, (L, d) = compress_leaf(acc2, comp, stacked)

        # ---- bit-packed wire (DESIGN.md §8): encode once, gather ONE
        # uint32 payload per leaf — the payload's byte length is exactly
        # Compressor.wire_bytes (checked at trace time below), and the EF
        # residual is taken against what receivers actually decode, so
        # quantization error AND tie-dropped entries are recycled.
        spec = wire_fmt.WireSpec.for_row(comp, d)
        # per-round valid count (DESIGN.md §9): entries past it are
        # masked out of the payload behind the count header word
        count = _leaf_count(comp, spec, gamma_t, d)
        counts = None if count is None else jnp.broadcast_to(count, (L,))
        payload = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
        check_payload(payload, spec, comp, d)

        all_pay = gather_packed(payload, dp_axes)        # (W, L, words)
        all_rows = faults.maybe_corrupt(
            all_pay.reshape(-1, spec.row_words), spec, leaf_i, L)
        g_vals, g_idx = wire_fmt.decode_rows(all_rows, spec)
        verdict = None
        if faults.guards_active():
            verdict = wire_fmt.row_verdict(all_rows, spec, g_vals, g_idx)
            g_vals, g_idx = wire_fmt.quarantine_rows(g_vals, g_idx,
                                                     verdict)
            verdict = verdict.reshape(W, L)
        g_vals = g_vals.reshape(W, L, spec.k)
        g_idx = g_idx.reshape(W, L, spec.k)
        (upd, mem_leaf, wire_add, eff_add, resid_sq, own_sq, own_dot,
         quar) = _consume_decoded_leaf(
                g, m, g2f, g_vals, g_idx, spec, L, d, count, W, dp_axes,
                use_fused, sent if use_fused else None,
                resid if use_fused else None,
                None if use_fused else acc2, verdict=verdict)
        updates.append(upd)
        new_mem.append(mem_leaf)
        wire = wire + wire_add
        eff_wire = eff_wire + eff_add
        sums = sums.add(g_sq=leaf_g_sq, acc_sq=leaf_acc_sq,
                        resid_sq=resid_sq, own_sq=own_sq,
                        own_dot_g=own_dot, quar_rows=quar)

    return updates, new_mem, wire, eff_wire, sums


@register_transport("bucketed", description=(
    "O(1) collectives: ONE flat packed all_gather + ONE pmean per step"))
def _bucketed_exchange(flat_g, flat_m, flat_s, eta, comp, dp_axes, gamma_t,
                       W):
    """Bucketed transport (DESIGN.md §11): the same per-leaf selection,
    EF, accounting, and telemetry as :func:`_perleaf_exchange` — but the
    step's collective/launch schedule is O(1), not O(leaves):

    * ONE batched fused-EF two-pass launch pair over every kernel-path
      leaf's concatenated block rows (``ops.fused_ef_compress_batched``);
    * ONE flat packed ``all_gather`` carrying every compressed leaf's
      exact payload rows back to back (``comm.bucket``), with one
      batched ``wire_pack``/``wire_unpack`` launch per bucket section;
    * ONE ``pmean`` of the concatenated dense small leaves.

    Per-leaf float accumulation order (wire/eff bytes, telemetry sums) is
    preserved, so updates/memory/byte outputs are bit-identical to the
    per-leaf path (telemetry to <= 8 ulp — see the reduce note below).
    """
    plan = build_bucket_plan([g.shape for g in flat_g], flat_s, comp)
    lanes = plan.leaves
    n = len(lanes)

    # ---- selection at the static budget, shared with the gossip
    # transport (repro.core.leafmath.select_and_encode): per-leaf BY
    # DESIGN — the contraction constant is per layer row; only the
    # collective schedule below is transport-specific
    sel = select_and_encode(flat_g, flat_m, flat_s, eta, comp, gamma_t,
                            plan)
    use_fused = sel.use_fused
    g2f, acc2, sent, resid = sel.g2f, sel.acc2, sel.sent, sel.resid
    leaf_g_sq, leaf_acc_sq = sel.leaf_g_sq, sel.leaf_acc_sq
    counts = sel.counts

    # ---- ONE flat all_gather for every compressed leaf ------------------
    decoded = [None] * n
    verdicts = [None] * n
    if plan.total_words:
        payload = encode_buckets(plan, sel.enc_rows)
        check_bucket_payload(payload, plan, comp)
        all_pay = gather_packed(payload, dp_axes)     # (W, total_words)
        if faults.guards_active():
            decoded, verdicts = decode_buckets(plan, all_pay,
                                               with_verdicts=True)
        else:
            decoded = decode_buckets(plan, all_pay)

    # ---- ONE pmean folds every dense small leaf -------------------------
    dense_acc = [None] * n
    dense_mean = [None] * n
    dense_ids = list(plan.dense_ids)
    for i in dense_ids:
        dense_acc[i] = flat_m[i].astype(jnp.float32) \
            + eta * flat_g[i].astype(jnp.float32)
    if dense_ids:
        mean_cat = jax.lax.pmean(
            jnp.concatenate([dense_acc[i].reshape(-1) for i in dense_ids]),
            dp_axes)
        off = 0
        for i in dense_ids:
            size = dense_acc[i].size
            dense_mean[i] = mean_cat[off:off + size].reshape(
                dense_acc[i].shape)
            off += size

    # ---- per-leaf consumers, ORIGINAL tree order (the f32 accumulation
    # order of the byte counters and telemetry sums is part of the
    # bit-exact parity contract with the per-leaf path)
    updates, new_mem = [], []
    wire = jnp.float32(0.0)
    eff_wire = jnp.float32(0.0)
    sums = TelemetrySums.zero()
    for lane, g, m in zip(lanes, flat_g, flat_m):
        i = lane.index
        if lane.dense:
            acc = dense_acc[i]
            updates.append(dense_mean[i])
            new_mem.append(jnp.zeros_like(m))
            wire = wire + jnp.float32(acc.size * acc.dtype.itemsize)
            eff_wire = eff_wire + jnp.float32(acc.size * acc.dtype.itemsize)
            sums = sums.add_dense(acc, g)
            continue
        spec, L, d = lane.spec, lane.L, lane.d
        g_vals, g_idx = decoded[i]
        (upd, mem_leaf, wire_add, eff_add, resid_sq, own_sq, own_dot,
         quar) = _consume_decoded_leaf(
                g, m, g2f[i], g_vals, g_idx, spec, L, d, counts[i], W,
                dp_axes, use_fused, sent[i], resid[i], acc2[i],
                verdict=verdicts[i])
        updates.append(upd)
        new_mem.append(mem_leaf)
        wire = wire + wire_add
        eff_wire = eff_wire + eff_add
        sums = sums.add(g_sq=leaf_g_sq[i], acc_sq=leaf_acc_sq[i],
                        resid_sq=resid_sq, own_sq=own_sq,
                        own_dot_g=own_dot, quar_rows=quar)

    return updates, new_mem, wire, eff_wire, sums


def dense_aggregate(grads: PyTree, eta: jax.Array,
                    dp_axes: AxisNames) -> tuple[PyTree, jax.Array]:
    """Baseline: dense pmean of eta*grad over dp axes (uncompressed wire).

    The bytes charged are the itemsize of the f32 buffer the pmean
    actually moves — the same ``size * dtype.itemsize`` basis the
    transports charge their dense leaves, so the two accountings cannot
    drift (they used to: this path hard-coded 4 bytes/element)."""
    upd = jax.tree.map(
        lambda g: jax.lax.pmean(eta * g.astype(jnp.float32), dp_axes), grads)
    wire = jnp.float32(sum(u.size * u.dtype.itemsize
                           for u in jax.tree.leaves(upd)))
    return upd, wire
