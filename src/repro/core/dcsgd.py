"""DCSGD-ASSS — distributed building blocks (paper Algorithm 3, appendix §VIII).

These functions run *inside* a ``jax.shard_map`` body that is manual over the
data-parallel mesh axes (``('pod','data')`` or ``('data',)``) and auto over
``'model'``.  Each data-parallel worker:

  1. computes its local gradient (done by the caller),
  2. runs its own Armijo search on its local batch -> per-worker ``eta^(k)``,
  3. forms ``acc = m^(k) + eta^(k) * grad^(k)`` per leaf,
  4. compresses ``acc`` to a (values, indices) pair and encodes it into a
     bit-packed ``uint32`` payload (repro/comm/wire.py, DESIGN.md §8),
  5. **all-gathers the packed payload** over the dp axes (this replaces the
     dense all-reduce; the payload's byte length IS ``wire_bytes`` — the
     paper's communication saving made physically real),
  6. decodes every worker's payload and applies the dense mean of the
     contributions,
  7. keeps ``m^(k) = acc - decode(own payload)`` locally (step 7 of
     Algorithm 3) — so wire quantization error and tie-dropped entries are
     recycled through the error feedback.

Leaves below the compression size threshold are aggregated densely
(``pmean``), matching §IV-A ("layers with less than 1000 parameters are not
compressed").

Scan-stacked leaves (leading axis = layers) are compressed **per layer**
(axis-0-batched top_k), matching the paper's per-layer compression.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.comm import wire as wire_fmt
from repro.comm.exchange import check_payload, gather_packed
from repro.kernels import ops
from .compression import Compressor, block_extract_sparse
from .telemetry import CompressionTelemetry, TelemetrySums, sparse_own_sums

PyTree = Any
AxisNames = Sequence[str] | str


def _dp_size(dp_axes: AxisNames):
    return compat.axis_size(dp_axes)


def _dp_index(dp_axes: AxisNames):
    """This worker's row in the all-gathered leading axis (lax.axis_index
    handles axis tuples row-major, matching all_gather's stacking order)."""
    axes = dp_axes if isinstance(dp_axes, str) else tuple(dp_axes)
    return jax.lax.axis_index(axes)


def _per_layer_topk(acc2d: jax.Array, k: int):
    """Batched exact top-k over the last axis. acc2d: (L, d)."""
    mag = jnp.abs(acc2d)
    _, idx = jax.lax.top_k(mag, k)                     # (L, k)
    vals = jnp.take_along_axis(acc2d, idx, axis=1)     # (L, k)
    return vals, idx.astype(jnp.int32)


def _scatter_layers(vals: jax.Array, idx: jax.Array, L: int, d: int,
                    dtype) -> jax.Array:
    """Scatter (..., L, k) sparse pairs into a dense (L, d) accumulator."""
    vals = vals.reshape(-1, vals.shape[-1]) if vals.ndim == 2 else vals
    if vals.ndim == 3:                                  # (W, L, k) gathered
        W, L_, k = vals.shape
        lidx = jnp.broadcast_to(jnp.arange(L_)[None, :, None], (W, L_, k))
        dense = jnp.zeros((L_, d), dtype)
        return dense.at[lidx, idx].add(vals.astype(dtype))
    L_, k = vals.shape
    lidx = jnp.broadcast_to(jnp.arange(L_)[:, None], (L_, k))
    dense = jnp.zeros((L_, d), dtype)
    return dense.at[lidx, idx].add(vals.astype(dtype))


def _leaf_2d(x: jax.Array, stacked: bool) -> jax.Array:
    """(L, d) per-layer view of a leaf (L = 1 when unstacked)."""
    if stacked and x.ndim >= 2:
        return x.reshape(x.shape[0], -1)
    return x.reshape(1, -1)


def compress_leaf(acc: jax.Array, comp: Compressor, stacked: bool):
    """Per-leaf sparse compression. Returns (vals, idx, (L, d)) flat layout."""
    flat = _leaf_2d(acc, stacked)
    L, d = flat.shape
    if comp.method == "block_topk" and d >= comp.min_compress_size:
        # block-local selection, batched over layers
        vals, idx = block_extract_sparse(flat, comp)
        return vals, idx, (L, d)
    vals, idx = _per_layer_topk(flat, comp.k_for(d))
    return vals, idx, (L, d)


def worker_compress_aggregate(
    grads: PyTree,
    memory: PyTree,
    eta: jax.Array,
    comp: Compressor,
    dp_axes: AxisNames,
    stacked_mask: PyTree | None = None,
    gamma_t: jax.Array | None = None,
    telemetry_axes: AxisNames | None = None,
) -> tuple[PyTree, PyTree, jax.Array, jax.Array, CompressionTelemetry]:
    """Steps 3-7 of Algorithm 3 for a whole gradient pytree.

    Returns ``(mean_update, new_memory, wire_bytes, effective_wire_bytes,
    telemetry)`` where ``mean_update`` is the dense averaged compressed
    update (to subtract from params), ``wire_bytes`` counts this worker's
    transmitted payload-buffer bytes this step (the static budget), and
    ``telemetry`` is this worker's :class:`CompressionTelemetry` for the
    round (EF backlog, decode cosine, relative decode error, empirical
    contraction — DESIGN.md §10).  Its dense reductions are fused into the
    Pallas EF block-stats pass on the kernel path; the decoded-side sums
    touch only the k wire entries.

    ``telemetry_axes``: extra manual mesh axes this call's inputs are
    sharded over WITHOUT being separate dp workers (the nested
    shard-local-topk 'model' region): the telemetry sums are psum'd over
    them before the ratios form, so the returned telemetry describes the
    worker's whole gradient, not one shard's slice.  The updates/memory/
    byte outputs are unaffected (selection stays shard-local by design).

    ``gamma_t`` (adaptive compressors, DESIGN.md §9): this worker's traced
    per-round compression level.  Selection still runs at the static
    ``k_max`` budget — the all-gathered buffer never changes shape — but
    entries ranked beyond ``k_t`` are masked behind the payload's
    valid-count header, receivers decode only the valid prefix (workers
    may carry *different* k_t), the masked entries recycle through the EF
    residual, and ``effective_wire_bytes`` reports what a ragged
    collective would have shipped.  For non-adaptive compressors the two
    byte counts coincide.
    """
    W = _dp_size(dp_axes)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(memory)
    if stacked_mask is None:
        flat_s = [leaf.ndim >= 2 for leaf in flat_g]
    else:
        flat_s = treedef.flatten_up_to(stacked_mask)

    if comp.adaptive and gamma_t is None:
        gamma_t = jnp.float32(comp.gamma)
    use_fused = comp.method == "block_topk" and comp.use_kernel
    updates, new_mem = [], []
    wire = jnp.float32(0.0)
    eff_wire = jnp.float32(0.0)
    sums = TelemetrySums.zero()
    for g, m, stacked in zip(flat_g, flat_m, flat_s):
        g2 = _leaf_2d(g, stacked)
        L, d = g2.shape
        if comp.method == "none" or d < comp.min_compress_size \
                or comp.sparse_k(d) >= d:
            acc = m.astype(jnp.float32) + eta * g.astype(jnp.float32)
            upd = jax.lax.pmean(acc, dp_axes)
            updates.append(upd)
            new_mem.append(jnp.zeros_like(m))
            wire = wire + jnp.float32(acc.size * acc.dtype.itemsize)
            eff_wire = eff_wire + jnp.float32(acc.size * acc.dtype.itemsize)
            sums = sums.add_dense(acc, g)
            continue
        g2f = g2.astype(jnp.float32)
        if use_fused:
            # fused two-pass Pallas path (DESIGN.md §3): pass 1 streams
            # (m, g) once for the per-block k_b-th |m + eta*g| statistic
            # AND the dense telemetry moments (sum g^2, sum acc^2) on the
            # same resident tile; pass 2 streams them again and writes
            # (sent, m') — the accumulator never round-trips through HBM.
            m2 = _leaf_2d(m, stacked).astype(jnp.float32)
            # threshold at the BUDGET level (geometry_gamma == max_gamma
            # for adaptive compressors): block_extract_sparse below pulls
            # exactly block_k() budget entries per block, and any
            # per-round k_t mask is applied at encode time
            sent, resid, _, moments = ops.fused_ef_compress(
                m2, g2f, eta, comp.geometry_gamma, comp.block,
                telemetry=True)
            leaf_g_sq = jnp.sum(moments[:, 0])
            leaf_acc_sq = jnp.sum(moments[:, 1])
            # per-block top-k_b of |sent| recovers the kept wire entries
            # (>= k_b survive the threshold; ties beyond k_b are dropped
            # from the wire and recycled into m' below)
            vals, idx = block_extract_sparse(sent, comp)
        else:
            acc2 = _leaf_2d(m, stacked).astype(jnp.float32) + eta * g2f
            leaf_g_sq = jnp.sum(g2f * g2f)
            leaf_acc_sq = jnp.sum(acc2 * acc2)
            vals, idx, (L, d) = compress_leaf(acc2, comp, stacked)

        # ---- bit-packed wire (DESIGN.md §8): encode once, gather ONE
        # uint32 payload per leaf — the payload's byte length is exactly
        # Compressor.wire_bytes (checked at trace time below), and the EF
        # residual is taken against what receivers actually decode, so
        # quantization error AND tie-dropped entries are recycled.
        spec = wire_fmt.WireSpec.for_row(comp, d)
        if spec.ragged:
            # per-round valid count (DESIGN.md §9): entries past it are
            # masked out of the payload behind the count header word
            count = comp.block_k_t(gamma_t) if spec.local \
                else comp.k_t_for(d, gamma_t)
            counts = jnp.broadcast_to(count, (L,))
        else:
            count, counts = None, None
        payload = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
        check_payload(payload, spec, comp, d)

        all_pay = gather_packed(payload, dp_axes)        # (W, L, words)
        g_vals, g_idx = wire_fmt.decode_rows(
            all_pay.reshape(-1, spec.row_words), spec)
        g_vals = g_vals.reshape(W, L, spec.k)
        g_idx = g_idx.reshape(W, L, spec.k)
        mean_dense = _scatter_layers(g_vals, g_idx, L, d, jnp.float32) / W
        updates.append(mean_dense.reshape(g.shape))
        wire = wire + jnp.float32(L * spec.row_bytes)
        eff_wire = eff_wire + (jnp.float32(L) * spec.effective_row_bytes(
            count) if spec.ragged else jnp.float32(L * spec.row_bytes))

        # EF residual against what receivers actually decoded — this
        # worker's rows are already in the gathered decode, so slice them
        # out instead of decoding the own payload a second time.
        w_idx = _dp_index(dp_axes)
        own_vals = jax.lax.dynamic_index_in_dim(g_vals, w_idx, 0,
                                                keepdims=False)
        own_idx = jax.lax.dynamic_index_in_dim(g_idx, w_idx, 0,
                                               keepdims=False)
        own_dense = _scatter_layers(own_vals, own_idx, L, d, jnp.float32)
        # masked-beyond-k_t entries are absent from own_dense, so — like
        # quantization error and tie drops — they land in the residual
        if use_fused:
            resid = resid + (sent - own_dense)
        else:
            resid = acc2 - own_dense
        new_mem.append(resid.reshape(m.shape).astype(m.dtype))
        # telemetry: the decoded-side sums touch only the k wire entries;
        # sum m'^2 fuses into the residual's own materialization above
        leaf_own_sq, leaf_dot = sparse_own_sums(own_vals, own_idx, g2f)
        sums = sums.add(g_sq=leaf_g_sq, acc_sq=leaf_acc_sq,
                        resid_sq=jnp.sum(resid * resid),
                        own_sq=leaf_own_sq, own_dot_g=leaf_dot)

    if telemetry_axes is not None:
        # sums are additive; ratios are not — reduce BEFORE finalizing
        sums = jax.tree.map(lambda x: jax.lax.psum(x, telemetry_axes), sums)
    return (treedef.unflatten(updates), treedef.unflatten(new_mem), wire,
            eff_wire, sums.finalize())


def dense_aggregate(grads: PyTree, eta: jax.Array,
                    dp_axes: AxisNames) -> tuple[PyTree, jax.Array]:
    """Baseline: dense pmean of eta*grad over dp axes (uncompressed wire)."""
    upd = jax.tree.map(
        lambda g: jax.lax.pmean(eta * g.astype(jnp.float32), dp_axes), grads)
    wire = jnp.float32(sum(g.size * 4 for g in jax.tree.leaves(grads)))
    return upd, wire
