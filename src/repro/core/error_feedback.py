"""Error-feedback memory state (paper eq. (6) and Algorithm 2 step 8).

The memory ``m_t`` accumulates what compression dropped:

    g_t     = top_k(m_t + eta_t * grad_t)
    m_{t+1} = m_t + eta_t * grad_t - g_t

Lemma 6: ``m_t = x_t - x_hat_t`` where ``x_hat`` is the uncompressed virtual
iterate — tested as a property test.

Supports quantized storage (int8 with per-block scales) as a beyond-paper
memory optimization for mega-models (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def init_ef(params: PyTree, dtype=jnp.float32) -> PyTree:
    """m_0 = 0, shaped like params (per paper; per-worker in DCSGD)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


# --------------------------- int8 quantized EF -----------------------------

EF_QBLOCK = 256


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedEF:
    """Per-block absmax-scaled int8 residual storage (4x smaller than f32)."""

    q: jax.Array        # int8, padded flat (nb, EF_QBLOCK)
    scale: jax.Array    # f32 (nb, 1)
    shape: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))


def quantize_ef(m: jax.Array) -> QuantizedEF:
    flat = m.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % EF_QBLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, EF_QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QuantizedEF(q=q, scale=scale, shape=tuple(m.shape))


def dequantize_ef(qef: QuantizedEF, dtype=jnp.float32) -> jax.Array:
    d = 1
    for n in qef.shape:
        d *= n
    flat = (qef.q.astype(jnp.float32) * qef.scale).reshape(-1)[:d]
    return flat.reshape(qef.shape).astype(dtype)


def init_ef_quantized(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: quantize_ef(jnp.zeros(p.shape, jnp.float32)),
                        params)
