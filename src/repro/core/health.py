"""Step-level health state and the divergence circuit breaker
(DESIGN.md §16).

The quarantine layer (comm/faults.py + wire.row_verdict) defends the
*wire*; this module defends the *step*: a fused all-finite check over the
round's updates and pmean'd loss gates the parameter write.  A failing
check SKIPS the step — parameters, EF memory, velocity, gamma, and every
carried transport state freeze bit-exactly while the step counter and
telemetry advance — and consecutive skips beyond
``OptimizerConfig.max_consecutive_skips`` raise :class:`DivergenceError`
on the host, naming the last step that wrote parameters so a checkpoint
rollback knows where to aim.

The skip decision is computed from REPLICATED quantities only (the
pmean'd loss plus the decoded-aggregate updates, which every worker
derives from the same gathered payload), so gating adds ZERO collectives
and the gated state stays replicated — the HLO-pinned faults-off
guarantee.  On the gossip transport updates are per-worker by design;
there the breaker couples through the pmean'd loss alone (a NaN loss on
ANY worker poisons the mean, tripping a fleet-wide skip one round after
a per-worker blowup at the latest).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


class DivergenceError(RuntimeError):
    """Raised (host-side) when the consecutive-skip threshold trips."""

    def __init__(self, step: int, last_good_step: int,
                 consecutive: int, threshold: int):
        self.step = int(step)
        self.last_good_step = int(last_good_step)
        self.consecutive = int(consecutive)
        self.threshold = int(threshold)
        super().__init__(
            f"divergence at step {self.step}: {self.consecutive} "
            f"consecutive non-finite steps skipped (threshold "
            f"{self.threshold}); last good step was "
            f"{self.last_good_step} — roll back to a checkpoint at or "
            f"before it")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HealthState:
    """Per-worker step-health counters (``DistOptState.health``)."""

    steps_skipped: jax.Array      # i32 — total gated-off steps
    consecutive_skips: jax.Array  # i32 — current skip run length
    last_good_step: jax.Array     # i32 — last step that wrote params (-1
                                  #       before the first good step)
    rows_quarantined: jax.Array   # f32 — cumulative §16 quarantined rows

    @classmethod
    def init(cls, batch_shape: tuple[int, ...] = (),
             abstract: bool = False) -> "HealthState":
        def leaf(v, dt):
            if abstract:
                return jax.ShapeDtypeStruct(batch_shape, dt)
            return jnp.full(batch_shape, v, dt)
        return cls(steps_skipped=leaf(0, jnp.int32),
                   consecutive_skips=leaf(0, jnp.int32),
                   last_good_step=leaf(-1, jnp.int32),
                   rows_quarantined=leaf(0.0, jnp.float32))


def all_finite(*trees) -> jax.Array:
    """Scalar bool: every leaf of every tree is all-finite.  One fused
    reduction chain, no collectives — operands are already replicated."""
    ok = jnp.bool_(True)
    for t in trees:
        for leaf in jax.tree.leaves(t):
            ok &= jnp.all(jnp.isfinite(leaf))
    return ok


def advance_health(health: HealthState, step_ok: jax.Array, step,
                   quarantined) -> HealthState:
    """Next round's counters given this round's gate verdict.

    ``step_ok``: scalar bool (True = parameters were written);
    ``step``: the i32 round index that just ran; ``quarantined``: this
    round's §16 row count.
    """
    skipped = jnp.where(step_ok, 0, 1).astype(jnp.int32)
    return HealthState(
        steps_skipped=health.steps_skipped + skipped,
        consecutive_skips=jnp.where(step_ok, jnp.int32(0),
                                    health.consecutive_skips + 1),
        last_good_step=jnp.where(step_ok, jnp.asarray(step, jnp.int32),
                                 health.last_good_step),
        rows_quarantined=health.rows_quarantined
        + jnp.asarray(quarantined, jnp.float32))


def check_divergence(metrics, max_consecutive_skips: int) -> None:
    """Host-side breaker: raise :class:`DivergenceError` when a metrics
    dict (one logged step: ``consecutive_skips``, ``last_good_step``,
    ``step``) shows the threshold tripped.  A no-op when the breaker is
    disabled (``max_consecutive_skips <= 0``) or the keys are absent."""
    if max_consecutive_skips <= 0:
        return
    consec = metrics.get("consecutive_skips")
    if consec is None:
        return
    consec = int(consec)
    if consec >= max_consecutive_skips:
        raise DivergenceError(
            step=int(metrics.get("step", -1)),
            last_good_step=int(metrics.get("last_good_step", -1)),
            consecutive=consec,
            threshold=max_consecutive_skips)
