"""Core of the reproduction: the paper's optimizer family.

Public API:
  - Compressor, Sparse              (top_k / block-local compression)
  - ArmijoConfig, armijo_search     (scaled Armijo search, Algorithm 1)
  - CSGDConfig, csgd_asss, CSGD     (Algorithm 2)
  - AcgdConfig, acgd, ACGD          (Nesterov-accelerated compressed GD)
  - NonAdaptiveCSGD, SGD, SLS       (paper baselines)
  - worker_compress_aggregate       (Algorithm 3 building block for shard_map)
"""
from .compression import (Compressor, Sparse, topk_select, sparse_to_dense,
                          block_threshold, threshold_select, tree_wire_bytes,
                          tree_effective_wire_bytes, contraction_gamma,
                          MIN_COMPRESS_SIZE)
from .armijo import ArmijoConfig, ArmijoResult, armijo_search, next_alpha_max, tree_sqnorm
from .telemetry import (CompressionTelemetry, SearchTelemetry, TelemetrySums,
                        sparse_own_sums)
from .gamma import GammaControllerConfig, gamma_init, gamma_update
from .csgd import CSGD, CSGDConfig, CSGDState, StepAux, csgd_asss
from .acgd import ACGD, AcgdAux, AcgdConfig, AcgdState, acgd
from .baselines import NonAdaptiveCSGD, SGD, SLS
from .dcsgd import worker_compress_aggregate, dense_aggregate
from .error_feedback import (init_ef, init_ef_quantized, quantize_ef,
                             dequantize_ef, QuantizedEF)

__all__ = [
    "Compressor", "Sparse", "topk_select", "sparse_to_dense",
    "block_threshold", "threshold_select", "tree_wire_bytes",
    "contraction_gamma", "MIN_COMPRESS_SIZE",
    "tree_effective_wire_bytes",
    "ArmijoConfig", "ArmijoResult", "armijo_search", "next_alpha_max",
    "tree_sqnorm",
    "CompressionTelemetry", "SearchTelemetry", "TelemetrySums",
    "sparse_own_sums",
    "GammaControllerConfig", "gamma_init", "gamma_update",
    "CSGD", "CSGDConfig", "CSGDState", "StepAux", "csgd_asss",
    "ACGD", "AcgdAux", "AcgdConfig", "AcgdState", "acgd",
    "NonAdaptiveCSGD", "SGD", "SLS",
    "worker_compress_aggregate", "dense_aggregate",
    "init_ef", "init_ef_quantized", "quantize_ef", "dequantize_ef",
    "QuantizedEF",
]
