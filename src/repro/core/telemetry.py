"""Compression telemetry: the EF-aware signal the gamma controller couples to.

DESIGN.md §9 documented the blind spot of the ``armijo-coupled`` controller:
the Armijo search runs on the *uncompressed* gradient, so its telemetry is
nearly flat in gamma and cannot sense over-compression — ``gamma_min`` was
the only safety rail.  The right signal is the compressor's own distortion
(AdaCGD adapts the compression level from the observed compression error;
AdaGossip adapts step parameters from compressed-difference magnitudes).

:class:`CompressionTelemetry` is that signal, one typed pytree per worker
per round, computed inside ``dcsgd.worker_compress_aggregate`` from FOUR
scalar reductions that ride existing passes (DESIGN.md §10):

* ``ef_backlog``    — ``||m'|| / ||g||``: how much compressed-away mass the
  error feedback is carrying relative to the fresh gradient.  Over-
  compression makes the backlog grow without bound; a healthy gamma keeps
  it at a problem-dependent steady state.
* ``cosine``        — cos(decode(own payload), g): alignment of what this
  worker actually put on the wire with its gradient.
* ``decode_error``  — ``||acc - decode(own payload)|| / ||acc||``: relative
  per-round distortion of the full EF accumulator ``acc = m + eta*g``.
* ``eff_gamma``     — ``1 - decode_error**2``: the empirical Lemma-7
  contraction coefficient of the whole encode->wire->decode pipeline (the
  *effective* compression ratio actually delivered at this round's k_t).

The five underlying sums (:class:`TelemetrySums`) are accumulated across
leaves and turned into ratios once, so telemetry composes over a gradient
pytree exactly like the byte accounting does.  The bucketed transport
(DESIGN.md §11) accumulates the same per-leaf sums in the same tree order
from its per-leaf bucket slices — f32 accumulation order is part of the
bit-exact parity contract — so the signal is transport-invariant.  The heavy reductions
(``sum g^2``, ``sum acc^2``) are fused into the Pallas EF block-stats pass
(``kernels/ef_topk.ef_stats_telemetry``) — the accumulator is formed on the
fly and never costs an extra HBM sweep; the decoded-side sums touch only
the k wire entries, and ``sum m'^2`` fuses into the residual's own write.

Controllers are pure functions of these structs (plus
:class:`SearchTelemetry` for the Armijo-side signals), not of ad-hoc
keyword arguments — see ``core/gamma.py``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: Guard for the ratio denominators.  Small enough to vanish against any
#: real gradient energy in f32 (adding it does not change the rounded
#: value), so the telemetry invariants — backlog == 0 and cosine == 1
#: bit-exactly for an identity compressor, bit-exact invariance under
#: power-of-two gradient scaling — hold exactly (tests/test_property.py).
_TINY = 1e-30


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressionTelemetry:
    """Per-worker, per-round compression health (all f32, batchable)."""

    ef_backlog: jax.Array    # ||m'|| / ||g||            (>= 0)
    cosine: jax.Array        # cos(decode(own), g)       (in [-1, 1])
    decode_error: jax.Array  # ||acc - decode(own)|| / ||acc||
    eff_gamma: jax.Array     # 1 - decode_error^2 (empirical contraction)
    rows_quarantined: jax.Array = 0.0  # decoded rows failing the §16
                                       # validity verdict this round

    @classmethod
    def init(cls, batch_shape: tuple[int, ...] = (), abstract: bool = False):
        """Neutral ("perfectly healthy") telemetry for state init: zero
        backlog, perfect alignment, zero distortion, full contraction."""
        def leaf(v):
            if abstract:
                return jax.ShapeDtypeStruct(batch_shape, jnp.float32)
            return jnp.full(batch_shape, v, jnp.float32)
        return cls(ef_backlog=leaf(0.0), cosine=leaf(1.0),
                   decode_error=leaf(0.0), eff_gamma=leaf(1.0),
                   rows_quarantined=leaf(0.0))

    def pmean(self, axis_names) -> "CompressionTelemetry":
        """Mean over the mesh axes — the permutation-invariant aggregate
        view of a dp worker group (tests/distributed)."""
        return jax.tree.map(lambda x: jax.lax.pmean(x, axis_names), self)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TelemetrySums:
    """Additive accumulator behind :class:`CompressionTelemetry`.

    One instance per worker; leaves contribute via :meth:`add` and the
    ratios are formed once at :meth:`finalize`.  ``own`` means this
    worker's decoded wire contribution (== ``acc`` for dense-shipped
    leaves, == decode(own payload) for compressed ones).
    """

    g_sq: jax.Array       # sum ||g||^2        over leaves
    acc_sq: jax.Array     # sum ||m + eta*g||^2
    resid_sq: jax.Array   # sum ||m'||^2       (the new EF memory)
    own_sq: jax.Array     # sum ||decode(own)||^2
    own_dot_g: jax.Array  # sum <decode(own), g>
    quar_rows: jax.Array = 0.0  # gathered rows quarantined by the §16 verdict

    @classmethod
    def zero(cls) -> "TelemetrySums":
        z = jnp.float32(0.0)
        return cls(g_sq=z, acc_sq=z, resid_sq=z, own_sq=z, own_dot_g=z,
                   quar_rows=z)

    def add(self, *, g_sq, acc_sq, resid_sq, own_sq,
            own_dot_g, quar_rows=0.0) -> "TelemetrySums":
        return TelemetrySums(
            g_sq=self.g_sq + g_sq,
            acc_sq=self.acc_sq + acc_sq,
            resid_sq=self.resid_sq + resid_sq,
            own_sq=self.own_sq + own_sq,
            own_dot_g=self.own_dot_g + own_dot_g,
            quar_rows=self.quar_rows + quar_rows)

    def add_dense(self, acc: jax.Array, g: jax.Array) -> "TelemetrySums":
        """Contribution of an uncompressed (dense-shipped) leaf: decode ==
        acc exactly and the residual is identically zero — contributed as a
        literal 0 so the zero-backlog invariant is bit-exact."""
        gf = g.astype(jnp.float32)
        accf = acc.astype(jnp.float32)
        g_sq = jnp.sum(gf * gf)
        acc_sq = jnp.sum(accf * accf)
        return self.add(g_sq=g_sq, acc_sq=acc_sq, resid_sq=jnp.float32(0.0),
                        own_sq=acc_sq, own_dot_g=jnp.sum(accf * gf))

    def finalize(self) -> CompressionTelemetry:
        resid_sq = self.resid_sq
        backlog = jnp.sqrt(resid_sq / (self.g_sq + _TINY))
        decode_err = jnp.sqrt(resid_sq / (self.acc_sq + _TINY))
        cosine = self.own_dot_g / jnp.sqrt(self.own_sq * self.g_sq + _TINY)
        return CompressionTelemetry(
            ef_backlog=backlog,
            cosine=cosine,
            decode_error=decode_err,
            eff_gamma=1.0 - resid_sq / (self.acc_sq + _TINY),
            rows_quarantined=self.quar_rows,
        )


def sparse_own_sums(own_vals: jax.Array, own_idx: jax.Array,
                    g2: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sum ||decode(own)||^2, sum <decode(own), g>) from the k decoded
    wire entries alone — no dense sweep.  ``own_vals``/``own_idx``:
    (L, k) decoded values and flat indices; ``g2``: the (L, d) f32 layer
    view of the gradient.  Padding entries carry value 0 at a clamped
    in-bounds index, so they contribute nothing to either sum.
    """
    d = g2.shape[-1]
    vals = own_vals.astype(jnp.float32)
    g_at = jnp.take_along_axis(g2, jnp.minimum(own_idx, d - 1), axis=-1)
    return jnp.sum(vals * vals), jnp.sum(vals * g_at)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SearchTelemetry:
    """Armijo line-search signals of the round that just finished — the
    typed replacement for the controller's old ad-hoc keyword arguments."""

    alpha: jax.Array         # accepted step of round t
    alpha_prev: jax.Array    # accepted step of round t-1
    n_evals: jax.Array       # stopping-condition evaluations of round t
    n_evals_ema: jax.Array   # running mean of n_evals
