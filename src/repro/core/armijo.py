"""Armijo step-size search with scaling (paper Algorithm 1 + §III-A).

Faithful semantics:

* search starts at ``alpha_max`` and *first multiplies by rho* before the
  first test (Algorithm 1 lines 4-6: ``repeat alpha <- alpha*rho ... until``);
* stopping condition (2): ``f(x - alpha*grad) <= f(x) - sigma*alpha*||grad||^2``
  — evaluated with the *unscaled* alpha;
* the descent step uses ``eta = a * alpha`` (the paper's key contribution).
  NOTE the paper's own settings contradict its theory: §IV-A runs
  ``a = 3*sigma = 0.3``, but Theorem 15 needs ``a < 2*sigma = 0.2`` and
  the compressed-SGD bound is tighter still, ``a <= zeta(gamma) =
  sigma*gamma/(2-gamma)``.  Both are exposed: ``a_scale`` defaults to the
  paper's empirical ``3*sigma`` (validated in benchmarks), and
  ``theory_safe=True`` clamps the effective scale to ``min(a_scale,
  zeta(gamma))`` per round via :meth:`ArmijoConfig.scale_for` — with
  adaptive compression the clamp tracks the *current* ``gamma_t``;
* across iterations ``alpha_max_t = omega * alpha_{t-1}`` (Algorithm 2 step 3).

Implemented as a ``jax.lax.while_loop`` so it lowers into the train_step HLO;
each trial costs one forward pass of the sampled batch's loss.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ArmijoConfig:
    sigma: float = 0.1          # sufficient-decrease parameter (paper sigma)
    rho: float = 0.8            # backtracking factor (paper rho)
    omega: float = 1.2          # alpha_max growth (paper omega)
    a_scale: float = 0.3        # eta = a * alpha  (paper uses a = 3*sigma)
    alpha0: float = 0.1         # initial alpha_max (paper §IV-A)
    max_backtracks: int = 40    # safety cap on the while loop
    alpha_min: float = 1e-8     # numerical floor
    #: clamp the effective scale to the compressed-SGD theory bound
    #: zeta(gamma) each round (off by default: the paper's empirical
    #: a = 3*sigma violates its own a < 2*sigma — see module docstring)
    theory_safe: bool = False

    @property
    def theory_a_bound(self) -> float:
        """Scaled-GD theory bound a < 2*sigma (Theorem 15)."""
        return 2.0 * self.sigma

    def zeta(self, gamma):
        """Compressed-SGD theory bound: a <= zeta = sigma*gamma/(2-gamma).
        Works on floats and on a traced per-round gamma_t alike."""
        return self.sigma * gamma / (2.0 - gamma)

    def scale_for(self, gamma=None):
        """Effective step scale a for this round: ``a_scale``, clamped to
        ``zeta(gamma)`` when ``theory_safe`` — re-evaluated per round under
        adaptive compression, where gamma is the traced gamma_t."""
        if gamma is None or not self.theory_safe:
            return self.a_scale
        return jnp.minimum(jnp.float32(self.a_scale),
                           jnp.asarray(self.zeta(gamma), jnp.float32))


class ArmijoResult(NamedTuple):
    alpha: jax.Array          # accepted (unscaled) alpha_t
    eta: jax.Array            # a * alpha_t — the step used in the descent
    f0: jax.Array             # f(x_t) at the sampled batch
    n_evals: jax.Array        # number of stopping-condition evaluations
    accepted: jax.Array       # bool: condition met before max_backtracks


def _tree_axpy(a: jax.Array, x: PyTree, y: PyTree) -> PyTree:
    """y - a*x elementwise over the tree (candidate iterate)."""
    return jax.tree.map(lambda yi, xi: yi - a * xi.astype(yi.dtype), y, x)


def tree_sqnorm(t: PyTree) -> jax.Array:
    return sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
               for leaf in jax.tree.leaves(t))


def armijo_search(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    grads: PyTree,
    alpha_max: jax.Array,
    cfg: ArmijoConfig,
    f0: jax.Array | None = None,
    grad_sqnorm: jax.Array | None = None,
    gamma: jax.Array | None = None,
) -> ArmijoResult:
    """Run Algorithm 1 starting at ``alpha_max`` for loss ``loss_fn``.

    ``loss_fn`` must be the loss of the *sampled batch* ``f_{i_t}`` closed
    over the batch (paper line-searches the sampled function, not f).
    ``gamma``: the round's compression level, used only to clamp the
    returned ``eta`` under ``cfg.theory_safe`` (see ``scale_for``).
    """
    if f0 is None:
        f0 = loss_fn(params)
    if grad_sqnorm is None:
        grad_sqnorm = tree_sqnorm(grads)
    f0 = f0.astype(jnp.float32)
    alpha_max = jnp.asarray(alpha_max, jnp.float32)

    def trial(alpha):
        cand = _tree_axpy(alpha, grads, params)
        return loss_fn(cand).astype(jnp.float32)

    def cond(state):
        alpha, f_try, n = state
        # a NaN/Inf candidate loss must read as a REJECTED trial, not an
        # accepted one: NaN makes `<=` false (keeps backtracking, which
        # is right) but Inf-f0 arithmetic or a -Inf f_try could satisfy
        # the inequality — the explicit isfinite guard pins the step off
        # any non-finite loss surface (DESIGN.md §16)
        ok = jnp.isfinite(f_try) & \
            (f_try <= f0 - cfg.sigma * alpha * grad_sqnorm)
        return jnp.logical_and(~ok,
                               jnp.logical_and(n < cfg.max_backtracks,
                                               alpha > cfg.alpha_min))

    def body(state):
        alpha, _, n = state
        alpha = alpha * cfg.rho
        return alpha, trial(alpha), n + 1

    # First candidate is alpha_max itself (do-while reading of Algorithm 1:
    # the literal pseudocode pre-multiplies by rho before the first test,
    # which with omega*rho = 0.96 < 1 would make alpha monotonically
    # decreasing — contradicting the paper's own §IV-B accounting of "~2
    # stopping-condition evaluations per step".  Testing alpha_max first
    # matches [15] and the paper's cost claim; see DESIGN.md §7).
    init = (alpha_max, trial(alpha_max), jnp.int32(1))
    alpha, f_try, n = jax.lax.while_loop(cond, body, init)
    accepted = jnp.isfinite(f_try) & \
        (f_try <= f0 - cfg.sigma * alpha * grad_sqnorm)
    eta = cfg.scale_for(gamma) * alpha
    return ArmijoResult(alpha=alpha, eta=eta, f0=f0,
                        n_evals=n, accepted=accepted)


def next_alpha_max(alpha_t: jax.Array, cfg: ArmijoConfig) -> jax.Array:
    """Algorithm 2 step 3: alpha_max_{t+1} = omega * alpha_t."""
    return jnp.clip(cfg.omega * alpha_t, cfg.alpha_min, 1e6)
