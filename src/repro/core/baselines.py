"""Baseline optimizers the paper compares against (§IV).

* ``NonAdaptiveCSGD`` — top_k + memory feedback with a *fixed* step size
  (Aji & Heafield [3]; the paper's main baseline, run at 0.1/0.05/0.01).
* ``SGD``             — plain uncompressed SGD (optionally with momentum).
* ``SLS``             — uncompressed SGD with Armijo line search
  (Vaswani et al. [15]; the method CSGD-ASSS extends to compression).

All expose the same ``init/step(loss_fn, params, state)`` interface as CSGD
so train loops and benchmarks are optimizer-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .armijo import ArmijoConfig, armijo_search, next_alpha_max, tree_sqnorm
from .compression import Compressor
from . import error_feedback as ef

PyTree = Any


class NonAdaptiveState(NamedTuple):
    step: jax.Array
    memory: PyTree


class NonAdaptiveAux(NamedTuple):
    loss: jax.Array
    grad_sqnorm: jax.Array


@dataclasses.dataclass(frozen=True)
class NonAdaptiveCSGD:
    """Compressed SGD with memory feedback, fixed step size eta [3]."""

    eta: float = 0.1
    compressor: Compressor = Compressor()

    def init(self, params: PyTree) -> NonAdaptiveState:
        return NonAdaptiveState(step=jnp.int32(0), memory=ef.init_ef(params))

    def step(self, loss_fn: Callable, params: PyTree,
             state: NonAdaptiveState):
        loss, grads = jax.value_and_grad(loss_fn)(params)

        def leaf(m, g):
            acc = m + self.eta * g.astype(m.dtype)
            return self.compressor.compress_dense(acc)

        flat_m, treedef = jax.tree.flatten(state.memory)
        flat_g = treedef.flatten_up_to(grads)
        pairs = [leaf(m, g) for m, g in zip(flat_m, flat_g)]
        sent = treedef.unflatten([p[0] for p in pairs])
        resid = treedef.unflatten([p[1] for p in pairs])
        new_params = jax.tree.map(
            lambda p, s: (p.astype(jnp.float32) - s).astype(p.dtype),
            params, sent)
        return new_params, NonAdaptiveState(state.step + 1, resid), \
            NonAdaptiveAux(loss=loss, grad_sqnorm=tree_sqnorm(grads))


class SGDState(NamedTuple):
    step: jax.Array
    momentum: PyTree | None


@dataclasses.dataclass(frozen=True)
class SGD:
    """Plain (uncompressed) SGD, optional heavy-ball momentum."""

    eta: float = 0.1
    beta: float = 0.0

    def init(self, params: PyTree) -> SGDState:
        mom = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
               if self.beta else None)
        return SGDState(step=jnp.int32(0), momentum=mom)

    def step(self, loss_fn: Callable, params: PyTree, state: SGDState):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        if self.beta:
            mom = jax.tree.map(lambda v, g: self.beta * v + g.astype(jnp.float32),
                               state.momentum, grads)
            upd = mom
        else:
            mom = None
            upd = grads
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - self.eta * u.astype(jnp.float32)).astype(p.dtype),
            params, upd)
        return new_params, SGDState(state.step + 1, mom), \
            NonAdaptiveAux(loss=loss, grad_sqnorm=tree_sqnorm(grads))


class SLSState(NamedTuple):
    step: jax.Array
    alpha_prev: jax.Array


class SLSAux(NamedTuple):
    loss: jax.Array
    alpha: jax.Array
    n_evals: jax.Array


@dataclasses.dataclass(frozen=True)
class SLS:
    """Uncompressed stochastic line search [15] (no scaling, no compression)."""

    armijo: ArmijoConfig = ArmijoConfig(a_scale=1.0)

    def init(self, params: PyTree) -> SLSState:
        return SLSState(step=jnp.int32(0),
                        alpha_prev=jnp.float32(self.armijo.alpha0))

    def step(self, loss_fn: Callable, params: PyTree, state: SLSState):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        gsq = tree_sqnorm(grads)
        amax = next_alpha_max(state.alpha_prev, self.armijo)
        res = armijo_search(loss_fn, params, grads, amax, self.armijo,
                            f0=loss, grad_sqnorm=gsq)
        eta = self.armijo.a_scale * res.alpha
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - eta * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, SLSState(state.step + 1, res.alpha), \
            SLSAux(loss=loss, alpha=res.alpha, n_evals=res.n_evals)
