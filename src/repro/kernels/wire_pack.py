"""Pallas kernels for the bit-packed compressed-gradient wire format.

The packed payload (DESIGN.md §8) stores each wire entry's index and
quantized value as fixed-width bit-fields inside contiguous ``uint32``
words.  The field<->word conversion is the only data-parallel part of the
codec and the part worth a kernel: on TPU it is a pure VPU shift/or (pack)
or shift/mask (unpack) streaming pass — one read + one write at the packed
byte width, so packing k int8 values costs k bytes of HBM traffic, not 4k.

Layout contract (shared with the ``kernels/ref.py`` oracles bit-for-bit):
``F = 32 // bits`` fields per word, field ``f`` occupying bits
``[f*bits, (f+1)*bits)`` — little-endian fields within each word.

Tiles are (rows, chunk) with the word chunk VPU-lane aligned; the field
side of each tile is ``F`` times wider than the word side, expressed as two
BlockSpec widths over the same grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row/word tile geometry: the word side of a tile is (256, 512) uint32 =
# 512 KiB; the field side is at most 8x wider (bits=4) = 4 MiB — both
# VMEM-resident with double-buffering headroom, and payload row counts
# (model layers) rarely exceed a few tiles.
ROWS = 256
WORD_CHUNK = 512


def stream_shape(n_words: int) -> tuple[int, int]:
    """(rows, cols) reflow geometry for a FLAT word stream of ``n_words``
    uint32 words — the bucket-shaped launch (DESIGN.md §11).

    Packing is word-local (word w holds fields [w*F, (w+1)*F) whatever the
    row structure), so a whole bucket's concatenated field stream can be
    reshaped row-major into (rows, cols) word tiles, packed/unpacked in
    ONE kernel launch, and flattened back — each leaf's exact word segment
    slices out unchanged.  Cols saturate at :data:`WORD_CHUNK` so big
    buckets fill full (ROWS, WORD_CHUNK) VPU tiles.
    """
    cols = min(WORD_CHUNK, max(n_words, 1))
    return -(-max(n_words, 1) // cols), cols


def _field_mask(c_ref, n: int, rows: int, period: int):
    """(rows, n) validity mask for the current grid tile: GLOBAL field
    index j (tile column offset + local column) is valid iff
    ``j % period < count[row]`` — the ragged-payload predicate.  The
    modulo makes it a per-block prefix for block-local wire rows and a
    plain prefix for flat rows, with zero extra HBM traffic: counts ride
    in as one (rows, 1) int32 column per tile."""
    j = pl.program_id(1)
    gidx = j * n + jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1)
    return (gidx % period) < c_ref[...]


def _pack_kernel(f_ref, out_ref, *, bits: int):
    """(rows, W*F) uint32 fields -> (rows, W) uint32 words."""
    F = 32 // bits
    f = f_ref[...].astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    rows, n = f.shape
    shifts = jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits)
    w = f.reshape(rows, n // F, F) << shifts[None, None, :]
    # disjoint bit ranges: or == sum, and sum lowers to a VPU reduction
    out_ref[...] = jnp.sum(w, axis=-1, dtype=jnp.uint32)


def _pack_kernel_ragged(f_ref, c_ref, out_ref, *, bits: int, period: int):
    """Ragged variant: zero fields beyond the per-row valid count on the
    same streaming pass, then pack."""
    F = 32 // bits
    f = f_ref[...].astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    rows, n = f.shape
    f = jnp.where(_field_mask(c_ref, n, rows, period), f, jnp.uint32(0))
    shifts = jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits)
    w = f.reshape(rows, n // F, F) << shifts[None, None, :]
    out_ref[...] = jnp.sum(w, axis=-1, dtype=jnp.uint32)


def _unpack_kernel(w_ref, out_ref, *, bits: int):
    """(rows, W) uint32 words -> (rows, W*F) uint32 fields."""
    F = 32 // bits
    w = w_ref[...].astype(jnp.uint32)
    rows, W = w.shape
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = (w[:, :, None] >> shifts[None, None, :]) & mask
    out_ref[...] = fields.reshape(rows, W * F)


def _unpack_kernel_ragged(w_ref, c_ref, out_ref, *, bits: int, period: int):
    """Ragged variant: decoded fields beyond the valid count come out 0
    regardless of the packed tail's bytes."""
    F = 32 // bits
    w = w_ref[...].astype(jnp.uint32)
    rows, W = w.shape
    mask = jnp.uint32((1 << bits) - 1)
    shifts = jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = (w[:, :, None] >> shifts[None, None, :]) & mask
    fields = fields.reshape(rows, W * F)
    out_ref[...] = jnp.where(_field_mask(c_ref, W * F, rows, period),
                             fields, jnp.uint32(0))


@functools.partial(jax.jit, static_argnames=("bits", "period", "interpret"))
def pack_words(fields: jax.Array, bits: int,
               counts: jax.Array | None = None, period: int = 0, *,
               interpret: bool = True):
    """Pack (R, n) uint32 bit-fields into (R, n*bits/32) uint32 words.

    n must be a multiple of 32//bits (``ops.pack_fields`` zero-pads).
    ``counts``/``period``: ragged payloads — fields with
    ``j % period >= counts[row]`` are zeroed inside the kernel before
    packing (valid-count semantics, DESIGN.md §9).
    """
    if bits >= 32:
        out = fields.astype(jnp.uint32)
        if counts is not None:
            from . import ref
            out = jnp.where(ref._count_mask(*out.shape, counts, period),
                            out, 0)
        return out
    F = 32 // bits
    R, n = fields.shape
    W = n // F
    rows = min(ROWS, R)
    wc = min(WORD_CHUNK, W)
    grid = (pl.cdiv(R, rows), pl.cdiv(W, wc))
    if counts is None:
        return pl.pallas_call(
            functools.partial(_pack_kernel, bits=bits),
            grid=grid,
            in_specs=[pl.BlockSpec((rows, wc * F), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((rows, wc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((R, W), jnp.uint32),
            interpret=interpret,
        )(fields.astype(jnp.uint32))
    c = jnp.asarray(counts, jnp.int32).reshape(-1, 1)
    return pl.pallas_call(
        functools.partial(_pack_kernel_ragged, bits=bits, period=period),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, wc * F), lambda i, j: (i, j)),
                  pl.BlockSpec((rows, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((rows, wc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, W), jnp.uint32),
        interpret=interpret,
    )(fields.astype(jnp.uint32), c)


@functools.partial(jax.jit, static_argnames=("bits", "period", "interpret"))
def unpack_words(words: jax.Array, bits: int,
                 counts: jax.Array | None = None, period: int = 0, *,
                 interpret: bool = True):
    """Inverse of :func:`pack_words`: (R, W) words -> (R, W*32/bits)
    fields, masked beyond the per-row valid count when ``counts`` is
    given."""
    if bits >= 32:
        out = words.astype(jnp.uint32)
        if counts is not None:
            from . import ref
            out = jnp.where(ref._count_mask(*out.shape, counts, period),
                            out, 0)
        return out
    F = 32 // bits
    R, W = words.shape
    rows = min(ROWS, R)
    wc = min(WORD_CHUNK, W)
    grid = (pl.cdiv(R, rows), pl.cdiv(W, wc))
    if counts is None:
        return pl.pallas_call(
            functools.partial(_unpack_kernel, bits=bits),
            grid=grid,
            in_specs=[pl.BlockSpec((rows, wc), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((rows, wc * F), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((R, W * F), jnp.uint32),
            interpret=interpret,
        )(words.astype(jnp.uint32))
    c = jnp.asarray(counts, jnp.int32).reshape(-1, 1)
    return pl.pallas_call(
        functools.partial(_unpack_kernel_ragged, bits=bits, period=period),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, wc), lambda i, j: (i, j)),
                  pl.BlockSpec((rows, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((rows, wc * F), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, W * F), jnp.uint32),
        interpret=interpret,
    )(words.astype(jnp.uint32), c)
