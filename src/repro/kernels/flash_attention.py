"""Pallas TPU flash-attention forward kernel (serving hot path).

Online-softmax tiling [Dao '22], adapted to the TPU memory hierarchy:

* grid = (batch*heads, Sq/BQ); the KV sequence is walked *inside* the kernel
  with a ``fori_loop`` so the (BQ, D) query tile, the running (BQ, 1)
  max/denominator and the (BQ, D) accumulator all stay in VMEM/VREGs;
* K/V tiles are streamed HBM->VMEM by the BlockSpec pipeline, (BK, D) at a
  time, with D padded to a 128-lane multiple so the (BQ, BK) logits matmul
  lands on the MXU;
* causal + sliding-window masking is applied per tile; tiles entirely outside
  the (causal, window) band are skipped via the loop bounds — this is what
  makes the sliding-window variant sub-quadratic.

Used for prefill; decode uses the seq-sharded flash-decode combine in
``repro/models/attention.py`` (a different memory layout problem).
Validated in interpret mode against ``ref.mha_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: int | None, bq: int, bk: int, sk: int,
                  q_offset: int):
    qi = pl.program_id(1)
    # NB: refs must be indexed with slices (pl.dslice / [...]), never bare
    # Python ints — interpret-mode discharge chokes on raw int indices.
    q = q_ref[...][0].astype(jnp.float32) * scale       # (BQ, D)
    D = q.shape[-1]

    # Query i sits at absolute position q_offset + i (q_offset = Sk - Sq:
    # rectangular Q<K means the queries are the *last* Sq positions).
    q_start = qi * bq + q_offset
    # KV tile range intersecting the causal/window band of this Q tile.
    lo = 0
    if window is not None:
        lo = jnp.maximum(q_start - (window - 1), 0) // bk
    hi = pl.cdiv(sk, bk)
    if causal:
        hi = jnp.minimum(hi, pl.cdiv(q_start + bq, bk))

    def body(kj, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * bk, bk),
                            slice(None)))[0].astype(jnp.float32)  # (BK, D)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * bk, bk),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                      # (BQ, BK) on MXU
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return acc, m_new, l_new

    init = (jnp.zeros((bq, D), jnp.float32),
            jnp.full((bq, 1), NEG_INF, jnp.float32),
            jnp.zeros((bq, 1), jnp.float32))
    acc, m_i, l_i = jax.lax.fori_loop(lo, hi, body, init)
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) (GQA pre-broadcast in ops.py)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * H, Sk, D)
    vr = v.reshape(B * H, Sk, D)
    grid = (B * H, Sq // bq)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, sk=Sk,
                               q_offset=Sk - Sq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)
