"""Pure-jnp oracles for every Pallas kernel. Ground truth for tests.

Each function mirrors the corresponding kernel's contract exactly; kernels are
validated with ``assert_allclose`` against these across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------- ef_topk ---------------------------------------

def ef_threshold_update(m: jax.Array, g: jax.Array, eta: jax.Array,
                        tau: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused error-feedback threshold sparsification (DESIGN.md §3).

        acc  = m + eta * g
        sent = acc * (|acc| >= tau)
        m'   = acc - sent

    All arrays same shape; eta, tau scalars. Returns (sent, m_new) in the
    dtype of ``m``.
    """
    acc = m.astype(jnp.float32) + eta.astype(jnp.float32) * g.astype(jnp.float32)
    mask = jnp.abs(acc) >= tau.astype(jnp.float32)
    sent = jnp.where(mask, acc, 0.0)
    m_new = acc - sent
    return sent.astype(m.dtype), m_new.astype(m.dtype)


def ef_block_update(m: jax.Array, g: jax.Array, eta: jax.Array,
                    tau: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block-row EF threshold sparsification (DESIGN.md §3).

    m, g: (R, C) block rows; eta scalar; tau: (R, 1) per-row thresholds.

        acc  = m + eta * g
        sent = acc * (|acc| >= tau_row)
        m'   = acc - sent

    Returns (sent, m_new) in the dtype of ``m``.  The EF identity
    ``sent + m' == m + eta*g`` holds bit-exactly in f32.
    """
    acc = m.astype(jnp.float32) + eta.astype(jnp.float32) * g.astype(jnp.float32)
    mask = jnp.abs(acc) >= tau.reshape(-1, 1).astype(jnp.float32)
    sent = jnp.where(mask, acc, 0.0)
    return sent.astype(m.dtype), (acc - sent).astype(m.dtype)


def ef_block_stats(m: jax.Array, g: jax.Array, eta: jax.Array,
                   k_b: int) -> jax.Array:
    """Per-block-row k_b-th largest |m + eta*g|. (R, C) -> (R, 1) f32."""
    acc = m.astype(jnp.float32) + eta.astype(jnp.float32) * g.astype(jnp.float32)
    vals, _ = jax.lax.top_k(jnp.abs(acc), k_b)
    return vals[:, -1:]


def ef_block_stats_telemetry(m: jax.Array, g: jax.Array, eta: jax.Array,
                             k_b: int) -> tuple[jax.Array, jax.Array]:
    """Fused pass 1 + telemetry moments (DESIGN.md §10): per-block-row
    k_b-th largest |m + eta*g| AND the dense telemetry moments of the same
    streamed operands.  (R, C) -> (tau (R, 1), moments (R, 2) f32 with
    columns [sum g^2, sum acc^2])."""
    gf = g.astype(jnp.float32)
    acc = m.astype(jnp.float32) + eta.astype(jnp.float32) * gf
    vals, _ = jax.lax.top_k(jnp.abs(acc), k_b)
    moments = jnp.concatenate(
        [jnp.sum(gf * gf, axis=-1, keepdims=True),
         jnp.sum(acc * acc, axis=-1, keepdims=True)], axis=-1)
    return vals[:, -1:], moments


def threshold_split(x: jax.Array, tau: jax.Array) -> tuple[jax.Array,
                                                           jax.Array]:
    """Per-block-row dense split: (sent, residual). x: (R, C); tau: (R, 1)."""
    xf = x.astype(jnp.float32)
    sent = jnp.where(jnp.abs(xf) >= tau.reshape(-1, 1).astype(jnp.float32),
                     xf, 0.0)
    return sent.astype(x.dtype), (xf - sent).astype(x.dtype)


def block_abs_topk_threshold(x: jax.Array, k_b: int, block: int) -> jax.Array:
    """Per-block k_b-th largest |x|. x flat, padded to a multiple of block.

    Returns (n_blocks,) thresholds — pass-1 statistics for the two-pass
    block-local selection.
    """
    blocks = x.reshape(-1, block)
    mag = jnp.abs(blocks)
    vals, _ = jax.lax.top_k(mag, k_b)
    return vals[:, -1]


# --------------------------- wire pack/unpack ------------------------------

def _count_mask(R: int, n: int, counts: jax.Array, period: int) -> jax.Array:
    """(R, n) validity mask: field j of a row is valid iff
    ``j % period < count`` — the ragged-payload predicate (DESIGN.md §9):
    a per-block prefix for block-local wire rows (period = k_b), a plain
    row prefix for flat rows (period = k)."""
    pos = jnp.arange(n, dtype=jnp.int32) % jnp.int32(period)
    return pos[None, :] < jnp.asarray(counts, jnp.int32).reshape(-1, 1)


def pack_fields(fields: jax.Array, bits: int,
                counts: jax.Array | None = None,
                period: int = 0) -> jax.Array:
    """Pack (R, n) uint32 bit-fields into (R, n*bits/32) uint32 words.

    ``bits`` in {4, 8, 16, 32}; n must be a multiple of 32//bits (callers
    zero-pad).  Field f of word w occupies bits [f*bits, (f+1)*bits) —
    little-endian fields within the word, so packed payloads are
    byte-order independent at the word level.  Fields are masked to
    ``bits`` before packing; disjoint bit ranges make the or a sum.

    ``counts`` (+ static ``period``): per-row valid counts; fields with
    ``j % period >= counts[row]`` are zeroed on the way into the words, so
    ragged payloads never leak stale entries past their count header.
    """
    fields = fields.astype(jnp.uint32)
    R, n = fields.shape
    if counts is not None:
        fields = jnp.where(_count_mask(R, n, counts, period), fields, 0)
    if bits >= 32:
        return fields
    F = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    w = (fields & mask).reshape(R, n // F, F)
    shifts = (jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits))
    return jnp.sum(w << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_fields(words: jax.Array, bits: int,
                  counts: jax.Array | None = None,
                  period: int = 0) -> jax.Array:
    """Inverse of :func:`pack_fields`: (R, W) words -> (R, W*32/bits)
    fields.  ``counts`` masks decoded fields beyond the per-row valid
    count to 0 — decode-side enforcement of the ragged contract, robust
    to arbitrary bytes in the invalid tail."""
    words = words.astype(jnp.uint32)
    if bits >= 32:
        if counts is not None:
            words = jnp.where(
                _count_mask(*words.shape, counts, period), words, 0)
        return words
    F = 32 // bits
    R, W = words.shape
    mask = jnp.uint32((1 << bits) - 1)
    shifts = (jnp.arange(F, dtype=jnp.uint32) * jnp.uint32(bits))
    fields = (words[:, :, None] >> shifts[None, None, :]) & mask
    fields = fields.reshape(R, W * F)
    if counts is not None:
        fields = jnp.where(_count_mask(R, W * F, counts, period), fields, 0)
    return fields


# --------------------------- flash attention -------------------------------

def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None,
                  q_offset: int | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, H, Sq, D); k, v: (B, H, Sk, D). ``window`` = sliding-window size
    (None = full). ``q_offset`` = absolute position of the first query
    (default Sk - Sq: queries are the trailing positions). Returns
    (B, H, Sq, D) in q.dtype, computed in f32.
    """
    *_, Sq, D = q.shape
    Sk = k.shape[-2]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if q_offset is None:
        q_offset = Sk - Sq
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------- rmsnorm ----------------------------------------

def rmsnorm_reference(x: jax.Array, w: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    """RMSNorm oracle: x * rsqrt(mean(x^2) + eps) * w, f32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------- rwkv wkv ---------------------------------------

def wkv_reference(r, k, v, w, u, s0):
    """Sequential oracle for the RWKV-6 WKV recurrence.

    r/k/v/w: (B, S, H, K|V); u: (H, K); s0: (B, H, K, V).
    Returns (y: (B, S, H, V), sT)."""
    B, S, H, K = r.shape
    S_state = s0.astype(jnp.float32)
    ys = []
    for t in range(S):
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, t].astype(jnp.float32),
                        v[:, t].astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r[:, t].astype(jnp.float32),
                       S_state + u[None, :, :, None] * kv)
        ys.append(y)
        S_state = w[:, t].astype(jnp.float32)[..., None] * S_state + kv
    return jnp.stack(ys, axis=1), S_state
