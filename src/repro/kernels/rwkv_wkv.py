"""Pallas TPU kernel for the RWKV-6 WKV recurrence — VMEM-resident state.

The roofline analysis (EXPERIMENTS §Roofline) shows rwkv6 *training* is
memory-bound 200x over compute: the per-token ``lax.scan`` reads and writes
the (K, V) = (64, 64) f32 state from HBM at every one of seq*layers steps
(1.6 TB/chip/step at 4k x 24L).  The structural fix is a kernel that keeps
the state in VMEM for the whole sequence:

    grid = (B*H,); each program owns one (batch, head) pair;
    blocks: r/k/v/w: (1, S, K) streamed HBM->VMEM once; y written once;
    the (K, V) state lives in registers/VMEM across the fori_loop.

HBM traffic per layer drops from 2*S*K*V*4 (state) + streams to just the
5 linear streams — a ~60x reduction of the dominant term (analytic; the
CPU dry-run lowers the jnp path, see kernels/ops.py note).

Semantics (per head, per step; w, u per-channel on the K axis):

    y_t = r_t . (S + diag(u) k_t^T v_t)
    S  <- diag(w_t) S + k_t^T v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                *, seq_len: int):
    # NB: refs are indexed with slices (pl.dslice / [...]), never bare
    # Python ints — interpret-mode discharge chokes on raw int indices.
    S = s0_ref[...][0].astype(jnp.float32)         # (K, V)
    u = u_ref[...][0].astype(jnp.float32)          # (K,)

    def _step(ref, t):
        return pl.load(ref, (pl.dslice(0, 1), pl.dslice(t, 1),
                             slice(None)))[0, 0]

    def body(t, S):
        rt = _step(r_ref, t).astype(jnp.float32)   # (K,)
        kt = _step(k_ref, t).astype(jnp.float32)
        vt = _step(v_ref, t).astype(jnp.float32)   # (V,)
        wt = _step(w_ref, t).astype(jnp.float32)   # (K,)
        kv = kt[:, None] * vt[None, :]             # (K, V) outer
        y = jnp.sum(rt[:, None] * (S + u[:, None] * kv), axis=0)
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y.astype(y_ref.dtype)[None, None])
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, seq_len, body, S)
    sT_ref[...] = S.astype(sT_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_forward(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, *, interpret: bool = True):
    """r/k/v: (B, S, H, K|V); w: (B, S, H, K) decay in (0,1); u: (H, K);
    s0: (B, H, K, V).  Returns (y: (B, S, H, V), sT: (B, H, K, V))."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    rr = r.transpose(0, 2, 1, 3).reshape(B * H, S, K)
    kk = k.transpose(0, 2, 1, 3).reshape(B * H, S, K)
    vv = v.transpose(0, 2, 1, 3).reshape(B * H, S, V)
    ww = w.transpose(0, 2, 1, 3).reshape(B * H, S, K)
    uu = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    ss = s0.reshape(B * H, K, V)

    seq_spec = pl.BlockSpec((1, S, K), lambda i: (i, 0, 0))
    val_spec = pl.BlockSpec((1, S, V), lambda i: (i, 0, 0))
    y, sT = pl.pallas_call(
        functools.partial(_wkv_kernel, seq_len=S),
        grid=(B * H,),
        in_specs=[seq_spec, seq_spec, val_spec, seq_spec,
                  pl.BlockSpec((1, K), lambda i: (i, 0)),
                  pl.BlockSpec((1, K, V), lambda i: (i, 0, 0))],
        out_specs=(val_spec, pl.BlockSpec((1, K, V), lambda i: (i, 0, 0))),
        out_shape=(jax.ShapeDtypeStruct((B * H, S, V), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, K, V), jnp.float32)),
        interpret=interpret,
    )(rr, kk, vv, ww, uu, ss)
    return (y.reshape(B, H, S, V).transpose(0, 2, 1, 3),
            sT.reshape(B, H, K, V))
