"""Public kernel ops — thin, shape-normalizing wrappers over the dispatch
registry (see :mod:`repro.kernels.dispatch`).

Every op takes ``impl``: None (the op's registered default policy), "ref"
(pure jnp), "pallas" (backend-appropriate kernel variant), or an explicit
"pallas-interpret" / "pallas-tpu".  The EF-compression ops default to the
fused Pallas path everywhere; the model-side ops default to the kernel only
on TPU (the CPU dry-run lowers the jnp oracle).  In tests both paths are
compared across shape/dtype sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import dispatch, ref, wire_pack
from .ef_topk import (block_stats, ef_apply, ef_block_stats as
                      _ef_block_stats_kernel, ef_stats_telemetry as
                      _ef_stats_telemetry_kernel, threshold_split as
                      _threshold_split_kernel)
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .rwkv_wkv import wkv_forward
from .wire_pack import pack_words as _pack_words_kernel, \
    unpack_words as _unpack_words_kernel

# --------------------------------------------------------------------------
# registry — the single place that binds op names to implementations
# --------------------------------------------------------------------------

dispatch.register_op(
    "ef_update",
    ref=ref.ef_block_update,
    pallas_interpret=functools.partial(ef_apply, interpret=True),
    pallas_tpu=functools.partial(ef_apply, interpret=False),
    default="pallas")

dispatch.register_op(
    "block_stats",
    ref=lambda x2, k_b: ref.block_abs_topk_threshold(
        x2.reshape(-1), k_b, x2.shape[1]).reshape(-1, 1),
    pallas_interpret=functools.partial(block_stats, interpret=True),
    pallas_tpu=functools.partial(block_stats, interpret=False),
    default="pallas")

dispatch.register_op(
    "ef_stats",
    ref=ref.ef_block_stats,
    pallas_interpret=functools.partial(_ef_block_stats_kernel,
                                       interpret=True),
    pallas_tpu=functools.partial(_ef_block_stats_kernel, interpret=False),
    default="pallas")

dispatch.register_op(
    "ef_stats_telemetry",
    ref=ref.ef_block_stats_telemetry,
    pallas_interpret=functools.partial(_ef_stats_telemetry_kernel,
                                       interpret=True),
    pallas_tpu=functools.partial(_ef_stats_telemetry_kernel,
                                 interpret=False),
    default="pallas")

dispatch.register_op(
    "threshold_split",
    ref=ref.threshold_split,
    pallas_interpret=functools.partial(_threshold_split_kernel,
                                       interpret=True),
    pallas_tpu=functools.partial(_threshold_split_kernel, interpret=False),
    default="pallas")

# pack/unpack run per leaf per step with a rows/ROWS-sized grid, so the
# interpret-mode cost is NOT one tile evaluation like the EF ops — policy
# "backend" keeps CPU runs on the vectorized jnp ref and TPUs on the kernel
# (parity is pinned across impls in tests/test_wire_format.py).
dispatch.register_op(
    "wire_pack",
    ref=ref.pack_fields,
    pallas_interpret=functools.partial(_pack_words_kernel, interpret=True),
    pallas_tpu=functools.partial(_pack_words_kernel, interpret=False),
    default="backend")

dispatch.register_op(
    "wire_unpack",
    ref=ref.unpack_fields,
    pallas_interpret=functools.partial(_unpack_words_kernel, interpret=True),
    pallas_tpu=functools.partial(_unpack_words_kernel, interpret=False),
    default="backend")

dispatch.register_op(
    "attention",
    ref=ref.mha_reference,
    pallas_interpret=functools.partial(flash_attention, interpret=True),
    pallas_tpu=functools.partial(flash_attention, interpret=False),
    default="backend")

dispatch.register_op(
    "rmsnorm",
    ref=ref.rmsnorm_reference,
    pallas_interpret=functools.partial(rmsnorm, interpret=True),
    pallas_tpu=functools.partial(rmsnorm, interpret=False),
    default="backend")

dispatch.register_op(
    "wkv",
    ref=ref.wkv_reference,
    pallas_interpret=functools.partial(wkv_forward, interpret=True),
    pallas_tpu=functools.partial(wkv_forward, interpret=False),
    default="backend")


# --------------------------------------------------------------------------
# block layout helpers
# --------------------------------------------------------------------------

def _to_blocks(x: jax.Array, block: int):
    """(L?, d) -> (L*nb, block) zero-padded block rows; blocks never span
    the leading (layer) axis.  1D inputs are a single layer."""
    shape = x.shape
    L = math.prod(shape[:-1]) if x.ndim >= 2 else 1
    d = shape[-1] if x.ndim >= 1 else 1
    flat = x.reshape(L, d)
    pad = (-d) % block
    padded = jnp.pad(flat, ((0, 0), (0, pad)))
    nb = (d + pad) // block
    return padded.reshape(L * nb, block), (shape, L, d)


def _from_blocks(blocks: jax.Array, meta) -> jax.Array:
    shape, L, d = meta
    return blocks.reshape(L, -1)[:, :d].reshape(shape)


# --------------------------------------------------------------------------
# EF-compression ops (the paper's per-step hot loop)
# --------------------------------------------------------------------------

def ef_threshold_update(m, g, eta, tau, *, impl: str | None = None):
    """Fused EF accumulate+sparsify against ONE scalar threshold.

    m, g: any shape; returns (sent, m') in m.dtype with the exact identity
    ``sent + m' == m + eta*g``.
    """
    m2, meta = _to_blocks(m.reshape(-1), 1024)
    g2, _ = _to_blocks(g.reshape(-1), 1024)
    tau_r = jnp.broadcast_to(jnp.asarray(tau, jnp.float32),
                             (m2.shape[0],)).reshape(-1, 1)
    sent, mnew = dispatch.call("ef_update", m2, g2,
                               jnp.asarray(eta, jnp.float32), tau_r,
                               impl=impl)
    meta = (m.shape, 1, m.size)
    return _from_blocks(sent, meta), _from_blocks(mnew, meta)


def block_topk_threshold(x, k_b: int, block: int = 1024, *,
                         impl: str | None = None):
    """Per-block k_b-th |.| statistic; (n_blocks,) f32."""
    x2, _ = _to_blocks(x.reshape(-1), block)
    return dispatch.call("block_stats", x2, k_b, impl=impl).reshape(-1)


def ef_block_stats(m, g, eta, k_b: int, block: int = 1024, *,
                   impl: str | None = None):
    """Fused pass 1: per-block k_b-th largest |m + eta*g|; (L*nb, 1) f32.

    m, g: (d,) or (L, d); blocks never span layers.
    """
    m2, _ = _to_blocks(m, block)
    g2, _ = _to_blocks(g, block)
    return dispatch.call("ef_stats", m2, g2, jnp.asarray(eta, jnp.float32),
                         k_b, impl=impl)


def fused_ef_compress(m, g, eta, gamma: float, block: int = 1024, *,
                      telemetry: bool = False, impl: str | None = None):
    """The full two-pass fused EF compression (DESIGN.md §3).

    Per 1024-wide block b of ``acc = m + eta*g`` (blocks never span the
    leading layer axis): tau_b = k_b-th largest |acc_b| with
    k_b = round(gamma*block); sent keeps entries with |acc| >= tau_b and
    m' carries the rest.  Returns (sent, m', tau) where sent/m' have m's
    shape and ``sent + m' == m + eta*g`` holds exactly; tau is (L*nb, 1).

    ``telemetry`` (DESIGN.md §10): pass 1 additionally reduces the dense
    telemetry moments [sum g^2, sum acc^2] per block row on the same
    streamed operands — no extra HBM sweep — and a fourth element
    ``moments`` ((L*nb, 2) f32) is returned.
    """
    k_b = max(1, int(round(gamma * block)))
    m2, meta = _to_blocks(m, block)
    g2, _ = _to_blocks(g, block)
    eta = jnp.asarray(eta, jnp.float32)
    if telemetry:
        tau, moments = dispatch.call("ef_stats_telemetry", m2, g2, eta, k_b,
                                     impl=impl)
    else:
        tau = dispatch.call("ef_stats", m2, g2, eta, k_b, impl=impl)
    sent, mnew = dispatch.call("ef_update", m2, g2, eta, tau, impl=impl)
    if telemetry:
        return _from_blocks(sent, meta), _from_blocks(mnew, meta), tau, \
            moments
    return _from_blocks(sent, meta), _from_blocks(mnew, meta), tau


def fused_ef_compress_batched(ms, gs, eta, gamma: float, block: int = 1024,
                              *, telemetry: bool = False,
                              impl: str | None = None):
    """Batched :func:`fused_ef_compress` over a LIST of (L_i, d_i) leaf
    pairs — ONE pass-1 launch and ONE pass-2 launch for the whole list
    (bucket-shaped launches, DESIGN.md §11).

    Every op in the two-pass scheme is block-row-local (blocks never span
    rows, thresholds/moments are per block row, the EF update is
    elementwise against its row's tau), so concatenating all leaves' block
    rows changes the launch geometry and nothing else: the returned list
    of per-leaf ``(sent, m', tau[, moments])`` tuples is bit-identical to
    per-leaf :func:`fused_ef_compress` calls.
    """
    k_b = max(1, int(round(gamma * block)))
    blocks_m, blocks_g, metas, offs = [], [], [], [0]
    for m, g in zip(ms, gs):
        m2, meta = _to_blocks(m, block)
        g2, _ = _to_blocks(g, block)
        blocks_m.append(m2)
        blocks_g.append(g2)
        metas.append(meta)
        offs.append(offs[-1] + m2.shape[0])
    cat_m = jnp.concatenate(blocks_m, axis=0)
    cat_g = jnp.concatenate(blocks_g, axis=0)
    eta = jnp.asarray(eta, jnp.float32)
    if telemetry:
        tau, moments = dispatch.call("ef_stats_telemetry", cat_m, cat_g,
                                     eta, k_b, impl=impl)
    else:
        tau = dispatch.call("ef_stats", cat_m, cat_g, eta, k_b, impl=impl)
    sent, mnew = dispatch.call("ef_update", cat_m, cat_g, eta, tau,
                               impl=impl)
    out = []
    for i, meta in enumerate(metas):
        rows = slice(offs[i], offs[i + 1])
        leaf = (_from_blocks(sent[rows], meta),
                _from_blocks(mnew[rows], meta), tau[rows])
        if telemetry:
            leaf = leaf + (moments[rows],)
        out.append(leaf)
    return out


def threshold_split_blocks(x, tau, block: int = 1024, *,
                           impl: str | None = None):
    """Dense split of x into (sent, residual) against per-block tau.

    x: (d,) or (L, d); tau: (L*nb, 1) from :func:`ef_block_stats` /
    :func:`block_topk_threshold`.  ``sent + residual == x`` exactly.
    """
    x2, meta = _to_blocks(x, block)
    sent, res = dispatch.call("threshold_split", x2, tau, impl=impl)
    return _from_blocks(sent, meta), _from_blocks(res, meta)


# --------------------------------------------------------------------------
# wire pack/unpack (the packed payload codec's data-parallel core)
# --------------------------------------------------------------------------

def pack_fields(fields, bits: int, *, counts=None, period: int = 0,
                impl: str | None = None):
    """Pack (R, n) uint32 bit-fields into (R, ceil(n*bits/32)) uint32 words.

    ``bits`` in {4, 8, 16, 32}; n is zero-padded up to a whole word here, so
    callers slice by field count on unpack.  Layout per kernels/ref.py:
    little-endian fields within each word.

    ``counts`` + static ``period`` (ragged payloads, DESIGN.md §9): per-row
    valid counts — field j is zeroed when ``j % period >= counts[row]``,
    inside the ref/Pallas implementations' streaming pass.
    """
    if counts is not None and period <= 0:
        raise ValueError("ragged pack needs a positive period")
    if bits >= 32:
        out = fields.astype(jnp.uint32)
        if counts is not None:
            out = jnp.where(ref._count_mask(*out.shape, counts, period),
                            out, 0)
        return out
    F = 32 // bits
    R, n = fields.shape
    W = -(-n // F)
    pad = W * F - n
    if pad:
        fields = jnp.pad(fields, ((0, 0), (0, pad)))
    return dispatch.call("wire_pack", fields, bits, counts, period,
                         impl=impl)


def unpack_fields(words, n: int, bits: int, *, counts=None, period: int = 0,
                  impl: str | None = None):
    """Inverse of :func:`pack_fields`: (R, W) words -> first ``n`` fields,
    masked beyond the per-row valid ``counts`` when given."""
    if counts is not None and period <= 0:
        raise ValueError("ragged unpack needs a positive period")
    if bits >= 32:
        out = words.astype(jnp.uint32)
        if counts is not None:
            out = jnp.where(ref._count_mask(*out.shape, counts, period),
                            out, 0)
        return out
    out = dispatch.call("wire_unpack", words, bits, counts, period,
                        impl=impl)
    return out[:, :n]


def pack_fields_stream(fields, bits: int, *, impl: str | None = None):
    """Pack a FLAT word-aligned field stream — (N,) uint32 with N a
    multiple of 32//bits — into (N*bits/32,) uint32 words in ONE
    bucket-shaped launch (DESIGN.md §11).

    Packing is word-local, so this equals row-by-row :func:`pack_fields`
    on any row structure whose sections are whole words: the concatenated
    (already count-masked and zero-padded-to-word) field sections of every
    payload row of every leaf in a bucket go through a single kernel
    launch, and each leaf slices its exact words back out.
    """
    fields = fields.astype(jnp.uint32)
    if bits >= 32:
        return fields
    F = 32 // bits
    (n,) = fields.shape
    if n % F:
        raise ValueError(f"stream of {n} {bits}-bit fields is not "
                         f"word-aligned (need a multiple of {F})")
    W = n // F
    R, C = wire_pack.stream_shape(W)
    pad = R * C - W
    if pad:
        fields = jnp.concatenate(
            [fields, jnp.zeros((pad * F,), jnp.uint32)])
    words = dispatch.call("wire_pack", fields.reshape(R, C * F), bits,
                          None, 0, impl=impl)
    return words.reshape(-1)[:W]


def unpack_fields_stream(words, bits: int, *, impl: str | None = None):
    """Inverse of :func:`pack_fields_stream`: (W,) uint32 words -> the
    (W*32/bits,) uint32 field stream, one bucket-shaped launch."""
    words = words.astype(jnp.uint32)
    if bits >= 32:
        return words
    F = 32 // bits
    (W,) = words.shape
    R, C = wire_pack.stream_shape(W)
    pad = R * C - W
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), jnp.uint32)])
    fields = dispatch.call("wire_unpack", words.reshape(R, C), bits,
                           None, 0, impl=impl)
    return fields.reshape(-1)[:W * F]


# --------------------------------------------------------------------------
# model-side ops
# --------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, q_offset: int | None = None,
              impl: str | None = None):
    """MHA (B,H,S,D)x(B,H,Sk,D). GQA: broadcast kv heads before calling."""
    if q_offset is not None or dispatch.resolve("attention", impl) == "ref":
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return dispatch.call("attention", q, k, v, causal=causal, window=window,
                         scale=scale, impl=impl)


def rms_norm(x, w, *, eps: float = 1e-6, impl: str | None = None):
    return dispatch.call("rmsnorm", x, w, eps=eps, impl=impl)


def wkv(r, k, v, w, u, s0, *, impl: str | None = None):
    """RWKV-6 WKV recurrence (see rwkv_wkv.py). Returns (y, final_state)."""
    return dispatch.call("wkv", r, k, v, w, u, s0, impl=impl)
