"""Jit'd public wrappers around the Pallas kernels.

Every op takes ``impl`` ("pallas" | "ref"): the dry-run/CPU path uses "ref"
(pure jnp — the CPU backend cannot lower TPU custom calls), real-TPU configs
flip to "pallas".  In tests both paths are compared (pallas in interpret
mode) across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .ef_topk import ef_apply, block_stats
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm

_INTERPRET = True  # CPU container: interpret Pallas; on TPU set False.


# --------------------------------------------------------------------------
def ef_threshold_update(m, g, eta, tau, *, impl: str = "ref"):
    """Fused EF accumulate+sparsify. m, g: any shape; returns (sent, m')."""
    if impl == "ref":
        return ref.ef_threshold_update(m, g, jnp.asarray(eta),
                                       jnp.asarray(tau))
    shape = m.shape
    flat = m.reshape(-1)
    C = 1024
    pad = (-flat.size) % C
    m2 = jnp.pad(m.reshape(-1), (0, pad)).reshape(-1, C)
    g2 = jnp.pad(g.reshape(-1), (0, pad)).reshape(-1, C)
    sent, mnew = ef_apply(m2, g2, jnp.asarray(eta, jnp.float32),
                          jnp.asarray(tau, jnp.float32),
                          interpret=_INTERPRET)
    d = flat.size
    return (sent.reshape(-1)[:d].reshape(shape),
            mnew.reshape(-1)[:d].reshape(shape))


def block_topk_threshold(x, k_b: int, block: int = 1024, *,
                         impl: str = "ref"):
    """Per-block k_b-th |.| statistic; (n_blocks,) f32."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
    if impl == "ref":
        return ref.block_abs_topk_threshold(blocks.reshape(-1), k_b, block)
    return block_stats(blocks, k_b, interpret=_INTERPRET).reshape(-1)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale: float | None = None, q_offset: int | None = None,
              impl: str = "ref"):
    """MHA (B,H,S,D)x(B,H,Sk,D). GQA: broadcast kv heads before calling."""
    if impl == "ref" or q_offset is not None:
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return flash_attention(q, k, v, causal=causal, window=window,
                           scale=scale, interpret=_INTERPRET)


def rms_norm(x, w, *, eps: float = 1e-6, impl: str = "ref"):
    if impl == "ref":
        return ref.rmsnorm_reference(x, w, eps)
    return rmsnorm(x, w, eps=eps, interpret=_INTERPRET)


def wkv(r, k, v, w, u, s0, *, impl: str = "ref"):
    """RWKV-6 WKV recurrence (see rwkv_wkv.py). Returns (y, final_state)."""
    if impl == "ref":
        return ref.wkv_reference(r, k, v, w, u, s0)
    from .rwkv_wkv import wkv_forward
    return wkv_forward(r, k, v, w, u, s0, interpret=_INTERPRET)
