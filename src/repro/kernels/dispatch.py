"""Kernel dispatch: one place decides which implementation runs per op.

Every public op in :mod:`repro.kernels.ops` is registered here with up to
three implementations:

* ``ref``              — pure jnp oracle (always present; CPU dry-run path)
* ``pallas-interpret`` — the Pallas kernel, interpret mode (CPU containers;
                         numerically identical to the TPU lowering)
* ``pallas-tpu``       — the Pallas kernel, compiled (real TPU)

Selection order for a call: explicit ``impl=`` argument > process-wide
override (:func:`set_default` / :func:`using`) > the op's registered default
policy, resolved against the active backend:

* policy ``"pallas"``  — always take the kernel path (interpret off-TPU);
  used for the EF-compression ops — including the telemetry-fused pass-1
  ``ef_stats_telemetry`` (DESIGN.md §10) — which are the paper's hot loop
  and whose interpret-mode cost is one vectorized tile evaluation per
  grid step;
* policy ``"backend"`` — kernel on TPU, ``ref`` elsewhere; used for the
  model-side ops (attention, rmsnorm, wkv) where the jnp oracle is what the
  CPU dry-run is expected to lower, and for the wire pack/unpack codec.

The bucketed transport (DESIGN.md §11) reuses the registered ``wire_pack``
/ ``wire_unpack`` and EF ops at bucket-shaped geometries — whole-pytree
field streams and concatenated block rows instead of per-leaf calls — so
one registry entry serves both the per-leaf reference schedule and the
coalesced launches; no bucket-specific kernels exist to drift.

``impl="pallas"`` resolves to the backend-appropriate kernel variant, so
callers (configs' ``use_pallas``) never hard-code interpret mode.  This
replaces the scattered module-level ``_INTERPRET`` flags (DESIGN.md §7).
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax

IMPLS = ("ref", "pallas-interpret", "pallas-tpu")

_REGISTRY: dict[str, dict[str, Callable]] = {}
_POLICY: dict[str, str] = {}
_OVERRIDE: str | None = None


def register_op(name: str, *, ref: Callable,
                pallas_interpret: Callable | None = None,
                pallas_tpu: Callable | None = None,
                default: str = "backend") -> None:
    """Register an op's implementations. ``default``: "backend" | "pallas"."""
    if default not in ("backend", "pallas"):
        raise ValueError(f"bad default policy {default!r}")
    _REGISTRY[name] = {"ref": ref,
                       "pallas-interpret": pallas_interpret,
                       "pallas-tpu": pallas_tpu}
    _POLICY[name] = default


def registered() -> dict[str, tuple[str, ...]]:
    """op -> available impl names (introspection for tests/benchmarks)."""
    return {op: tuple(k for k, v in impls.items() if v is not None)
            for op, impls in _REGISTRY.items()}


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def resolve(name: str, impl: str | None = None) -> str:
    """Resolve a requested impl ("ref"|"pallas"|full name|None) for an op."""
    impl = impl or _OVERRIDE or _POLICY.get(name, "backend")
    if impl == "backend":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "pallas":
        impl = "pallas-tpu" if _on_tpu() else "pallas-interpret"
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r} (want one of {IMPLS})")
    return impl


def call(name: str, *args, impl: str | None = None, **kwargs):
    """Dispatch ``name`` to the resolved implementation (ref fallback)."""
    table = _REGISTRY[name]
    fn = table.get(resolve(name, impl)) or table["ref"]
    return fn(*args, **kwargs)


def set_default(impl: str | None) -> None:
    """Force every op to ``impl`` process-wide (None restores per-op policy)."""
    global _OVERRIDE
    if impl is not None and impl not in IMPLS + ("pallas",):
        raise ValueError(f"unknown impl {impl!r}")
    _OVERRIDE = impl


@contextlib.contextmanager
def using(impl: str | None):
    """Scoped :func:`set_default` — ``with dispatch.using("ref"): ...``"""
    global _OVERRIDE
    prev = _OVERRIDE
    set_default(impl)
    try:
        yield
    finally:
        _OVERRIDE = prev
