"""Pallas TPU kernels for fused error-feedback sparsification.

The compression hot spot of CSGD-ASSS is, per step and per layer shard:

    acc  = m + eta*g          (read m, g : 2 streams)
    tau  = k-th |.| statistic (selection)
    sent = acc * (|acc|>=tau) (write)
    m'   = acc - sent         (write)

A naive jnp composition reads ``acc`` three times from HBM and materializes
intermediates; the fused kernel streams each element exactly once:
2 reads + 2 writes, perfectly memory-bound at 4 bytes/elem/stream.

Three kernels implement the two-pass block-local scheme (DESIGN.md §3):

* pass 1 ``_block_stats_kernel``  — per-block k_b-th largest |acc|,
  computing ``acc = m + eta*g`` on the fly (2 reads, tiny write);
* pass 2 ``_ef_apply_kernel``     — the fused elementwise update above,
  thresholding each 1024-wide block against ITS OWN tau from pass 1;
* ``_threshold_split_kernel``     — single-input variant (x -> sent,
  residual) for the dense ``Compressor.compress_dense`` path.

Blocks are (8, 128)-lane aligned for the VPU; tensors are processed as
(rows, 1024) tiles resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry: 8 sublanes x 128 lanes = the float32 VREG footprint.  A
# (256, 1024) f32 tile is 1 MiB per stream; the pass-2 kernel touches 4
# streams (m, g, sent, m') = 4 MiB of VMEM, a quarter of a core's ~16 MiB —
# leaving headroom for double buffering of the HBM->VMEM pipeline.
ROWS = 256
COLS = 1024


# Interpret-mode row cap: the `_kth_largest` fori_loop re-touches its
# whole tile every iteration, so off-TPU the tile should sit in L2 —
# (64, 512) f32 = 128 KiB was the measured optimum on the bucketed
# transport's concatenated row counts (~30% faster than 256-row tiles).
# Compiled TPU launches keep ROWS (a VMEM budget, not a cache guess).
INTERPRET_ROWS = 64


def _tile_rows(R: int, interpret: bool) -> int:
    """Row-tile height for an R-row launch: split the grid EVENLY instead
    of ``min(cap, R)`` so the last tile carries < n_tiles padding rows.
    A naive cap wastes up to cap-1 padded rows — on the bucketed
    transport's concatenated block rows (DESIGN.md §11) that was measured
    as ~60% dead work for row counts just past a tile boundary.  Every op
    here is row-local, so the tiling is numerically invisible."""
    cap = INTERPRET_ROWS if interpret else ROWS
    n_tiles = -(-R // cap)
    return -(-R // n_tiles)


def _kth_largest(mag: jax.Array, k_b: int) -> jax.Array:
    """k_b-th largest value per row of ``mag`` (rows, C) via iterative
    max-extraction — k_b is small (= gamma*block <= ~32), so this maps to
    VPU max-reductions rather than a full sort; the MXU stays free.

    Exactly ONE element is knocked out per iteration (ties broken by
    lowest lane index), so duplicated magnitudes count like lax.top_k's
    and the result matches the ref.py oracle bit-for-bit.
    """
    rows, C = mag.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, C), 1)

    def body(i, carry):
        mag_c, cur = carry
        cur = jnp.max(mag_c, axis=-1, keepdims=True)      # (rows, 1)
        hit = jnp.min(jnp.where(mag_c >= cur, lane, C),
                      axis=-1, keepdims=True)             # first argmax
        mag_c = jnp.where(lane == hit, -jnp.inf, mag_c)
        return (mag_c, cur)

    _, kth = jax.lax.fori_loop(0, k_b, body,
                               (mag, jnp.zeros((mag.shape[0], 1),
                                               jnp.float32)))
    return kth


# ---------------------------------------------------------------------------
# pass 2: fused EF accumulate + block-threshold sparsify
# ---------------------------------------------------------------------------

def _ef_apply_kernel(m_ref, g_ref, eta_ref, tau_ref, sent_ref, mnew_ref):
    """Fused: acc = m + eta*g; sent = acc*(|acc|>=tau_row); m' = acc - sent.

    tau_ref: (rows, 1) — one threshold per 1024-wide block row, broadcast
    across the lanes of its row.
    """
    eta = eta_ref[0]
    tau = tau_ref[...]                                   # (rows, 1)
    acc = m_ref[...].astype(jnp.float32) + eta * g_ref[...].astype(jnp.float32)
    keep = jnp.abs(acc) >= tau
    sent = jnp.where(keep, acc, 0.0)
    sent_ref[...] = sent.astype(sent_ref.dtype)
    mnew_ref[...] = (acc - sent).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_apply(m: jax.Array, g: jax.Array, eta: jax.Array, tau: jax.Array,
             *, interpret: bool = True):
    """Apply the fused EF update to a 2D (R, C) block-padded tensor pair.

    m, g: (R, C) with C % 128 == 0. eta: scalar (shape (1,)); tau: (R, 1)
    per-block-row thresholds.  Returns (sent, m_new) with m.dtype.
    """
    R, C = m.shape
    rows = _tile_rows(R, interpret)
    grid = (pl.cdiv(R, rows), pl.cdiv(C, COLS))
    spec = pl.BlockSpec((rows, min(COLS, C)), lambda i, j: (i, j))
    scal = pl.BlockSpec((1,), lambda i, j: (0,))  # eta broadcast to all tiles
    tspec = pl.BlockSpec((rows, 1), lambda i, j: (i, 0))
    out_shape = (jax.ShapeDtypeStruct(m.shape, m.dtype),
                 jax.ShapeDtypeStruct(m.shape, m.dtype))
    return pl.pallas_call(
        _ef_apply_kernel,
        grid=grid,
        in_specs=[spec, spec, scal, tspec],
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(m, g, eta.reshape(1), tau.reshape(R, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# pass 1: per-block selection statistics
# ---------------------------------------------------------------------------

def _block_stats_kernel(x_ref, out_ref, *, k_b: int):
    """Per (C-wide) block: k_b-th largest |x| within each row-block.

    x_ref: (rows, C) tile; out_ref: (rows, 1) thresholds per row-block.
    """
    mag = jnp.abs(x_ref[...].astype(jnp.float32))
    out_ref[...] = _kth_largest(mag, k_b)


@functools.partial(jax.jit, static_argnames=("k_b", "interpret"))
def block_stats(x: jax.Array, k_b: int, *, interpret: bool = True):
    """Per-block k_b-th largest |x|. x: (nb, C) -> (nb, 1) f32."""
    nb, C = x.shape
    rows = _tile_rows(nb, interpret)
    grid = (pl.cdiv(nb, rows),)
    return pl.pallas_call(
        functools.partial(_block_stats_kernel, k_b=k_b),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=interpret,
    )(x)


def _ef_block_stats_kernel(m_ref, g_ref, eta_ref, out_ref, *, k_b: int):
    """Fused pass 1: per-block k_b-th largest |m + eta*g| — the accumulator
    is formed on the fly so it is never written back to HBM."""
    eta = eta_ref[0]
    acc = m_ref[...].astype(jnp.float32) + eta * g_ref[...].astype(jnp.float32)
    out_ref[...] = _kth_largest(jnp.abs(acc), k_b)


def _ef_stats_telemetry_kernel(m_ref, g_ref, eta_ref, tau_ref, mom_ref, *,
                               k_b: int):
    """Fused pass 1 + telemetry moments (DESIGN.md §10): the same streaming
    pass that ranks |acc| also reduces the two dense telemetry moments —
    ``sum g^2`` and ``sum acc^2`` per block row — while the operands sit in
    VMEM, so the compression-telemetry signal costs no extra HBM sweep.

    mom_ref: (rows, 2) per block row: [sum g^2, sum acc^2].
    """
    eta = eta_ref[0]
    gf = g_ref[...].astype(jnp.float32)
    acc = m_ref[...].astype(jnp.float32) + eta * gf
    tau_ref[...] = _kth_largest(jnp.abs(acc), k_b)
    mom_ref[...] = jnp.concatenate(
        [jnp.sum(gf * gf, axis=-1, keepdims=True),
         jnp.sum(acc * acc, axis=-1, keepdims=True)], axis=-1)


@functools.partial(jax.jit, static_argnames=("k_b", "interpret"))
def ef_block_stats(m: jax.Array, g: jax.Array, eta: jax.Array, k_b: int,
                   *, interpret: bool = True):
    """Per-block k_b-th largest |m + eta*g|. m, g: (nb, C) -> (nb, 1) f32."""
    nb, C = m.shape
    rows = _tile_rows(nb, interpret)
    grid = (pl.cdiv(nb, rows),)
    spec = pl.BlockSpec((rows, C), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ef_block_stats_kernel, k_b=k_b),
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=interpret,
    )(m, g, eta.reshape(1))


@functools.partial(jax.jit, static_argnames=("k_b", "interpret"))
def ef_stats_telemetry(m: jax.Array, g: jax.Array, eta: jax.Array, k_b: int,
                       *, interpret: bool = True):
    """Fused pass 1 with telemetry moments.  m, g: (nb, C).

    Returns (tau: (nb, 1) f32, moments: (nb, 2) f32 = [sum g^2, sum acc^2]
    per block row).
    """
    nb, C = m.shape
    rows = _tile_rows(nb, interpret)
    grid = (pl.cdiv(nb, rows),)
    spec = pl.BlockSpec((rows, C), lambda i: (i, 0))
    out_shape = (jax.ShapeDtypeStruct((nb, 1), jnp.float32),
                 jax.ShapeDtypeStruct((nb, 2), jnp.float32))
    return pl.pallas_call(
        functools.partial(_ef_stats_telemetry_kernel, k_b=k_b),
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(pl.BlockSpec((rows, 1), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 2), lambda i: (i, 0))),
        out_shape=out_shape,
        interpret=interpret,
    )(m, g, eta.reshape(1))


# ---------------------------------------------------------------------------
# dense split (compress_dense path): x -> (sent, residual)
# ---------------------------------------------------------------------------

def _threshold_split_kernel(x_ref, tau_ref, sent_ref, res_ref):
    x = x_ref[...].astype(jnp.float32)
    tau = tau_ref[...]                                   # (rows, 1)
    sent = jnp.where(jnp.abs(x) >= tau, x, 0.0)
    sent_ref[...] = sent.astype(sent_ref.dtype)
    res_ref[...] = (x - sent).astype(res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def threshold_split(x: jax.Array, tau: jax.Array, *, interpret: bool = True):
    """Split (R, C) blocks into kept values and residual: 1 read, 2 writes.

    tau: (R, 1) per-block-row thresholds. Returns (sent, residual), x.dtype.
    """
    R, C = x.shape
    rows = _tile_rows(R, interpret)
    grid = (pl.cdiv(R, rows), pl.cdiv(C, COLS))
    spec = pl.BlockSpec((rows, min(COLS, C)), lambda i, j: (i, j))
    tspec = pl.BlockSpec((rows, 1), lambda i, j: (i, 0))
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(x.shape, x.dtype))
    return pl.pallas_call(
        _threshold_split_kernel,
        grid=grid,
        in_specs=[spec, tspec],
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(x, tau.reshape(R, 1).astype(jnp.float32))
