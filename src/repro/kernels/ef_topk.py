"""Pallas TPU kernels for fused error-feedback sparsification.

The compression hot spot of CSGD-ASSS is, per step and per layer shard:

    acc  = m + eta*g          (read m, g : 2 streams)
    tau  = k-th |.| statistic (selection)
    sent = acc * (|acc|>=tau) (write)
    m'   = acc - sent         (write)

A naive jnp composition reads ``acc`` three times from HBM and materializes
intermediates; the fused kernel streams each element exactly once:
2 reads + 2 writes, perfectly memory-bound at 4 bytes/elem/stream.

Two kernels implement the two-pass block-local scheme (DESIGN.md §3):

* pass 1 ``block_stats_kernel``   — per-block sorted |.| candidates
  (k_b-th largest per block) used to pick a per-tensor threshold;
* pass 2 ``ef_apply_kernel``      — the fused elementwise update above.

Blocks are (8, 128)-lane aligned for the VPU; tensors are processed as
(rows, 1024) tiles resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry: 8 sublanes x 128 lanes = the float32 VREG footprint; a
# (256, 1024) f32 tile = 1 MiB per stream, 4 streams -> 4 MiB of VMEM (half
# of a v5e core's 8... v5e has 128MiB VMEM/core; this leaves headroom for
# double buffering).
ROWS = 256
COLS = 1024


def _ef_apply_kernel(m_ref, g_ref, eta_ref, tau_ref, sent_ref, mnew_ref):
    """Fused: acc = m + eta*g; sent = acc*(|acc|>=tau); m' = acc - sent."""
    eta = eta_ref[0]
    tau = tau_ref[0]
    acc = m_ref[...].astype(jnp.float32) + eta * g_ref[...].astype(jnp.float32)
    keep = jnp.abs(acc) >= tau
    sent = jnp.where(keep, acc, 0.0)
    sent_ref[...] = sent.astype(sent_ref.dtype)
    mnew_ref[...] = (acc - sent).astype(mnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_apply(m: jax.Array, g: jax.Array, eta: jax.Array, tau: jax.Array,
             *, interpret: bool = True):
    """Apply the fused EF update to a 2D (N, COLS)-padded tensor pair.

    m, g: (R, C) with C % 128 == 0. eta, tau: scalars (shape (1,)).
    Returns (sent, m_new) with m.dtype.
    """
    R, C = m.shape
    rows = min(ROWS, R)
    grid = (pl.cdiv(R, rows), pl.cdiv(C, COLS))
    blk = lambda i, j: (i, j)
    spec = pl.BlockSpec((rows, min(COLS, C)), blk)
    scal = pl.BlockSpec((1,), lambda i, j: (0,))  # scalar broadcast to all tiles
    out_shape = (jax.ShapeDtypeStruct(m.shape, m.dtype),
                 jax.ShapeDtypeStruct(m.shape, m.dtype))
    return pl.pallas_call(
        _ef_apply_kernel,
        grid=grid,
        in_specs=[spec, spec, scal, scal],
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(m, g, eta.reshape(1), tau.reshape(1))


def _block_stats_kernel(x_ref, out_ref, *, k_b: int):
    """Per (COLS-wide) block: k_b-th largest |x| within each row-block.

    x_ref: (rows, COLS) tile; out_ref: (rows, 1) thresholds per row-block.
    Selection is done with an iterative max-extraction loop (k_b is small,
    = gamma*block <= ~32), which maps to VPU max-reductions rather than a
    full sort — the MXU stays free.
    """
    mag = jnp.abs(x_ref[...].astype(jnp.float32))

    def body(i, carry):
        mag_c, cur = carry
        cur = jnp.max(mag_c, axis=-1, keepdims=True)      # (rows, 1)
        mag_c = jnp.where(mag_c >= cur, -jnp.inf, mag_c)  # knock out the max
        return (mag_c, cur)

    _, kth = jax.lax.fori_loop(0, k_b, body,
                               (mag, jnp.zeros((mag.shape[0], 1), jnp.float32)))
    out_ref[...] = kth


@functools.partial(jax.jit, static_argnames=("k_b", "interpret"))
def block_stats(x: jax.Array, k_b: int, *, interpret: bool = True):
    """Per-block k_b-th largest |x|. x: (nb, COLS) -> (nb, 1) f32."""
    nb, C = x.shape
    rows = min(ROWS, nb)
    grid = (pl.cdiv(nb, rows),)
    return pl.pallas_call(
        functools.partial(_block_stats_kernel, k_b=k_b),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=interpret,
    )(x)
