"""Fused RMSNorm Pallas kernel.

RMSNorm is applied 2x per layer on the (tokens, d_model) residual stream; a
naive jnp lowering reads x twice (once for the mean-square, once for the
scale-multiply).  The fused kernel computes the row statistic and the output
in one VMEM-resident pass: 1 read + 1 write per element.

Tiling: (BT, d_model) tiles — d_model is always a 128-multiple in our
configs; rows are processed 8-sublane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            bt: int = DEFAULT_BT, interpret: bool = True) -> jax.Array:
    """x: (..., T, D), w: (D,). Normalizes the last axis."""
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    T = xr.shape[0]
    bt = min(bt, T)
    pad = (-T) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xr.shape[0] // bt,),
        in_specs=[pl.BlockSpec((bt, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, w)
    if pad:
        out = out[:T]
    return out.reshape(orig_shape)
