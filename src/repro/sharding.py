"""Partition rules: parameter PartitionSpecs per model family.

Rules are *name-based* and use negative dim indices, so they apply uniformly
to unstacked, (L, ...)-stacked and (G, per, ...)-stacked leaves.  The model
axis shards: attention heads (qkv out-dim / o in-dim), MLP hidden, MoE
experts, SSM inner channels, vocab (embedding d_model / head vocab).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# (path-suffix match) -> dim (negative index) to shard over 'model'
_COL_NAMES = {"wq", "wk", "wv", "wg", "wi", "cm_k", "in_proj"}   # last dim
_ROW_NAMES = {"wo", "out_proj", "cm_v"}                          # dim -2
_VEC_LAST = {"conv_w", "conv_b", "A_log", "D_skip", "dt_bias", "u",
             "w_base", "ln_w", "ln_b"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return out


def leaf_pspec(path, leaf) -> P:
    names = _path_names(path)
    ndim = leaf.ndim
    spec = [None] * ndim

    def set_dim(neg_idx):
        if ndim + neg_idx >= 0:
            spec[neg_idx] = "model"

    if "moe" in names:
        # router replicated; expert tensors sharded on E (dim -3)
        if names[-1] in ("wg", "wi", "wo"):
            set_dim(-3)
        return P(*spec)
    if "embed" in names:
        set_dim(-1)          # (V, D): shard d_model -> local token gather
        return P(*spec)
    if "lm_head" in names:
        set_dim(-1)          # (D, V): vocab-parallel logits
        return P(*spec)
    last = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    if last == "w" and parent in _COL_NAMES:
        set_dim(-1)
    elif last == "w" and parent in _ROW_NAMES:
        set_dim(-2)
    elif last == "b" and parent in _COL_NAMES:
        set_dim(-1)
    elif last in _COL_NAMES and ndim >= 2:      # rwkv raw arrays
        set_dim(-1)
    elif last in _ROW_NAMES and ndim >= 2:
        set_dim(-2)
    elif last in _VEC_LAST:
        if last == "u":
            set_dim(-2)
        elif last in ("w_base", "ln_w", "ln_b"):
            pass             # small per-channel vectors: replicate
        else:
            set_dim(-1)
    elif parent == "norm" and last == "w":
        # mamba gated-norm over sharded d_in
        set_dim(-1)
    return P(*spec)


def param_pspecs(params: PyTree, two_d: bool = False,
                 dp_axis: str = "data") -> PyTree:
    """Standard: model-axis TP only (replicated over dp — required for the
    per-worker gradient semantics of DCSGD-ASSS).

    ``two_d=True`` (serving only): additionally shard the largest
    still-replicated dim of every big leaf over ``dp_axis`` — per-chip
    weights drop from P/|model| to P/(|model|*|dp|) at the cost of a
    per-layer weight all-gather (XLA inserts it inside the layer scan).
    This is what lets llama3-405b fit a single v5e pod for serving.
    """
    specs = jax.tree_util.tree_map_with_path(leaf_pspec, params)
    if not two_d:
        return specs

    def widen(leaf, spec):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2 or leaf.size < 2**20:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # largest unsharded dim divisible by 16
        cand = [(leaf.shape[i], i) for i in range(leaf.ndim)
                if entries[i] is None and leaf.shape[i] % 16 == 0]
        if not cand:
            return spec
        _, dim = max(cand)
        entries[dim] = dp_axis
        return P(*entries)

    return jax.tree.map(widen, params, specs)


def param_shardings(params: PyTree, mesh) -> PyTree:
    """Training-path parameter placement (serving uses param_pspecs).

    On 0.4.x JAX the manual-dp train shard_map cannot carry model-sharded
    operands through the layer scan (compat.PARTIAL_AUTO_SAFE), so params
    are kept replicated there; the pspecs themselves are unchanged.
    """
    from repro import compat
    specs = param_pspecs(params)
    if not compat.PARTIAL_AUTO_SAFE:
        from jax.sharding import PartitionSpec
        specs = jax.tree.map(lambda _: PartitionSpec(), specs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def cache_pspecs(cache, dp, seq_axes) -> Any:
    """Decode-cache shardings (path-aware).

    * KV caches ``(..., B, S, H, hd)``: B over dp, S over ``seq_axes``
      (('model',) normally; every mesh axis when global batch = 1).
    * SSM states ``(..., B, H, hd, N)`` / RWKV wkv ``(..., B, H, hd, hd)``:
      B over dp, heads over 'model'.
    * conv states ``(..., B, K, C)``: B over dp, channels over 'model'.
    """
    dp_spec = (dp if len(dp) > 1 else dp[0]) if dp else None
    seq_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def one(path, leaf):
        if not hasattr(leaf, "ndim"):
            return P()
        names = _path_names(path)
        ndim = leaf.ndim
        spec = [None] * ndim

        def put(i, v):
            if ndim + i >= 0 and v is not None:
                spec[i] = v

        leafname = names[-1] if names else ""
        if leafname in ("tm_prev", "cm_prev"):
            put(-2, dp_spec)
        elif leafname == "conv" or "conv" in names:
            put(-3, dp_spec)
            put(-1, "model")
        elif "kv" in names or "cross_kv" in names:
            put(-4, dp_spec)
            put(-3, seq_spec)
        elif "ssm" in names or "wkv" in names:
            put(-4, dp_spec)
            put(-3, "model")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)
