"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode.  [Dao & Gu '24, as used by Zamba2, arXiv:2411.15242]

State-space semantics per head h with scalar decay A_h < 0:

    dA_t = exp(dt_t * A)                  (per-token decay)
    S_t  = dA_t * S_{t-1} + dt_t * B_t (x) x_t     (S: (hd, N))
    y_t  = C_t . S_t + D_skip * x_t

Train/prefill uses the chunked formulation (intra-chunk quadratic attention-
like term + inter-chunk state scan over ``seq/chunk`` steps); TPU-wise, the
intra-chunk einsums are MXU matmuls of shape (chunk x chunk) and the scan
carries only the (H, hd, N) state — the sequential dependency is seq/chunk
long, not seq long.  Heads are tensor-parallel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import DP, TP, hint
from .layers import he_init, rms_norm


class SSMState(NamedTuple):
    conv: jax.Array    # (B, K-1, d_conv_in)  rolling conv window
    ssm: jax.Array     # (B, H, hd, N)        recurrent state


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_state, cfg.ssm_head_dim


def init_mamba2(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    d_in, nh, N, hd = _dims(cfg)
    d_conv_in = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": {"w": he_init(ks[0], (D, 2 * d_in + 2 * N + nh), dtype)},
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_conv_in))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_conv_in,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"w": jnp.ones((d_in,), dtype)},
        "out_proj": {"w": he_init(ks[3], (d_in, D), dtype)},
    }


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv over seq. xBC: (B, L, Cc); w: (K, Cc).

    If ``state`` (B, K-1, Cc) is given, it is the rolling history (decode /
    chunked prefill continuation); returns (out, new_state)."""
    B, L, Cc = xBC.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, Cc), xBC.dtype)
    full = jnp.concatenate([state, xBC], axis=1)           # (B, L+K-1, Cc)
    out = jnp.zeros((B, L, Cc), jnp.float32)
    for i in range(K):                                      # K=4: unrolled taps
        out = out + full[:, i:i + L].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = full[:, L:]
    return jax.nn.silu(out).astype(xBC.dtype), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H); A: (H,) (negative); Bm, Cm: (B, L, N).
    Returns (y: (B, L, H, P), final_state: (B, H, P, N)).
    """
    Bb, L, H, P = x.shape
    N = Bm.shape[-1]
    nc = max(1, L // chunk)
    cl = L // nc
    assert nc * cl == L, (L, chunk)

    xr = x.reshape(Bb, nc, cl, H, P)
    dtr = dt.reshape(Bb, nc, cl, H)
    Br = Bm.reshape(Bb, nc, cl, N)
    Cr = Cm.reshape(Bb, nc, cl, N)

    dA = dtr * A                                   # (B, nc, cl, H), negative
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk log decay
    total = cum[:, :, -1:, :]                      # (B, nc, 1, H)

    dx = dtr[..., None] * xr                       # dt * x

    # intra-chunk: y[i] += sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dx_j
    li = cum[:, :, :, None, :]                     # (B,nc,cl_i,1,H)
    lj = cum[:, :, None, :, :]                     # (B,nc,1,cl_j,H)
    mask = jnp.tril(jnp.ones((cl, cl), bool))[None, None, :, :, None]
    # mask in log space BEFORE exp: exp(positive) for j>i would overflow and
    # poison the backward pass with inf*0 = nan.
    logdecay = jnp.where(mask, li - lj, -1e30)
    decay = jnp.exp(logdecay)                      # (B,nc,i,j,H)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr.astype(jnp.float32),
                    Br.astype(jnp.float32))        # (B,nc,i,j)
    att = cb[..., None] * decay                    # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att,
                         dx.astype(jnp.float32))

    # chunk-final states: S_c = sum_j exp(total - cum_j) B_j (x) dx_j
    sdecay = jnp.exp(total - cum)                  # (B,nc,cl,H)
    s_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", sdecay,
                         Br.astype(jnp.float32), dx.astype(jnp.float32))

    # inter-chunk scan: S = exp(total_c) * S_prev + S_chunk
    tot_t = jnp.exp(total[:, :, 0, :])             # (B, nc, H)

    def scan_fn(S, inp):
        t, sc = inp                                # t: (B,H); sc: (B,H,P,N)
        S_new = S * t[..., None, None] + sc
        return S_new, S                            # emit state *entering* chunk

    S0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bb, H, P, N), jnp.float32))
    S_final, S_enter = jax.lax.scan(
        scan_fn, S0, (tot_t.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    S_enter = S_enter.transpose(1, 0, 2, 3, 4)     # (B, nc, H, P, N)

    # inter-chunk contribution: y[i] += exp(cum_i) * C_i . S_enter
    y_inter = jnp.einsum("bcih,bcin,bchpn->bcihp", jnp.exp(cum),
                         Cr.astype(jnp.float32), S_enter)
    y = (y_intra + y_inter).reshape(Bb, L, H, P)
    return y, S_final


def mamba2_block(p, x, cfg: ModelConfig, state: SSMState | None = None,
                 return_state: bool = False):
    """x: (B, L, D) -> (y, new_state|None). Full-sequence path."""
    B, L, D = x.shape
    d_in, nh, N, hd = _dims(cfg)
    proj = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    conv_state = state.conv if state is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xs = hint(xs.reshape(B, L, nh, hd), DP, None, TP, None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, S = ssd_chunked(xs, dt, A, Bm, Cm, min(cfg.ssm_chunk, L),
                       init_state=state.ssm if state is not None else None)
    y = y + p["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = hint(y @ p["out_proj"]["w"].astype(x.dtype), DP, None, None)
    if return_state:
        return out, SSMState(conv=new_conv, ssm=S)
    return out, None


def mamba2_decode(p, x, state: SSMState, cfg: ModelConfig):
    """One-token recurrence. x: (B, 1, D). Returns (y, new_state)."""
    B, _, D = x.shape
    d_in, nh, N, hd = _dims(cfg)
    proj = x @ p["in_proj"]["w"].astype(x.dtype)
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], state.conv)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                       # (B, H)
    dx = dt[..., None] * xs.astype(jnp.float32)                # (B, H, P)
    S = state.ssm * dA[..., None, None] + \
        jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32), dx)
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), S)
    y = y + p["D_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]["w"].astype(x.dtype)
    return out, SSMState(conv=new_conv, ssm=S)


def init_ssm_state(cfg: ModelConfig, B: int, dtype) -> SSMState:
    d_in, nh, N, hd = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((B, cfg.ssm_conv - 1, d_in + 2 * N), dtype),
        ssm=jnp.zeros((B, nh, hd, N), jnp.float32),
    )
