"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend (mel + conv feature extractor) is stubbed per the task
contract: the encoder consumes precomputed frame embeddings
``batch["src_embed"]: (B, S_enc, d_model)``.  The text decoder is a standard
causal transformer with per-layer cross-attention into the encoder output.

For ``long_500k`` the encoder self-attention runs banded (two-sided window)
and the decoder self-attention sliding-window — full quadratic attention at
524k is out of scope for any full-attention arch (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import DP, hint
from . import attention as attn
from .layers import (embed, init_embed, init_lm_head, init_mlp,
                     init_rms_norm, lm_head, mlp, rms_norm, softmax_xent)
from .lm import DecodeCache, _stack_init

PyTree = Any


def _init_enc_block(cfg: ModelConfig, dtype):
    def one(key):
        ks = jax.random.split(key, 2)
        return {
            "attn_norm": init_rms_norm(cfg.d_model, dtype),
            "attn": attn.init_attn(ks[0], cfg, dtype),
            "mlp_norm": init_rms_norm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg, dtype=dtype),
        }
    return one


def _init_dec_block(cfg: ModelConfig, dtype):
    def one(key):
        ks = jax.random.split(key, 3)
        return {
            "self_norm": init_rms_norm(cfg.d_model, dtype),
            "self_attn": attn.init_attn(ks[0], cfg, dtype),
            "cross_norm": init_rms_norm(cfg.d_model, dtype),
            "cross": attn.init_cross_attn(ks[1], cfg, dtype),
            "mlp_norm": init_rms_norm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[2], cfg, dtype=dtype),
        }
    return one


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": init_embed(k1, cfg, dtype),
        "enc_blocks": _stack_init(_init_enc_block(cfg, dtype), k2,
                                  cfg.n_enc_layers),
        "enc_norm": init_rms_norm(cfg.d_model, dtype),
        "dec_blocks": _stack_init(_init_dec_block(cfg, dtype), k3,
                                  cfg.n_dec_layers),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
        "lm_head": init_lm_head(k4, cfg, dtype),
    }


def encode(params, src_embed, cfg: ModelConfig,
           window: int | None = None) -> jax.Array:
    """src_embed: (B, S_enc, D) -> encoder memory (B, S_enc, D)."""
    x = hint(src_embed.astype(jnp.dtype(cfg.compute_dtype)), DP, None, None)

    def body(h, lp):
        a, _ = attn.attention_block(
            lp["attn"], rms_norm(lp["attn_norm"], h, cfg.norm_eps), cfg,
            causal=False, window=window)
        h = h + a
        h = h + mlp(lp["mlp"], rms_norm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, None
    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(lp, x, memory, cfg, window=None, kv_cross=None):
    a, kv_self = attn.attention_block(
        lp["self_attn"], rms_norm(lp["self_norm"], x, cfg.norm_eps), cfg,
        causal=True, window=window)
    x = x + a
    c, kv_cross = attn.cross_attention_block(
        lp["cross"], rms_norm(lp["cross_norm"], x, cfg.norm_eps), memory,
        cfg, kv=kv_cross)
    x = x + c
    x = x + mlp(lp["mlp"], rms_norm(lp["mlp_norm"], x, cfg.norm_eps))
    return x, kv_self, kv_cross


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig):
    """batch: src_embed (B, S_enc, D) + tokens (B, S_dec)."""
    window = cfg.sliding_window or None
    memory = encode(params, batch["src_embed"], cfg, window=window)
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = embed(params["embed"], inputs, cfg).astype(memory.dtype)

    def body(carry, lp):
        h, _ = carry
        h, _, _ = _dec_block(lp, h, memory, cfg, window=window)
        return (h, jnp.float32(0.0)), None
    body = jax.checkpoint(body) if cfg.remat else body
    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                             params["dec_blocks"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params["lm_head"], x, cfg.vocab_size)
    ce = softmax_xent(logits, targets)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def prefill(params: PyTree, batch: dict, cfg: ModelConfig,
            capacity: int | None = None):
    """Encode source + ingest decoder context; returns (logits, cache)."""
    window = cfg.sliding_window or None
    memory = encode(params, batch["src_embed"], cfg, window=window)
    tokens = batch["tokens"]
    B, S = tokens.shape
    capacity = capacity or S
    x = embed(params["embed"], tokens, cfg).astype(memory.dtype)

    def pad_kv(kv):
        kv = attn.maybe_quantize_cache(kv, cfg)
        pad = capacity - kv.k.shape[1]
        if pad <= 0:
            return kv

        def p4(x):
            if not hasattr(x, "ndim"):
                return x
            return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return attn.KVCache(k=p4(kv.k), v=p4(kv.v),
                            k_scale=p4(kv.k_scale), v_scale=p4(kv.v_scale))

    def body(h, lp):
        h, kv_self, kv_cross = _dec_block(lp, h, memory, cfg, window=window)
        return h, (pad_kv(kv_self), kv_cross)
    x, (kv_selfs, kv_crosses) = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = lm_head(params["lm_head"], x, cfg.vocab_size)
    return logits, DecodeCache(kv=kv_selfs, cross_kv=kv_crosses)


def init_cache(cfg: ModelConfig, B: int, capacity: int, s_enc: int,
               dtype=None) -> DecodeCache:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.hd
    L = cfg.n_dec_layers
    shape = (L, B, capacity, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        kv = attn.KVCache(k=jnp.zeros(shape, jnp.int8),
                          v=jnp.zeros(shape, jnp.int8),
                          k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
                          v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32))
    else:
        kv = attn.KVCache(k=jnp.zeros(shape, dtype),
                          v=jnp.zeros(shape, dtype))
    cross = attn.KVCache(
        k=jnp.zeros((L, B, s_enc, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((L, B, s_enc, cfg.n_kv_heads, hd), dtype))
    return DecodeCache(kv=kv, cross_kv=cross)


def decode_step(params: PyTree, token: jax.Array, cache: DecodeCache,
                cur_len: jax.Array, cfg: ModelConfig,
                window: int | None = None):
    """One decoder token against (self cache, precomputed cross K/V)."""
    window = window or (cfg.sliding_window or None)
    x = embed(params["embed"], token, cfg)
    x = x.astype(jnp.dtype(cfg.compute_dtype))

    def body(h, inp):
        lp, kv_self, kv_cross = inp
        a, kv_self = attn.decode_attention_block(
            lp["self_attn"], rms_norm(lp["self_norm"], h, cfg.norm_eps),
            kv_self, cur_len, cfg, window=window)
        h = h + a
        c, _ = attn.cross_attention_block(
            lp["cross"], rms_norm(lp["cross_norm"], h, cfg.norm_eps), None,
            cfg, kv=kv_cross)
        h = h + c
        h = h + mlp(lp["mlp"], rms_norm(lp["mlp_norm"], h, cfg.norm_eps))
        return h, kv_self
    x, kv_selfs = jax.lax.scan(body, x, (params["dec_blocks"], cache.kv,
                                         cache.cross_kv))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params["lm_head"], x, cfg.vocab_size)
    return logits, cache._replace(kv=kv_selfs)
