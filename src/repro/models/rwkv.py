"""RWKV-6 "Finch" block — attention-free linear-recurrence time mixing with
data-dependent decay, plus channel mixing.  [arXiv:2404.05892]

Per head (hd = head size), per token:

    S_t  = diag(w_t) S_{t-1} + k_t^T v_t        (S: (hd_k, hd_v))
    y_t  = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(w_base + lora_w(x_t))) — the *data-dependent* decay that
distinguishes RWKV-6 — and token-shift ddlerp mixing for the r/k/v/w/g
projections.  Train/prefill is a ``lax.scan`` over time carrying S (the
sequential dependency is inherent; the per-step body is (hd x hd) outer
products on the VPU/MXU); decode is the same body once.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import DP, TP, hint
from .layers import he_init

MIX = ("r", "k", "v", "w", "g")


class RWKVState(NamedTuple):
    tm_prev: jax.Array   # (B, D) last token entering time-mix
    cm_prev: jax.Array   # (B, D) last token entering channel-mix
    wkv: jax.Array       # (B, H, hd, hd) recurrent state


def _dims(cfg: ModelConfig):
    hd = cfg.hd
    H = cfg.d_model // hd
    return H, hd


def init_rwkv6(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    H, hd = _dims(cfg)
    r = cfg.rwkv_lora_rank
    ks = jax.random.split(key, 12)
    p = {
        "mu": {c: (0.5 * jnp.ones((D,), jnp.float32)) for c in MIX},
        "lora_A": {c: he_init(ks[i], (D, r), dtype) for i, c in enumerate(MIX)},
        "lora_B": {c: (jnp.zeros((r, D), dtype)) for c in MIX},
        "wr": {"w": he_init(ks[5], (D, D), dtype)},
        "wk": {"w": he_init(ks[6], (D, D), dtype)},
        "wv": {"w": he_init(ks[7], (D, D), dtype)},
        "wg": {"w": he_init(ks[8], (D, D), dtype)},
        "wo": {"w": he_init(ks[9], (D, D), dtype)},
        "w_base": jnp.full((D,), -2.0, jnp.float32),
        "u": (0.1 * jax.random.normal(ks[10], (H, hd))).astype(jnp.float32),
        "ln_w": jnp.ones((D,), jnp.float32),
        "ln_b": jnp.zeros((D,), jnp.float32),
        "cm_k": {"w": he_init(ks[11], (D, cfg.d_ff), dtype)},
        "cm_v": {"w": he_init(ks[0], (cfg.d_ff, D), dtype)},
        "mu_cm": 0.5 * jnp.ones((D,), jnp.float32),
    }
    return p


def _ddlerp(p, c, x, xx):
    """Data-dependent lerp between x and shifted xx for channel c."""
    mix = p["mu"][c] + jnp.tanh(x @ p["lora_A"][c].astype(x.dtype)) \
        @ p["lora_B"][c].astype(x.dtype)
    return x + (xx - x) * mix.astype(x.dtype)


def _group_norm(y, w, b, H, hd, eps=1e-5):
    """Per-head layer norm of (..., H, hd) flattened output."""
    shape = y.shape
    yr = y.reshape(*shape[:-1], H, hd).astype(jnp.float32)
    mean = jnp.mean(yr, -1, keepdims=True)
    var = jnp.var(yr, -1, keepdims=True)
    yr = (yr - mean) * jax.lax.rsqrt(var + eps)
    out = yr.reshape(shape) * w + b
    return out


def time_mix(p, x, cfg: ModelConfig, state: RWKVState):
    """x: (B, L, D). Returns (y, new_state). Scan over time."""
    B, L, D = x.shape
    H, hd = _dims(cfg)
    # token shift: x_{t-1} with the carried boundary token
    xx = jnp.concatenate([state.tm_prev[:, None, :].astype(x.dtype),
                          x[:, :-1]], axis=1)
    xr = _ddlerp(p, "r", x, xx)
    xk = _ddlerp(p, "k", x, xx)
    xv = _ddlerp(p, "v", x, xx)
    xw = _ddlerp(p, "w", x, xx)
    xg = _ddlerp(p, "g", x, xx)

    r = (xr @ p["wr"]["w"].astype(x.dtype)).reshape(B, L, H, hd)
    k = (xk @ p["wk"]["w"].astype(x.dtype)).reshape(B, L, H, hd)
    v = (xv @ p["wv"]["w"].astype(x.dtype)).reshape(B, L, H, hd)
    g = jax.nn.silu(xg @ p["wg"]["w"].astype(x.dtype))
    r = hint(r, DP, None, TP, None)
    k = hint(k, DP, None, TP, None)
    v = hint(v, DP, None, TP, None)

    # data-dependent decay (B, L, H, hd), in (0,1)
    wdec = p["w_base"] + (jnp.tanh(xw @ p["lora_A"]["w"].astype(x.dtype))
                          @ p["lora_B"]["w"].astype(x.dtype)).astype(jnp.float32)
    wdec = jnp.exp(-jnp.exp(wdec.astype(jnp.float32))).reshape(B, L, H, hd)

    u = p["u"]

    if cfg.use_pallas:
        # VMEM-resident WKV kernel (kernels/rwkv_wkv.py): eliminates the
        # per-step HBM state round-trip that makes the scan memory-bound.
        from repro.kernels import ops as kops
        y4, S_final = kops.wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), wdec, u, state.wkv,
                               impl="pallas")
        y = y4.reshape(B, L, D)
    else:
        def step(S, inp):
            rt, kt, vt, wt = inp          # (B,H,hd) each
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             S + u[None, :, :, None] * kv)
            S = wt[..., None] * S + kv
            return S, out

        rs = r.transpose(1, 0, 2, 3).astype(jnp.float32)
        ks_ = k.transpose(1, 0, 2, 3).astype(jnp.float32)
        vs = v.transpose(1, 0, 2, 3).astype(jnp.float32)
        ws = wdec.transpose(1, 0, 2, 3)
        S_final, outs = jax.lax.scan(step, state.wkv, (rs, ks_, vs, ws))
        y = outs.transpose(1, 0, 2, 3).reshape(B, L, D)
    y = _group_norm(y, p["ln_w"], p["ln_b"], H, hd).astype(x.dtype) * g
    out = hint(y @ p["wo"]["w"].astype(x.dtype), DP, None, None)
    new_state = state._replace(tm_prev=x[:, -1].astype(jnp.float32),
                               wkv=S_final)
    return out, new_state


def channel_mix(p, x, state: RWKVState):
    B, L, D = x.shape
    xx = jnp.concatenate([state.cm_prev[:, None, :].astype(x.dtype),
                          x[:, :-1]], axis=1)
    xk = x + (xx - x) * p["mu_cm"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["cm_k"]["w"].astype(x.dtype)))
    h = hint(h, DP, None, TP)
    y = hint(h @ p["cm_v"]["w"].astype(x.dtype), DP, None, None)
    return y, state._replace(cm_prev=x[:, -1].astype(jnp.float32))


def init_rwkv_state(cfg: ModelConfig, B: int) -> RWKVState:
    H, hd = _dims(cfg)
    return RWKVState(tm_prev=jnp.zeros((B, cfg.d_model), jnp.float32),
                     cm_prev=jnp.zeros((B, cfg.d_model), jnp.float32),
                     wkv=jnp.zeros((B, H, hd, hd), jnp.float32))
