"""Model registry: uniform API over decoder-only and enc-dec families.

``build_model(cfg)`` returns a ``Model`` with ``init / loss / prefill /
decode_step / init_cache / input_specs`` — the launcher, dry-run, tests and
benchmarks all go through this object.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from . import encdec, lm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss: Callable[[PyTree, dict], tuple[jax.Array, dict]]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    init_cache: Callable[..., Any]
    stacked_mask: Callable[[PyTree], PyTree]

    def input_specs(self, shape: ShapeConfig,
                    local_batch: int | None = None) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape.

        For train/prefill the batch dim is the *global* batch (the launcher
        shards it); ``local_batch`` overrides (inside shard_map bodies).
        """
        cfg = self.cfg
        B = local_batch or shape.global_batch
        S = shape.seq_len
        f = jnp.dtype(cfg.compute_dtype)
        i = jnp.int32
        sd = jax.ShapeDtypeStruct

        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                return {"src_embed": sd((B, S // 2, cfg.d_model), f),
                        "tokens": sd((B, S // 2), i)}
            spec = {"tokens": sd((B, S), i)}
            if cfg.family == "vlm":
                spec["image_embed"] = sd((B, cfg.n_patches, cfg.d_model), f)
            return spec
        # decode: one token + cache of S
        spec = {"token": sd((B, 1), i)}
        return spec


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss=lambda p, b: encdec.loss_fn(p, b, cfg),
            prefill=lambda p, b, **kw: encdec.prefill(p, b, cfg, **kw),
            decode_step=lambda p, t, c, n, **kw: encdec.decode_step(
                p, t, c, n, cfg, **kw),
            init_cache=lambda B, capacity, s_enc=None, dtype=None:
                encdec.init_cache(cfg, B, capacity, s_enc or capacity, dtype),
            stacked_mask=lm.stacked_mask,
        )
    return Model(
        cfg=cfg,
        init=lambda key: lm.init_params(cfg, key),
        loss=lambda p, b: lm.loss_fn(p, b, cfg),
        prefill=lambda p, b, **kw: lm.prefill(p, b, cfg, **kw),
        decode_step=lambda p, t, c, n, **kw: lm.decode_step(
            p, t, c, n, cfg, **kw),
        init_cache=lambda B, capacity, dtype=None, **kw:
            lm.init_cache(cfg, B, capacity, dtype),
        stacked_mask=lm.stacked_mask,
    )
