"""Basic layers: init helpers, norms, rotary embeddings, MLPs, embeddings.

Everything is functional: params are nested dicts of jnp arrays; layer
functions take ``(params, x, cfg)``.  Tensor-parallel sharding is expressed
with ``utils.hint`` symbolic constraints ("dp"/"tp") so the same code runs on
bare CPU, inside manual-over-data shard_map, or under full-auto pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.utils import DP, TP, hint


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def init_dense(key, d_in, d_out, dtype, bias=False):
    p = {"w": he_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, tp_dim: str | None = None):
    """x @ w (+ b). tp_dim: which side is tensor-parallel ("out"|"in"|None)."""
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    if tp_dim == "out":
        y = hint(y, DP, None, TP)
    return y


def rms_norm(p, x, eps: float, use_pallas: bool = False):
    return ops.rms_norm(x, p["w"], eps=eps,
                        impl="pallas" if use_pallas else "ref")


def init_rms_norm(d, dtype):
    return {"w": jnp.ones((d,), dtype)}


# ------------------------------ rotary --------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); pos: (S,) or (B, S) absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    if pos.ndim == 1:
        ang = pos[:, None].astype(jnp.float32) * freqs[None, :]    # (S, hd/2)
        ang = ang[None, :, None, :]
    else:
        ang = pos[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------ MLP (SwiGLU) ---------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, dtype=None):
    d_ff = d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": he_init(k1, (cfg.d_model, d_ff), dtype),
        "wi": he_init(k2, (cfg.d_model, d_ff), dtype),
        "wo": he_init(k3, (d_ff, cfg.d_model), dtype),
    }


def mlp(p, x):
    """SwiGLU; hidden dim is tensor-parallel."""
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    h = hint(h, DP, None, TP)
    y = h @ p["wo"].astype(x.dtype)
    return hint(y, DP, None, None)


# ------------------------------ embeddings -----------------------------------

def init_embed(key, cfg: ModelConfig, dtype):
    return {"w": (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model))
                  * 0.02).astype(dtype)}


def embed(p, tokens, cfg: ModelConfig):
    """Token embedding; the table is sharded on d_model (tp) so the gather
    stays local and no vocab all-gather is generated."""
    w = hint(p["w"], None, TP)
    out = jnp.take(w, tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    return hint(out, DP, None, None)


def init_lm_head(key, cfg: ModelConfig, dtype):
    return {"w": he_init(key, (cfg.d_model, cfg.padded_vocab), dtype,
                         fan_in=cfg.d_model)}


def lm_head(p, x, true_vocab: int | None = None):
    """Vocab-parallel projection; logits stay sharded on vocab. Padded
    vocab columns (table rounded to a 256 multiple) are masked to -inf."""
    logits = (x @ p["w"].astype(x.dtype)).astype(jnp.float32)
    V = logits.shape[-1]
    if true_vocab is not None and true_vocab < V:
        mask = jnp.arange(V) < true_vocab
        logits = jnp.where(mask, logits, -1e30)
    return hint(logits, DP, None, TP)


def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Stable CE over a (possibly vocab-sharded) logits tensor."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
