"""Mixture-of-Experts layer: top-k token-choice routing, capacity buffers,
expert-parallel einsums.

Routing is sort-based (no (T, E, C) one-hot dispatch tensor — that would be
O(T*E*C) memory): tokens are replicated k ways, sorted by expert id, and
scattered into a ``(E, C, D)`` capacity buffer which is what the experts'
batched einsums consume.  The expert dimension is tensor-parallel
(``hint(..., TP)``), so the scatter/gather lower to all-to-all-style
collectives under pjit — expert parallelism.

Overflow beyond capacity ``C = ceil(T*k/E * capacity_factor)`` is dropped
(standard GShard/Switch behaviour); the router aux loss keeps loads balanced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.utils import DP, TP, hint
from .layers import he_init


def _maybe_expert_parallel(p, x, cfg: ModelConfig, no_drop: bool):
    """Expert-parallel dispatch under an explicit shard_map (§Perf pair B).

    Key observation: the token activations are already replicated across
    the model axis (TP keeps the residual stream replicated), so expert
    parallelism needs NO token exchange at all — each model shard routes
    the full local token set, builds capacity buffers for its E/|model|
    local experts, runs the expert FFNs, and contributes a partial (T, D)
    output; a single activation-sized ``psum`` over 'model' combines.
    Returns None when no mesh/model axis is active (CPU smoke path).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    manual = set(mesh.manual_axes)
    if "model" in manual:
        return None
    n_shards = mesh.shape["model"]
    if cfg.n_experts % n_shards:
        return None

    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    # Manualize the batch over the still-auto dp axes too (when divisible):
    # otherwise the router argsort over the dp-sharded token dim makes the
    # partitioner gather all tokens.  In the train path dp is already
    # manual (outer shard_map) and this is a no-op.
    import math as _m
    dp = [a for a in mesh.axis_names
          if a != "model" and a not in manual]
    dp_size = _m.prod(mesh.shape[a] for a in dp) if dp else 1
    if not dp or B % dp_size:
        dp = []
    xspec = P(tuple(dp) if len(dp) > 1 else (dp[0] if dp else None),
              None, None)
    wspec = P("model", None, None)    # (E, D, F) sharded on experts

    def body(xb, router_w, wg, wi, wo):
        shard = jax.lax.axis_index("model")
        E_loc = wg.shape[0]
        y, aux = _moe_local(xb, router_w, wg, wi, wo, cfg,
                            e_offset=shard * E_loc, no_drop=no_drop)
        if dp:
            aux = jax.lax.pmean(aux, tuple(dp))
        return jax.lax.psum(y, "model"), aux

    f = compat.shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(), wspec, wspec, wspec),
        out_specs=(xspec, P()),
        axis_names={"model"} | set(dp), check_vma=False)
    return f(x, p["router"]["w"], p["wg"], p["wi"], p["wo"])


def _moe_local(x, router_w, wg, wi, wo, cfg: ModelConfig, e_offset,
               no_drop: bool):
    """Routing + capacity dispatch + FFN for a LOCAL slice of experts.

    x: (B, S, D) local tokens; wg/wi/wo: (E_loc, ...) local expert weights.
    Tokens routed to non-local experts contribute nothing here (their
    output comes from the owning shard via the caller's psum).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    E_loc = wg.shape[0]
    T = B * S
    xt = x.reshape(T, D)

    logits = xt.astype(jnp.float32) @ router_w                 # (T, E) full
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    density = jnp.mean(jax.nn.one_hot(eids[:, 0], E), axis=0)
    aux = jnp.sum(density * jnp.mean(probs, axis=0)) * E * cfg.router_aux_coef

    C = T if no_drop else min(T, max(1, int(-(-T * k // E)
                                            * cfg.capacity_factor)))
    flat_e = eids.reshape(-1) - e_offset                        # local ids
    flat_g = gate_vals.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(T), k)
    is_local = (flat_e >= 0) & (flat_e < E_loc)
    sort_key = jnp.where(is_local, flat_e, E_loc)               # strangers last
    order = jnp.argsort(sort_key)
    se, sg, st = sort_key[order], flat_g[order], tok_id[order]
    keep_local = se < E_loc
    counts = jnp.bincount(jnp.where(is_local, flat_e, E_loc), length=E_loc + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[jnp.minimum(se, E_loc)]
    keep = keep_local & (pos < C)
    se_c = jnp.minimum(se, E_loc - 1)
    pos_c = jnp.clip(pos, 0, C - 1)

    buf = jnp.zeros((E_loc, C, D), xt.dtype)
    buf = buf.at[se_c, pos_c].add(jnp.where(keep[:, None], xt[st], 0))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, wi.astype(buf.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))

    expert_out = out_buf[se_c, pos_c]
    w = jnp.where(keep, sg, 0.0)[:, None].astype(expert_out.dtype)
    y = jnp.zeros((T, D), expert_out.dtype).at[st].add(expert_out * w)
    return y.reshape(B, S, D), aux


def init_moe(key, cfg: ModelConfig, dtype):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": {"w": he_init(ks[0], (D, E), jnp.float32)},
        "wg": he_init(ks[1], (E, D, F), dtype),
        "wi": he_init(ks[2], (E, D, F), dtype),
        "wo": he_init(ks[3], (E, F, D), dtype),
    }


def moe_block(p, x, cfg: ModelConfig, no_drop: bool = False):
    """x: (B, S, D) -> (y, aux_loss).

    ``no_drop=True`` (decode path) sets capacity C=T so no token is ever
    dropped — at decode T = batch, so the buffers stay tiny and serving is
    exact w.r.t. the routing decision.

    With ``cfg.moe_expert_parallel`` and an active mesh, dispatch runs under
    an explicit expert-parallel shard_map (see ``moe_block_ep``): the
    auto-partitioner otherwise lowers the buffer scatter/gather into
    full-activation all-reduces per layer (measured 2 x 68 GB/layer on
    qwen3-moe prefill — §Perf pair B).
    """
    if cfg.moe_expert_parallel:
        out = _maybe_expert_parallel(p, x, cfg, no_drop)
        if out is not None:
            return out
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)                   # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # Switch-style load-balance aux loss.
    density = jnp.mean(jax.nn.one_hot(eids[:, 0], E), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E * cfg.router_aux_coef

    # ---- sort-based dispatch into (E, C, D) capacity buffers ----
    C = T if no_drop else min(T, max(1, int(-(-T * k // E)
                                            * cfg.capacity_factor)))
    flat_e = eids.reshape(-1)                                   # (T*k,)
    flat_g = gate_vals.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e)                                 # stable
    se, sg, st = flat_e[order], flat_g[order], tok_id[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[se]                        # pos in expert
    keep = pos < C
    pos_c = jnp.minimum(pos, C - 1)

    buf = jnp.zeros((E, C, D), xt.dtype)
    gathered = jnp.where(keep[:, None], xt[st], 0)
    buf = buf.at[se, pos_c].add(gathered)
    buf = hint(buf, TP, None, None)                             # expert-parallel

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(buf.dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(buf.dtype))
    h = hint(h, TP, None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(buf.dtype))
    out_buf = hint(out_buf, TP, None, None)

    # ---- combine: weighted gather back to tokens ----
    expert_out = out_buf[se, pos_c]                             # (T*k, D)
    w = jnp.where(keep, sg, 0.0)[:, None].astype(expert_out.dtype)
    y = jnp.zeros((T, D), expert_out.dtype).at[st].add(expert_out * w)
    return hint(y.reshape(B, S, D), DP, None, None), aux
