"""Attention: GQA with rope, query-chunked full/sliding-window for
train/prefill, masked-cache attention for decode.

Decode cache layout (see DESIGN.md §4): ``(B, S_max, H_kv, hd)`` with the
*sequence* dimension sharded over the model axis (and over data too for
batch=1 long-context).  Decode attention is written as plain einsums +
masked softmax; under pjit the partitioner turns the seq-dim reductions
into the flash-decoding (partial max/sum + small all-reduce) schedule.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.utils import DP, TP, hint
from .layers import apply_rope, dense, he_init


class KVCache(NamedTuple):
    k: jax.Array      # (B, S_max, H_kv, hd) — compute dtype or int8
    v: jax.Array      # (B, S_max, H_kv, hd)
    k_scale: Any = () # (B, S_max, H_kv, 1) f32 absmax scales (int8 only)
    v_scale: Any = ()

    @property
    def quantized(self) -> bool:
        return hasattr(self.k_scale, "ndim")


def quantize_kv(x: jax.Array):
    """Per-(position, head) absmax int8 quantization of a K/V tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def maybe_quantize_cache(kv: "KVCache", cfg) -> "KVCache":
    if cfg.kv_cache_dtype != "int8":
        return kv
    kq, ks = quantize_kv(kv.k)
    vq, vs = quantize_kv(kv.v)
    return KVCache(k=kq, v=vq, k_scale=ks, v_scale=vs)


def init_attn(key, cfg: ModelConfig, dtype, d_model: int | None = None):
    D = d_model or cfg.d_model
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": {"w": he_init(ks[0], (D, cfg.n_heads * hd), dtype)},
        "wk": {"w": he_init(ks[1], (D, cfg.n_kv_heads * hd), dtype)},
        "wv": {"w": he_init(ks[2], (D, cfg.n_kv_heads * hd), dtype)},
        "wo": {"w": he_init(ks[3], (cfg.n_heads * hd, D), dtype)},
    }
    if cfg.qkv_bias:
        for n, d_out in (("wq", cfg.n_heads * hd), ("wk", cfg.n_kv_heads * hd),
                         ("wv", cfg.n_kv_heads * hd)):
            p[n]["b"] = jnp.zeros((d_out,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, pos):
    B, S, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if not cfg.attn_free:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = hint(q, DP, None, TP, None)
    k = hint(k, DP, None, TP, None)
    v = hint(v, DP, None, TP, None)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, H_kv, hd) -> (B, S, H, hd) by GQA group broadcast."""
    B, S, Hkv, hd = k.shape
    rep = n_heads // Hkv
    if rep == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :],
                            (B, S, Hkv, rep, hd)).reshape(B, S, n_heads, hd)


def _sdpa(q, k, v, cfg: ModelConfig, causal: bool, window: int | None):
    """q,k,v: (B, S, H, hd) -> (B, Sq, H, hd); query-chunked if long."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    impl = "pallas" if cfg.use_pallas else "ref"
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    chunk = cfg.attn_chunk
    if Sq <= chunk:
        out = ops.attention(qT, kT, vT, causal=causal, window=window,
                            impl=impl)
    else:
        pad = (-Sq) % chunk
        qp = jnp.pad(qT, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else qT
        nq = (Sq + pad) // chunk

        def one(i):
            qi = jax.lax.dynamic_slice_in_dim(qp, i * chunk, chunk, axis=2)
            off = i * chunk + (Sk - Sq)
            return ops.attention(qi, kT, vT, causal=causal, window=window,
                                 q_offset=off, impl="ref")
        out = jax.lax.map(one, jnp.arange(nq)) \
            .transpose(1, 2, 0, 3, 4).reshape(B, H, Sq + pad, hd)
        if pad:
            out = out[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)


def attention_block(p, x, cfg: ModelConfig, *, pos=None, causal=True,
                    window: int | None = None):
    """Full-sequence attention (train/prefill). Returns (out, KVCache)."""
    B, S, _ = x.shape
    if pos is None:
        pos = jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, pos)
    win = window if window is not None else (cfg.sliding_window or None)
    out = _sdpa(q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
                cfg, causal, win)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    y = dense(p["wo"], out)
    return hint(y, DP, None, None), KVCache(k=k, v=v)


def decode_attention_block(p, x, cache: KVCache, cur_len, cfg: ModelConfig,
                           window: int | None = None):
    """One-token decode against a cache.

    x: (B, 1, D); cache.k/v: (B, S_max, H_kv, hd); cur_len: scalar — number
    of valid history tokens; the new token is written at index cur_len.
    Returns (out (B,1,D), updated cache).
    """
    B = x.shape[0]
    hd = cfg.hd
    pos = jnp.full((B, 1), cur_len, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, pos)

    S_max = cache.k.shape[1]
    onehot = (jnp.arange(S_max) == cur_len)[None, :, None, None]
    if cache.quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_store = hint(jnp.where(onehot, kq, cache.k), DP, TP, None, None)
        v_store = hint(jnp.where(onehot, vq, cache.v), DP, TP, None, None)
        ks_store = jnp.where(onehot, ks, cache.k_scale)
        vs_store = jnp.where(onehot, vs, cache.v_scale)
        new_cache = KVCache(k=k_store, v=v_store, k_scale=ks_store,
                            v_scale=vs_store)
        k_all = dequantize_kv(k_store, ks_store, x.dtype)
        v_all = dequantize_kv(v_store, vs_store, x.dtype)
    else:
        k_all = jnp.where(onehot, k_new.astype(cache.k.dtype), cache.k)
        v_all = jnp.where(onehot, v_new.astype(cache.v.dtype), cache.v)
        k_all = hint(k_all, DP, TP, None, None)   # seq-sharded cache
        v_all = hint(v_all, DP, TP, None, None)
        new_cache = KVCache(k=k_all, v=v_all)

    # GQA grouped score: (B, Hkv, G, hd) x (B, S, Hkv, hd)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_all.astype(jnp.float32)) / (hd ** 0.5)
    kpos = jnp.arange(S_max)[None, None, None, :]
    valid = kpos <= cur_len
    if window:
        valid &= kpos > cur_len - window
    scores = jnp.where(valid, scores, -1e30)
    # softmax over the (model-sharded) seq axis -> flash-decode combine
    m = jnp.max(scores, axis=-1, keepdims=True)
    p_ = jnp.exp(scores - m)
    denom = jnp.sum(p_, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p_, v_all.astype(jnp.float32))
    out = (out / denom).reshape(B, 1, cfg.n_heads * hd)
    y = dense(p["wo"], out.astype(x.dtype))
    return hint(y, DP, None, None), new_cache


# ------------------------------ cross attention ------------------------------

def init_cross_attn(key, cfg: ModelConfig, dtype, kv_dim: int | None = None):
    """Cross-attention: queries from the stream, K/V from memory (encoder
    output / image patches)."""
    D = cfg.d_model
    kvd = kv_dim or D
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": {"w": he_init(ks[0], (D, cfg.n_heads * hd), dtype)},
        "wk": {"w": he_init(ks[1], (kvd, cfg.n_kv_heads * hd), dtype)},
        "wv": {"w": he_init(ks[2], (kvd, cfg.n_kv_heads * hd), dtype)},
        "wo": {"w": he_init(ks[3], (cfg.n_heads * hd, D), dtype)},
    }


def cross_attention_block(p, x, memory, cfg: ModelConfig,
                          kv: KVCache | None = None):
    """x: (B, Sq, D); memory: (B, Sm, D_kv). kv: precomputed memory K/V
    (decode path — memory is static). Returns (out, KVCache over memory)."""
    B, Sq, _ = x.shape
    hd = cfg.hd
    q = dense(p["wq"], x).reshape(B, Sq, cfg.n_heads, hd)
    if kv is None:
        Sm = memory.shape[1]
        k = dense(p["wk"], memory).reshape(B, Sm, cfg.n_kv_heads, hd)
        v = dense(p["wv"], memory).reshape(B, Sm, cfg.n_kv_heads, hd)
        kv = KVCache(k=k, v=v)
    out = _sdpa(q, _expand_kv(kv.k, cfg.n_heads),
                _expand_kv(kv.v, cfg.n_heads), cfg, causal=False, window=None)
    out = out.reshape(B, Sq, cfg.n_heads * hd)
    return hint(dense(p["wo"], out), DP, None, None), kv
