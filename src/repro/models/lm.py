"""Decoder-only language models: dense / MoE / SSM (Mamba2, RWKV6) /
hybrid (Zamba2) / VLM (cross-attn) — one scan-over-layers implementation.

Layer parameters are *stacked* on a leading layer axis and driven by
``jax.lax.scan`` (optionally ``jax.checkpoint``-rematerialized), so the HLO
is one layer body regardless of depth — essential for the 80-combination
dry-run compile budget and for per-layer gradient compression (the stacked
leaves are compressed per layer, paper §IV-A).

Three entry points per model:  ``loss``  (train),  ``prefill``  (batched
context ingestion returning caches),  ``decode_step``  (one token against
caches).  Caches are pytrees with stacked layer axes, scanned jointly with
the parameters.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import DP, TP, hint
from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import (embed, init_embed, init_lm_head, init_mlp,
                     init_rms_norm, lm_head, mlp, rms_norm,
                     softmax_xent)

PyTree = Any


def _stack_init(init_one, key, n: int):
    """vmap an init function over n layer keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def _is_rwkv(cfg: ModelConfig) -> bool:
    return cfg.name.startswith("rwkv")


# ===========================================================================
# per-layer blocks
# ===========================================================================

def _init_dense_block(cfg: ModelConfig, dtype):
    def one(key):
        ks = jax.random.split(key, 4)
        blk = {
            "attn_norm": init_rms_norm(cfg.d_model, dtype),
            "attn": attn.init_attn(ks[0], cfg, dtype),
            "mlp_norm": init_rms_norm(cfg.d_model, dtype),
        }
        if cfg.family == "moe":
            blk["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            blk["mlp"] = init_mlp(ks[1], cfg, dtype=dtype)
        return blk
    return one


def _dense_block(p, x, cfg: ModelConfig, *, pos=None, window=None):
    """Pre-norm attn + (mlp|moe). Returns (x, kv, aux)."""
    h, kv = attn.attention_block(p["attn"],
                                 rms_norm(p["attn_norm"], x, cfg.norm_eps),
                                 cfg, pos=pos, window=window)
    x = x + h
    hn = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if cfg.family == "moe" or "moe" in p:
        h2, aux = moe_mod.moe_block(p["moe"], hn, cfg)
    else:
        h2, aux = mlp(p["mlp"], hn), jnp.float32(0.0)
    return x + h2, kv, aux


def _dense_block_decode(p, x, kv_cache, cur_len, cfg: ModelConfig,
                        window=None):
    h, kv = attn.decode_attention_block(
        p["attn"], rms_norm(p["attn_norm"], x, cfg.norm_eps),
        kv_cache, cur_len, cfg, window=window)
    x = x + h
    hn = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    if "moe" in p:
        h2, _ = moe_mod.moe_block(p["moe"], hn, cfg, no_drop=True)
    else:
        h2 = mlp(p["mlp"], hn)
    return x + h2, kv


def _init_mamba_block(cfg: ModelConfig, dtype):
    def one(key):
        return {"norm": init_rms_norm(cfg.d_model, dtype),
                "mamba": ssm_mod.init_mamba2(key, cfg, dtype)}
    return one


def _mamba_block(p, x, cfg, state=None, return_state=False):
    h, st = ssm_mod.mamba2_block(p["mamba"],
                                 rms_norm(p["norm"], x, cfg.norm_eps),
                                 cfg, state=state, return_state=return_state)
    return x + h, st


def _mamba_block_decode(p, x, state, cfg):
    h, st = ssm_mod.mamba2_decode(p["mamba"],
                                  rms_norm(p["norm"], x, cfg.norm_eps),
                                  state, cfg)
    return x + h, st


def _init_rwkv_block(cfg: ModelConfig, dtype):
    def one(key):
        return {"norm1": init_rms_norm(cfg.d_model, dtype),
                "norm2": init_rms_norm(cfg.d_model, dtype),
                "rwkv": rwkv_mod.init_rwkv6(key, cfg, dtype)}
    return one


def _rwkv_block(p, x, cfg, state: rwkv_mod.RWKVState):
    h, state = rwkv_mod.time_mix(p["rwkv"],
                                 rms_norm(p["norm1"], x, cfg.norm_eps),
                                 cfg, state)
    x = x + h
    h, state = rwkv_mod.channel_mix(p["rwkv"],
                                    rms_norm(p["norm2"], x, cfg.norm_eps),
                                    state)
    return x + h, state


def _init_cross_block(cfg: ModelConfig, dtype):
    def one(key):
        ks = jax.random.split(key, 2)
        return {
            "norm": init_rms_norm(cfg.d_model, dtype),
            "cross": attn.init_cross_attn(ks[0], cfg, dtype),
            "mlp_norm": init_rms_norm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg, dtype=dtype),
            "gate_attn": jnp.zeros((), jnp.float32),
            "gate_mlp": jnp.zeros((), jnp.float32),
        }
    return one


def _cross_block(p, x, memory, cfg, kv=None):
    """Gated cross-attn block (llama-3.2-vision style)."""
    h, kv = attn.cross_attention_block(
        p["cross"], rms_norm(p["norm"], x, cfg.norm_eps), memory, cfg, kv=kv)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
    h2 = mlp(p["mlp"], rms_norm(p["mlp_norm"], x, cfg.norm_eps))
    return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h2, kv


# ===========================================================================
# parameter init
# ===========================================================================

def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": init_embed(k_emb, cfg, dtype),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_lm_head(k_head, cfg, dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        params["blocks"] = _stack_init(_init_dense_block(cfg, dtype),
                                       k_blocks, cfg.n_layers)
    elif fam == "ssm" and _is_rwkv(cfg):
        params["blocks"] = _stack_init(_init_rwkv_block(cfg, dtype),
                                       k_blocks, cfg.n_layers)
    elif fam == "ssm":
        params["blocks"] = _stack_init(_init_mamba_block(cfg, dtype),
                                       k_blocks, cfg.n_layers)
    elif fam == "hybrid":
        every = cfg.shared_attn_every
        groups, tail = divmod(cfg.n_layers, every)
        stacked = _stack_init(_init_mamba_block(cfg, dtype),
                              k_blocks, groups * every)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape(groups, every, *x.shape[1:]), stacked)
        if tail:
            params["tail"] = _stack_init(_init_mamba_block(cfg, dtype),
                                         jax.random.fold_in(k_blocks, 1), tail)
        ks = jax.random.split(k_extra, 2)
        params["shared"] = {
            "attn_norm": init_rms_norm(cfg.d_model, dtype),
            "attn": attn.init_attn(ks[0], cfg, dtype),
            "mlp_norm": init_rms_norm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg, dtype=dtype),
        }
    elif fam == "vlm":
        every = cfg.cross_attn_every
        groups = cfg.n_layers // every
        stacked = _stack_init(_init_dense_block(cfg, dtype),
                              k_blocks, cfg.n_layers)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape(groups, every, *x.shape[1:]), stacked)
        params["cross"] = _stack_init(_init_cross_block(cfg, dtype),
                                      k_extra, groups)
    else:
        raise ValueError(f"init_params: family {fam} handled in encdec.py")
    return params


def stacked_mask(params: PyTree) -> PyTree:
    """True for leaves with a leading layer axis (per-layer compression)."""
    def mark(path, leaf):
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        return top in ("blocks", "cross", "tail")
    return jax.tree_util.tree_map_with_path(mark, params)


# ===========================================================================
# forward passes
# ===========================================================================

def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _backbone_train(params, x, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Residual-stream forward over all layers. x: (B, S, D)."""
    fam = cfg.family
    aux0 = jnp.float32(0.0)
    window = cfg.sliding_window or None

    def sp(h):
        # Megatron-style sequence parallelism: the residual stream carried
        # between blocks (and saved by remat) lives seq-sharded over the
        # model axis; the partitioner inserts all-gather at attention/MLP
        # entry and reduce-scatter at exit instead of full all-reduces.
        return hint(h, DP, TP, None) if cfg.seq_parallel else h

    if fam in ("dense", "moe"):
        def body(carry, lp):
            h, aux = carry
            h, _, a = _dense_block(lp, sp(h), cfg, window=window)
            return (sp(h), aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0),
                                   params["blocks"])
        return x, aux

    if fam == "ssm" and _is_rwkv(cfg):
        B = x.shape[0]
        def body(carry, lp):
            h, aux = carry
            st = rwkv_mod.init_rwkv_state(cfg, B)
            h, _ = _rwkv_block(lp, sp(h), cfg, st)
            return (sp(h), aux), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0),
                                   params["blocks"])
        return x, aux

    if fam == "ssm":
        def body(carry, lp):
            h, aux = carry
            h, _ = _mamba_block(lp, sp(h), cfg)
            return (sp(h), aux), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0),
                                   params["blocks"])
        return x, aux

    if fam == "hybrid":
        shared = params["shared"]

        def group(carry, gp):
            h, aux = carry
            def inner(c, lp):
                hh, _ = _mamba_block(lp, sp(c), cfg)
                return sp(hh), None
            h, _ = jax.lax.scan(inner, h, gp)
            h, _, a = _dense_block(
                {**shared, "mlp": shared["mlp"]}, h, cfg, window=window)
            return (h, aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(group, cfg), (x, aux0),
                                   params["blocks"])
        if "tail" in params:
            def inner(c, lp):
                hh, _ = _mamba_block(lp, c, cfg)
                return hh, None
            x, _ = jax.lax.scan(inner, x, params["tail"])
        return x, aux

    if fam == "vlm":
        memory = batch["image_embed"].astype(x.dtype)
        memory = hint(memory, DP, None, None)

        def group(carry, gp):
            h, aux = carry
            self_p, cross_p = gp
            def inner_body(c, lp):
                hh, _, a = _dense_block(lp, sp(c[0]), cfg, window=window)
                return (sp(hh), c[1] + a), None
            (h, aux), _ = jax.lax.scan(inner_body, (h, aux), self_p)
            h, _ = _cross_block(cross_p, h, memory, cfg)
            return (h, aux), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(group, cfg), (x, aux0),
                                   (params["blocks"], params["cross"]))
        return x, aux

    raise ValueError(fam)


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Next-token CE. batch["tokens"]: (B, S) int32."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = embed(params["embed"], inputs, cfg)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    x, aux = _backbone_train(params, x, cfg, batch)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params.get("lm_head", {"w": params["embed"]["w"].T}), x, cfg.vocab_size)
    ce = softmax_xent(logits, targets)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Family-polymorphic cache container; unused fields are () sentinels."""
    kv: Any = ()          # stacked KVCache (dense/moe/vlm self / hybrid shared)
    ssm: Any = ()         # stacked SSMState / RWKVState
    tail_ssm: Any = ()    # hybrid tail layers
    cross_kv: Any = ()    # static memory K/V (vlm / encdec)


def init_cache(cfg: ModelConfig, B: int, capacity: int,
               dtype=None) -> DecodeCache:
    """Zero caches with seq capacity ``capacity`` (abstract-safe)."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hd = cfg.hd
    fam = cfg.family

    def kv_stack(n, s=None):
        shape = (n, B, capacity, cfg.n_kv_heads, hd) if s is None \
            else (n, B, s, cfg.n_kv_heads, hd)
        if cfg.kv_cache_dtype == "int8":
            sshape = shape[:-1] + (1,)
            return attn.KVCache(k=jnp.zeros(shape, jnp.int8),
                                v=jnp.zeros(shape, jnp.int8),
                                k_scale=jnp.zeros(sshape, jnp.float32),
                                v_scale=jnp.zeros(sshape, jnp.float32))
        return attn.KVCache(k=jnp.zeros(shape, dtype),
                            v=jnp.zeros(shape, dtype))

    if fam in ("dense", "moe"):
        return DecodeCache(kv=kv_stack(cfg.n_layers))
    if fam == "ssm" and _is_rwkv(cfg):
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            rwkv_mod.init_rwkv_state(cfg, B))
        return DecodeCache(ssm=states)
    if fam == "ssm":
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            ssm_mod.init_ssm_state(cfg, B, dtype))
        return DecodeCache(ssm=states)
    if fam == "hybrid":
        every = cfg.shared_attn_every
        groups, tail = divmod(cfg.n_layers, every)
        st1 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (groups, every) + x.shape).copy(),
            ssm_mod.init_ssm_state(cfg, B, dtype))
        st2 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (tail,) + x.shape).copy(),
            ssm_mod.init_ssm_state(cfg, B, dtype)) if tail else ()
        return DecodeCache(kv=kv_stack(groups), ssm=st1, tail_ssm=st2)
    if fam == "vlm":
        groups = cfg.n_layers // cfg.cross_attn_every
        cross = attn.KVCache(
            k=jnp.zeros((groups, B, cfg.n_patches, cfg.n_kv_heads, hd), dtype),
            v=jnp.zeros((groups, B, cfg.n_patches, cfg.n_kv_heads, hd), dtype))
        return DecodeCache(
            kv=jax.tree.map(
                lambda x: x.reshape(groups, cfg.cross_attn_every, *x.shape[1:]),
                kv_stack(cfg.n_layers)),
            cross_kv=cross)
    raise ValueError(fam)


def shard_cache(cache: DecodeCache, seq_axes) -> DecodeCache:
    """Apply seq-dim sharding hints to KV caches (decode layout)."""
    def kv_leaf(x):
        if not hasattr(x, "ndim") or x.ndim < 5:
            return x
        spec = [None] * x.ndim
        spec[-3] = seq_axes      # the capacity/seq dim of (..., B, S, H, hd)
        spec[-4] = DP if x.shape[-4] > 1 else None
        return hint(x, *spec)
    kv = jax.tree.map(kv_leaf, cache.kv) if cache.kv != () else ()
    return cache._replace(kv=kv)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params: PyTree, batch: dict, cfg: ModelConfig,
            capacity: int | None = None) -> tuple[jax.Array, DecodeCache]:
    """Ingest (B, S) context; return last-position logits + caches.

    Caches are allocated at ``capacity`` (default S) along seq.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    capacity = capacity or S
    x = embed(params["embed"], tokens, cfg)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    fam = cfg.family
    window = cfg.sliding_window or None

    def pad_kv(kv: attn.KVCache) -> attn.KVCache:
        kv = attn.maybe_quantize_cache(kv, cfg)
        pad = capacity - kv.k.shape[1]
        if pad <= 0:
            return kv

        def p4(x):
            if not hasattr(x, "ndim"):
                return x
            return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return attn.KVCache(k=p4(kv.k), v=p4(kv.v),
                            k_scale=p4(kv.k_scale), v_scale=p4(kv.v_scale))

    if fam in ("dense", "moe"):
        def body(h, lp):
            h, kv, _ = _dense_block(lp, h, cfg, window=window)
            return h, pad_kv(kv)
        x, kvs = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(kv=kvs)
    elif fam == "ssm" and _is_rwkv(cfg):
        def body(h, lp):
            st = rwkv_mod.init_rwkv_state(cfg, B)
            h, st = _rwkv_block(lp, h, cfg, st)
            return h, st
        x, states = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(ssm=states)
    elif fam == "ssm":
        def body(h, lp):
            h, st = _mamba_block(lp, h, cfg, return_state=True)
            return h, st
        x, states = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(ssm=states)
    elif fam == "hybrid":
        shared = params["shared"]
        def group(h, gp):
            def inner(c, lp):
                hh, st = _mamba_block(lp, c, cfg, return_state=True)
                return hh, st
            h, sts = jax.lax.scan(inner, h, gp)
            h, kv, _ = _dense_block(shared, h, cfg, window=window)
            return h, (sts, pad_kv(kv))
        x, (ssm_states, kvs) = jax.lax.scan(group, x, params["blocks"])
        tail_states = ()
        if "tail" in params:
            def inner(c, lp):
                hh, st = _mamba_block(lp, c, cfg, return_state=True)
                return hh, st
            x, tail_states = jax.lax.scan(inner, x, params["tail"])
        cache = DecodeCache(kv=kvs, ssm=ssm_states, tail_ssm=tail_states)
    elif fam == "vlm":
        memory = batch["image_embed"].astype(x.dtype)
        def group(h, gp):
            self_p, cross_p = gp
            def inner(c, lp):
                hh, kv, _ = _dense_block(lp, c, cfg, window=window)
                return hh, pad_kv(kv)
            h, kvs = jax.lax.scan(inner, h, self_p)
            h, ckv = _cross_block(cross_p, h, memory, cfg)
            return h, (kvs, ckv)
        x, (kvs, cross_kvs) = jax.lax.scan(group, x,
                                           (params["blocks"], params["cross"]))
        cache = DecodeCache(kv=kvs, cross_kv=cross_kvs)
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = lm_head(params.get("lm_head", {"w": params["embed"]["w"].T}), x, cfg.vocab_size)
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params: PyTree, token: jax.Array, cache: DecodeCache,
                cur_len: jax.Array, cfg: ModelConfig,
                window: int | None = None) -> tuple[jax.Array, DecodeCache]:
    """One decode step. token: (B, 1) int32; cur_len: history length (the
    new token is written at cache index cur_len). Returns (logits, cache)."""
    x = embed(params["embed"], token, cfg)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    fam = cfg.family
    window = window or (cfg.sliding_window or None)

    if fam in ("dense", "moe"):
        def body(h, inp):
            lp, kv = inp
            h, kv = _dense_block_decode(lp, h, kv, cur_len, cfg, window=window)
            return h, kv
        x, kvs = jax.lax.scan(body, x, (params["blocks"], cache.kv))
        cache = cache._replace(kv=kvs)
    elif fam == "ssm" and _is_rwkv(cfg):
        def body(h, inp):
            lp, st = inp
            h, st = _rwkv_block(lp, h, cfg, st)
            return h, st
        x, states = jax.lax.scan(body, x, (params["blocks"], cache.ssm))
        cache = cache._replace(ssm=states)
    elif fam == "ssm":
        def body(h, inp):
            lp, st = inp
            h, st = _mamba_block_decode(lp, h, st, cfg)
            return h, st
        x, states = jax.lax.scan(body, x, (params["blocks"], cache.ssm))
        cache = cache._replace(ssm=states)
    elif fam == "hybrid":
        shared = params["shared"]
        def group(h, inp):
            gp, sts, kv = inp
            def inner(c, i2):
                lp, st = i2
                hh, st = _mamba_block_decode(lp, c, st, cfg)
                return hh, st
            h, sts = jax.lax.scan(inner, h, (gp, sts))
            h, kv = _dense_block_decode(shared, h, kv, cur_len, cfg,
                                        window=window)
            return h, (sts, kv)
        x, (ssm_states, kvs) = jax.lax.scan(
            group, x, (params["blocks"], cache.ssm, cache.kv))
        tail_states = cache.tail_ssm
        if "tail" in params:
            def inner(c, i2):
                lp, st = i2
                hh, st = _mamba_block_decode(lp, c, st, cfg)
                return hh, st
            x, tail_states = jax.lax.scan(inner, x,
                                          (params["tail"], cache.tail_ssm))
        cache = cache._replace(kv=kvs, ssm=ssm_states, tail_ssm=tail_states)
    elif fam == "vlm":
        def group(h, inp):
            (self_p, cross_p), kvs, ckv = inp
            def inner(c, i2):
                lp, kv = i2
                hh, kv = _dense_block_decode(lp, c, kv, cur_len, cfg,
                                             window=window)
                return hh, kv
            h, kvs = jax.lax.scan(inner, h, (self_p, kvs))
            h, _ = _cross_block(cross_p, h, None, cfg,
                                kv=attn.KVCache(k=ckv.k, v=ckv.v))
            return h, kvs
        x, kvs = jax.lax.scan(
            group, x, ((params["blocks"], params["cross"]), cache.kv,
                       cache.cross_kv))
        cache = cache._replace(kv=kvs)
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_head(params.get("lm_head", {"w": params["embed"]["w"].T}), x, cfg.vocab_size)
    return logits, cache
