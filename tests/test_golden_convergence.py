"""Golden reproduction of the paper's simulation claim (Fig. 4 regime):
on the quadratic objective, CSGD-ASSS *with* step-size scaling converges
with bounded iterates, while the unscaled variant (a = 1) blows up.

Fixed seeds throughout — this is a golden test: the trajectories are
deterministic and the bounds are loose enough to survive numerics churn
but tight enough that a regression in the scaling logic, the compression
operator, or the EF memory flips the verdict.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.data.synthetic import interpolated_regression

SEED = 0
D = 256
N = 512
STEPS = 400
BATCH = 32


def _quadratic_problem():
    """min_w (1/2n)||Aw - b||^2 with interpolation (b in range(A)) — the
    convex quadratic of the paper's simulations."""
    A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)

    def batch_loss(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)

    return batch_loss


def _trajectory(use_scaling: bool, gamma: float, a_scale: float,
                steps: int = STEPS):
    bl = _quadratic_problem()
    cfg = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=a_scale),
        compressor=Compressor(gamma=gamma, min_compress_size=1),
        use_scaling=use_scaling)
    opt = csgd_asss(cfg)
    w = jnp.zeros(D)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(SEED)
    sup_norm, loss = 0.0, None
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, st, aux = step(w, st, idx)
        loss = float(aux.loss)
        wn = float(jnp.linalg.norm(w))
        sup_norm = max(sup_norm, wn if np.isfinite(wn) else np.inf)
        if not np.isfinite(loss) or loss > 1e10:
            break
    return loss, sup_norm


def test_scaling_converges_with_bounded_iterates():
    """CSGD-ASSS (a = 3*sigma scaling): loss drops below 0.1 and every
    iterate stays inside a fixed ball — Theorem 1's bounded-trajectory
    behavior on the interpolating quadratic."""
    loss, sup_norm = _trajectory(use_scaling=True, gamma=0.04, a_scale=0.3)
    assert np.isfinite(loss) and loss < 0.1, loss
    assert sup_norm < 50.0, sup_norm


def test_no_scaling_diverges_unbounded_iterates():
    """The same problem and seeds without scaling (a = 1), at the paper's
    Fig. 4 compression level (gamma = 1%): iterates leave any bounded set.
    (The same-gamma controlled pairing is the discriminator test below.)"""
    loss, sup_norm = _trajectory(use_scaling=False, gamma=0.01, a_scale=1.0,
                                 steps=150)
    diverged = (not np.isfinite(loss)) or loss > 100.0 or sup_norm > 1e3
    assert diverged, (loss, sup_norm)


def test_scaling_necessity_is_the_discriminator():
    """Golden pairing: identical gamma, identical seeds — ONLY the scaling
    flag differs, and it alone separates convergence from divergence."""
    gamma = 0.02
    loss_s, sup_s = _trajectory(use_scaling=True, gamma=gamma, a_scale=0.3,
                                steps=250)
    loss_u, sup_u = _trajectory(use_scaling=False, gamma=gamma, a_scale=1.0,
                                steps=250)
    assert np.isfinite(loss_s) and loss_s < 5.0 and sup_s < 50.0, \
        (loss_s, sup_s)
    assert (not np.isfinite(loss_u)) or loss_u > 10.0 * max(loss_s, 1e-6) \
        or sup_u > 20.0 * sup_s, (loss_u, sup_u)
