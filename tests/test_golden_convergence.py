"""Golden reproduction of the paper's simulation claim (Fig. 4 regime):
on the quadratic objective, CSGD-ASSS *with* step-size scaling converges
with bounded iterates, while the unscaled variant (a = 1) blows up.

Fixed seeds throughout — this is a golden test: the trajectories are
deterministic and the bounds are loose enough to survive numerics churn
but tight enough that a regression in the scaling logic, the compression
operator, or the EF memory flips the verdict.

The OBSERVABILITY pair at the bottom pins the DESIGN.md §9 caveat as a
regression test: injected over-compression (gamma forced below this
problem's divergence threshold) is invisible to the armijo-coupled
controller — the line search runs on the uncompressed gradient, so it
stalls at gamma_min — while the ef-coupled controller senses the EF
backlog and recovers gamma, restoring convergence (ISSUE 4 acceptance).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ArmijoConfig, Compressor, CSGDConfig,
                        GammaControllerConfig, csgd_asss)
from repro.data.synthetic import interpolated_regression

SEED = 0
D = 256
N = 512
STEPS = 400
BATCH = 32


def _quadratic_problem():
    """min_w (1/2n)||Aw - b||^2 with interpolation (b in range(A)) — the
    convex quadratic of the paper's simulations."""
    A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)

    def batch_loss(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)

    return batch_loss


def _trajectory(use_scaling: bool, gamma: float, a_scale: float,
                steps: int = STEPS):
    bl = _quadratic_problem()
    cfg = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=a_scale),
        compressor=Compressor(gamma=gamma, min_compress_size=1),
        use_scaling=use_scaling)
    opt = csgd_asss(cfg)
    w = jnp.zeros(D)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(SEED)
    sup_norm, loss = 0.0, None
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, st, aux = step(w, st, idx)
        loss = float(aux.loss)
        wn = float(jnp.linalg.norm(w))
        sup_norm = max(sup_norm, wn if np.isfinite(wn) else np.inf)
        if not np.isfinite(loss) or loss > 1e10:
            break
    return loss, sup_norm


def test_scaling_converges_with_bounded_iterates():
    """CSGD-ASSS (a = 3*sigma scaling): loss drops below 0.1 and every
    iterate stays inside a fixed ball — Theorem 1's bounded-trajectory
    behavior on the interpolating quadratic."""
    loss, sup_norm = _trajectory(use_scaling=True, gamma=0.04, a_scale=0.3)
    assert np.isfinite(loss) and loss < 0.1, loss
    assert sup_norm < 50.0, sup_norm


def test_no_scaling_diverges_unbounded_iterates():
    """The same problem and seeds without scaling (a = 1), at the paper's
    Fig. 4 compression level (gamma = 1%): iterates leave any bounded set.
    (The same-gamma controlled pairing is the discriminator test below.)"""
    loss, sup_norm = _trajectory(use_scaling=False, gamma=0.01, a_scale=1.0,
                                 steps=150)
    diverged = (not np.isfinite(loss)) or loss > 100.0 or sup_norm > 1e3
    assert diverged, (loss, sup_norm)


def test_scaling_necessity_is_the_discriminator():
    """Golden pairing: identical gamma, identical seeds — ONLY the scaling
    flag differs, and it alone separates convergence from divergence."""
    gamma = 0.02
    loss_s, sup_s = _trajectory(use_scaling=True, gamma=gamma, a_scale=0.3,
                                steps=250)
    loss_u, sup_u = _trajectory(use_scaling=False, gamma=gamma, a_scale=1.0,
                                steps=250)
    assert np.isfinite(loss_s) and loss_s < 5.0 and sup_s < 50.0, \
        (loss_s, sup_s)
    assert (not np.isfinite(loss_u)) or loss_u > 10.0 * max(loss_s, 1e-6) \
        or sup_u > 20.0 * sup_s, (loss_u, sup_u)


# ---------------------------------------------------------------------------
# controller observability pair (ISSUE 4): injected over-compression
# ---------------------------------------------------------------------------

GMAX = 0.04        # healthy budget (k = 10 of d = 256)
GLOW = 0.004       # injected level: k = 1, below the divergence threshold
                   # for a_scale = 0.3 (gammas <= 0.01 stall at loss >= 1e2
                   # on this seeded problem; 0.04 reaches ~3e-4)
CTRL_STEPS = 900
CTRL_TAIL = 400


def _controller_trajectory(schedule: str):
    """900 steps from an over-compressed start: gamma0 = gamma_min = GLOW
    inside a GMAX ragged budget; the controller must climb out on its own
    signal.  Returns (Polyak-tail loss, per-step gammas, cum eff bytes)."""
    bl = _quadratic_problem()

    @jax.jit
    def full_loss(w):
        A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)
        return jnp.mean((A @ w - b) ** 2)

    if schedule == "fixed-max":
        compressor = Compressor(gamma=GMAX, min_compress_size=1)
        ctrl = GammaControllerConfig()
    else:
        compressor = Compressor(gamma=GLOW, max_gamma=GMAX,
                                min_compress_size=1)
        ctrl = GammaControllerConfig(schedule=schedule, gamma_min=GLOW)
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=compressor, gamma_ctrl=ctrl)
    opt = csgd_asss(cfg)
    w = jnp.zeros(D)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(SEED)
    wbar = np.zeros(D)
    navg = 0
    gammas = []
    for t in range(CTRL_STEPS):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, st, aux = step(w, st, idx)
        gammas.append(float(aux.gamma))
        if t >= CTRL_STEPS - CTRL_TAIL:
            wbar += np.asarray(w)
            navg += 1
    return (float(full_loss(jnp.asarray(wbar / navg))), gammas,
            float(aux.cum_eff_bytes))


def _burst_trajectory(fault_cfg=None, breaker=True, steps=600,
                      tail=200):
    """CSGD-ASSS on the golden quadratic through the REAL wire path —
    ``worker_compress_aggregate`` on a 1-worker mesh, optionally under
    the "faulty" §16 wrapper — with the train step's breaker gating
    (``all_finite`` gate + bit-frozen carried state on a failed check).

    Returns (Polyak-tail full loss, final HealthState, final w).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.comm.faults import FaultCtx
    from repro.core import armijo_search, next_alpha_max
    from repro.core.dcsgd import worker_compress_aggregate
    from repro.core.health import HealthState, advance_health, all_finite

    bl = _quadratic_problem()
    comp = Compressor(gamma=GMAX, min_compress_size=1)
    acfg = ArmijoConfig(sigma=0.1, a_scale=0.3)
    mesh = jax.make_mesh((1,), ("data",))
    faulty = fault_cfg is not None and fault_cfg.enabled

    def worker(w, m, amax, health, step, idx):
        def loss_fn(ww):
            return bl(ww, idx)

        g = jax.grad(loss_fn)(w)
        res = armijo_search(loss_fn, w, g, amax, acfg)
        t_name, t_ctx = "bucketed", None
        if faulty:
            t_name = "faulty"
            t_ctx = FaultCtx(cfg=fault_cfg, step=step, inner="bucketed")
        out = worker_compress_aggregate(g, m, res.eta, comp, ("data",),
                                        transport=t_name,
                                        transport_ctx=t_ctx)
        upd, m_new, tel = out[0], out[1], out[4]
        step_ok = jnp.isfinite(res.f0) & all_finite(upd)
        cand = (w - upd, m_new, next_alpha_max(res.alpha, acfg))
        if breaker:
            cand = jax.tree.map(lambda a, b: jnp.where(step_ok, a, b),
                                cand, (w, m, amax))
        health = advance_health(health, step_ok, step,
                                tel.rows_quarantined)
        return (*cand, health)

    fn = jax.jit(shard_map(worker, mesh=mesh,
                           in_specs=(P(),) * 6, out_specs=P(),
                           axis_names={"data"}))

    @jax.jit
    def full_loss(w):
        A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)
        return jnp.mean((A @ w - b) ** 2)

    w = jnp.zeros(D)
    m = jnp.zeros(D)
    amax = jnp.float32(acfg.alpha0)
    health = HealthState.init(())
    rng = np.random.default_rng(SEED)
    wbar, navg = np.zeros(D), 0
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, m, amax, health, = fn(w, m, amax, health, jnp.int32(t), idx)
        if t >= steps - tail:
            wbar += np.asarray(w)
            navg += 1
    return float(full_loss(jnp.asarray(wbar / navg))), health, w


BURST = dict(seed=7, p_nonfinite=1.0, start_step=100, n_steps=10)


def test_hostile_burst_quarantine_recovers_within_five_percent():
    """THE §16 acceptance pair, golden-seeded: a 10-step all-NaN wire
    burst mid-run.

    * quarantine on (default): every poisoned row is caught at decode,
      zero (or at most burst-length) steps skip, and the run converges
      to within 5% + noise floor of the fault-free trajectory;
    * breaker-only (quarantine disabled): each poisoned round trips the
      all-finite gate instead — skips bounded by the burst length, state
      freezes through it, and convergence still lands within the band;
    * neither (the unguarded ablation): the same burst is pinned
      divergent/stalled.
    """
    from repro.comm.faults import FaultConfig

    loss_clean, h_clean, _ = _burst_trajectory()
    assert np.isfinite(loss_clean) and loss_clean < 1e-2, loss_clean
    assert int(h_clean.steps_skipped) == 0
    assert float(h_clean.rows_quarantined) == 0.0

    # quarantine arm
    loss_q, h_q, w_q = _burst_trajectory(FaultConfig(**BURST))
    assert np.all(np.isfinite(np.asarray(w_q)))
    assert float(h_q.rows_quarantined) >= 10.0          # the whole burst
    assert int(h_q.steps_skipped) <= 10                 # <= burst length
    assert np.isfinite(loss_q)
    assert loss_q <= 1.05 * loss_clean + 5e-4, (loss_q, loss_clean)

    # breaker-only arm: the gate catches what the verdicts no longer do
    loss_b, h_b, w_b = _burst_trajectory(
        FaultConfig(quarantine=False, **BURST))
    assert np.all(np.isfinite(np.asarray(w_b)))
    assert 1 <= int(h_b.steps_skipped) <= 10
    assert int(h_b.last_good_step) > 110                # resumed after
    assert np.isfinite(loss_b)
    assert loss_b <= 1.05 * loss_clean + 5e-4, (loss_b, loss_clean)


def test_hostile_burst_unguarded_is_pinned_divergent():
    """Ablation pin: the identical burst with quarantine AND breaker off
    poisons the parameters — NaN sticks and the run never recovers."""
    from repro.comm.faults import FaultConfig

    loss_u, _, w_u = _burst_trajectory(
        FaultConfig(quarantine=False, **BURST), breaker=False, steps=200,
        tail=50)
    diverged = (not np.isfinite(loss_u)) \
        or not np.all(np.isfinite(np.asarray(w_u)))
    assert diverged, loss_u


def test_ef_coupled_recovers_injected_over_compression():
    """THE observability pair (DESIGN.md §9 caveat -> §10 fix, pinned):

    * ``armijo-coupled`` cannot see the injected over-compression — its
      telemetry comes from a line search on the *uncompressed* gradient —
      so it stays pinned at gamma_min and the run stalls orders of
      magnitude above the healthy floor;
    * ``ef-coupled`` reads the EF backlog ``||m'||/||g||``, grows gamma
      back into the budget, and restores convergence to within 5% (plus
      the trajectory-noise floor, see tests/test_gamma.py) of the
      fixed-gamma=GMAX baseline.
    """
    loss_fixed, _, _ = _controller_trajectory("fixed-max")
    loss_ef, gam_ef, _ = _controller_trajectory("ef-coupled")
    loss_arm, gam_arm, _ = _controller_trajectory("armijo-coupled")

    # healthy baseline converged
    assert np.isfinite(loss_fixed) and loss_fixed < 1e-3, loss_fixed
    # ef-coupled restored convergence: within 5% + the noise floor
    assert np.isfinite(loss_ef), loss_ef
    assert loss_ef <= 1.05 * loss_fixed + 5e-4, (loss_ef, loss_fixed)
    # ... by actually recovering gamma out of the injected hole
    assert max(gam_ef) >= 0.5 * GMAX, max(gam_ef)
    assert gam_ef[0] <= GLOW + 1e-6
    # armijo-coupled provably did not: gamma never escaped the
    # over-compressed regime (the divergence threshold is ~0.01) ...
    assert max(gam_arm) <= 0.01, max(gam_arm)
    assert gam_arm[-1] <= GLOW + 1e-6, gam_arm[-1]
    # ... and the run stalled far above both the baseline and ef-coupled
    assert (not np.isfinite(loss_arm)) or \
        loss_arm > 100.0 * max(loss_fixed, loss_ef), \
        (loss_arm, loss_fixed, loss_ef)
