"""Single-device unit tests for the federated cohort tier (DESIGN.md §13).

The multi-worker parity/convergence suite lives in tests/federated/ under
the 8-virtual-device harness; everything here runs collective-free with
``dp_axes=None`` (W=1) so it rides tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FederatedConfig, OptimizerConfig
from repro.core.compression import Compressor
from repro.fed.aggregate import (scatter_with_support, support_weighted_mean,
                                 validate_aggregation, zero_averaged_mean)
from repro.fed.clients import (cohort_compress_aggregate, init_client_state,
                               local_participation, per_client_wire_bytes)
from repro.fed.sampling import ZeroParticipationError, participation_mask


def _comp(**kw):
    base = dict(gamma=0.25, method="topk", min_compress_size=64,
                use_kernel=False)
    base.update(kw)
    return Compressor(**base)


# ---------------------------------------------------------------------------
# aggregation semantics
# ---------------------------------------------------------------------------

def test_support_counts_only_nonzero_senders():
    """Support is per-coordinate nonzero-sender count: decode-to-zero
    entries (ragged tails, padding clamps) and non-participants are
    invisible."""
    L, d = 1, 8
    vals = jnp.asarray([[[2.0, 0.0, 4.0]],     # client 0: coord 0, (3 is a
                        [[2.0, 6.0, 0.0]],     #   zero pad), coord 5
                        [[9.0, 9.0, 9.0]]])    # client 2: NOT participating
    idx = jnp.asarray([[[0, 3, 5]],
                       [[0, 3, 5]],
                       [[0, 3, 5]]], dtype=jnp.int32)
    w = jnp.asarray([1.0, 1.0, 0.0])
    total, support = scatter_with_support(vals, idx, w, L, d)
    np.testing.assert_array_equal(
        np.asarray(support[0]), [2, 0, 0, 1, 0, 1, 0, 0])
    np.testing.assert_array_equal(
        np.asarray(total[0]), [4, 0, 0, 6, 0, 4, 0, 0])
    sup = support_weighted_mean(total, support)
    np.testing.assert_array_equal(
        np.asarray(sup[0]), [2, 0, 0, 6, 0, 4, 0, 0])
    # the zero-averaging reference shrinks by the implicit zeros
    zav = zero_averaged_mean(total, jnp.float32(2.0))
    np.testing.assert_array_equal(
        np.asarray(zav[0]), [2, 0, 0, 3, 0, 2, 0, 0])


def test_support_mean_never_divides_by_zero():
    total = jnp.zeros((2, 16))
    support = jnp.zeros((2, 16))
    out = support_weighted_mean(total, support)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_validate_aggregation():
    validate_aggregation("support")
    validate_aggregation("mean")
    with pytest.raises(ValueError, match="unknown aggregation"):
        validate_aggregation("median")


# ---------------------------------------------------------------------------
# cohort exchange (W=1, collective-free)
# ---------------------------------------------------------------------------

def _cohort_inputs(key, C=6, shapes=((3, 300), (2000,), (40,))):
    grads, mem = {}, {}
    for i, s in enumerate(shapes):
        k1, k2, key = jax.random.split(key, 3)
        grads[f"l{i}"] = jax.random.normal(k1, (C,) + s)
        mem[f"l{i}"] = jax.random.normal(k2, (C,) + s) * 0.1
    return grads, mem


def test_cohort_ef_identity_participants_frozen_nonparticipants(key):
    """The EF contract per client: for participants, decode(own payload) +
    m' == m + eta*g (within quantization-free f32 exactness on the topk
    path); non-participants' memory is bit-frozen."""
    C = 6
    grads, mem = _cohort_inputs(key, C)
    comp = _comp()
    part = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    eta = jnp.float32(0.3)
    updates, new_mem, wire, eff = cohort_compress_aggregate(
        grads, mem, eta, comp, None, part)
    for name in grads:
        g, m, m2 = grads[name], mem[name], new_mem[name]
        acc = np.asarray(m, np.float32) + 0.3 * np.asarray(g, np.float32)
        for c in range(C):
            if part[c] == 0:
                np.testing.assert_array_equal(np.asarray(m2[c]),
                                              np.asarray(m[c]))
            else:
                # sent = acc - m'  must hold coordinatewise (value_bits=32
                # topk: kept values ride the wire exactly)
                sent = acc[c] - np.asarray(m2[c])
                d = sent.size
                if comp.ships_dense(np.prod(g.shape[1:])) or \
                        g[c].size < comp.min_compress_size:
                    # dense lane: whole acc ships, memory zeroed
                    np.testing.assert_allclose(np.asarray(m2[c]), 0.0)
                else:
                    kept = np.count_nonzero(sent.reshape(-1))
                    assert 0 < kept <= sent.size
                    # unsent coordinates keep the full acc in memory
                    unsent = sent.reshape(-1) == 0.0
                    np.testing.assert_allclose(
                        np.asarray(m2[c]).reshape(-1)[unsent],
                        acc[c].reshape(-1)[unsent], atol=1e-6)


def test_cohort_wire_accounting(key):
    """wire == n_participants * per-client static bytes; eff <= wire and
    counts only participants."""
    from repro.comm.bucket import build_bucket_plan

    C = 4
    grads, mem = _cohort_inputs(key, C)
    comp = _comp(max_gamma=0.5)       # adaptive: ragged eff < static wire
    shapes = [g.shape[1:] for g in jax.tree.leaves(grads)]
    stacked = [len(s) >= 2 for s in shapes]
    plan = build_bucket_plan(shapes, stacked, comp)
    per_client = per_client_wire_bytes(plan)
    for n_on in (1, 3, 4):
        part = jnp.asarray([1.0] * n_on + [0.0] * (C - n_on))
        _, _, wire, eff = cohort_compress_aggregate(
            grads, mem, 0.1, comp, None, part,
            gamma_c=jnp.full((C,), 0.25))
        assert float(wire) == n_on * per_client
        assert 0.0 < float(eff) <= float(wire)


def test_cohort_heterogeneous_gamma(key):
    """Per-client gamma_c yields per-client k_t: lower-gamma clients ship
    fewer coordinates (visible in the per-client EF sparsity) while all
    payloads still ride the one fixed-shape exchange."""
    C = 4
    grads, mem = _cohort_inputs(key, C, shapes=((4096,),))
    mem = jax.tree.map(jnp.zeros_like, mem)
    comp = _comp(gamma=0.05, max_gamma=0.5)
    gamma_c = jnp.asarray([0.05, 0.1, 0.3, 0.5])
    part = jnp.ones((C,), jnp.float32)
    _, new_mem, _, _ = cohort_compress_aggregate(
        grads, mem, 1.0, comp, None, part, gamma_c=gamma_c)
    resid = np.asarray(new_mem["l0"])
    kept = [int(np.count_nonzero(np.asarray(grads["l0"][c]) - resid[c]))
            for c in range(C)]
    assert kept[0] < kept[1] < kept[2] < kept[3]
    for c, gt in enumerate(np.asarray(gamma_c)):
        assert abs(kept[c] - round(gt * 4096)) <= 2


def test_cohort_update_is_support_weighted(key):
    """The aggregated update equals the NumPy support-weighted mean of the
    per-client sent tensors."""
    C = 3
    grads, mem = _cohort_inputs(key, C, shapes=((1500,),))
    comp = _comp()
    part = jnp.asarray([1.0, 1.0, 1.0])
    eta = jnp.float32(0.5)
    updates, new_mem, _, _ = cohort_compress_aggregate(
        grads, mem, eta, comp, None, part)
    acc = (np.asarray(mem["l0"], np.float32)
           + 0.5 * np.asarray(grads["l0"], np.float32))
    sent = acc - np.asarray(new_mem["l0"], np.float32)   # (C, d)
    supp = np.count_nonzero(sent, axis=0).astype(np.float32)
    expect = sent.sum(0) / np.maximum(supp, 1.0)
    np.testing.assert_allclose(np.asarray(updates["l0"]), expect,
                               atol=1e-6)


def test_cohort_rejects_bad_mask_shape(key):
    grads, mem = _cohort_inputs(key, C=4)
    with pytest.raises(ValueError, match="participation"):
        cohort_compress_aggregate(grads, mem, 0.1, _comp(), None,
                                  jnp.ones((3,)))


def test_cohort_vmap_matches_loop(key):
    """The vmap'd cohort encode is bit-identical to running each client
    through the same selection alone (vmap is batching, not math)."""
    C = 3
    grads, mem = _cohort_inputs(key, C, shapes=((2048,), (50,)))
    comp = _comp()
    part_all = jnp.ones((C,), jnp.float32)
    up_all, nm_all, _, _ = cohort_compress_aggregate(
        grads, mem, 0.2, comp, None, part_all)
    for c in range(C):
        g1 = jax.tree.map(lambda x: x[c:c + 1], grads)
        m1 = jax.tree.map(lambda x: x[c:c + 1], mem)
        _, nm1, _, _ = cohort_compress_aggregate(
            g1, m1, 0.2, comp, None, jnp.ones((1,), jnp.float32))
        for k in grads:
            np.testing.assert_array_equal(np.asarray(nm_all[k][c]),
                                          np.asarray(nm1[k][0]))


# ---------------------------------------------------------------------------
# client state + config plumbing
# ---------------------------------------------------------------------------

def test_init_client_state_shapes():
    params = {"w": jnp.zeros((4, 32)), "b": jnp.zeros((7,))}
    opt = OptimizerConfig(kind="csgd_asss",
                          compressor=Compressor(gamma=0.1),
                          federated=FederatedConfig(n_clients=6))
    st = init_client_state(params, opt, 6)
    assert st.memory["w"].shape == (6, 4, 32)
    assert st.memory["b"].shape == (6, 7)
    assert st.gamma.shape == st.rounds.shape == st.alpha.shape == (6,)
    assert st.rounds.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(st.rounds), 0)
    ab = init_client_state(params, opt, 6, abstract=True)
    assert ab.memory["w"].shape == (6, 4, 32)


def test_local_participation_identity_without_dp():
    m = jnp.asarray([1.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(local_participation(m, None, 3)),
                                  np.asarray(m))


def test_federated_config_validation():
    assert not FederatedConfig().enabled
    assert FederatedConfig(n_clients=8).enabled
    with pytest.raises(ValueError):
        FederatedConfig(n_clients=4, clients_per_round=5)
    with pytest.raises(ValueError):
        FederatedConfig(n_clients=4, sampling="roulette")
    with pytest.raises(ValueError):
        FederatedConfig(n_clients=4, aggregation="median")
    with pytest.raises(ValueError):
        FederatedConfig(n_clients=4, participation_rate=1.5)
    with pytest.raises(ValueError):
        OptimizerConfig(kind="csgd_asss",
                        compressor=Compressor(gamma=0.1),
                        transport="gossip",
                        federated=FederatedConfig(n_clients=4))


def test_build_train_step_rejects_bad_fed_combos():
    from repro.configs import get_smoke_config
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.launch.train_step import build_train_step
    from repro.models import build_model

    cfg = get_smoke_config("paper-lm-100m")
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    shape = ShapeConfig("t", 16, 4, "train")

    def run(**opt_kw):
        base = dict(kind="csgd_asss", compressor=Compressor(gamma=0.1),
                    federated=FederatedConfig(n_clients=4))
        base.update(opt_kw)
        return RunConfig(model=cfg, shape=shape,
                         optimizer=OptimizerConfig(**base))

    with pytest.raises(ValueError, match="compressing"):
        build_train_step(model, run(kind="sgd"), mesh)
    with pytest.raises(ValueError, match="local_steps"):
        build_train_step(model, run(local_steps=2), mesh)
    with pytest.raises(ValueError, match="shard_local_topk"):
        build_train_step(model, run(shard_local_topk=True), mesh)
    with pytest.raises(ValueError, match="schedule"):
        from repro.core.gamma import GammaControllerConfig
        build_train_step(model, run(
            compressor=Compressor(gamma=0.1, max_gamma=0.3),
            gamma_controller=GammaControllerConfig(
                schedule="ef-coupled")), mesh)


def test_sampling_fixed_no_replacement():
    m = participation_mask(32, 5, seed=1, mode="fixed", clients_per_round=8)
    assert m.shape == (32,) and int(m.sum()) == 8
    with pytest.raises(ValueError, match="out of range"):
        participation_mask(4, 0, mode="fixed", clients_per_round=9)
    with pytest.raises(ZeroParticipationError):
        participation_mask(8, 0, mode="bernoulli", rate=0.0)
