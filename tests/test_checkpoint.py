"""Full ``DistOptState`` checkpoint round-trips (ISSUE 9 satellite).

Every optional substate the train step can carry — gossip, overlap,
federated, downlink, acgd velocity — must survive ``ckpt.save`` /
``ckpt.restore`` bit-exactly AND restore into the abstract
``init_opt_state(..., abstract=True)`` skeleton (the resume path: the
launcher builds the tree_like without materializing a state).  A substate
that falls out of the NamedTuple flattening, or whose abstract skeleton
drifts from the concrete one, fails the leaf-count/shape asserts here
before it silently truncates a resumed run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import (FederatedConfig, OptimizerConfig, RunConfig,
                                ShapeConfig)
from repro.core import Compressor, GammaControllerConfig
from repro.launch.train_step import init_opt_state

W = 8


def _params(key):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (2, 256)),
        "b": jax.random.normal(ks[1], (300,)),
        "tiny": jax.random.normal(ks[2], (40,)),
    }


def _run_cfg(**opt_kw):
    from repro.configs.base import smoke_variant
    from repro.configs import get_config
    base = dict(kind="csgd_asss",
                compressor=Compressor(gamma=0.1, min_compress_size=64))
    base.update(opt_kw)
    return RunConfig(model=smoke_variant(get_config("qwen1.5-4b")),
                     shape=ShapeConfig("t", 32, 8, "train"),
                     optimizer=OptimizerConfig(**base))


def _fill_unique(tree):
    """Give every leaf a distinct, position-dependent value so a restore
    that permutes or drops leaves cannot pass the equality check."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        base = jnp.arange(leaf.size, dtype=jnp.float32).reshape(leaf.shape)
        out.append((base * 0.01 + i).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


VARIANTS = {
    "baseline": {},
    "gossip": dict(transport="gossip"),
    "overlap": dict(transport="overlap"),
    "federated": dict(federated=FederatedConfig(n_clients=4)),
    "downlink": dict(downlink="compressed",
                     downlink_gamma=GammaControllerConfig(gamma0=0.05),
                     compressor=Compressor(gamma=0.1, max_gamma=0.1,
                                           min_compress_size=64)),
    "acgd_downlink": dict(kind="acgd", downlink="compressed",
                          compressor=Compressor(gamma=0.1,
                                                min_compress_size=64)),
}


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_dist_opt_state_roundtrip(tmp_path, key, variant):
    run = _run_cfg(**VARIANTS[variant])
    params = _params(key)
    state = _fill_unique(init_opt_state(params, run, W))
    # the variant actually carries its substate (guards against a config
    # change silently disabling what this test is meant to cover)
    if variant == "gossip":
        assert state.gossip != ()
    if variant == "overlap":
        assert state.overlap != ()
    if variant == "federated":
        assert state.fed != () and state.memory == ()
    if "downlink" in variant:
        assert state.downlink != ()
    if variant.startswith("acgd"):
        assert state.velocity != ()

    d = str(tmp_path / variant)
    ckpt.save(d, 7, state, metadata={"variant": variant})
    restored, meta = ckpt.restore(d, state)
    assert meta["variant"] == variant
    for i, (a, b) in enumerate(zip(jax.tree.leaves(state),
                                   jax.tree.leaves(restored))):
        assert a.dtype == b.dtype, i
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{variant} leaf {i}")


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_restore_into_abstract_skeleton(tmp_path, key, variant):
    """The resume path: tree_like comes from ``abstract=True`` — it must
    agree with the concrete state leaf-for-leaf (count, shape, dtype)."""
    run = _run_cfg(**VARIANTS[variant])
    params = _params(key)
    state = _fill_unique(init_opt_state(params, run, W))
    skel = init_opt_state(jax.eval_shape(lambda: params), run, W,
                          abstract=True)
    c_leaves = jax.tree.leaves(state)
    s_leaves = jax.tree.leaves(skel)
    assert len(c_leaves) == len(s_leaves), variant
    for i, (c, s) in enumerate(zip(c_leaves, s_leaves)):
        assert tuple(c.shape) == tuple(s.shape), (variant, i)
        assert c.dtype == s.dtype, (variant, i)
    assert jax.tree.structure(state) == jax.tree.structure(skel)

    d = str(tmp_path / variant)
    ckpt.save(d, 3, state)
    restored, _ = ckpt.restore(d, skel)
    for a, b in zip(c_leaves, jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_mismatched_skeleton(tmp_path, key):
    """Loading a downlink checkpoint into a dense-downlink skeleton must
    fail loudly (leaf-count assert), not silently drop the server EF."""
    params = _params(key)
    state = init_opt_state(params, _run_cfg(**VARIANTS["downlink"]), W)
    d = str(tmp_path / "mismatch")
    ckpt.save(d, 1, state)
    plain = init_opt_state(params, _run_cfg(), W, abstract=True)
    with pytest.raises(AssertionError):
        ckpt.restore(d, plain)


# ---------------------------------------------------------------------------
# crash-safety: torn writes, truncated files, fallback (DESIGN.md §16)
# ---------------------------------------------------------------------------

import logging  # noqa: E402
import os  # noqa: E402


def _two_committed(tmp_path, key):
    state = _fill_unique(init_opt_state(_params(key), _run_cfg(), W))
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, state, metadata={"tag": "one"})
    ckpt.save(d, 2, state, metadata={"tag": "two"})
    return d, state


def _truncate(d, step, name="arrays.npz"):
    p = os.path.join(d, f"step_{step:010d}", name)
    with open(p, "rb") as f:
        blob = f.read()
    with open(p, "wb") as f:
        f.write(blob[:len(blob) // 2])


def test_restore_falls_back_on_truncated_npz(tmp_path, key, caplog):
    """A torn arrays.npz in the newest checkpoint must not strand the
    run: restore(step=None) warns and answers with the older step."""
    d, state = _two_committed(tmp_path, key)
    _truncate(d, 2)
    with caplog.at_level(logging.WARNING,
                         logger="repro.checkpoint.checkpoint"):
        restored, meta = ckpt.restore(d, state)
    assert meta["tag"] == "one"
    assert any("step_0000000002" in r.message and "corrupt" in r.message
               for r in caplog.records)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_falls_back_on_garbage_manifest(tmp_path, key):
    d, state = _two_committed(tmp_path, key)
    with open(os.path.join(d, "step_0000000002", "manifest.json"),
              "w") as f:
        f.write("{not json")
    _, meta = ckpt.restore(d, state)
    assert meta["tag"] == "one"


def test_restore_falls_back_on_missing_file(tmp_path, key):
    d, state = _two_committed(tmp_path, key)
    os.remove(os.path.join(d, "step_0000000002", "arrays.npz"))
    _, meta = ckpt.restore(d, state)
    assert meta["tag"] == "one"


def test_restore_explicit_step_raises_on_corruption(tmp_path, key):
    """An explicitly requested step must raise, never silently answer
    with a different step's data."""
    d, state = _two_committed(tmp_path, key)
    _truncate(d, 2)
    with pytest.raises(ckpt.CORRUPTION_ERRORS):
        ckpt.restore(d, state, step=2)
    _, meta = ckpt.restore(d, state, step=1)    # the good one still loads
    assert meta["tag"] == "one"


def test_restore_every_step_corrupt_raises(tmp_path, key):
    d, state = _two_committed(tmp_path, key)
    _truncate(d, 1)
    _truncate(d, 2)
    with pytest.raises(FileNotFoundError, match="corrupt"):
        ckpt.restore(d, state)


def test_discovery_ignores_uncommitted_and_tmp_dirs(tmp_path, key):
    """A crash mid-save leaves a .tmp dir (even one with a COMMITTED
    marker inside) or a dir without the marker — both invisible."""
    d, state = _two_committed(tmp_path, key)
    torn = os.path.join(d, "step_0000000005.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "COMMITTED"), "w") as f:
        f.write("ok")
    os.makedirs(os.path.join(d, "step_0000000006"))
    assert ckpt.all_steps(d) == [1, 2]
    assert ckpt.latest_step(d) == 2
    _, meta = ckpt.restore(d, state)
    assert meta["tag"] == "two"


def test_resave_same_step_is_atomic(tmp_path, key):
    """Overwriting an existing step keeps a committed copy discoverable
    throughout and leaves no .old/.tmp debris."""
    d, state = _two_committed(tmp_path, key)
    ckpt.save(d, 2, state, metadata={"tag": "two-redux"})
    assert ckpt.all_steps(d) == [1, 2]
    _, meta = ckpt.restore(d, state)
    assert meta["tag"] == "two-redux"
    leftovers = [n for n in os.listdir(d)
                 if n.endswith(".tmp") or n.endswith(".old")]
    assert leftovers == []
