"""Distributed integration tests (subprocess with 8 forced host devices so
the main pytest process keeps its single-device view).

Covers: DCSGD-ASSS == single-node CSGD-ASSS when every worker sees the same
batch; the compressed train step's only dp collective is the sparse
all-gather; decode step compiles with seq-sharded caches; the dry-run module
works end-to-end on a small mesh.
"""
import json
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_dcsgd_equals_csgd_same_data():
    """With identical per-worker batches, the distributed all-gather mean of
    identical sparse updates == the single-node compressed update."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig, OptimizerConfig, ShapeConfig
        from repro.core import Compressor, ArmijoConfig, CSGDConfig, csgd_asss
        from repro.models import build_model
        from repro.launch.train_step import build_train_step, init_opt_state, opt_state_shardings
        from repro.compat import set_mesh
        from repro.sharding import param_shardings
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("qwen1.5-4b")
        m = build_model(cfg)
        comp = Compressor(gamma=0.1, min_compress_size=64)
        arm = ArmijoConfig()
        run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                        optimizer=OptimizerConfig(kind="csgd_asss",
                                                  armijo=arm, compressor=comp))
        with set_mesh(mesh):
            params = m.init(jax.random.PRNGKey(0))
            one = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                                0, cfg.vocab_size)}
            batch = {"tokens": jnp.tile(one["tokens"], (4, 1))}  # same data 4x
            params = jax.device_put(params, param_shardings(params, mesh))
            st = init_opt_state(params, run, 4)
            st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
            batch = jax.device_put(batch, jax.tree.map(
                lambda _: NamedSharding(mesh, P("data")), batch))
            step = build_train_step(m, run, mesh)(params, batch)
            p_dist, st_dist, metrics = step(params, st, batch)

        # single-node reference on the same (single-worker) batch
        opt = csgd_asss(CSGDConfig(armijo=arm, compressor=comp))
        p0 = m.init(jax.random.PRNGKey(0))
        s0 = opt.init(p0)
        p_ref, s_ref, aux = opt.step(lambda p: m.loss(p, one)[0], p0, s0)

        da = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p_dist, p_ref)
        worst = max(jax.tree.leaves(da))
        print("MAXDIFF", worst)
        print("LOSSDIFF", abs(float(metrics["loss"]) - float(aux.loss)))
        assert worst < 5e-3, worst
        assert abs(float(metrics["loss"]) - float(aux.loss)) < 1e-4
    """)
    assert "MAXDIFF" in out


def test_compressed_step_trains_and_saves_wire_bytes():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig, OptimizerConfig, ShapeConfig
        from repro.core import Compressor, ArmijoConfig
        from repro.models import build_model
        from repro.launch.train_step import build_train_step, init_opt_state, opt_state_shardings
        from repro.compat import set_mesh
        from repro.sharding import param_shardings
        from repro.data.synthetic import TokenPipeline
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("yi-34b")
        m = build_model(cfg)
        def mkrun(kind, gamma=0.05):
            return RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                optimizer=OptimizerConfig(kind=kind, armijo=ArmijoConfig(),
                    compressor=Compressor(gamma=gamma, min_compress_size=64),
                    eta=0.05))
        pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
        with set_mesh(mesh):
            results = {}
            for kind in ("csgd_asss", "dense"):
                run = mkrun(kind)
                params = m.init(jax.random.PRNGKey(0))
                params = jax.device_put(params, param_shardings(params, mesh))
                st = init_opt_state(params, run, 4)
                st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
                step = None
                for i in range(12):
                    b = jax.device_put(pipe.batch(i), jax.tree.map(
                        lambda _: NamedSharding(mesh, P("data")), pipe.batch(i)))
                    if step is None:
                        step = build_train_step(m, run, mesh)(params, b)
                    params, st, metrics = step(params, st, b)
                results[kind] = {k: float(v) for k, v in metrics.items()}
            print("CSGD", results["csgd_asss"])
            print("DENSE", results["dense"])
            assert results["csgd_asss"]["loss"] < 7.0
            # compression reduces wire bytes by >5x at gamma=0.05
            assert results["csgd_asss"]["wire_bytes"] * 5 < results["dense"]["wire_bytes"]
    """)
    assert "CSGD" in out


def test_decode_step_seq_sharded_cache_compiles():
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig, OptimizerConfig, ShapeConfig
        from repro.models import build_model
        from repro.launch.train_step import build_decode_step
        from repro.compat import set_mesh
        import re

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("yi-34b")
        m = build_model(cfg)
        shape = ShapeConfig("d", 256, 8, "decode")
        run = RunConfig(model=cfg, shape=shape)
        with set_mesh(mesh):
            params_like = jax.eval_shape(m.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
            cache_like = jax.eval_shape(lambda: m.init_cache(8, 256))
            tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
            step = build_decode_step(m, run, mesh, shape)(params_like, tok, cache_like)
            co = step.lower(params_like, tok, cache_like, jnp.int32(255)).compile()
            txt = co.as_text()
            assert "all-reduce" in txt  # flash-decode combine over seq shards
            from repro.compat import cost_analysis
            print("DECODE_OK", cost_analysis(co).get("flops"))
    """)


def test_dryrun_smoke_combo():
    """The dry-run machinery itself (uses its own 512-device env)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "rwkv6-1.6b",
         "--shape", "decode_32k", "--out", "/tmp/_test_dryrun.json"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr
    rec = json.load(open("/tmp/_test_dryrun.json"))[0]
    assert rec["status"] == "ok", rec
    assert rec["flops_per_chip"] > 0
    assert rec["collectives"]["total_wire_bytes"] > 0


def test_moe_expert_parallel_exact():
    """Expert-parallel shard_map MoE == single-device baseline (no_drop)."""
    run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.compat import set_mesh
        from repro.models import moe as moe_mod

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                                  n_experts=8, experts_per_token=2,
                                  capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        p = moe_mod.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y_base, _ = moe_mod.moe_block(p, x, cfg, no_drop=True)
        with set_mesh(mesh):
            cfg_ep = dataclasses.replace(cfg, moe_expert_parallel=True)
            psh = {"router": {"w": NamedSharding(mesh, P())},
                   "wg": NamedSharding(mesh, P("model")),
                   "wi": NamedSharding(mesh, P("model")),
                   "wo": NamedSharding(mesh, P("model"))}
            pd = jax.device_put(p, psh)
            xd = jax.device_put(x, NamedSharding(mesh, P("data")))
            y_ep, _ = jax.jit(lambda p, x: moe_mod.moe_block(
                p, x, cfg_ep, no_drop=True))(pd, xd)
        err = float(jnp.max(jnp.abs(y_base - y_ep)))
        assert err < 1e-4, err
        print("EP_EXACT", err)
    """)
