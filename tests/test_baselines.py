"""Coverage for core/baselines.py (previously untested): the fixed-step
compressed baseline's parity with CSGD(armijo=None), plus SGD/SLS sanity on
the paper's quadratic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Compressor, CSGDConfig, NonAdaptiveCSGD, SGD, SLS,
                        csgd_asss)
from repro.data.synthetic import interpolated_regression

D = 256
N = 512


def _problem(seed=0):
    A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=seed)

    def bl(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)

    return bl


def _drive(opt, bl, steps, seed=0):
    w = jnp.zeros(D)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(seed)
    aux = None
    for _ in range(steps):
        idx = jnp.asarray(rng.integers(0, N, 32))
        w, st, aux = step(w, st, idx)
    return w, st, aux


@pytest.mark.parametrize("method", ["topk", "block_topk"])
def test_nonadaptive_matches_csgd_without_armijo(method):
    """NonAdaptiveCSGD == CSGD(armijo=None) step for step on the quadratic:
    identical iterates through compression + EF (the CSGD docstring's
    'also covers the non-adaptive baseline' claim, now actually true)."""
    bl = _problem()
    eta = 0.01
    comp = Compressor(gamma=0.05, method=method, block=64,
                      min_compress_size=1)
    w1, s1, a1 = _drive(csgd_asss(CSGDConfig(armijo=None, eta=eta,
                                             compressor=comp)), bl, 60)
    w2, s2, a2 = _drive(NonAdaptiveCSGD(eta=eta, compressor=comp), bl, 60)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.memory), np.asarray(s2.memory),
                               atol=1e-6)
    assert float(a1.loss) == pytest.approx(float(a2.loss), rel=1e-5)
    # the fixed-step aux surface reports no search activity
    assert int(a1.n_evals) == 0
    assert float(a1.alpha) == pytest.approx(eta)


def test_nonadaptive_converges_at_paper_step():
    """[3]-style baseline at the paper's 0.01 step converges on the
    interpolated quadratic (its §IV comparison point)."""
    bl = _problem()
    comp = Compressor(gamma=0.05, min_compress_size=1)
    _, _, aux = _drive(NonAdaptiveCSGD(eta=0.01, compressor=comp), bl, 400)
    assert np.isfinite(float(aux.loss)) and float(aux.loss) < 1.0


def test_sgd_momentum_state_and_descent():
    bl = _problem()
    w, st, aux = _drive(SGD(eta=0.005, beta=0.9), bl, 300)
    assert np.isfinite(float(aux.loss)) and float(aux.loss) < 5.0
    assert st.momentum is not None
    # plain SGD carries no momentum tree
    _, st2, _ = _drive(SGD(eta=0.01), bl, 5)
    assert st2.momentum is None


def test_sls_tracks_armijo_and_converges():
    bl = _problem()
    _, st, aux = _drive(SLS(), bl, 300)
    assert np.isfinite(float(aux.loss)) and float(aux.loss) < 0.5
    assert 0.0 < float(st.alpha_prev) <= 1e6
    assert int(aux.n_evals) >= 1
