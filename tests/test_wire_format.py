"""Unit tests for the bit-packed wire format (DESIGN.md §8): field<->word
pack/unpack (ref vs Pallas), codec round-trips, payload byte accounting,
and the tie-handling regression in the fused wire extraction."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import wire as wire_fmt
from repro.core import Compressor
from repro.core.compression import block_extract_sparse
from repro.core.dcsgd import worker_compress_aggregate
from repro.kernels import ops


# ---------------------------------------------------------------------------
# field <-> word packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8, 16, 32])
@pytest.mark.parametrize("n", [1, 7, 64, 1000, 4097])
def test_pack_unpack_fields_roundtrip(key, bits, n):
    """pack -> unpack recovers every field exactly, for odd lengths that
    exercise the zero-padding to whole words."""
    hi = np.uint32(1) << np.uint32(bits - 1)  # keep values within the field
    fields = jnp.asarray(
        np.random.default_rng(bits * 1000 + n).integers(
            0, int(hi), (3, n), dtype=np.uint32))
    words = ops.pack_fields(fields, bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (3, -(-n * bits // 32))
    back = ops.unpack_fields(words, n, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(fields))


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pack_fields_ref_pallas_parity(key, bits):
    fields = jnp.asarray(np.random.default_rng(bits).integers(
        0, 1 << bits, (5, 777), dtype=np.uint32))
    w_ref = ops.pack_fields(fields, bits, impl="ref")
    w_pal = ops.pack_fields(fields, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_pal))
    f_ref = ops.unpack_fields(w_ref, 777, bits, impl="ref")
    f_pal = ops.unpack_fields(w_ref, 777, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_pal))


def test_pack_masks_out_of_range_fields():
    """Fields wider than ``bits`` are masked, not smeared into neighbors."""
    fields = jnp.full((1, 8), 0xFFFFFFFF, jnp.uint32)
    words = ops.pack_fields(fields, 8)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.full((1, 2), 0xFFFFFFFF, np.uint32))
    back = ops.unpack_fields(words, 8, 8)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.full((1, 8), 0xFF, np.uint32))


def test_wire_ops_registered():
    from repro.kernels import dispatch
    reg = dispatch.registered()
    for op in ("wire_pack", "wire_unpack"):
        assert set(reg[op]) == {"ref", "pallas-interpret", "pallas-tpu"}, op
        # backend policy: vectorized jnp ref on CPU, the kernel on TPU
        assert dispatch._POLICY[op] == "backend"


# ---------------------------------------------------------------------------
# codec: WireSpec layout + encode/decode round-trips
# ---------------------------------------------------------------------------

def test_wirespec_layout_math():
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    spec = wire_fmt.WireSpec.for_row(comp, 2048)
    k = 4 * comp.block_k()
    assert spec.k == k and spec.local and spec.index_bits == 16
    assert spec.header_words == 1
    assert spec.index_words == -(-k * 16 // 32)
    assert spec.value_words == -(-k * 8 // 32)
    assert spec.row_bytes == 4 * (1 + spec.index_words + spec.value_words)
    assert spec.row_bytes == comp.wire_bytes(2048)
    # uncompressed rows have no packed payload
    assert wire_fmt.WireSpec.for_row(Compressor(method="none"), 2048) is None
    # block padding can push nb*k_b PAST d at large gamma: such rows ship
    # dense (matching dcsgd's pmean branch), never a None spec deref
    fat = Compressor(gamma=0.55, method="block_topk", block=1024,
                     min_compress_size=64)
    assert fat.sparse_k(1100) >= 1100
    assert fat.wire_bytes(1100) == 1100 * 4
    # flat 32-bit indices once d outgrows 16-bit addressing (topk)
    big = wire_fmt.WireSpec.for_row(
        Compressor(gamma=0.01, method="topk"), 100000)
    assert big.index_bits == 32 and not big.local


def test_wirespec_rejects_bad_widths():
    with pytest.raises(ValueError):
        wire_fmt.WireSpec(k=8, d=64, value_bits=12, index_bits=16,
                          local=False)
    with pytest.raises(ValueError):
        wire_fmt.WireSpec(k=8, d=64, value_bits=8, index_bits=8, local=False)


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
@pytest.mark.parametrize("d", [1300, 2048, 4097])
def test_encode_decode_roundtrip(key, value_bits, d):
    """decode(encode(vals, idx)) == (quantize_values(vals), idx) exactly,
    including odd row sizes with padded last blocks."""
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=64, value_bits=value_bits)
    x = jax.random.normal(key, (3, d))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    payload = wire_fmt.encode_rows(vals, idx, spec)
    assert payload.dtype == jnp.uint32
    assert payload.nbytes == 3 * comp.wire_bytes(d)
    v2, i2 = wire_fmt.decode_rows(payload, spec)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(comp.quantize_values(vals)))


def test_encode_decode_negative_values_sign_extension(key):
    """Two's-complement sub-byte fields: all-negative rows survive."""
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64, value_bits=4)
    x = -jnp.abs(jax.random.normal(key, (1, 1024))) - 0.5
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, 1024)
    v2, _ = wire_fmt.decode_rows(wire_fmt.encode_rows(vals, idx, spec), spec)
    assert np.all(np.asarray(v2) < 0)
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(comp.quantize_values(vals)))


# ---------------------------------------------------------------------------
# tie handling in the fused wire extraction (ROADMAP open item)
# ---------------------------------------------------------------------------

def _run_worker(tree, comp, eta=1.0):
    """worker_compress_aggregate under a 1-device shard_map (W == 1, so the
    returned update IS this worker's decoded wire contribution)."""
    from repro.compat import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    mem = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        functools.partial(worker_compress_aggregate, comp=comp,
                          dp_axes=("data",)),
        mesh=mesh, in_specs=(spec, spec, P()), out_specs=(spec, spec, P()),
        axis_names={"data"})
    return jax.jit(f)(tree, mem, jnp.float32(eta))


@pytest.mark.parametrize("value_bits", [16, 8, 32])
def test_tie_drop_correction_regression(value_bits):
    """A block with MORE than k_b entries exactly at tau: the wire ships
    exactly k_b of them (documented drop) and the dropped tied entries are
    recycled into the EF memory by the decoded-payload correction, so
    sent + m' == acc holds bit-exactly.  Historically the correction only
    ran under value_bits<32; the packed wire applies it always (the
    residual is taken against what receivers actually decode)."""
    comp = Compressor(gamma=0.01, method="block_topk", block=512,
                      min_compress_size=64, value_bits=value_bits)
    k_b = comp.block_k()             # = 5
    assert k_b == 5
    d = 1024                         # two 512-wide blocks
    rng = np.random.default_rng(0)
    acc = rng.uniform(-1.0, 1.0, d).astype(np.float32)
    # block 0: EIGHT entries tied exactly at |acc| == 3.0 (> k_b of them)
    tied = np.array([3.0, -3.0, 3.0, 3.0, -3.0, 3.0, 3.0, -3.0], np.float32)
    acc[:8] = tied
    tree = {"x": jnp.asarray(acc)}

    upd, mem, wire = _run_worker(tree, comp, eta=1.0)  # m=0, eta=1 -> acc
    upd, mem = np.asarray(upd["x"]), np.asarray(mem["x"])

    # drop semantics: exactly k_b entries per block survive on the wire
    assert np.count_nonzero(upd[:512]) == k_b
    assert np.count_nonzero(upd[512:]) == k_b
    kept_ties = np.count_nonzero(upd[:8])
    assert kept_ties == k_b          # all five winners come from the tie
    # correction semantics: dropped tied entries live on in the EF memory
    dropped = np.count_nonzero(mem[:8])
    assert dropped == 8 - k_b
    # and the EF identity is bit-exact through the packed wire
    np.testing.assert_array_equal(upd + mem, acc)


def test_tie_drop_matches_unfused_path():
    """The fused-kernel tie semantics equal the pure-jnp escape hatch."""
    comp_kwargs = dict(gamma=0.01, method="block_topk", block=512,
                       min_compress_size=64, value_bits=8)
    d = 1024
    rng = np.random.default_rng(1)
    acc = rng.uniform(-1.0, 1.0, d).astype(np.float32)
    acc[:8] = 2.5
    tree = {"x": jnp.asarray(acc)}
    u_k, m_k, w_k = _run_worker(tree, Compressor(use_kernel=True,
                                                 **comp_kwargs))
    u_j, m_j, w_j = _run_worker(tree, Compressor(use_kernel=False,
                                                 **comp_kwargs))
    np.testing.assert_array_equal(np.asarray(u_k["x"]), np.asarray(u_j["x"]))
    np.testing.assert_array_equal(np.asarray(m_k["x"]), np.asarray(m_j["x"]))
    assert float(w_k) == float(w_j)
