"""Unit tests for the bit-packed wire format (DESIGN.md §8): field<->word
pack/unpack (ref vs Pallas), codec round-trips, payload byte accounting,
and the tie-handling regression in the fused wire extraction."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import wire as wire_fmt
from repro.core import Compressor
from repro.core.compression import block_extract_sparse
from repro.core.dcsgd import worker_compress_aggregate
from repro.kernels import ops


# ---------------------------------------------------------------------------
# field <-> word packing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8, 16, 32])
@pytest.mark.parametrize("n", [1, 7, 64, 1000, 4097])
def test_pack_unpack_fields_roundtrip(key, bits, n):
    """pack -> unpack recovers every field exactly, for odd lengths that
    exercise the zero-padding to whole words."""
    hi = np.uint32(1) << np.uint32(bits - 1)  # keep values within the field
    fields = jnp.asarray(
        np.random.default_rng(bits * 1000 + n).integers(
            0, int(hi), (3, n), dtype=np.uint32))
    words = ops.pack_fields(fields, bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (3, -(-n * bits // 32))
    back = ops.unpack_fields(words, n, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(fields))


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pack_fields_ref_pallas_parity(key, bits):
    fields = jnp.asarray(np.random.default_rng(bits).integers(
        0, 1 << bits, (5, 777), dtype=np.uint32))
    w_ref = ops.pack_fields(fields, bits, impl="ref")
    w_pal = ops.pack_fields(fields, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_pal))
    f_ref = ops.unpack_fields(w_ref, 777, bits, impl="ref")
    f_pal = ops.unpack_fields(w_ref, 777, bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_pal))


def test_pack_masks_out_of_range_fields():
    """Fields wider than ``bits`` are masked, not smeared into neighbors."""
    fields = jnp.full((1, 8), 0xFFFFFFFF, jnp.uint32)
    words = ops.pack_fields(fields, 8)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.full((1, 2), 0xFFFFFFFF, np.uint32))
    back = ops.unpack_fields(words, 8, 8)
    np.testing.assert_array_equal(np.asarray(back),
                                  np.full((1, 8), 0xFF, np.uint32))


def test_wire_ops_registered():
    from repro.kernels import dispatch
    reg = dispatch.registered()
    for op in ("wire_pack", "wire_unpack"):
        assert set(reg[op]) == {"ref", "pallas-interpret", "pallas-tpu"}, op
        # backend policy: vectorized jnp ref on CPU, the kernel on TPU
        assert dispatch._POLICY[op] == "backend"


# ---------------------------------------------------------------------------
# codec: WireSpec layout + encode/decode round-trips
# ---------------------------------------------------------------------------

def test_wirespec_layout_math():
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    spec = wire_fmt.WireSpec.for_row(comp, 2048)
    k = 4 * comp.block_k()
    assert spec.k == k and spec.local and spec.index_bits == 16
    assert spec.header_words == 1
    assert spec.index_words == -(-k * 16 // 32)
    assert spec.value_words == -(-k * 8 // 32)
    assert spec.row_bytes == 4 * (1 + spec.index_words + spec.value_words)
    assert spec.row_bytes == comp.wire_bytes(2048)
    # uncompressed rows have no packed payload
    assert wire_fmt.WireSpec.for_row(Compressor(method="none"), 2048) is None
    # block padding can push nb*k_b PAST d at large gamma: such rows ship
    # dense (matching dcsgd's pmean branch), never a None spec deref
    fat = Compressor(gamma=0.55, method="block_topk", block=1024,
                     min_compress_size=64)
    assert fat.sparse_k(1100) >= 1100
    assert fat.wire_bytes(1100) == 1100 * 4
    # flat 32-bit indices once d outgrows 16-bit addressing (topk)
    big = wire_fmt.WireSpec.for_row(
        Compressor(gamma=0.01, method="topk"), 100000)
    assert big.index_bits == 32 and not big.local


def test_wirespec_rejects_bad_widths():
    with pytest.raises(ValueError):
        wire_fmt.WireSpec(k=8, d=64, value_bits=12, index_bits=16,
                          local=False)
    with pytest.raises(ValueError):
        wire_fmt.WireSpec(k=8, d=64, value_bits=8, index_bits=8, local=False)


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
@pytest.mark.parametrize("d", [1300, 2048, 4097])
def test_encode_decode_roundtrip(key, value_bits, d):
    """decode(encode(vals, idx)) == (quantize_values(vals), idx) exactly,
    including odd row sizes with padded last blocks."""
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=64, value_bits=value_bits)
    x = jax.random.normal(key, (3, d))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    payload = wire_fmt.encode_rows(vals, idx, spec)
    assert payload.dtype == jnp.uint32
    assert payload.nbytes == 3 * comp.wire_bytes(d)
    v2, i2 = wire_fmt.decode_rows(payload, spec)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(comp.quantize_values(vals)))


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
@pytest.mark.parametrize("ragged", [False, True])
def test_roundtrip_rows_matches_encode_decode(key, value_bits, ragged):
    """``roundtrip_rows`` (the overlap transport's launch-free own-payload
    view, DESIGN.md §14) is BIT-IDENTICAL to a literal
    decode_rows(encode_rows(...)) at every value width, ragged counts
    included — so the delay-1 EF residual equals the one a real decode of
    the carried payload would produce."""
    d = 1300
    comp = Compressor(gamma=0.05, max_gamma=0.05 if ragged else 0.0,
                      method="block_topk", block=256, min_compress_size=64,
                      value_bits=value_bits)
    x = jax.random.normal(key, (3, d))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    assert spec.ragged == ragged
    counts = None
    if ragged:
        counts = jnp.asarray(
            np.random.default_rng(value_bits).integers(
                1, spec.full_count + 1, 3), jnp.int32)
    ref = wire_fmt.decode_rows(
        wire_fmt.encode_rows(vals, idx, spec, counts=counts), spec)
    got = wire_fmt.roundtrip_rows(vals, idx, spec, counts=counts)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))


def test_encode_decode_negative_values_sign_extension(key):
    """Two's-complement sub-byte fields: all-negative rows survive."""
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64, value_bits=4)
    x = -jnp.abs(jax.random.normal(key, (1, 1024))) - 0.5
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, 1024)
    v2, _ = wire_fmt.decode_rows(wire_fmt.encode_rows(vals, idx, spec), spec)
    assert np.all(np.asarray(v2) < 0)
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(comp.quantize_values(vals)))


# ---------------------------------------------------------------------------
# tie handling in the fused wire extraction (ROADMAP open item)
# ---------------------------------------------------------------------------

def _run_worker(tree, comp, eta=1.0, gamma_t=None):
    """worker_compress_aggregate under a 1-device shard_map (W == 1, so the
    returned update IS this worker's decoded wire contribution)."""
    from repro.compat import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    mem = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        functools.partial(worker_compress_aggregate, comp=comp,
                          dp_axes=("data",), gamma_t=gamma_t),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(spec, spec, P(), P(), P()), axis_names={"data"})
    # telemetry (the 5th output) has dedicated coverage in
    # tests/test_property.py and tests/distributed/test_telemetry_exchange
    return jax.jit(f)(tree, mem, jnp.float32(eta))[:4]


@pytest.mark.parametrize("value_bits", [16, 8, 32])
def test_tie_drop_correction_regression(value_bits):
    """A block with MORE than k_b entries exactly at tau: the wire ships
    exactly k_b of them (documented drop) and the dropped tied entries are
    recycled into the EF memory by the decoded-payload correction, so
    sent + m' == acc holds bit-exactly.  Historically the correction only
    ran under value_bits<32; the packed wire applies it always (the
    residual is taken against what receivers actually decode)."""
    comp = Compressor(gamma=0.01, method="block_topk", block=512,
                      min_compress_size=64, value_bits=value_bits)
    k_b = comp.block_k()             # = 5
    assert k_b == 5
    d = 1024                         # two 512-wide blocks
    rng = np.random.default_rng(0)
    acc = rng.uniform(-1.0, 1.0, d).astype(np.float32)
    # block 0: EIGHT entries tied exactly at |acc| == 3.0 (> k_b of them)
    tied = np.array([3.0, -3.0, 3.0, 3.0, -3.0, 3.0, 3.0, -3.0], np.float32)
    acc[:8] = tied
    tree = {"x": jnp.asarray(acc)}

    upd, mem, wire, _ = _run_worker(tree, comp, eta=1.0)  # m=0, eta=1 -> acc
    upd, mem = np.asarray(upd["x"]), np.asarray(mem["x"])

    # drop semantics: exactly k_b entries per block survive on the wire
    assert np.count_nonzero(upd[:512]) == k_b
    assert np.count_nonzero(upd[512:]) == k_b
    kept_ties = np.count_nonzero(upd[:8])
    assert kept_ties == k_b          # all five winners come from the tie
    # correction semantics: dropped tied entries live on in the EF memory
    dropped = np.count_nonzero(mem[:8])
    assert dropped == 8 - k_b
    # and the EF identity is bit-exact through the packed wire
    np.testing.assert_array_equal(upd + mem, acc)


def test_tie_drop_matches_unfused_path():
    """The fused-kernel tie semantics equal the pure-jnp escape hatch."""
    comp_kwargs = dict(gamma=0.01, method="block_topk", block=512,
                       min_compress_size=64, value_bits=8)
    d = 1024
    rng = np.random.default_rng(1)
    acc = rng.uniform(-1.0, 1.0, d).astype(np.float32)
    acc[:8] = 2.5
    tree = {"x": jnp.asarray(acc)}
    u_k, m_k, w_k, _ = _run_worker(tree, Compressor(use_kernel=True,
                                                    **comp_kwargs))
    u_j, m_j, w_j, _ = _run_worker(tree, Compressor(use_kernel=False,
                                                    **comp_kwargs))
    np.testing.assert_array_equal(np.asarray(u_k["x"]), np.asarray(u_j["x"]))
    np.testing.assert_array_equal(np.asarray(m_k["x"]), np.asarray(m_j["x"]))
    assert float(w_k) == float(w_j)


# ---------------------------------------------------------------------------
# ragged payloads: valid-count header + decode-honors-count (DESIGN.md §9)
# ---------------------------------------------------------------------------

def _ragged_comp(**kw):
    base = dict(gamma=0.05, max_gamma=0.05, method="block_topk", block=256,
                min_compress_size=64, value_bits=8)
    base.update(kw)
    return Compressor(**base)


def test_ragged_spec_layout():
    """Adaptive compressors get a count header word; the static budget
    bytes stay the trace-time bound."""
    comp = _ragged_comp()
    spec = wire_fmt.WireSpec.for_row(comp, 2048)
    assert spec.ragged
    plain = wire_fmt.WireSpec.for_row(
        Compressor(gamma=0.05, method="block_topk", block=256,
                   min_compress_size=64, value_bits=8), 2048)
    assert not plain.ragged
    assert spec.header_words == plain.header_words + 1
    assert spec.row_bytes == plain.row_bytes + 4
    assert comp.wire_bytes(2048) == spec.row_bytes
    # effective bytes at full count == the static budget; below it, less
    assert float(spec.effective_row_bytes(spec.full_count)) == spec.row_bytes
    assert float(spec.effective_row_bytes(1)) < spec.row_bytes
    # geometry comes from max_gamma, not gamma
    assert _ragged_comp(gamma=0.01).k_for(2048) == comp.k_for(2048)


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
@pytest.mark.parametrize("method", ["block_topk", "topk"])
def test_ragged_roundtrip_random_counts(method, value_bits):
    """encode(counts) -> decode masks exactly the invalid suffix of each
    period, per row, for random counts in [1, full_count] — both index
    layouts, every value width."""
    comp = _ragged_comp(method=method, value_bits=value_bits)
    d = 1300
    rng = np.random.default_rng(value_bits)
    x = jnp.asarray(rng.standard_normal((4, d)).astype(np.float32))
    if method == "block_topk":
        vals, idx = block_extract_sparse(x, comp)
    else:
        from repro.core.dcsgd import _per_layer_topk
        vals, idx = _per_layer_topk(x, comp.k_for(d))
    spec = wire_fmt.WireSpec.for_row(comp, d)
    counts = jnp.asarray(rng.integers(1, spec.full_count + 1, 4),
                         jnp.int32)
    payload = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
    assert payload.nbytes == 4 * comp.wire_bytes(d)   # fixed budget buffer
    # runtime pricing reads the counts straight from the header words
    from repro.comm.exchange import effective_payload_bytes
    np.testing.assert_allclose(
        float(effective_payload_bytes(payload, spec)),
        float(jnp.sum(spec.effective_row_bytes(counts))))
    assert float(effective_payload_bytes(payload, spec)) <= payload.nbytes
    v2, i2, c2 = wire_fmt.decode_rows(payload, spec, return_counts=True)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))
    pos = np.arange(spec.k) % spec.count_period
    for r in range(4):
        valid = pos < int(counts[r])
        expect = comp.quantize_values(
            jnp.where(jnp.asarray(valid), vals[r:r + 1], 0.0))
        np.testing.assert_array_equal(np.asarray(v2[r:r + 1]),
                                      np.asarray(expect))
        assert np.all(np.asarray(v2[r])[~valid] == 0.0)
        np.testing.assert_array_equal(np.asarray(i2[r])[valid],
                                      np.asarray(idx[r])[valid])


def test_effective_bytes_clamps_hostile_count_header():
    """Byte-metric counterpart of the decode clamp below: the gathered
    count header is worker-controlled garbage until proven otherwise.
    decode_rows masks any bit pattern into [0, k]; the pricing in
    effective_payload_bytes used to trust the raw header, so a hostile
    count (0xFFFFFFFF, or any value above full_count) inflated
    effective_wire_bytes beyond the static budget — it must clamp to the
    same [0, full_count] range."""
    from repro.comm.exchange import effective_payload_bytes
    comp = _ragged_comp(value_bits=32)
    d = 1024
    x = jax.random.normal(jax.random.PRNGKey(5), (2, d))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    payload = wire_fmt.encode_rows(vals, idx, spec)
    budget = float(payload.shape[0] * spec.row_bytes)
    full_pricing = float(jnp.sum(spec.effective_row_bytes(
        jnp.full((2,), spec.full_count, jnp.int32))))
    header_only = float(jnp.sum(spec.effective_row_bytes(
        jnp.zeros(2, jnp.int32))))
    # positive overflow clamps to full_count; bit patterns that read as
    # negative int32 (0xFFFFFFFF == -1, 0x80000000 == INT32_MIN) clamp to 0
    for garbage, expect in (
        (spec.full_count + 10_000, full_pricing),
        (0x7FFFFFFF, full_pricing),
        (0xFFFFFFFF, header_only),
        (0x80000000, header_only),
    ):
        hacked = payload.at[:, 0].set(jnp.uint32(garbage))
        eff = float(effective_payload_bytes(hacked, spec))
        assert eff <= budget, (garbage, eff, budget)
        assert eff == pytest.approx(expect), (garbage, eff, expect)
    # a zeroed header prices only the per-row header overhead
    zeroed = payload.at[:, 0].set(jnp.uint32(0))
    assert float(effective_payload_bytes(zeroed, spec)) == pytest.approx(
        float(jnp.sum(spec.effective_row_bytes(jnp.zeros(2, jnp.int32)))))


def test_decode_honors_count_not_payload_tail():
    """The fixed-k_max buffer is ragged-IN-CONTENT: rewriting the count
    header below the encoded count masks entries that were genuinely
    encoded — decode trusts the count, never the tail bytes."""
    comp = _ragged_comp(value_bits=32)
    d = 1024
    x = jax.random.normal(jax.random.PRNGKey(3), (1, d))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    full = wire_fmt.encode_rows(vals, idx, spec)      # all entries valid
    k_b_small = 3
    hacked = full.at[:, 0].set(jnp.uint32(k_b_small))
    v2, i2 = wire_fmt.decode_rows(hacked, spec)
    pos = np.arange(spec.k) % spec.k_b
    assert np.all(np.asarray(v2)[0][pos >= k_b_small] == 0.0)
    np.testing.assert_array_equal(
        np.asarray(v2)[0][pos < k_b_small],
        np.asarray(vals)[0][pos < k_b_small])
    # decoded indices of masked entries are clamped in-bounds
    assert np.all((np.asarray(i2) >= 0) & (np.asarray(i2) < d))


def test_ragged_worker_effective_bytes_and_ef_identity(key):
    """worker_compress_aggregate(gamma_t): EF identity stays bit-exact at a
    reduced per-round level, effective bytes drop below the static budget,
    and the budget stays the payload's literal byte length."""
    from repro.core import tree_wire_bytes
    comp = _ragged_comp(value_bits=32)
    tree = {"v": jax.random.normal(key, (3000,))}
    upd, mem, wire, eff = _run_worker(tree, comp, eta=1.0,
                                      gamma_t=jnp.float32(0.02))
    assert int(wire) == tree_wire_bytes(tree, comp)
    assert float(eff) < float(wire)
    np.testing.assert_allclose(np.asarray(upd["v"] + mem["v"]),
                               np.asarray(tree["v"]), atol=1e-6)
    # at the full budget the two byte counts coincide
    _, _, wire_f, eff_f = _run_worker(tree, comp, eta=1.0,
                                      gamma_t=jnp.float32(0.05))
    assert float(eff_f) == float(wire_f)


def test_pack_fields_ragged_ref_pallas_parity():
    """Counts-aware pack/unpack: the Pallas kernels match the jnp ref for
    periodic (block-local) and prefix (flat) masks."""
    rng = np.random.default_rng(7)
    fields = jnp.asarray(rng.integers(0, 1 << 8, (5, 777), dtype=np.uint32))
    counts = jnp.asarray(rng.integers(1, 37, 5), jnp.int32)
    for period in (37, 777):          # block-periodic and whole-row prefix
        w_ref = ops.pack_fields(fields, 8, counts=counts, period=period,
                                impl="ref")
        w_pal = ops.pack_fields(fields, 8, counts=counts, period=period,
                                impl="pallas")
        np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_pal))
        f_ref = ops.unpack_fields(w_ref, 777, 8, counts=counts,
                                  period=period, impl="ref")
        f_pal = ops.unpack_fields(w_ref, 777, 8, counts=counts,
                                  period=period, impl="pallas")
        np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_pal))
        # the mask is really applied
        pos = np.arange(777) % period
        assert np.all(np.asarray(f_ref)[pos[None, :] >= np.asarray(counts)[:, None]] == 0)


def test_ragged_fused_path_thresholds_at_budget(key):
    """Regression: with gamma (initial) < max_gamma the fused kernel path
    must threshold at the BUDGET level — otherwise block_extract comes up
    short and ships zeros.  Fused == unfused at a reduced gamma_t."""
    kw = dict(gamma=0.01, max_gamma=0.05, method="block_topk", block=512,
              min_compress_size=64, value_bits=32)
    tree = {"v": jax.random.normal(key, (3000,))}
    gt = jnp.float32(0.03)
    u_k, m_k, w_k, e_k = _run_worker(tree, Compressor(use_kernel=True, **kw),
                                     gamma_t=gt)
    u_j, m_j, w_j, e_j = _run_worker(tree, Compressor(use_kernel=False,
                                                      **kw), gamma_t=gt)
    np.testing.assert_allclose(np.asarray(u_k["v"]), np.asarray(u_j["v"]),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(m_k["v"]), np.asarray(m_j["v"]),
                               atol=1e-7)
    assert float(w_k) == float(w_j) and float(e_k) == float(e_j)
    # k_t entries per (full) block actually survive: 0.03*512 ~ 15, not
    # the initial gamma's 5
    comp = Compressor(use_kernel=True, **kw)
    nz = np.count_nonzero(np.asarray(u_k["v"])[:512])
    assert nz == int(comp.block_k_t(gt))


def test_ragged_block_topk_requires_block_local_indices():
    """Adaptive block_topk with block > 2^16 cannot express the per-block
    count mask (entries are block-ordered, not row-sorted) — rejected at
    spec construction instead of silently mis-masking."""
    comp = Compressor(gamma=0.01, max_gamma=0.05, method="block_topk",
                      block=1 << 17, min_compress_size=64)
    with pytest.raises(ValueError, match="block-local"):
        wire_fmt.WireSpec.for_row(comp, 1 << 18)
    # the non-adaptive counterpart still builds (flat 32-bit indices)
    plain = Compressor(gamma=0.01, method="block_topk", block=1 << 17,
                       min_compress_size=64)
    spec = wire_fmt.WireSpec.for_row(plain, 1 << 18)
    assert spec.index_bits == 32 and not spec.local and not spec.ragged
