"""Data pipeline determinism/sharding + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.data.synthetic import (TokenPipeline, class_batch,
                                  interpolated_regression,
                                  teacher_classification)


def test_pipeline_deterministic():
    p = TokenPipeline(vocab_size=100, seq_len=32, global_batch=8)
    b1, b2 = p.batch(7), p.batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch(8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_shards_disjoint():
    kw = dict(vocab_size=1000, seq_len=64, global_batch=8, n_shards=4)
    shards = [TokenPipeline(shard=i, **kw).batch(3)["tokens"]
              for i in range(4)]
    assert all(s.shape == (2, 64) for s in shards)
    # different shards see different data
    assert not np.array_equal(np.asarray(shards[0]), np.asarray(shards[1]))


def test_pipeline_tokens_in_vocab():
    p = TokenPipeline(vocab_size=50, seq_len=128, global_batch=4)
    t = p.batch(0)["tokens"]
    assert int(jnp.min(t)) >= 0 and int(jnp.max(t)) < 50


def test_interpolated_regression_interpolates():
    A, b, xs = interpolated_regression(100, 32)
    np.testing.assert_allclose(np.asarray(A @ xs), np.asarray(b), atol=1e-4)


def test_teacher_labels_realizable():
    x, y = teacher_classification(64, n_classes=10)
    assert x.shape[0] == 64 and int(jnp.max(y)) < 10
    b = class_batch(x, y, 16, 0)
    assert b["x"].shape[0] == 16


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"w": jax.random.normal(key, (8, 8)),
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.int32(3)}}
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 10, tree, metadata={"step": 10})
    ckpt.save(d, 20, jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32
                                  else x, tree), metadata={"step": 20})
    assert ckpt.all_steps(d) == [10, 20]
    restored, meta = ckpt.restore(d, tree)
    assert meta["step"] == 20
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]) + 1)
    restored10, _ = ckpt.restore(d, tree, step=10)
    np.testing.assert_allclose(np.asarray(restored10["w"]),
                               np.asarray(tree["w"]))


def test_checkpoint_prune(tmp_path, key):
    tree = {"w": jnp.zeros((4,))}
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.all_steps(d) == [4, 5]


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    d = str(tmp_path / "ck")
    p = ckpt.save(d, 1, tree)
    os.remove(os.path.join(p, "COMMITTED"))
    assert ckpt.all_steps(d) == []
