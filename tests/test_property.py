"""Hypothesis property tests on the system's invariants (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import wire as wire_fmt
from repro.core import (ArmijoConfig, Compressor, armijo_search,
                        topk_select, sparse_to_dense)
from repro.core.compression import block_extract_sparse
from repro.core.error_feedback import dequantize_ef, quantize_ef
from repro.kernels import ref
from repro.kernels import ops as kops

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

finite_arrays = st.integers(0, 2**31 - 1).flatmap(
    lambda seed: st.integers(64, 2048).map(
        lambda n: np.random.default_rng(seed).standard_normal(n)
        .astype(np.float32)))


@given(finite_arrays, st.floats(0.01, 0.9))
def test_topk_contraction_property(x, gamma):
    """Lemma 7 for arbitrary inputs and ratios."""
    d = x.size
    k = max(1, int(round(gamma * d)))
    s = topk_select(jnp.asarray(x), k)
    dense = np.asarray(sparse_to_dense(s))
    lhs = np.sum((x - dense) ** 2)
    rhs = (1 - k / d) * np.sum(x ** 2)
    assert lhs <= rhs + 1e-4 * max(1.0, rhs)


@given(finite_arrays)
def test_topk_idempotent(x):
    k = max(1, x.size // 10)
    s = topk_select(jnp.asarray(x), k)
    dense = sparse_to_dense(s)
    s2 = topk_select(dense, k)
    np.testing.assert_allclose(np.asarray(sparse_to_dense(s2)),
                               np.asarray(dense), atol=1e-7)


@given(finite_arrays, st.floats(0.0, 0.5), st.floats(0.0, 2.0))
def test_ef_update_telescopes(x, eta, tau):
    """sent + m' == m + eta*g exactly, for any threshold."""
    n = x.size // 2
    m, g = jnp.asarray(x[:n]), jnp.asarray(x[n:2 * n])
    sent, m_new = ref.ef_threshold_update(m, g, jnp.float32(eta),
                                          jnp.float32(tau))
    np.testing.assert_allclose(np.asarray(sent + m_new),
                               np.asarray(m + eta * g), atol=1e-5)


@given(finite_arrays)
def test_ef_quantization_bounded_error(x):
    """int8 EF storage: error bounded by scale/2 per block."""
    m = jnp.asarray(x)
    q = quantize_ef(m)
    back = dequantize_ef(q)
    err = np.abs(np.asarray(back) - x)
    per_block_bound = np.repeat(np.asarray(q.scale)[:, 0], 256)[:x.size]
    assert np.all(err <= per_block_bound * 0.75 + 1e-7)


@given(st.integers(0, 10**6), st.floats(0.05, 0.45),
       st.floats(0.5, 0.95))
def test_armijo_alpha_in_bounds(seed, sigma, rho):
    """Accepted alpha in [alpha_min, alpha_max]; condition holds on a
    random convex quadratic."""
    rng = np.random.default_rng(seed)
    scales = jnp.asarray(rng.uniform(0.1, 4.0, 16).astype(np.float32))

    def f(w):
        return jnp.sum(scales * w ** 2)

    w = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    g = jax.grad(f)(w)
    cfg = ArmijoConfig(sigma=sigma, rho=rho, max_backtracks=60)
    amax = jnp.float32(1.0)
    res = armijo_search(f, w, g, amax, cfg)
    assert 0 < float(res.alpha) <= 1.0 + 1e-6
    if bool(res.accepted):
        lhs = float(f(w - res.alpha * g))
        rhs = float(f(w) - sigma * res.alpha * jnp.sum(g ** 2))
        assert lhs <= rhs + 1e-4 * max(1.0, abs(rhs))


@given(st.integers(0, 10**6), st.integers(1, 4))
def test_attention_window_subset_of_causal(seed, wexp):
    """Sliding-window attention == causal attention when window >= seq."""
    rng = np.random.default_rng(seed)
    B, H, S, D = 1, 2, 16, 8
    q = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32)) * .1
    k = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32)) * .1
    v = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32))
    full = ref.mha_reference(q, k, v, causal=True)
    win = ref.mha_reference(q, k, v, causal=True, window=S * wexp)
    np.testing.assert_allclose(np.asarray(win), np.asarray(full), atol=1e-5)


# ---------------------------------------------------------------------------
# packed wire format (DESIGN.md §8) — the property bodies are plain helpers
# so they can also be driven without hypothesis
# ---------------------------------------------------------------------------

def check_pack_roundtrip(seed: int, n: int, bits: int):
    """pack -> unpack is the identity on ``bits``-wide fields, for any
    length (zero-padding to whole words must never leak)."""
    hi = 1 << bits
    fields = jnp.asarray(np.random.default_rng(seed).integers(
        0, hi, (2, n), dtype=np.uint32))
    words = kops.pack_fields(fields, bits)
    back = kops.unpack_fields(words, n, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(fields))
    # packed size is exactly the accounted ceil(n*bits/32) words
    assert words.shape == (2, -(-n * bits // 32))


def check_codec_roundtrip(seed: int, d: int, block: int, value_bits: int):
    """encode -> decode recovers (quantize_values(vals), idx) EXACTLY for
    odd row sizes d (padded last block) and every supported value width."""
    comp = Compressor(gamma=0.05, method="block_topk", block=block,
                      min_compress_size=1, value_bits=value_bits)
    x = jnp.asarray(np.random.default_rng(seed)
                    .standard_normal((2, d)).astype(np.float32))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    payload = wire_fmt.encode_rows(vals, idx, spec)
    assert payload.nbytes == 2 * comp.wire_bytes(d)
    v2, i2 = wire_fmt.decode_rows(payload, spec)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(v2),
                                  np.asarray(comp.quantize_values(vals)))


def check_packed_ef_identity(seed: int, value_bits: int, log2_eta: int):
    """Bit-level EF identity END-TO-END through the packed path:
    decode(own payload) + m' == m + eta*g with strict float equality.

    Exactness argument: at unkept positions m' carries acc untouched; at
    kept positions the dequantized wire value v satisfies |acc - v| <=
    |v| / 2 (absmax int quantization with q >= 1, or bf16 rounding), so
    Sterbenz's lemma makes both acc - v and v + (acc - v) exact.  eta a
    power of two keeps acc = m + eta*g reproducible in numpy.
    """
    rng = np.random.default_rng(seed)
    d = 1280
    m = rng.standard_normal(d).astype(np.float32)
    g = rng.standard_normal(d).astype(np.float32)
    eta = np.float32(2.0 ** log2_eta)
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=1, value_bits=value_bits)
    acc = (jnp.asarray(m).reshape(1, -1).astype(jnp.float32)
           + eta * jnp.asarray(g).reshape(1, -1).astype(jnp.float32))
    vals, idx = block_extract_sparse(acc, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    v2, i2 = wire_fmt.decode_rows(
        wire_fmt.encode_rows(vals, idx, spec), spec)
    sent = jnp.zeros((d,), jnp.float32).at[i2.reshape(-1)].add(v2.reshape(-1))
    m_new = acc.reshape(-1) - sent
    np.testing.assert_array_equal(np.asarray(sent + m_new),
                                  np.asarray(acc.reshape(-1)))


@given(st.integers(0, 2**31 - 1), st.integers(1, 3000),
       st.sampled_from([4, 8, 16, 32]))
def test_pack_roundtrip_property(seed, n, bits):
    check_pack_roundtrip(seed, n, bits)


@given(st.integers(0, 2**31 - 1), st.integers(64, 2048),
       st.sampled_from([64, 256, 1024]), st.sampled_from([4, 8, 16, 32]))
def test_codec_roundtrip_property(seed, d, block, value_bits):
    check_codec_roundtrip(seed, d, block, value_bits)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16, 32]),
       st.integers(-3, 1))
def test_packed_ef_identity_bitlevel_property(seed, value_bits, log2_eta):
    check_packed_ef_identity(seed, value_bits, log2_eta)


@given(st.integers(0, 10**6))
def test_blockwise_gamma_at_least_half(seed):
    """DESIGN §3: block-local selection achieves realized gamma >= gamma/2
    in energy terms for the kept-count (count-based check)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=1)
    sent, resid = comp.compress_dense(x)
    kept = int(jnp.sum(sent != 0))
    assert kept >= int(0.5 * 0.1 * 4096)


def check_ragged_roundtrip(seed: int, d: int, block: int, value_bits: int):
    """Ragged codec (DESIGN.md §9): for random per-row valid counts in
    [1, k_max-per-period], decode returns exactly the masked quantized
    values, the count survives the header word, and the payload buffer
    stays the static budget size."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=block, min_compress_size=1,
                      value_bits=value_bits)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, d)).astype(np.float32))
    vals, idx = block_extract_sparse(x, comp)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    assert spec.ragged
    counts = jnp.asarray(rng.integers(1, spec.full_count + 1, 3), jnp.int32)
    payload = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
    assert payload.nbytes == 3 * comp.wire_bytes(d)   # fixed budget buffer
    v2, i2, c2 = wire_fmt.decode_rows(payload, spec, return_counts=True)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))
    pos = np.arange(spec.k) % spec.count_period
    valid = pos[None, :] < np.asarray(counts)[:, None]
    expect = comp.quantize_values(jnp.where(jnp.asarray(valid), vals, 0.0))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(expect))
    assert np.all(np.asarray(v2)[~valid] == 0.0)
    # effective bytes are monotone in the count and bounded by the budget
    eff = np.asarray(spec.effective_row_bytes(counts))
    assert np.all(eff <= spec.row_bytes)
    assert np.all(np.asarray(spec.effective_row_bytes(spec.full_count))
                  == spec.row_bytes)


@given(st.integers(0, 2**31 - 1), st.integers(64, 2048),
       st.sampled_from([64, 256, 1024]), st.sampled_from([4, 8, 16, 32]))
def test_ragged_roundtrip_property(seed, d, block, value_bits):
    check_ragged_roundtrip(seed, d, block, value_bits)


# ---------------------------------------------------------------------------
# compression telemetry invariants (DESIGN.md §10)
# ---------------------------------------------------------------------------

import functools  # noqa: E402

_TEL_GAMMA = 0.05
_TEL_DS = (320, 1024, 1300)     # odd/padded block geometries


@functools.lru_cache(maxsize=None)
def _telemetry_fn(method: str, value_bits: int, adaptive: bool,
                  use_kernel: bool):
    """Jitted 1-worker worker_compress_aggregate -> CompressionTelemetry,
    cached per static config so hypothesis examples reuse compilations."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.dcsgd import worker_compress_aggregate

    comp = Compressor(gamma=_TEL_GAMMA,
                      max_gamma=_TEL_GAMMA if adaptive else 0.0,
                      method=method, block=256, min_compress_size=1,
                      value_bits=value_bits, use_kernel=use_kernel)
    mesh = jax.make_mesh((1,), ("data",))
    f = shard_map(
        lambda g, m, eta, gt: worker_compress_aggregate(
            g, m, eta, comp, ("data",),
            gamma_t=gt if adaptive else None)[4],
        mesh=mesh, in_specs=(P(), P(), P(), P()), out_specs=P(),
        axis_names={"data"})
    return jax.jit(f)


def _tel_inputs(seed: int, d: int):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    m = jnp.asarray(rng.standard_normal(d).astype(np.float32)) * 0.5
    return g, m


def check_telemetry_ranges(seed: int, d: int, method: str, value_bits: int,
                           gfrac: float):
    """For any shape, value width and per-round count: cosine in [-1, 1],
    backlog >= 0, decode_error >= 0, eff_gamma <= 1, everything finite."""
    g, m = _tel_inputs(seed, d)
    tel = _telemetry_fn(method, value_bits, True, True)(
        g, m, jnp.float32(0.25), jnp.float32(gfrac * _TEL_GAMMA))
    for leaf in jax.tree.leaves(tel):
        assert np.isfinite(float(leaf))
    assert -1.0 - 1e-5 <= float(tel.cosine) <= 1.0 + 1e-5
    assert float(tel.ef_backlog) >= 0.0
    assert float(tel.decode_error) >= 0.0
    assert float(tel.eff_gamma) <= 1.0 + 1e-5


def check_telemetry_identity_compressor(seed: int, d: int, log2_eta: int):
    """When compression is the identity (dense ship) and the EF memory is
    empty: backlog == 0, decode_error == 0 and eff_gamma == 1 BIT-EXACTLY
    (the residual is a literal zero — the one case where zero backlog is
    even reachable), and cosine == 1 to within one f32 ulp.  The cosine
    bound is one ulp rather than equality because XLA may emit FMA for
    ``sum(acc*g)`` but plain mul+add for ``sum(g*g)``, splitting the two
    otherwise-identical (power-of-two-scaled) reductions by one rounding.
    """
    g, _ = _tel_inputs(seed, d)
    tel = _telemetry_fn("none", 32, False, True)(
        g, jnp.zeros_like(g), jnp.float32(2.0 ** log2_eta), jnp.float32(0))
    assert float(tel.ef_backlog) == 0.0
    assert abs(float(tel.cosine) - 1.0) <= np.finfo(np.float32).eps
    assert float(tel.decode_error) == 0.0
    assert float(tel.eff_gamma) == 1.0


def check_telemetry_full_budget_matches_nonadaptive(seed: int, d: int,
                                                    method: str):
    """gamma_t == geometry_gamma with value_bits = 32: the ragged mask is
    a no-op and telemetry equals the non-adaptive compressor's bit-for-bit
    (the adaptive machinery adds zero distortion at full count)."""
    g, m = _tel_inputs(seed, d)
    eta = jnp.float32(0.25)
    t_ad = _telemetry_fn(method, 32, True, True)(
        g, m, eta, jnp.float32(_TEL_GAMMA))
    t_fx = _telemetry_fn(method, 32, False, True)(g, m, eta, jnp.float32(0))
    for a, b in zip(jax.tree.leaves(t_ad), jax.tree.leaves(t_fx)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def check_telemetry_scale_invariance(seed: int, d: int, value_bits: int,
                                     log2_c: int, gfrac: float):
    """Telemetry is a pure shape descriptor: scaling (g, m) by a power of
    two changes no field, bit-exactly, at every value width and per-round
    count (selection, quantization scales and all five sums scale
    exactly)."""
    g, m = _tel_inputs(seed, d)
    c = jnp.float32(2.0 ** log2_c)
    fn = _telemetry_fn("block_topk", value_bits, True, True)
    eta = jnp.float32(0.5)
    gt = jnp.float32(gfrac * _TEL_GAMMA)
    t1 = fn(g, m, eta, gt)
    t2 = fn(c * g, c * m, eta, gt)
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2**31 - 1), st.sampled_from(_TEL_DS),
       st.sampled_from(["block_topk", "topk"]),
       st.sampled_from([4, 8, 16, 32]), st.floats(0.05, 1.0))
def test_telemetry_ranges_property(seed, d, method, value_bits, gfrac):
    check_telemetry_ranges(seed, d, method, value_bits, gfrac)


@given(st.integers(0, 2**31 - 1), st.sampled_from(_TEL_DS),
       st.integers(-2, 2))
def test_telemetry_identity_compressor_property(seed, d, log2_eta):
    check_telemetry_identity_compressor(seed, d, log2_eta)


@given(st.integers(0, 2**31 - 1), st.sampled_from(_TEL_DS),
       st.sampled_from(["block_topk", "topk"]))
def test_telemetry_full_budget_matches_nonadaptive_property(seed, d, method):
    check_telemetry_full_budget_matches_nonadaptive(seed, d, method)


@given(st.integers(0, 2**31 - 1), st.sampled_from(_TEL_DS),
       st.sampled_from([4, 8, 16, 32]), st.integers(-3, 3),
       st.floats(0.05, 1.0))
def test_telemetry_scale_invariance_property(seed, d, value_bits, log2_c,
                                             gfrac):
    check_telemetry_scale_invariance(seed, d, value_bits, log2_c, gfrac)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3000),
       st.sampled_from([4, 8, 16, 32]), st.integers(1, 64))
def test_pack_roundtrip_with_counts_property(seed, n, bits, period):
    """Counts-aware pack -> unpack == identity on the valid mask, zeros on
    the invalid positions, for arbitrary period/count combinations."""
    rng = np.random.default_rng(seed)
    fields = jnp.asarray(rng.integers(0, 1 << bits, (2, n),
                                      dtype=np.uint32))
    counts = jnp.asarray(rng.integers(1, period + 1, 2), jnp.int32)
    words = kops.pack_fields(fields, bits, counts=counts, period=period)
    back = kops.unpack_fields(words, n, bits, counts=counts, period=period)
    pos = np.arange(n) % period
    valid = pos[None, :] < np.asarray(counts)[:, None]
    np.testing.assert_array_equal(np.asarray(back)[valid],
                                  np.asarray(fields)[valid])
    assert np.all(np.asarray(back)[~valid] == 0)


# ---------------------------------------------------------------------------
# bucketed transport round-trip (DESIGN.md §11)
# ---------------------------------------------------------------------------

def check_bucket_roundtrip(seed: int, method: str, value_bits: int,
                           adaptive: bool):
    """Random leaf mixes (stacked/unstacked, odd d, 1-5 leaves) encode
    into the flat bucket payload EXACTLY as the in-order concatenation of
    the per-leaf codec's payloads, and a 2-worker gathered bucket decodes
    per leaf bit-identically to per-leaf decode_rows — random per-row
    counts riding the ragged headers included."""
    from repro.comm.bucket import (build_bucket_plan, decode_buckets,
                                   encode_buckets)
    from repro.comm.exchange import check_bucket_payload
    from repro.core.dcsgd import _per_layer_topk

    rng = np.random.default_rng(seed)
    comp = Compressor(gamma=0.05, max_gamma=0.05 if adaptive else 0.0,
                      method=method, block=256, min_compress_size=64,
                      value_bits=value_bits)
    n_leaves = int(rng.integers(1, 6))
    shapes, stacked = [], []
    for _ in range(n_leaves):
        d = int(rng.integers(64, 3000))
        if rng.integers(2):
            shapes.append((int(rng.integers(1, 4)), d))
            stacked.append(True)
        else:
            shapes.append((d,))
            stacked.append(False)
    plan = build_bucket_plan(shapes, stacked, comp)
    if not plan.total_words:
        return                                     # nothing compresses

    def encode_worker(worker_seed):
        wrng = np.random.default_rng(worker_seed)
        rows, perleaf = [], []
        for ln in plan.leaves:
            if ln.dense:
                rows.append(None)
                perleaf.append(None)
                continue
            x = jnp.asarray(wrng.standard_normal((ln.L, ln.d))
                            .astype(np.float32))
            if method == "block_topk":
                vals, idx = block_extract_sparse(x, comp)
            else:
                vals, idx = _per_layer_topk(x, comp.k_for(ln.d))
            counts = None
            if ln.spec.ragged:
                counts = jnp.asarray(
                    wrng.integers(1, ln.spec.full_count + 1, ln.L),
                    jnp.int32)
            rows.append((vals, idx, counts))
            perleaf.append(wire_fmt.encode_rows(vals, idx, ln.spec,
                                                counts=counts))
        payload = encode_buckets(plan, rows)
        check_bucket_payload(payload, plan, comp)
        np.testing.assert_array_equal(
            np.asarray(payload),
            np.concatenate([np.asarray(p).reshape(-1)
                            for p in perleaf if p is not None]))
        return payload, perleaf

    pay_a, ref_a = encode_worker(seed + 1)
    pay_b, ref_b = encode_worker(seed + 2)
    decoded = decode_buckets(plan, jnp.stack([pay_a, pay_b]))
    for ln in plan.leaves:
        if ln.dense:
            assert decoded[ln.index] is None
            continue
        v2, i2 = decoded[ln.index]
        assert v2.shape == (2, ln.L, ln.spec.k)
        for w, ref_pay in enumerate((ref_a, ref_b)):
            v_ref, i_ref = wire_fmt.decode_rows(ref_pay[ln.index],
                                                ln.spec)
            np.testing.assert_array_equal(np.asarray(v2[w]),
                                          np.asarray(v_ref))
            np.testing.assert_array_equal(np.asarray(i2[w]),
                                          np.asarray(i_ref))


@given(st.integers(0, 2**31 - 1),
       st.sampled_from(["block_topk", "topk"]),
       st.sampled_from([4, 8, 16, 32]), st.booleans())
def test_bucket_roundtrip_property(seed, method, value_bits, adaptive):
    check_bucket_roundtrip(seed, method, value_bits, adaptive)


# ---- chunked ring schedule (DESIGN.md §14) ------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 16), st.integers(1, 40),
       st.integers(0, 2000))
def test_ring_gather_schedule_property(seed, W, n_chunks, total_words):
    """For arbitrary (W, n_chunks, total_words) — including n_chunks that
    do not divide the buffer and n_chunks > total_words — the simulated
    ring schedule assembles, on EVERY worker, the bit-identical
    (W, total_words) buffer the flat all_gather produces, covering each
    slot exactly once (``ring_gather_reference`` raises otherwise).  The
    SPMD path shares ``chunk_table``/``step_source`` with the simulator
    and is pinned against ``lax.all_gather`` on real meshes in
    tests/distributed/test_overlap_exchange.py."""
    from repro.comm.ring import (chunk_table, n_permutes,
                                 ring_gather_reference)

    rng = np.random.default_rng(seed)
    bufs = rng.integers(0, 2**32, (W, total_words), dtype=np.uint32)
    out = ring_gather_reference(bufs, n_chunks)
    np.testing.assert_array_equal(
        out, np.broadcast_to(bufs[None], (W, W, total_words)))
    # chunk table: contiguous, exhaustive, near-even word-aligned split
    table = chunk_table(total_words, n_chunks)
    assert sum(ln for _, ln in table) == total_words
    off = 0
    for o, ln in table:
        assert o == off and ln >= 1
        off += ln
    if total_words:
        assert len(table) == min(n_chunks, total_words)
        lens = [ln for _, ln in table]
        assert max(lens) - min(lens) <= 1
    # the permute budget the HLO pins count: chunks x (W-1) per axis
    want = len(table) * (W - 1) if total_words else 0
    assert n_permutes((W,), total_words, n_chunks) == want


@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(1, 17),
       st.integers(64, 1024), st.sampled_from([4, 8, 16, 32]))
def test_ring_carries_ragged_rows_property(seed, W, n_chunks, d,
                                           value_bits):
    """Ragged §9 payload rows (random per-worker valid counts in the
    header word) survive the chunked ring bit-exactly: chunk boundaries
    fall anywhere — mid-header, mid-field — yet every assembled row
    decodes to exactly its source worker's (values, indices, count)."""
    from repro.comm.ring import ring_gather_reference

    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=256, min_compress_size=1,
                      value_bits=value_bits)
    spec = wire_fmt.WireSpec.for_row(comp, d)
    assert spec.ragged
    rng = np.random.default_rng(seed)
    payloads, expect = [], []
    for _ in range(W):
        x = jnp.asarray(rng.standard_normal((1, d)).astype(np.float32))
        vals, idx = block_extract_sparse(x, comp)
        counts = jnp.asarray(rng.integers(1, spec.full_count + 1, 1),
                             jnp.int32)
        pay = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
        payloads.append(np.asarray(pay).reshape(-1))
        expect.append(wire_fmt.decode_rows(pay, spec, return_counts=True))
    out = ring_gather_reference(np.stack(payloads), n_chunks)
    # worker 0's assembled buffer: one payload row per source worker
    v2, i2, c2 = wire_fmt.decode_rows(jnp.asarray(out[0]), spec,
                                      return_counts=True)
    for src in range(W):
        ve, ie, ce = expect[src]
        np.testing.assert_array_equal(np.asarray(v2[src]),
                                      np.asarray(ve[0]))
        np.testing.assert_array_equal(np.asarray(i2[src]),
                                      np.asarray(ie[0]))
        np.testing.assert_array_equal(np.asarray(c2[src]),
                                      np.asarray(ce[0]))


# ---- gossip topology invariants (DESIGN.md §12) -------------------------

@given(st.sampled_from(["ring", "torus", "exp"]),
       st.sampled_from([4, 8, 16]))
def test_mixing_matrix_invariants_property(name, n):
    """Every registered topology builder yields a symmetric, doubly
    stochastic mixing matrix with a strictly positive spectral gap —
    the three conditions under which gossip averaging converges to the
    true mean at a geometric rate."""
    from repro.comm.topology import build_topology

    topo = build_topology(name, n)
    m = topo.mixing_matrix()
    assert m.shape == (n, n)
    np.testing.assert_array_equal(m, m.T)
    ones = np.ones(n)
    np.testing.assert_allclose(m @ ones, ones, atol=1e-12)
    np.testing.assert_allclose(ones @ m, ones, atol=1e-12)
    assert np.all(m >= 0.0)
    assert topo.spectral_gap() > 0.0
    # every row mixes self + degree neighbors at the uniform weight
    assert np.count_nonzero(m[0]) == topo.degree + 1
    np.testing.assert_allclose(m[m > 0], topo.mix_weight)


# ---------------------------------------------------------------------------
# federated tier: non-IID shard determinism + client sampling (DESIGN.md §13)
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(0, 1000),
       st.integers(0, 3), st.floats(0.05, 5.0))
def test_noniid_shard_determinism_property(seed, step, shard, alpha):
    """The (seed, step, shard) determinism contract survives the Dirichlet
    tilt AND n_shards refactors: the same shard of the same stream yields
    the bit-identical batch whether the cohort is 4 or 8 shards wide
    (same per-shard batch rows), across independent processes by
    construction (pure numpy SeedSequence)."""
    from repro.data.synthetic import TokenPipeline

    def pipe(n_shards):
        return TokenPipeline(vocab_size=128, seq_len=16,
                             global_batch=2 * n_shards, seed=seed,
                             n_shards=n_shards, shard=shard,
                             dirichlet_alpha=alpha)

    a = pipe(4).batch(step)["tokens"]
    b = pipe(8).batch(step)["tokens"]
    c = pipe(4).batch(step)["tokens"]       # fresh pipeline, same stream
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 50.0))
def test_dirichlet_tilt_property(seed, alpha):
    """The per-shard unigram tilt: a valid distribution, deterministic in
    (seed, shard), genuinely different across shards (non-IID), exactly
    the base zipf at alpha=0, and step-independent by construction (the
    tilt never sees the step counter)."""
    from repro.data.synthetic import TokenPipeline

    def probs(shard, a):
        return TokenPipeline(vocab_size=256, seq_len=8, global_batch=4,
                             seed=seed, n_shards=4, shard=shard,
                             dirichlet_alpha=a).unigram_probs()

    p0, p1 = probs(0, alpha), probs(1, alpha)
    for p in (p0, p1):
        assert np.all(p >= 0.0)
        np.testing.assert_allclose(p.sum(), 1.0, atol=1e-9)
    assert np.max(np.abs(p0 - p1)) > 0.0          # shards differ
    np.testing.assert_array_equal(probs(0, alpha), p0)   # deterministic
    base = probs(0, 0.0)
    zipf = 1.0 / np.arange(1, 257)
    np.testing.assert_allclose(base, zipf / zipf.sum(), atol=1e-12)


@given(st.integers(0, 2**31 - 1), st.integers(2, 16),
       st.floats(0.05, 20.0), st.integers(40, 400))
def test_dirichlet_label_shards_property(seed, n_shards, alpha, n):
    """Label-skew partition: a complete partition (every sample on exactly
    one shard), deterministic, and skew grows as alpha shrinks — at
    alpha <= 0.1 some class concentrates harder than the uniform split."""
    from repro.data.synthetic import dirichlet_label_shards

    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, n)
    s1 = dirichlet_label_shards(labels, n_shards, alpha, seed=seed)
    s2 = dirichlet_label_shards(labels, n_shards, alpha, seed=seed)
    np.testing.assert_array_equal(s1, s2)
    assert s1.shape == labels.shape
    assert s1.min() >= 0 and s1.max() < n_shards
    # per-class apportionment is exact: class sizes are conserved
    for c in np.unique(labels):
        assert (s1[labels == c] >= 0).all()
    assert np.bincount(s1, minlength=n_shards).sum() == n


@given(st.integers(0, 2**31 - 1), st.integers(1, 10**6),
       st.integers(2, 64))
def test_participation_mask_reproducible_property(seed, round_idx, n):
    """Same (seed, round) -> bit-identical mask, for both samplers; masks
    are 0/1 float32 and fixed mode hits clients_per_round exactly."""
    from repro.fed.sampling import participation_mask

    k = max(1, n // 2)
    m1 = participation_mask(n, round_idx, seed=seed, mode="fixed",
                            clients_per_round=k)
    m2 = participation_mask(n, round_idx, seed=seed, mode="fixed",
                            clients_per_round=k)
    np.testing.assert_array_equal(m1, m2)
    assert m1.dtype == np.float32
    assert set(np.unique(m1)) <= {0.0, 1.0}
    assert int(m1.sum()) == k
    b1 = participation_mask(n, round_idx, seed=seed, mode="bernoulli",
                            rate=0.9)
    b2 = participation_mask(n, round_idx, seed=seed, mode="bernoulli",
                            rate=0.9)
    np.testing.assert_array_equal(b1, b2)
    # different rounds decorrelate (not a frozen mask)
    m3 = participation_mask(n, round_idx + 1, seed=seed, mode="fixed",
                            clients_per_round=k)
    assert int(m3.sum()) == k


@given(st.integers(0, 2**31 - 1), st.floats(0.2, 0.9))
def test_bernoulli_participation_binomial_bounds_property(seed, rate):
    """Bernoulli sampling: the participation count stays within 6 sigma of
    the binomial mean (per-seed deterministic, so this is a pure tail
    bound on the underlying generator)."""
    from repro.fed.sampling import participation_mask

    n = 512
    m = participation_mask(n, 0, seed=seed, mode="bernoulli", rate=rate)
    cnt = m.sum()
    mu, sd = n * rate, np.sqrt(n * rate * (1 - rate))
    assert mu - 6 * sd - 1 <= cnt <= mu + 6 * sd + 1


@given(st.integers(0, 2**31 - 1), st.integers(2, 64))
def test_zero_participation_raises_property(seed, n):
    """A round nobody survives raises instead of producing 0/0 NaNs —
    rate=0 bernoulli deterministically, and stragglers only ever shrink
    the sampled set."""
    from repro.fed.sampling import (ZeroParticipationError,
                                    participation_mask)

    with pytest.raises(ZeroParticipationError):
        participation_mask(n, 0, seed=seed, mode="bernoulli", rate=0.0)
    full = participation_mask(n, 3, seed=seed, mode="fixed")
    try:
        dropped = participation_mask(n, 3, seed=seed, mode="fixed",
                                     straggler_rate=0.5)
    except ZeroParticipationError:
        return                       # everyone straggled: also correct
    assert np.all(dropped <= full)   # stragglers are a subset


# ---------------------------------------------------------------------------
# hostile-wire fuzz (DESIGN.md §16) — bodies live in tests/wire_fuzz.py so
# the fixed-seed tier in tests/test_faults.py drives the SAME invariants on
# images without the hypothesis dev extra
# ---------------------------------------------------------------------------

from wire_fuzz import (check_garbage_bucket_decode_safe,      # noqa: E402
                       check_garbage_rows_decode_safe,
                       check_honest_rows_verdict_clean)


@given(st.integers(0, 2**31 - 1), st.integers(64, 2048),
       st.sampled_from([64, 256, 1024]), st.sampled_from([4, 8, 16, 32]),
       st.booleans(), st.sampled_from(["block_topk", "topk"]))
def test_garbage_rows_decode_safe_property(seed, d, block, value_bits,
                                           adaptive, method):
    """Arbitrary uint32 garbage rows: decode never indexes out of bounds,
    nothing non-finite survives the verdict layer, the verdict is always
    a well-defined bool."""
    check_garbage_rows_decode_safe(seed, d, block, value_bits, adaptive,
                                   method)


@given(st.integers(0, 2**31 - 1), st.integers(64, 2048),
       st.sampled_from([64, 256, 1024]), st.sampled_from([4, 8, 16, 32]),
       st.booleans(), st.sampled_from(["block_topk", "topk"]))
def test_honest_rows_verdict_clean_property(seed, d, block, value_bits,
                                            adaptive, method):
    """Honest encodes are verdict-True everywhere; quarantine is a
    bit-exact pass-through on them (the faults-off guarantee)."""
    check_honest_rows_verdict_clean(seed, d, block, value_bits, adaptive,
                                    method)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16, 32]),
       st.booleans())
def test_garbage_bucket_decode_safe_property(seed, value_bits, adaptive):
    """Same contract through the batched bucket decode with verdicts."""
    check_garbage_bucket_decode_safe(seed, value_bits, adaptive)


@given(st.integers(0, 2**31 - 1),
       st.sampled_from(["ring", "torus", "exp"]),
       st.sampled_from([4, 8, 16]))
def test_gossip_constant_fixed_point_property(seed, name, n):
    """A consensus-reached (constant-over-workers) state is a BIT-EXACT
    fixed point of the gossip round: the difference form makes every
    ``z_j - z_i`` literally zero before any weight multiplies it."""
    from repro.comm.topology import build_topology

    topo = build_topology(name, n)
    rng = np.random.default_rng(seed)
    row = rng.standard_normal(17).astype(np.float32)
    z = np.broadcast_to(row, (n, 17)).copy()
    np.testing.assert_array_equal(topo.mix_reference(z), z)
    # and one round strictly contracts a NON-constant state (gap > 0)
    z2 = rng.standard_normal((n, 17)).astype(np.float32)

    def err(a):
        return np.max(np.abs(a - a.mean(0)))

    assert err(topo.mix_reference(z2)) < err(z2)
