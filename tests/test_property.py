"""Hypothesis property tests on the system's invariants (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev extra "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (ArmijoConfig, Compressor, armijo_search,
                        topk_select, sparse_to_dense)
from repro.core.error_feedback import dequantize_ef, quantize_ef
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

finite_arrays = st.integers(0, 2**31 - 1).flatmap(
    lambda seed: st.integers(64, 2048).map(
        lambda n: np.random.default_rng(seed).standard_normal(n)
        .astype(np.float32)))


@given(finite_arrays, st.floats(0.01, 0.9))
def test_topk_contraction_property(x, gamma):
    """Lemma 7 for arbitrary inputs and ratios."""
    d = x.size
    k = max(1, int(round(gamma * d)))
    s = topk_select(jnp.asarray(x), k)
    dense = np.asarray(sparse_to_dense(s))
    lhs = np.sum((x - dense) ** 2)
    rhs = (1 - k / d) * np.sum(x ** 2)
    assert lhs <= rhs + 1e-4 * max(1.0, rhs)


@given(finite_arrays)
def test_topk_idempotent(x):
    k = max(1, x.size // 10)
    s = topk_select(jnp.asarray(x), k)
    dense = sparse_to_dense(s)
    s2 = topk_select(dense, k)
    np.testing.assert_allclose(np.asarray(sparse_to_dense(s2)),
                               np.asarray(dense), atol=1e-7)


@given(finite_arrays, st.floats(0.0, 0.5), st.floats(0.0, 2.0))
def test_ef_update_telescopes(x, eta, tau):
    """sent + m' == m + eta*g exactly, for any threshold."""
    n = x.size // 2
    m, g = jnp.asarray(x[:n]), jnp.asarray(x[n:2 * n])
    sent, m_new = ref.ef_threshold_update(m, g, jnp.float32(eta),
                                          jnp.float32(tau))
    np.testing.assert_allclose(np.asarray(sent + m_new),
                               np.asarray(m + eta * g), atol=1e-5)


@given(finite_arrays)
def test_ef_quantization_bounded_error(x):
    """int8 EF storage: error bounded by scale/2 per block."""
    m = jnp.asarray(x)
    q = quantize_ef(m)
    back = dequantize_ef(q)
    err = np.abs(np.asarray(back) - x)
    per_block_bound = np.repeat(np.asarray(q.scale)[:, 0], 256)[:x.size]
    assert np.all(err <= per_block_bound * 0.75 + 1e-7)


@given(st.integers(0, 10**6), st.floats(0.05, 0.45),
       st.floats(0.5, 0.95))
def test_armijo_alpha_in_bounds(seed, sigma, rho):
    """Accepted alpha in [alpha_min, alpha_max]; condition holds on a
    random convex quadratic."""
    rng = np.random.default_rng(seed)
    scales = jnp.asarray(rng.uniform(0.1, 4.0, 16).astype(np.float32))

    def f(w):
        return jnp.sum(scales * w ** 2)

    w = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    g = jax.grad(f)(w)
    cfg = ArmijoConfig(sigma=sigma, rho=rho, max_backtracks=60)
    amax = jnp.float32(1.0)
    res = armijo_search(f, w, g, amax, cfg)
    assert 0 < float(res.alpha) <= 1.0 + 1e-6
    if bool(res.accepted):
        lhs = float(f(w - res.alpha * g))
        rhs = float(f(w) - sigma * res.alpha * jnp.sum(g ** 2))
        assert lhs <= rhs + 1e-4 * max(1.0, abs(rhs))


@given(st.integers(0, 10**6), st.integers(1, 4))
def test_attention_window_subset_of_causal(seed, wexp):
    """Sliding-window attention == causal attention when window >= seq."""
    rng = np.random.default_rng(seed)
    B, H, S, D = 1, 2, 16, 8
    q = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32)) * .1
    k = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32)) * .1
    v = jnp.asarray(rng.standard_normal((B, H, S, D)).astype(np.float32))
    full = ref.mha_reference(q, k, v, causal=True)
    win = ref.mha_reference(q, k, v, causal=True, window=S * wexp)
    np.testing.assert_allclose(np.asarray(win), np.asarray(full), atol=1e-5)


@given(st.integers(0, 10**6))
def test_blockwise_gamma_at_least_half(seed):
    """DESIGN §3: block-local selection achieves realized gamma >= gamma/2
    in energy terms for the kept-count (count-based check)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=1)
    sent, resid = comp.compress_dense(x)
    kept = int(jnp.sum(sent != 0))
    assert kept >= int(0.5 * 0.1 * 4096)
