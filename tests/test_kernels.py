"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
swept over shapes and dtypes (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("n", [1024, 3000, 8192, 65536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ef_threshold_update_sweep(key, n, dtype):
    m = jax.random.normal(key, (n,), dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    s1, m1 = ops.ef_threshold_update(m, g, 0.1, 0.3, impl="ref")
    s2, m2 = ops.ef_threshold_update(m, g, 0.1, 0.3, impl="pallas")
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(s1, np.float32),
                               np.asarray(s2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m1, np.float32),
                               np.asarray(m2, np.float32), atol=tol)
    # fused-update identity: sent + m' == m + eta*g
    acc = np.asarray(m, np.float32) + 0.1 * np.asarray(g, np.float32)
    np.testing.assert_allclose(np.asarray(s2, np.float32)
                               + np.asarray(m2, np.float32), acc, atol=2e-2)


def test_dispatch_registry_and_resolution():
    """Every op is registered with a ref oracle; resolution follows the
    per-op policy (EF ops take the kernel path even off-TPU) and the
    process-wide override wins over policy."""
    from repro.kernels import dispatch
    reg = dispatch.registered()
    for op in ("ef_update", "block_stats", "ef_stats", "ef_stats_telemetry",
               "threshold_split", "attention", "rmsnorm", "wkv"):
        assert "ref" in reg[op], op
        assert "pallas-interpret" in reg[op], op
        assert "pallas-tpu" in reg[op], op
    on_tpu = jax.default_backend() == "tpu"
    want_ef = "pallas-tpu" if on_tpu else "pallas-interpret"
    assert dispatch.resolve("ef_update") == want_ef
    assert dispatch.resolve("attention") == ("pallas-tpu" if on_tpu
                                             else "ref")
    assert dispatch.resolve("attention", "pallas") == want_ef
    with dispatch.using("ref"):
        assert dispatch.resolve("ef_update") == "ref"
    assert dispatch.resolve("ef_update") == want_ef


@pytest.mark.parametrize("shape", [(5000,), (3, 4096), (2, 2500)])
def test_fused_ef_identity_bitlevel(key, shape):
    """The fused kernel's EF identity is BIT-exact: sent + m' == m + eta*g
    (each position is nonzero in exactly one of sent/m'), and the kernel
    path equals the ref.py math bit-for-bit in f32.

    eta is a power of two so eta*g is exact and FMA-vs-mul+add rounding
    cannot differ — the comparison against numpy is strict equality.
    """
    eta = 0.5
    m = jax.random.normal(key, shape, jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape, jnp.float32)
    sent, mnew, tau = ops.fused_ef_compress(m, g, eta, gamma=0.03,
                                            impl="pallas")
    acc = np.asarray(m, np.float32) + np.float32(eta) * np.asarray(
        g, np.float32)
    np.testing.assert_array_equal(np.asarray(sent) + np.asarray(mnew), acc)
    # disjoint support: fused split never duplicates or drops a position
    assert not np.any(np.logical_and(np.asarray(sent) != 0,
                                     np.asarray(mnew) != 0))
    sent_r, mnew_r, tau_r = ops.fused_ef_compress(m, g, eta, gamma=0.03,
                                                  impl="ref")
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(sent_r))
    np.testing.assert_array_equal(np.asarray(mnew), np.asarray(mnew_r))
    np.testing.assert_array_equal(np.asarray(tau), np.asarray(tau_r))


@pytest.mark.parametrize("shape", [(5000,), (3, 4096), (2, 2500)])
def test_fused_ef_telemetry_parity(key, shape):
    """The telemetry-fused pass 1 (DESIGN.md §10): tau equals the plain
    ef_stats pass bit-for-bit (same selection math), the moments equal the
    ref oracle across ref/pallas, and the moment totals reduce to the
    dense sums they claim to be."""
    eta = 0.5                       # power of two: acc exact in numpy too
    m = jax.random.normal(key, shape, jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape, jnp.float32)
    s_t, m_t, tau_t, mom_p = ops.fused_ef_compress(
        m, g, eta, gamma=0.03, telemetry=True, impl="pallas")
    s_p, m_p, tau_p = ops.fused_ef_compress(m, g, eta, gamma=0.03,
                                            impl="pallas")
    np.testing.assert_array_equal(np.asarray(tau_t), np.asarray(tau_p))
    np.testing.assert_array_equal(np.asarray(s_t), np.asarray(s_p))
    np.testing.assert_array_equal(np.asarray(m_t), np.asarray(m_p))
    *_, mom_r = ops.fused_ef_compress(m, g, eta, gamma=0.03,
                                      telemetry=True, impl="ref")
    np.testing.assert_allclose(np.asarray(mom_p), np.asarray(mom_r),
                               rtol=1e-6)
    acc = np.asarray(m, np.float64) + eta * np.asarray(g, np.float64)
    np.testing.assert_allclose(float(jnp.sum(mom_p[:, 0])),
                               float(np.sum(np.asarray(g, np.float64)**2)),
                               rtol=1e-5)
    np.testing.assert_allclose(float(jnp.sum(mom_p[:, 1])),
                               float(np.sum(acc**2)), rtol=1e-5)


def test_fused_ef_compress_block_budget(key):
    """Each full 1024-wide block keeps exactly k_b = round(gamma*block)
    entries (random floats: no ties)."""
    m = jax.random.normal(key, (4096,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (4096,))
    gamma = 0.05
    sent, mnew, _ = ops.fused_ef_compress(m, g, 0.2, gamma=gamma)
    k_b = round(gamma * 1024)
    per_block = np.count_nonzero(np.asarray(sent).reshape(4, 1024), axis=1)
    np.testing.assert_array_equal(per_block, np.full(4, k_b))


def test_threshold_split_blocks_matches_ref(key):
    x = jax.random.normal(key, (3, 3072))
    tau = ops.block_topk_threshold(x, 16, 1024).reshape(-1, 1)
    s1, r1 = ops.threshold_split_blocks(x, tau, 1024, impl="ref")
    s2, r2 = ops.threshold_split_blocks(x, tau, 1024, impl="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_allclose(np.asarray(s2 + r2), np.asarray(x),
                               atol=0.0)


def test_kth_largest_tie_semantics():
    """Tied magnitudes count like lax.top_k duplicates: for [5, -5, 3, 0...]
    the 2nd largest |.| is 5 (not 3) in BOTH the ref and the kernel path."""
    x = jnp.zeros((512,)).at[0].set(5.0).at[1].set(-5.0).at[2].set(3.0)
    t_ref = ops.block_topk_threshold(x, 2, 512, impl="ref")
    t_pal = ops.block_topk_threshold(x, 2, 512, impl="pallas")
    np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_pal))
    assert float(t_pal[0]) == 5.0


@pytest.mark.parametrize("k_b", [1, 8, 32])
def test_block_stats_sweep(key, k_b):
    x = jax.random.normal(key, (4096,))
    t1 = ops.block_topk_threshold(x, k_b, 512, impl="ref")
    t2 = ops.block_topk_threshold(x, k_b, 512, impl="pallas")
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-6)


@pytest.mark.parametrize("shape", [(1, 2, 128, 32), (2, 4, 256, 64),
                                   (1, 8, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(key, shape, causal, window):
    B, H, S, D = shape
    q = jax.random.normal(key, shape, jnp.float32) * 0.1
    k = jax.random.normal(jax.random.fold_in(key, 1), shape) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 2), shape)
    o1 = ops.attention(q, k, v, causal=causal, window=window, impl="ref")
    o2 = ops.attention(q, k, v, causal=causal, window=window, impl="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_flash_attention_rectangular(key):
    B, H, D = 2, 2, 64
    k = jax.random.normal(key, (B, H, 256, D)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, H, 256, D))
    q = jax.random.normal(jax.random.fold_in(key, 2), (B, H, 128, D)) * 0.1
    o1 = ops.attention(q, k, v, causal=True, impl="ref")
    o2 = ops.attention(q, k, v, causal=True, impl="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)


def test_flash_attention_bf16(key):
    shape = (1, 2, 256, 64)
    q = (jax.random.normal(key, shape) * 0.1).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.fold_in(key, 1), shape) * 0.1
         ).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape
                          ).astype(jnp.bfloat16)
    o1 = ops.attention(q, k, v, impl="ref")
    o2 = ops.attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


@pytest.mark.parametrize("shape", [(8, 128), (2, 100, 256), (3, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],),
                          jnp.float32)
    o1 = ops.rms_norm(x, w, impl="ref")
    o2 = ops.rms_norm(x, w, impl="pallas")
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=1e-5)


def test_softmax_invariance_flash(key):
    """Flash accumulation must be shift-invariant: adding a constant to all
    logits (via scaled q) changes nothing."""
    shape = (1, 1, 256, 64)
    q = jax.random.normal(key, shape) * 0.1
    k = jax.random.normal(jax.random.fold_in(key, 1), shape) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 2), shape)
    o1 = ops.attention(q, k, v, impl="pallas")
    o2 = ops.attention(q, k + 100.0 * 0, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


@pytest.mark.parametrize("S", [8, 33, 64])
@pytest.mark.parametrize("K", [8, 64])
def test_wkv_kernel_sweep(key, S, K):
    """RWKV-6 WKV Pallas kernel vs sequential oracle."""
    B, H, V = 2, 2, K
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, V))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K)))
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, K, V)) * 0.1
    y1, sT1 = ops.wkv(r, k, v, w, u, s0, impl="ref")
    y2, sT2 = ops.wkv(r, k, v, w, u, s0, impl="pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), atol=2e-5)


def test_wkv_kernel_matches_time_mix_scan(key):
    """The kernel path of rwkv.time_mix == the scan path (same block)."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import rwkv as rwkv_mod
    cfg = get_smoke_config("rwkv6-1.6b")
    p = rwkv_mod.init_rwkv6(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    st = rwkv_mod.init_rwkv_state(cfg, 2)
    y_scan, st_scan = rwkv_mod.time_mix(p, x, cfg, st)
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    st2 = rwkv_mod.init_rwkv_state(cfg, 2)
    y_ker, st_ker = rwkv_mod.time_mix(p, x, cfg_k, st2)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_ker),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_scan.wkv),
                               np.asarray(st_ker.wkv), atol=1e-4)
