"""Per-architecture smoke tests (deliverable (f)): reduced same-family
variant (2 layers, d_model<=512, <=4 experts) — one forward/train step on
CPU asserting output shapes + no NaNs, plus prefill/decode paths."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.models import build_model

B, S = 2, 64


def make_batch(cfg, key, seq=S):
    kb = jax.random.fold_in(key, 99)
    if cfg.family == "encdec":
        return {"src_embed": jax.random.normal(kb, (B, seq // 2, cfg.d_model)),
                "tokens": jax.random.randint(kb, (B, seq // 2), 0,
                                             cfg.vocab_size)}
    b = {"tokens": jax.random.randint(kb, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["image_embed"] = jax.random.normal(kb, (B, cfg.n_patches,
                                                  cfg.d_model))
    return b


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    return request.param


def test_smoke_contract(arch):
    """Prompt contract for the reduced variants."""
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 5
    if cfg.family == "moe":
        assert cfg.n_experts <= 4
    assert cfg.family == get_config(arch).family


def test_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0

    # one CSGD-ASSS train step on CPU: finite, params change
    opt = csgd_asss(CSGDConfig(
        armijo=ArmijoConfig(),
        compressor=Compressor(gamma=0.1, min_compress_size=128)))
    st = opt.init(params)
    new_params, st, aux = jax.jit(
        lambda p, s: opt.step(lambda pp: model.loss(pp, batch)[0], p, s)
    )(params, st)
    assert bool(jnp.isfinite(aux.loss))
    deltas = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                          params, new_params)
    assert max(jax.tree.leaves(deltas)) > 0


def test_prefill_decode_shapes(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = make_batch(cfg, key)
    ctx = S // 2 if cfg.family == "encdec" else S
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, capacity=ctx + 4))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert int(jnp.max(tok)) < cfg.vocab_size  # padded logits masked
    lg2, cache2 = jax.jit(model.decode_step)(params, tok, cache,
                                             jnp.int32(ctx))
    assert lg2.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg2[..., :cfg.vocab_size])))


def test_full_config_dims(arch):
    """The production config matches the assigned spec."""
    cfg = get_config(arch)
    spec = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    }[arch]
    L, D, H, KV, FF, V = spec
    assert cfg.n_layers == L and cfg.d_model == D
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == FF and cfg.vocab_size == V
    assert cfg.citation


def test_param_count_sanity(arch):
    """Analytic count within 2x of the arch's nameplate size."""
    cfg = get_config(arch)
    nameplate = {
        "seamless-m4t-large-v2": 2.3e9, "zamba2-7b": 7e9,
        "llama3-405b": 405e9, "llama-3.2-vision-11b": 10e9,  # LM part
        "qwen1.5-32b": 32e9, "granite-moe-1b-a400m": 1.3e9,
        "yi-34b": 34e9, "rwkv6-1.6b": 1.6e9, "qwen1.5-4b": 4e9,
        "qwen3-moe-30b-a3b": 30e9,
    }[arch]
    n = cfg.n_params()
    assert 0.4 * nameplate < n < 2.5 * nameplate, (n, nameplate)
