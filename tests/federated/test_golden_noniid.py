"""Golden non-IID convergence pair (DESIGN.md §13): support-weighted
aggregation matches the IID baseline on pathologically non-IID clients,
zero-averaged mean demonstrably lags.

Construction: least-squares in d=2048 with 64 clients, client ``c``'s
data (hence gradient, hence EF memory) supported ONLY on its own
32-coordinate stripe.  48/64 clients participate per round (fixed
sampling, deterministic in (seed, round)).  Under top-k compression the
budget covers a whole stripe, so a participating owner ships its full
stripe residual:

* ``support`` divides each coordinate by the clients that actually sent
  it (= 1, the owner) — the stripe takes the full step and the run
  converges at least as fast as the IID baseline;
* ``mean`` divides by all 48 participants — every stripe step is
  shrunk 48x with no EF recourse (the owner's residual against its OWN
  payload is zero), so after 40 rounds the loss is still O(1).

The numbers are golden: deterministic data (seeded), deterministic
participation, single device (dp_axes=None — the parity suite covers
mesh equivalence), so the final losses are pinned to ranges with an
order of magnitude of headroom rather than exact floats.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Compressor
from repro.fed.clients import cohort_compress_aggregate
from repro.fed.sampling import participation_mask

D, N_CLIENTS, STRIPE, ROUNDS = 2048, 64, 32, 40
ETA, GAMMA = 0.3, 0.05


def _run(noniid: bool, aggregation: str) -> float:
    comp = Compressor(gamma=GAMMA, method="topk", min_compress_size=64,
                      value_bits=32, use_kernel=False)
    rng = np.random.default_rng(0)
    wstar = rng.standard_normal(D).astype(np.float32)
    w = np.zeros(D, np.float32)
    mem = jnp.zeros((N_CLIENTS, D), jnp.float32)

    @jax.jit
    def step(g, m, p):
        u, nm, _, _ = cohort_compress_aggregate(
            {"w": g}, {"w": m}, jnp.float32(ETA), comp, None, p,
            aggregation=aggregation)
        return u["w"], nm["w"]

    for t in range(ROUNDS):
        mask = participation_mask(N_CLIENTS, t, seed=5, mode="fixed",
                                  clients_per_round=48)
        resid = w - wstar
        if noniid:
            g = np.zeros((N_CLIENTS, D), np.float32)
            for c in range(N_CLIENTS):
                sl = slice(c * STRIPE, (c + 1) * STRIPE)
                g[c, sl] = resid[sl]
        else:
            g = np.broadcast_to(resid, (N_CLIENTS, D)).copy()
        u, mem = step(jnp.asarray(g), mem, jnp.asarray(mask))
        w = w - np.asarray(u)
    return float(np.mean((w - wstar) ** 2) / np.mean(wstar ** 2))


def test_golden_noniid_convergence_pair():
    iid = _run(noniid=False, aggregation="support")
    sup = _run(noniid=True, aggregation="support")
    mean = _run(noniid=True, aggregation="mean")

    # the IID baseline itself converges (sanity: EF top-k is healthy)
    assert 0.005 < iid < 0.08, iid
    # support on non-IID clients: within 5% + noise of the IID baseline
    assert sup <= 1.05 * iid + 1e-3, (sup, iid)
    # ... in fact essentially exact here (full-stripe sends, support=1)
    assert sup < 1e-5, sup
    # zero-averaged mean lags by orders of magnitude
    assert mean > 10.0 * iid, (mean, iid)
    # golden range (measured 0.687 at seed 0; wide platform headroom)
    assert 0.5 < mean < 0.8, mean
