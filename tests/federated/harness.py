#!/usr/bin/env python
"""Relaunch pytest under 8 forced host devices for the federated tier.

Same contract as tests/distributed/harness.py (whose environment builder
this reuses): the main pytest process keeps its single-device view, and
the cohort suite runs in a fresh interpreter whose XLA backend is forced
to 8 host devices before jax initializes:

    python tests/federated/harness.py [extra pytest args]

CI runs the same thing as a dedicated job (see .github/workflows/ci.yml,
job ``tier1-federated``).
"""
import os
import subprocess
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(os.path.dirname(_HERE))
sys.path.insert(0, os.path.join(_REPO, "tests", "distributed"))

from harness import multidevice_env  # noqa: E402


def main(argv=None) -> int:
    cmd = [sys.executable, "-m", "pytest", "-q", "-m", "federated", _HERE]
    cmd += list(sys.argv[1:] if argv is None else argv)
    return subprocess.call(cmd, env=multidevice_env(_REPO), cwd=_REPO)


if __name__ == "__main__":
    raise SystemExit(main())
