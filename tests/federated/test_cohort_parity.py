"""Cohort-over-dp parity: the vmap'd shard_map exchange against the
collective-free NumPy reference (tests/federated/reference.py).

64 heterogeneous clients — per-client step sizes AND per-client adaptive
gamma (so every client ships a different k_t through the one fixed-shape
gather) — run on both an (8,) dp mesh and a (4, 2) two-axis mesh (the
multi-axis ``gather_packed`` reshape path).  The aggregated update must
match the float64 oracle to float32 tolerance, and the per-client EF
memory must match to within one float32 ulp: the residual is pure float32
arithmetic on both sides (see reference.py) — XLA fuses the EF
accumulate into an fma, numpy rounds the product separately — so
anything beyond roundoff means the client-id/gather-row mapping or the
own-slice EF contract broke.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm.bucket import build_bucket_plan
from repro.compat import shard_map
from repro.core import Compressor
from repro.fed.clients import cohort_compress_aggregate, per_client_wire_bytes
from repro.fed.sampling import participation_mask

from reference import simulate_cohort

N_CLIENTS = 64

MESHES = {
    "dp8": ((8,), ("data",)),
    "pod4x2": ((4, 2), ("pod", "data")),
}


def _cohort(seed=0):
    """(N, ...) client-leading leaves: one stacked, one flat compressed,
    one dense small — every lane kind of the bucket plan."""
    rng = np.random.default_rng(seed)
    grads = {
        "w": rng.standard_normal((N_CLIENTS, 3, 1200)).astype(np.float32),
        "v": rng.standard_normal((N_CLIENTS, 4096)).astype(np.float32),
        "t": rng.standard_normal((N_CLIENTS, 60)).astype(np.float32),
    }
    mem = {k: (0.1 * rng.standard_normal(v.shape)).astype(np.float32)
           for k, v in grads.items()}
    eta_c = np.linspace(0.1, 0.5, N_CLIENTS, dtype=np.float32)
    gamma_c = np.linspace(0.02, 0.2, N_CLIENTS, dtype=np.float32)
    part = participation_mask(N_CLIENTS, 3, seed=11, mode="fixed",
                              clients_per_round=48)
    return grads, mem, eta_c, gamma_c, part


def _run_mesh(mesh_name, grads, mem, eta_c, gamma_c, part, comp,
              aggregation):
    shape, axes = MESHES[mesh_name]
    mesh = jax.make_mesh(shape, axes)
    dp_axes = axes
    lead = P(axes)
    tlead = jax.tree.map(lambda _: lead, grads)
    trep = jax.tree.map(lambda _: P(), grads)
    fn = functools.partial(cohort_compress_aggregate, comp=comp,
                           dp_axes=dp_axes, aggregation=aggregation)
    f = shard_map(
        lambda g, m, e, gc, p: fn(g, m, e, participation=p, gamma_c=gc),
        mesh=mesh, in_specs=(tlead, tlead, lead, lead, P()),
        out_specs=(trep, tlead, P(), P()),
        axis_names=set(axes), check_vma=False)
    return jax.jit(f)(grads, mem, jnp.asarray(eta_c),
                      jnp.asarray(gamma_c), jnp.asarray(part))


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("aggregation", ["support", "mean"])
def test_cohort_parity_adaptive(mesh_name, aggregation):
    comp = Compressor(gamma=0.02, method="topk", min_compress_size=1000,
                      value_bits=32, use_kernel=False, max_gamma=0.2)
    grads, mem, eta_c, gamma_c, part = _cohort()
    upd, new_mem, wire, eff = _run_mesh(
        mesh_name, grads, mem, eta_c, gamma_c, part, comp, aggregation)
    ref_upd, ref_mem = simulate_cohort(grads, mem, eta_c, gamma_c, part,
                                       comp, aggregation)
    for name in grads:
        np.testing.assert_allclose(
            np.asarray(upd[name], np.float64), ref_upd[name],
            rtol=2e-6, atol=2e-6, err_msg=f"update leaf {name!r}")
        np.testing.assert_allclose(
            np.asarray(new_mem[name]), ref_mem[name], rtol=0, atol=5e-7,
            err_msg=f"EF memory leaf {name!r}")

    leaves = [v.shape[1:] for v in grads.values()]
    plan = build_bucket_plan(leaves,
                             [len(s) >= 2 for s in leaves], comp)
    n_part = float(part.sum())
    assert float(wire) == n_part * per_client_wire_bytes(plan)
    # heterogeneous k_t: ragged effective bytes strictly below budget
    assert 0.0 < float(eff) < float(wire)


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
def test_cohort_parity_nonadaptive(mesh_name):
    comp = Compressor(gamma=0.1, method="topk", min_compress_size=1000,
                      value_bits=32, use_kernel=False)
    grads, mem, eta_c, gamma_c, part = _cohort(seed=7)
    gamma0 = np.zeros(N_CLIENTS, np.float32)   # ignored: non-ragged wire
    upd, new_mem, wire, eff = _run_mesh(
        mesh_name, grads, mem, eta_c, gamma0, part, comp, "support")
    ref_upd, ref_mem = simulate_cohort(grads, mem, eta_c, gamma0, part,
                                       comp, "support")
    for name in grads:
        np.testing.assert_allclose(
            np.asarray(upd[name], np.float64), ref_upd[name],
            rtol=2e-6, atol=2e-6, err_msg=f"update leaf {name!r}")
        np.testing.assert_allclose(
            np.asarray(new_mem[name]), ref_mem[name], rtol=0, atol=5e-7,
            err_msg=f"EF memory leaf {name!r}")
    assert 0.0 < float(eff) <= float(wire)


def test_mesh_invariance():
    """Same cohort on (8,) and (4,2) — identical wire accounting and
    update within summation-order tolerance."""
    comp = Compressor(gamma=0.02, method="topk", min_compress_size=1000,
                      value_bits=32, use_kernel=False, max_gamma=0.2)
    grads, mem, eta_c, gamma_c, part = _cohort(seed=3)
    outs = {name: _run_mesh(name, grads, mem, eta_c, gamma_c, part,
                            comp, "support") for name in MESHES}
    a, b = outs["dp8"], outs["pod4x2"]
    for name in grads:
        np.testing.assert_allclose(np.asarray(a[0][name]),
                                   np.asarray(b[0][name]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a[1][name]),
                                      np.asarray(b[1][name]))
    assert float(a[2]) == float(b[2])
    np.testing.assert_allclose(float(a[3]), float(b[3]), rtol=1e-6)
