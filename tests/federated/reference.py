"""Collective-free NumPy/float64 reference of the cohort exchange.

Mirrors ``repro.fed.clients.cohort_compress_aggregate`` for the
``method='topk'``, ``value_bits=32`` configuration the parity suite
pins: per-client EF accumulate, per-layer exact top-k at the static
budget, the §9 ragged valid-count mask (first ``k_t`` of the
magnitude-ordered entries survive the wire), support-weighted
aggregation, and the participant-only EF recycle.  No jax, no
collectives — every client is a plain python loop iteration, which is
exactly what makes it a trustworthy oracle for the vmap'd shard_map
path.

Selection order matches ``lax.top_k`` (descending magnitude, ties to
the lower index) via a stable argsort on the negated magnitudes.  The
EF accumulate is computed in float32 — the SAME IEEE arithmetic the jax
path performs elementwise — so the selected set is identical by
construction (no near-tie flakiness between a float64 oracle and the
float32 device path); only the cross-client aggregation runs in
float64, which is the part where summation order actually differs.
Residuals therefore agree to one float32 ulp (XLA fuses the accumulate
into an fma; numpy rounds the product separately), and the parity suite
compares EF memory at roundoff tolerance, the update at float32
aggregation tolerance.
"""
from __future__ import annotations

import numpy as np


def _leaf2d(x: np.ndarray, stacked: bool) -> np.ndarray:
    if stacked and x.ndim >= 2:
        return x.reshape(x.shape[0], -1)
    return x.reshape(1, -1)


def _k_t(comp, gamma_t: float, d: int) -> int:
    """comp.k_t_for in numpy: round(f32(gamma_t) * d) clamped to
    [1, k_max] (same banker's rounding as jnp.round)."""
    k_max = comp.k_for(d)
    return int(np.clip(np.round(np.float32(gamma_t) * np.float32(d)),
                       1, k_max))


def simulate_cohort(grads: dict, mem: dict, eta_c: np.ndarray,
                    gamma_c: np.ndarray, part: np.ndarray, comp,
                    aggregation: str = "support"):
    """One cohort round in float64. ``grads``/``mem``: dicts of
    (N, *shape) arrays; ``eta_c``/``gamma_c``/``part``: (N,).

    Returns ``(updates, new_mem)`` — updates float64 per-leaf
    (*shape,), new_mem float32 per-client (N, *shape) with
    non-participants bit-frozen.
    """
    assert comp.method == "topk" and comp.value_bits == 32
    N = int(part.size)
    n_part = max(float(part.sum()), 1.0)
    updates, new_mem = {}, {}
    for name, g in grads.items():
        g = np.asarray(g, np.float32)
        m = np.asarray(mem[name], np.float32)
        stacked = (g.ndim - 1) >= 2
        L, d = _leaf2d(g[0], stacked).shape
        sent = np.zeros((N, L, d), np.float32)
        accs = np.zeros((N, L, d), np.float32)
        for c in range(N):
            # float32 on purpose — see module docstring
            acc = (_leaf2d(m[c], stacked)
                   + np.float32(eta_c[c]) * _leaf2d(g[c], stacked))
            accs[c] = acc
            if comp.ships_dense(d):
                sent[c] = acc              # dense lane: whole row ships
                continue
            k_max = comp.k_for(d)
            k_t = _k_t(comp, float(gamma_c[c]), d) if comp.adaptive \
                else k_max
            order = np.argsort(-np.abs(acc), axis=1, kind="stable")
            for ell in range(L):
                keep = order[ell, :k_t]
                sent[c, ell, keep] = acc[ell, keep]
        w = part.astype(np.float64).reshape(N, 1, 1)
        total = (sent.astype(np.float64) * w).sum(axis=0)
        if comp.ships_dense(d) or aggregation == "mean":
            upd = total / n_part
        else:
            support = ((sent != 0.0) * w).sum(axis=0)
            upd = np.where(support > 0.0,
                           total / np.maximum(support, 1.0), 0.0)
        updates[name] = upd.reshape(g.shape[1:])
        keep = part.astype(bool).reshape(N, 1, 1)
        m_rows = np.stack([_leaf2d(m[c], stacked) for c in range(N)])
        resid = np.where(keep, accs - sent, m_rows)
        new_mem[name] = resid.astype(np.float32).reshape(m.shape)
    return updates, new_mem
