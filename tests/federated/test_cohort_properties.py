"""Hypothesis properties for the cohort exchange (DESIGN.md §13):
arbitrary participation masks and per-client adaptive levels never
break the wire invariants.

* every participating client's transmitted set has between 1 and
  ``k_max`` coordinates, and never more than its own ``k_t`` — the
  per-client ragged budget holds for EVERY gamma in (0, max_gamma];
* non-participants are bit-frozen and their payloads are dead: the
  aggregated update is byte-identical no matter what garbage a
  non-participant would have sent;
* wire accounting prices exactly ``n_participants`` uplinks.

Shapes are static (one jit compile per module); hypothesis only drives
runtime arrays (masks, gammas, garbage seeds).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm.bucket import build_bucket_plan            # noqa: E402
from repro.core import Compressor                          # noqa: E402
from repro.fed.clients import (cohort_compress_aggregate,  # noqa: E402
                               per_client_wire_bytes)

C = 8            # cohort size (dp_axes=None: the whole cohort, one device)
D, D_SMALL = 512, 24

COMP = Compressor(gamma=0.05, method="topk", min_compress_size=64,
                  value_bits=32, use_kernel=False, max_gamma=0.25)
K_MAX = COMP.k_for(D)

_RNG = np.random.default_rng(42)
GRADS = {"v": _RNG.standard_normal((C, D)).astype(np.float32),
         "t": _RNG.standard_normal((C, D_SMALL)).astype(np.float32)}
MEM = {k: (0.1 * _RNG.standard_normal(v.shape)).astype(np.float32)
       for k, v in GRADS.items()}
ETA = np.float32(0.3)


@jax.jit
def _step(g, m, gamma_c, part):
    return cohort_compress_aggregate(g, m, ETA, COMP, None, part,
                                     gamma_c=gamma_c)


masks = st.lists(st.booleans(), min_size=C, max_size=C).filter(any)
gammas = st.lists(st.floats(0.005, 0.25, allow_nan=False, width=32),
                  min_size=C, max_size=C)


@settings(max_examples=12, deadline=None)
@given(mask=masks, gamma=gammas)
def test_per_client_counts_within_budget(mask, gamma):
    part = np.asarray(mask, np.float32)
    gamma_c = np.asarray(gamma, np.float32)
    upd, new_mem, wire, eff = _step(GRADS, MEM, gamma_c, part)

    acc = MEM["v"] + ETA * GRADS["v"]
    sent = acc - np.asarray(new_mem["v"])          # participants only
    for c in range(C):
        if not mask[c]:
            continue
        # roundoff threshold: host acc differs from the device's fma'd
        # acc by ~1 ulp; real transmitted magnitudes here are O(0.1)
        n_sent = int(np.count_nonzero(np.abs(sent[c]) > 1e-5))
        k_t = int(np.clip(np.round(gamma_c[c] * D), 1, K_MAX))
        assert 1 <= n_sent <= K_MAX
        assert n_sent <= k_t

    # non-participants: EF memory bit-frozen, both lanes
    for name in GRADS:
        froz = np.asarray(new_mem[name])
        for c in range(C):
            if not mask[c]:
                np.testing.assert_array_equal(froz[c], MEM[name][c])

    leaves = [v.shape[1:] for v in GRADS.values()]
    plan = build_bucket_plan(leaves, [len(s) >= 2 for s in leaves], COMP)
    n_on = float(part.sum())
    assert float(wire) == n_on * per_client_wire_bytes(plan)
    assert 0.0 < float(eff) <= float(wire)

    # dense small leaf: participation-weighted zero-averaged mean
    acc_t = MEM["t"] + ETA * GRADS["t"]
    want = (part[:, None] * acc_t).sum(0) / max(n_on, 1.0)
    np.testing.assert_allclose(np.asarray(upd["t"]), want,
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(mask=masks, seed=st.integers(0, 2**31 - 1))
def test_nonparticipant_payloads_are_dead(mask, seed):
    part = np.asarray(mask, np.float32)
    gamma_c = np.full(C, 0.1, np.float32)
    base = _step(GRADS, MEM, gamma_c, part)

    rng = np.random.default_rng(seed)
    g2 = {k: v.copy() for k, v in GRADS.items()}
    m2 = {k: v.copy() for k, v in MEM.items()}
    for c in range(C):
        if mask[c]:
            continue
        for t in (g2, m2):
            for k in t:
                t[k][c] = rng.standard_normal(t[k][c].shape)
    other = _step(g2, m2, gamma_c, part)

    for name in GRADS:
        np.testing.assert_array_equal(np.asarray(base[0][name]),
                                      np.asarray(other[0][name]))
    np.testing.assert_array_equal(np.asarray(base[2]),
                                  np.asarray(other[2]))
    np.testing.assert_array_equal(np.asarray(base[3]),
                                  np.asarray(other[3]))
