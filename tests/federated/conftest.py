"""tests/federated — the cohort-simulation test tier (DESIGN.md §13).

Every test here runs IN-PROCESS against 8 forced host devices, exactly
like tests/distributed: start the process with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — use
``python tests/federated/harness.py`` (which relaunches pytest with the
right environment) or the ``tier1-federated`` CI job.

Collected under fewer devices (the plain tier-1 run), everything here is
skipped so single-device runs stay fast.
"""
import os
import sys

import jax
import numpy as np
import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
# the tier's NumPy oracle (reference.py) imports as a plain module
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)


def pytest_collection_modifyitems(config, items):
    # scope by path — this hook sees the whole session's items
    n = jax.device_count()
    skip = pytest.mark.skip(
        reason=f"needs 8 virtual devices, have {n} "
               "(run tests/federated/harness.py)")
    for item in items:
        if not str(item.fspath).startswith(_HERE):
            continue
        item.add_marker(pytest.mark.federated)
        if n < 8:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _deterministic_seed():
    np.random.seed(0)
    yield


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
