"""Armijo step-size search with scaling (Algorithm 1 + Theorem 15)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import ArmijoConfig, armijo_search, next_alpha_max


def quad_loss(w):
    return 0.5 * jnp.sum(w ** 2)


def test_condition_satisfied(key):
    w = jax.random.normal(key, (32,))
    g = jax.grad(quad_loss)(w)
    cfg = ArmijoConfig(sigma=0.1)
    res = armijo_search(quad_loss, w, g, jnp.float32(10.0), cfg)
    f0 = quad_loss(w)
    f_after = quad_loss(w - res.alpha * g)
    assert bool(res.accepted)
    assert float(f_after) <= float(f0 - cfg.sigma * res.alpha
                                   * jnp.sum(g ** 2)) + 1e-6


def test_alpha_lower_bound():
    """Lemma 9: accepted alpha >= rho * 2(1-sigma)/L (L=1 quadratic)."""
    cfg = ArmijoConfig(sigma=0.1, rho=0.8)
    w = jnp.ones((8,))
    g = jax.grad(quad_loss)(w)
    res = armijo_search(quad_loss, w, g, jnp.float32(100.0), cfg)
    assert float(res.alpha) >= cfg.rho * 2 * (1 - cfg.sigma) - 1e-6


def test_accepts_alpha_max_when_valid():
    cfg = ArmijoConfig(sigma=0.1)
    w = jnp.ones((8,))
    g = jax.grad(quad_loss)(w)
    res = armijo_search(quad_loss, w, g, jnp.float32(0.5), cfg)
    assert float(res.alpha) == pytest.approx(0.5)
    assert int(res.n_evals) == 1


def test_alpha_max_growth():
    cfg = ArmijoConfig(omega=1.2)
    assert float(next_alpha_max(jnp.float32(0.1), cfg)) == pytest.approx(0.12)


def test_scaled_gd_convex_rate():
    """Theorem 15: scaled Armijo GD achieves O(1/T) on a convex quadratic
    for sigma < 0.5 (where the unscaled theory does not apply)."""
    cfg = ArmijoConfig(sigma=0.1, a_scale=0.15)  # a < 2*sigma
    scales = 2.0 ** -jnp.arange(1, 11)

    def f(w):
        return jnp.sum(scales * w ** 2)

    w = jnp.ones((10,))
    losses = []
    alpha_max = jnp.float32(cfg.alpha0)
    for t in range(200):
        g = jax.grad(f)(w)
        res = armijo_search(f, w, g, alpha_max, cfg)
        w = w - cfg.a_scale * res.alpha * g
        alpha_max = next_alpha_max(res.alpha, cfg)
        losses.append(float(f(w)))
    assert losses[-1] < 1e-3
    # O(1/T): f(x_T) * T bounded
    assert losses[-1] * 200 < losses[0] * 10


def test_scaling_beats_unscaled_on_asymmetric():
    """Paper Fig. 5b: on sum x_i^2/2^i, scaled GD converges much faster
    than unscaled GD with the same search — without scaling the accepted
    step is pinned at the steepest direction's 2/L stability cap (~1.92
    here), while scaling lets the search return alpha 10-30x larger for
    the flat directions.  The gap grows with T (paper: orders of magnitude
    by ~10k iters); at T=1000 we assert >=5x."""
    scales = 2.0 ** -jnp.arange(1, 11)

    def f(w):
        return jnp.sum(scales * w ** 2)

    def run(a_scale, T=1000):
        cfg = ArmijoConfig(sigma=0.1, a_scale=a_scale)

        @jax.jit
        def step(w, amax):
            g = jax.grad(f)(w)
            res = armijo_search(f, w, g, amax, cfg)
            return (w - a_scale * res.alpha * g,
                    next_alpha_max(res.alpha, cfg))

        w = jnp.ones((10,))
        amax = jnp.float32(cfg.alpha0)
        for _ in range(T):
            w, amax = step(w, amax)
        return float(f(w))

    scaled = run(0.15)     # a = 1.5*sigma (paper's appendix setting)
    unscaled = run(1.0)
    assert scaled < unscaled * 0.2, (scaled, unscaled)


def test_max_backtracks_cap():
    cfg = ArmijoConfig(max_backtracks=3)

    def bad_loss(w):  # never satisfies sufficient decrease w/ huge grad lie
        return jnp.sum(w ** 2) * 0 + 1.0

    w = jnp.ones((4,))
    g = jnp.ones((4,)) * 100.0
    res = armijo_search(bad_loss, w, g, jnp.float32(1.0), cfg)
    assert int(res.n_evals) <= cfg.max_backtracks + 1
    assert not bool(res.accepted)


@pytest.mark.parametrize("bad", [jnp.nan, -jnp.inf])
def test_nonfinite_candidate_loss_is_rejected(bad):
    """DESIGN.md §16 regression: a candidate whose loss is NaN/-Inf is a
    REJECTED trial.  Without the isfinite guard a -Inf f_try satisfies
    the sufficient-decrease inequality and the search would 'accept' a
    step onto a blown-up loss surface; the search must instead backtrack
    into the finite region and accept there."""
    w = jnp.ones((4,)) * 0.1
    g = jax.grad(quad_loss)(w)
    f0 = quad_loss(w)

    def cliff(v):
        # finite quadratic near w, non-finite once the candidate moves
        # beyond ~70% of the start norm (i.e. any alpha outside
        # (0.3, 1.7) for cand = (1-alpha) * w)
        return jnp.where(jnp.sum(v ** 2) > 0.5 * jnp.sum(w ** 2),
                         bad, quad_loss(v))

    cfg = ArmijoConfig(sigma=0.1, rho=0.5, max_backtracks=40)
    res = armijo_search(cliff, w, g, jnp.float32(64.0), cfg, f0=f0)
    assert bool(res.accepted)
    assert jnp.isfinite(res.alpha)
    # accepted inside the finite region: 64 * 0.5^k first lands there at 1
    assert float(res.alpha) <= 1.7
    assert bool(jnp.isfinite(cliff(w - res.alpha * g)))


def test_everywhere_nonfinite_loss_never_accepts():
    cfg = ArmijoConfig(max_backtracks=5)
    w = jnp.ones((4,))
    g = jnp.ones((4,))
    res = armijo_search(lambda v: jnp.sum(v) * jnp.nan, w, g,
                        jnp.float32(1.0), cfg, f0=jnp.float32(1.0))
    assert not bool(res.accepted)


def test_theory_safe_clamps_scale_to_zeta():
    """The a_scale doc/theory contradiction (paper §IV-A: a = 3*sigma, but
    theory needs a <= zeta(gamma) = sigma*gamma/(2-gamma) < 2*sigma):
    theory_safe=True clamps the effective scale per round; the default
    preserves the paper's empirical setting exactly."""
    cfg = ArmijoConfig(sigma=0.1, a_scale=0.3)
    # default off: the paper's empirical 3*sigma, even though it violates
    # the bound (0.3 > 2*sigma = 0.2 > zeta for every gamma <= 1)
    assert cfg.scale_for(0.01) == 0.3
    assert cfg.a_scale > cfg.theory_a_bound

    safe = ArmijoConfig(sigma=0.1, a_scale=0.3, theory_safe=True)
    for gamma in (0.01, 0.04, 0.5, 1.0):
        zeta = safe.zeta(gamma)
        assert zeta == pytest.approx(0.1 * gamma / (2.0 - gamma))
        got = float(safe.scale_for(gamma))
        assert got == pytest.approx(min(0.3, zeta))
        assert got <= safe.theory_a_bound + 1e-9
    # traced gamma_t (adaptive compression re-clamps each round)
    got = float(safe.scale_for(jnp.float32(0.04)))
    assert got == pytest.approx(safe.zeta(0.04), rel=1e-6)
    # no gamma -> no clamp (nothing to couple to)
    assert safe.scale_for(None) == 0.3

    # and the clamp flows through the search's returned eta
    def f(w):
        return jnp.sum(w ** 2)

    w = jnp.ones((8,))
    g = jax.grad(f)(w)
    res_paper = armijo_search(f, w, g, jnp.float32(0.5), cfg, gamma=0.04)
    res_safe = armijo_search(f, w, g, jnp.float32(0.5), safe, gamma=0.04)
    assert float(res_paper.alpha) == float(res_safe.alpha)
    assert float(res_paper.eta) == pytest.approx(0.3 * float(res_paper.alpha))
    assert float(res_safe.eta) == pytest.approx(
        safe.zeta(0.04) * float(res_safe.alpha), rel=1e-6)
    assert float(res_safe.eta) < float(res_paper.eta)
