"""MoE routing: weight normalization, capacity behaviour, aux loss,
expert utilization, no-drop decode mode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as moe_mod


@pytest.fixture
def cfg():
    return get_smoke_config("granite-moe-1b-a400m")


def test_moe_output_shape_and_finite(cfg, key):
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y, aux = moe_mod.moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_moe_grad_flows_to_all_parts(cfg, key):
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))

    def loss(p):
        y, aux = moe_mod.moe_block(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_leaves_with_path(g):
        assert float(jnp.sum(jnp.abs(leaf))) > 0, path


def test_capacity_dropping(cfg, key):
    """With tiny capacity_factor, some tokens must be dropped (combine
    weight 0) and outputs remain finite."""
    small = dataclasses.replace(cfg, capacity_factor=0.25)
    p = moe_mod.init_moe(key, small, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, small.d_model))
    y, _ = moe_mod.moe_block(p, x, small)
    assert bool(jnp.all(jnp.isfinite(y)))
    y_nodrop, _ = moe_mod.moe_block(p, x, small, no_drop=True)
    # dropping must change the result
    assert float(jnp.max(jnp.abs(y - y_nodrop))) > 1e-6


def test_no_drop_mode_exact_topk_mixture(key):
    """With E=2, k=2 and no_drop, MoE == gate-weighted sum of both expert
    MLPs (dense mixture oracle)."""
    cfg = dataclasses.replace(get_smoke_config("granite-moe-1b-a400m"),
                              n_experts=2, experts_per_token=2)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, cfg.d_model))
    y, _ = moe_mod.moe_block(p, x, cfg, no_drop=True)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    outs = []
    for e in range(2):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        outs.append(h @ p["wo"][e])
    oracle = sum(probs[:, e:e + 1] * outs[e] for e in range(2))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(oracle), atol=1e-4)


def test_aux_loss_balanced_vs_collapsed(cfg, key):
    """Aux loss is ~1*coef for a uniform router and larger for collapse."""
    E = cfg.n_experts
    T = 4096
    probs_uniform = jnp.full((T, E), 1.0 / E)
    eids_uniform = jnp.tile(jnp.arange(E), T // E + 1)[:T]
    dens_u = jnp.mean(jax.nn.one_hot(eids_uniform, E), 0)
    aux_u = float(jnp.sum(dens_u * jnp.mean(probs_uniform, 0)) * E)
    probs_collapsed = jnp.zeros((T, E)).at[:, 0].set(1.0)
    dens_c = jax.nn.one_hot(jnp.zeros(T, jnp.int32), E).mean(0)
    aux_c = float(jnp.sum(dens_c * jnp.mean(probs_collapsed, 0)) * E)
    assert aux_u == pytest.approx(1.0, rel=0.05)
    assert aux_c == pytest.approx(E, rel=0.05)
    assert aux_c > aux_u
