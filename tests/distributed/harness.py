#!/usr/bin/env python
"""Relaunch pytest under 8 forced host devices — the multi-device test tier.

The main pytest process must keep its single-device view (smoke tests and
benches depend on it), so the multi-device suite runs in a fresh
interpreter whose XLA backend is forced to 8 host devices *before* jax
initializes.  This runner sets that environment deterministically and execs
pytest on tests/distributed:

    python tests/distributed/harness.py [extra pytest args]

CI runs the same thing as a dedicated job (see .github/workflows/ci.yml,
job ``tier1-multidevice``).
"""
import os
import subprocess
import sys

DEVICE_FLAG = "--xla_force_host_platform_device_count=8"


def multidevice_env(repo: str) -> dict:
    """Environment for an 8-virtual-device JAX process with deterministic
    seeding (fixed PYTHONHASHSEED; tests use fixed PRNGKeys)."""
    env = dict(os.environ)
    xla = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in xla:
        env["XLA_FLAGS"] = (xla + " " + DEVICE_FLAG).strip()
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.setdefault("PYTHONHASHSEED", "0")
    return env


def main(argv=None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(here))
    cmd = [sys.executable, "-m", "pytest", "-q", "-m", "multidevice", here]
    cmd += list(sys.argv[1:] if argv is None else argv)
    return subprocess.call(cmd, env=multidevice_env(repo), cwd=repo)


if __name__ == "__main__":
    raise SystemExit(main())
