"""8-virtual-device parity for the compressed downlink (DESIGN.md §15).

The downlink is a *physically simulated* server: the bucketed aggregate is
bit-identical on every worker, so the server compress/EF runs replicated
with NO extra collective.  That claim is exactly testable:

* the downlink-enabled exchange must be bit-exact against a
  **collective-free oracle** — ``apply_downlink`` called on the host on
  the reference exchange's replicated mean (the uplink itself is pinned
  bit-exact against the per-leaf schedule in test_bucketed_exchange.py)
  — on both (8,) and (4, 2) dp meshes;
* the uplink outputs (EF memory, wire/effective bytes, telemetry) must be
  UNTOUCHED by enabling the downlink — ``downlink="dense"`` stays the
  bit-exact reference because compression is purely post-aggregate;
* at equal gamma the accounted ``up_eff + down_eff`` must come in
  strictly below the dense downlink charge the reference path pays;
* the server EF residual must actually recycle: round two with the
  carried state differs from round two with a zeroed server memory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm.downlink import (DownlinkCtx, DownlinkState,
                                 apply_downlink, dense_downlink_bytes,
                                 downlink_plan, downlink_wire_bytes,
                                 init_downlink_state)
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate
from repro.core.telemetry import CompressionTelemetry

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 5)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),   # stacked
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),        # dense
        "u": jax.random.normal(ks[3], (n_workers, 40)),        # dense
        "big": jax.random.normal(ks[4], (n_workers, 70000)),   # 32-bit idx
    }


def _flat_geometry(gtree):
    flat, _ = jax.tree.flatten(jax.tree.map(lambda x: x[0], gtree))
    return [x.shape for x in flat], [x.ndim >= 2 for x in flat]


def _fresh_state(gtree, comp, gamma0):
    shapes, flags = _flat_geometry(gtree)
    return init_downlink_state(shapes, flags, comp, gamma0)


def _run(gtree, mtree, gammas, comp, dl_state=None,
         mesh_shape=(W_WORKERS,), axes=("data",), eta=0.1):
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)
    tel_lead = jax.tree.map(lambda _: P(lead_axis),
                            CompressionTelemetry.init(abstract=True))
    use_gamma = gammas is not None
    if gammas is None:
        gammas = jnp.zeros((W_WORKERS,), jnp.float32)
    with_dl = dl_state is not None

    def worker(g, m, gam, dls):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        out = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes),
            gamma_t=gam[0] if use_gamma else None,
            downlink_ctx=DownlinkCtx(state=dls) if with_dl else None)
        upd, newm, wire, eff, tel = out[:5]
        res = (upd, jax.tree.map(lambda x: x[None], newm), wire,
               eff[None], jax.tree.map(lambda x: x[None], tel))
        if with_dl:
            res = res + (out[5],)
        return res

    dls_in = dl_state if with_dl else DownlinkState(
        memory=jnp.zeros((0,), jnp.float32), gamma=jnp.float32(0.0))
    dl_spec = DownlinkState(memory=P(), gamma=P())
    out_specs = (rep, lead, P(), P(lead_axis), tel_lead)
    if with_dl:
        from repro.comm.downlink import DownlinkResult
        out_specs = out_specs + (DownlinkResult(dl_spec, P(), P()),)
    f = shard_map(worker, mesh=mesh,
                  in_specs=(lead, lead, P(lead_axis), dl_spec),
                  out_specs=out_specs, axis_names=set(axes),
                  check_vma=False)
    return jax.jit(f)(gtree, mtree, gammas, dls_in)


def _assert_tree_equal(a, b, msg):
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                      err_msg=msg)


@pytest.mark.parametrize("mesh_shape,axes", [
    ((W_WORKERS,), ("data",)), ((4, 2), ("pod", "data")),
])
@pytest.mark.parametrize("comp", [
    Compressor(gamma=0.05, method="block_topk", block=512,
               min_compress_size=64, value_bits=8),
    Compressor(gamma=0.05, max_gamma=0.05, method="topk",
               min_compress_size=64, value_bits=32),
], ids=["block8", "ragged_topk32"])
def test_downlink_matches_collective_free_oracle(key, comp, mesh_shape,
                                                 axes):
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    gammas = (jnp.linspace(comp.max_gamma / 8.0, comp.max_gamma, W_WORKERS)
              .astype(jnp.float32) if comp.adaptive else None)
    dl0 = _fresh_state(gtree, comp, comp.gamma)
    assert dl0.memory.size > 0

    ref = _run(gtree, mtree, gammas, comp, None, mesh_shape, axes)
    got = _run(gtree, mtree, gammas, comp, dl0, mesh_shape, axes)

    # 1) enabling the downlink leaves every uplink output untouched —
    #    the dense-downlink reference path is bit-exact by construction
    for name, a, b in zip(("memory", "wire", "eff", "telemetry"),
                          ref[1:5], got[1:5]):
        _assert_tree_equal(a, b, f"uplink {name} changed")

    # 2) the mesh downlink == the pure host oracle on the reference mean
    shapes, flags = _flat_geometry(gtree)
    flat_ref, treedef = jax.tree.flatten(ref[0])
    want_upd, want_state, want_wire, want_eff = apply_downlink(
        flat_ref, flags, comp, dl0)
    dl_res = got[5]
    _assert_tree_equal(treedef.unflatten(want_upd), got[0],
                       f"{mesh_shape}: downlinked updates")
    np.testing.assert_array_equal(np.asarray(want_state.memory),
                                  np.asarray(dl_res.state.memory))
    assert float(dl_res.wire_bytes) == float(want_wire)
    assert float(dl_res.eff_wire_bytes) == float(want_eff)

    # 3) static budget matches the plan-level accounting
    plan = downlink_plan(shapes, flags, comp)
    assert float(dl_res.wire_bytes) == downlink_wire_bytes(plan)

    # 4) the downlink really changed the applied update (it compresses)
    same = all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(jax.tree.leaves(ref[0]),
                               jax.tree.leaves(got[0])))
    assert not same


def test_up_plus_down_beats_dense_downlink(key):
    """The acceptance inequality: at equal uplink/downlink gamma the
    accounted compressed round trip (up_eff + down_eff) must come in
    strictly below what the dense downlink alone charges per link."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(lambda x: x * 0.1, gtree)
    g = jnp.full((W_WORKERS,), comp.gamma, jnp.float32)
    dl0 = _fresh_state(gtree, comp, comp.gamma)
    out = _run(gtree, mtree, g, comp, dl0)
    up_eff = float(np.asarray(out[3])[0])
    down_eff = float(out[5].eff_wire_bytes)
    shapes, _ = _flat_geometry(gtree)
    dense_down = dense_downlink_bytes(shapes)
    assert up_eff + down_eff < dense_down, \
        (up_eff, down_eff, dense_down)
    # and the compressed downlink itself undercuts its dense reference
    assert down_eff < dense_down


def test_server_ef_recycles_across_rounds(key):
    """Round 2 with the carried server residual must differ from round 2
    with a zeroed server memory — the EF loop is live, not decorative."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(jnp.zeros_like, gtree)
    dl0 = _fresh_state(gtree, comp, comp.gamma)

    out1 = _run(gtree, mtree, None, comp, dl0)
    st1 = out1[5].state
    assert float(jnp.sum(st1.memory ** 2)) > 0.0

    gtree2 = jax.tree.map(lambda x: x * 0.5, gtree)
    mem2 = out1[1]
    carried = _run(gtree2, mem2, None, comp,
                   DownlinkState(memory=st1.memory, gamma=st1.gamma))
    zeroed = _run(gtree2, mem2, None, comp, dl0)
    same = all(np.array_equal(np.asarray(u), np.asarray(v))
               for u, v in zip(jax.tree.leaves(carried[0]),
                               jax.tree.leaves(zeroed[0])))
    assert not same
