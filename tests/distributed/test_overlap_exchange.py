"""8-virtual-device parity for the overlap transport (DESIGN.md §14).

The pinned contracts:

* the chunked ring all-gather is BIT-IDENTICAL to ``lax.all_gather`` /
  ``gather_packed`` on single- and multi-axis dp meshes, at divisible and
  non-divisible chunk counts;
* ``delay=0`` is a bit-exact drop-in for ``transport="bucketed"`` —
  updates, per-worker EF memory, wire and effective bytes — including
  heterogeneous per-worker k_t riding the ragged count headers, on both
  (8,) and (4, 2) dp meshes (telemetry to <= 8 ulp, same reduction-order
  caveat as tests/distributed/test_bucketed_exchange.py);
* ``delay=1`` double-buffering: the warm-up step applies a ZERO update
  (the initial zero payload) while the EF memory stays bit-exact vs
  bucketed (selection/EF are always current), and step t+1 applies step
  t's bucketed aggregate bit-exactly with the carried effective bytes;
* a delay-1 quadratic trajectory converges to within 5% (+ noise floor)
  of the bucketed trajectory's suboptimality — the golden convergence
  pair for the one-step-stale aggregation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm.overlap import OverlapConfig, OverlapCtx, init_overlap_state
from repro.comm.ring import ring_all_gather
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate
from repro.core.telemetry import CompressionTelemetry

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 5)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),   # stacked
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),        # dense
        "u": jax.random.normal(ks[3], (n_workers, 40)),        # dense
        "big": jax.random.normal(ks[4], (n_workers, 70000)),   # 32-bit idx
    }


def _mem_tree(key, gtree):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size + 1),
                                    x.shape) * 0.1, gtree)


def _hetero_gammas(comp):
    return jnp.linspace(comp.max_gamma / 8.0, comp.max_gamma,
                        W_WORKERS).astype(jnp.float32)


def _init_state(gtree, comp, n_workers=W_WORKERS):
    flat = jax.tree.leaves(jax.tree.map(lambda x: x[0], gtree))
    st = init_overlap_state([x.shape for x in flat],
                            [x.ndim >= 2 for x in flat], comp)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape), st)


def _run(gtree, mtree, gammas, comp, transport, cfg=None, state=None,
         mesh_shape=(W_WORKERS,), axes=("data",), eta=0.1):
    """One exchange; for the overlap transport also returns the new
    (W, ...)-batched carried state as a trailing element."""
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)
    tel_lead = jax.tree.map(lambda _: P(lead_axis),
                            CompressionTelemetry.init(abstract=True))
    use_gamma = gammas is not None
    if gammas is None:
        gammas = jnp.zeros((W_WORKERS,), jnp.float32)
    overlap = transport == "overlap"

    def worker(g, m, gam, st):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        kw = {}
        if overlap:
            kw["transport_ctx"] = OverlapCtx(
                cfg=cfg, state=jax.tree.map(lambda x: x[0], st))
        out = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes),
            gamma_t=gam[0] if use_gamma else None, transport=transport,
            **kw)
        upd, newm, wire, eff, tel = out[:5]
        wrapped = (upd, jax.tree.map(lambda x: x[None], newm), wire,
                   eff[None], jax.tree.map(lambda x: x[None], tel))
        if overlap:
            wrapped += (jax.tree.map(lambda x: x[None], out[5]),)
        return wrapped

    if state is None:
        state = _init_state(gtree, comp) if overlap else ()
    st_spec = jax.tree.map(lambda _: P(lead_axis), state)
    out_specs = (rep, lead, P(), P(lead_axis), tel_lead)
    if overlap:
        out_specs += (st_spec,)
    f = shard_map(worker, mesh=mesh,
                  in_specs=(lead, lead, P(lead_axis), st_spec),
                  out_specs=out_specs,
                  axis_names=set(axes), check_vma=False)
    return jax.jit(f)(gtree, mtree, gammas, state)


def _assert_tree_equal(a, b, msg, maxulp=0):
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if maxulp:
            np.testing.assert_array_max_ulp(np.asarray(u), np.asarray(v),
                                            maxulp=maxulp)
        else:
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=msg)


# ---------------------------------------------------------------------------
# ring gather parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape,axes", [
    ((W_WORKERS,), ("data",)), ((4, 2), ("pod", "data")),
])
@pytest.mark.parametrize("n_chunks", [1, 3, 7])
def test_ring_gather_matches_all_gather(mesh_shape, axes, n_chunks):
    """The chunked ring assembles the EXACT (W, total_words) buffer the
    flat all_gather produces, including non-divisible chunking and
    ring-of-rings multi-axis meshes."""
    total_words = 1000
    rng = np.random.default_rng(7)
    payload = jnp.asarray(
        rng.integers(0, 2**32, (W_WORKERS, total_words), dtype=np.uint32))
    mesh = jax.make_mesh(mesh_shape, axes)
    lead = axes[0] if len(axes) == 1 else tuple(axes)

    def via_ring(p):
        return ring_all_gather(p[0], axes, n_chunks)

    def via_gather(p):
        return jax.lax.all_gather(p[0], axes).reshape(-1, total_words)

    outs = []
    for fn in (via_ring, via_gather):
        f = shard_map(fn, mesh=mesh, in_specs=(P(lead),),
                      out_specs=P(), axis_names=set(axes), check_vma=False)
        outs.append(np.asarray(jax.jit(f)(payload)))
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# delay=0: bit-exact bucketed parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape,axes", [
    ((W_WORKERS,), ("data",)), ((4, 2), ("pod", "data")),
])
@pytest.mark.parametrize("n_chunks", [1, 3])
def test_overlap_delay0_bit_exact_vs_bucketed(key, mesh_shape, axes,
                                              n_chunks):
    """delay=0 over the ring is a bit-exact drop-in for the bucketed
    transport under heterogeneous per-worker k_t (the ragged headers)."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = _mem_tree(key, gtree)
    gammas = _hetero_gammas(comp)
    ref = _run(gtree, mtree, gammas, comp, "bucketed",
               mesh_shape=mesh_shape, axes=axes)
    got = _run(gtree, mtree, gammas, comp, "overlap",
               cfg=OverlapConfig(n_chunks=n_chunks, delay=0),
               mesh_shape=mesh_shape, axes=axes)
    for name, a, b in zip(("updates", "memory", "wire", "eff",
                           "telemetry"), ref, got[:5]):
        _assert_tree_equal(a, b, f"{mesh_shape}/nc={n_chunks}: {name}",
                           maxulp=8 if name == "telemetry" else 0)
    # the new carried state holds THIS step's encoded payload + eff bytes
    assert float(got[5].seeded[0]) == 1.0
    np.testing.assert_array_equal(np.asarray(got[5].eff_wire),
                                  np.asarray(ref[3]))


# ---------------------------------------------------------------------------
# delay=1: double-buffer semantics
# ---------------------------------------------------------------------------

def test_overlap_delay1_warmup_and_staleness(key):
    """Step 1 (warm-up, zero carried payload): zero update, EF memory
    bit-exact vs bucketed (selection is current).  Step 2: applies step
    1's bucketed aggregate bit-exactly, reporting the carried effective
    bytes; EF again bit-exact vs bucketed on the step-2 inputs."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    cfg = OverlapConfig(n_chunks=2, delay=1)
    gtree1 = _worker_tree(key)
    mtree1 = _mem_tree(key, gtree1)
    gtree2 = _worker_tree(jax.random.fold_in(key, 1))
    gammas = _hetero_gammas(comp)

    buck1 = _run(gtree1, mtree1, gammas, comp, "bucketed")
    ov1 = _run(gtree1, mtree1, gammas, comp, "overlap", cfg=cfg)

    # warm-up: zero update on every leaf, EF bit-exact vs bucketed
    for u in jax.tree.leaves(ov1[0]):
        np.testing.assert_array_equal(np.asarray(u), 0.0)
    _assert_tree_equal(buck1[1], ov1[1], "warmup EF")
    # wire is static (the full buffer crosses the wire every step);
    # effective bytes describe the zero payload actually shipped
    np.testing.assert_array_equal(np.asarray(buck1[2]),
                                  np.asarray(ov1[2]))
    assert float(np.asarray(ov1[3])[0]) <= float(np.asarray(buck1[3])[0])
    assert all(float(s) == 1.0 for s in np.asarray(ov1[5].seeded))

    # step 2 (same memory as bucketed — EFs matched bitwise above)
    buck2 = _run(gtree2, buck1[1], gammas, comp, "bucketed")
    ov2 = _run(gtree2, ov1[1], gammas, comp, "overlap", cfg=cfg,
               state=ov1[5])
    # the applied aggregate IS step 1's bucketed mean, bit for bit
    _assert_tree_equal(buck1[0], ov2[0], "delay-1 aggregate")
    # EF stays current: bit-exact vs bucketed on the step-2 inputs
    _assert_tree_equal(buck2[1], ov2[1], "step-2 EF")
    # the reported effective bytes are the carried step-1 ones
    np.testing.assert_array_equal(np.asarray(ov2[3]), np.asarray(buck1[3]))


# ---------------------------------------------------------------------------
# golden delay-1 convergence pair (quadratic)
# ---------------------------------------------------------------------------

def test_overlap_delay1_quadratic_convergence(key):
    """Fixed-gamma compressed SGD on a worker-heterogeneous quadratic:
    the delay-1 overlapped trajectory's suboptimality after T steps stays
    within 5% (+ noise floor) of the synchronous bucketed trajectory's —
    the golden pair pinning that one-step staleness does not degrade
    convergence (DESIGN.md §14)."""
    d = 512
    T = 120
    eta = 0.1
    comp = Compressor(gamma=0.25, method="block_topk", block=128,
                      min_compress_size=64, value_bits=32)
    ka, kb = jax.random.split(key)
    a_w = 0.5 + jax.random.uniform(ka, (W_WORKERS, d))      # diag Hessians
    b_w = jax.random.normal(kb, (W_WORKERS, d))
    a_bar, b_bar = jnp.mean(a_w, 0), jnp.mean(b_w, 0)
    x_star = b_bar / a_bar

    def f_global(x):
        return float(jnp.mean(jnp.sum(
            0.5 * a_w * x[None] ** 2 - b_w * x[None], axis=1)))
    f_star = f_global(x_star)

    def trajectory(transport, cfg=None):
        x = jnp.zeros((d,))
        mem = {"x": jnp.zeros((W_WORKERS, d))}
        state = _init_state({"x": jnp.zeros((W_WORKERS, d))}, comp) \
            if transport == "overlap" else None
        for _ in range(T):
            g = {"x": a_w * x[None] - b_w}
            out = _run(g, mem, None, comp, transport, cfg=cfg,
                       state=state, eta=eta)
            x = x - out[0]["x"]
            mem = out[1]
            if transport == "overlap":
                state = out[5]
        return f_global(x) - f_star

    gap_sync = trajectory("bucketed")
    gap_stale = trajectory("overlap", OverlapConfig(n_chunks=2, delay=1))
    assert gap_sync >= 0 and gap_stale >= 0
    assert gap_stale <= 1.05 * gap_sync + 5e-4, (gap_stale, gap_sync)
