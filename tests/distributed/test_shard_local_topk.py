"""ROADMAP open item: ``shard_local_topk`` on a real (4, 2) device mesh.

On 0.4.x the nested manual-'model' shard_map SIGFPEs XLA (the training body
is already fully manual there), so ``build_train_step`` degenerates
shard-local selection to the direct call — which is semantically identical
while the model axis is replicated.  This test pins the whole path end to
end: with identical per-worker batches, one ``shard_local_topk`` DCSGD-ASSS
step equals the single-device CSGD-ASSS step (the dense, paper-faithful
reference), through the packed wire exchange.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
from repro.core import ArmijoConfig, Compressor, CSGDConfig, csgd_asss
from repro.launch.train_step import (build_train_step, init_opt_state,
                                     opt_state_shardings)
from repro.models import build_model
from repro.sharding import param_shardings
from jax.sharding import NamedSharding, PartitionSpec as P


def _dist_step(m, cfg, run, mesh, params0, one_batch, n_workers=4):
    with set_mesh(mesh):
        # the train step donates params/opt_state and device_put may alias
        # params0's buffers — give every call its own copy
        params0 = jax.tree.map(jnp.array, params0)
        params = jax.device_put(params0, param_shardings(params0, mesh))
        batch = {"tokens": jnp.tile(one_batch["tokens"], (n_workers, 1))}
        st = init_opt_state(params, run, n_workers)
        st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
        batch = jax.device_put(batch, jax.tree.map(
            lambda _: NamedSharding(mesh, P("data")), batch))
        step = build_train_step(m, run, mesh)(params, batch)
        return step(params, st, batch)


def test_shard_local_topk_matches_single_device(key):
    """Same data on every worker: shard_local_topk DCSGD == single-node
    CSGD-ASSS (block_topk selection; block-aligned shards keep the
    block-local operator identical across the nesting)."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64)
    arm = ArmijoConfig()
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
        optimizer=OptimizerConfig(kind="csgd_asss", armijo=arm,
                                  compressor=comp, shard_local_topk=True))
    params0 = m.init(jax.random.PRNGKey(0))
    one = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                        cfg.vocab_size)}
    p_dist, st_dist, metrics = _dist_step(m, cfg, run, mesh, params0, one)

    opt = csgd_asss(CSGDConfig(armijo=arm, compressor=comp))
    p0 = m.init(jax.random.PRNGKey(0))
    s0 = opt.init(p0)
    p_ref, s_ref, aux = opt.step(lambda p: m.loss(p, one)[0], p0, s0)

    da = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p_dist, p_ref)
    worst = max(jax.tree.leaves(da))
    assert worst < 5e-3, worst
    assert abs(float(metrics["loss"]) - float(aux.loss)) < 1e-4
    assert float(metrics["wire_bytes"]) > 0


def test_shard_local_topk_equals_global_selection(key):
    """shard_local_topk=True and =False produce the SAME step while the
    model axis is replicated (0.4.x fallback) or block-aligned (0.5+
    nested path) — parity between the two build_train_step variants."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64)

    def mkrun(flag):
        return RunConfig(
            model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
            optimizer=OptimizerConfig(kind="csgd_asss",
                                      armijo=ArmijoConfig(),
                                      compressor=comp,
                                      shard_local_topk=flag))

    params0 = m.init(jax.random.PRNGKey(0))
    one = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                        cfg.vocab_size)}
    p_loc, _, m_loc = _dist_step(m, cfg, mkrun(True), mesh, params0, one)
    p_glob, _, m_glob = _dist_step(m, cfg, mkrun(False), mesh, params0, one)
    for a, b in zip(jax.tree.leaves(p_loc), jax.tree.leaves(p_glob)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
    assert float(m_loc["wire_bytes"]) == float(m_glob["wire_bytes"])
