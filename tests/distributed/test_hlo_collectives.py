"""HLO-level regression pins for the bucketed transport (DESIGN.md §11):
a future change must not silently fall back to per-leaf collectives.

Two levels:

* the lowered exchange itself — ``stablehlo.all_gather`` count equals the
  plan's gather count (ONE flat gather for every bucket; <= 2 by
  construction) and the ``pmean`` family (``stablehlo.all_reduce``) for
  dense small leaves is exactly 1;
* the lowered 8-virtual-device TRAIN STEP — the all-gather budget stays
  <= 2 end to end (metric pmeans lower to all_reduce, so the all_gather
  count is attributable to the exchange alone).

The per-leaf reference transport is lowered side by side to prove the
counters really count (it shows one collective per leaf).

The gossip transport (DESIGN.md §12) gets the same treatment: the lowered
exchange must contain exactly ``degree`` ``stablehlo.collective_permute``
ops (one neighbor ``ppermute`` per graph edge class — ring: 2) and ZERO
all_gathers / all_reduces: dense small leaves ride the permuted payload
buffer, and a global collective sneaking back in would silently
re-centralize the serverless path.
"""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm.bucket import build_bucket_plan
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate

W_WORKERS = 8
AG = '"stablehlo.all_gather"'
AR = '"stablehlo.all_reduce"'
CP = '"stablehlo.collective_permute"'


def _tree(key):
    ks = jax.random.split(key, 5)
    return {
        "w": jax.random.normal(ks[0], (2, 2048)),
        "v": jax.random.normal(ks[1], (3000,)),
        "t": jax.random.normal(ks[2], (50,)),        # dense
        "u": jax.random.normal(ks[3], (40,)),        # dense
        "big": jax.random.normal(ks[4], (70000,)),   # 32-bit idx (topk)
    }


def _lower_exchange(tree, comp, transport):
    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    mem = jax.tree.map(jnp.zeros_like, tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        functools.partial(worker_compress_aggregate, comp=comp,
                          dp_axes=("data",), transport=transport),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(spec, spec, P(), P(), P()), axis_names={"data"},
        check_vma=False)
    return jax.jit(f).lower(tree, mem, jnp.float32(0.1)).as_text()


@pytest.mark.parametrize("method", ["block_topk", "topk"])
def test_exchange_collective_counts(key, method):
    comp = Compressor(gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=8)
    tree = _tree(key)
    leaves = jax.tree.leaves(tree)
    plan = build_bucket_plan([x.shape for x in leaves],
                             [x.ndim >= 2 for x in leaves], comp)
    n_compressed, n_dense = len(plan.compressed_ids), len(plan.dense_ids)
    assert n_compressed == 3 and n_dense == 2
    assert len(plan.buckets) <= 2

    txt = _lower_exchange(tree, comp, "bucketed")
    # ONE flat all_gather for every bucket; ONE pmean for every dense leaf
    assert txt.count(AG) == plan.n_gathers == 1, txt.count(AG)
    assert txt.count(AR) == 1, txt.count(AR)

    # the reference schedule shows the counters have teeth: one collective
    # per leaf (this is the regression the bucketed path deletes)
    ref = _lower_exchange(tree, comp, "perleaf")
    assert ref.count(AG) == n_compressed
    assert ref.count(AR) == n_dense


def _lower_downlink_exchange(tree, comp):
    from repro.comm.downlink import (DownlinkCtx, DownlinkResult,
                                     DownlinkState, init_downlink_state)

    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    leaves = jax.tree.leaves(tree)
    dls = init_downlink_state([x.shape for x in leaves],
                              [x.ndim >= 2 for x in leaves], comp,
                              comp.gamma)
    mem = jax.tree.map(jnp.zeros_like, tree)
    spec = jax.tree.map(lambda _: P(), tree)
    dl_spec = DownlinkState(memory=P(), gamma=P())

    # the server state must be a traced INPUT (same reasoning as the
    # overlap lowering below: a constant would let XLA fold the EF away)
    def worker(g, m, eta, s):
        return worker_compress_aggregate(
            g, m, eta, comp, ("data",),
            downlink_ctx=DownlinkCtx(state=s))

    f = shard_map(
        worker, mesh=mesh,
        in_specs=(spec, spec, P(), dl_spec),
        out_specs=(spec, spec, P(), P(), P(),
                   DownlinkResult(dl_spec, P(), P())),
        axis_names={"data"}, check_vma=False)
    return jax.jit(f).lower(tree, mem, jnp.float32(0.1), dls).as_text()


@pytest.mark.parametrize("method", ["block_topk", "topk"])
def test_downlink_exchange_adds_no_collective(key, method):
    """DESIGN.md §15: the compressed downlink is a physically simulated
    server — replicated recompute, ZERO additional collectives.  The
    lowered downlink exchange must show the exact same budget as the
    plain bucketed exchange: ONE flat all_gather, ONE dense pmean."""
    comp = Compressor(gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=8)
    tree = _tree(key)
    txt = _lower_downlink_exchange(tree, comp)
    assert txt.count(AG) == 1, txt.count(AG)
    assert txt.count(AR) == 1, txt.count(AR)
    assert txt.count(CP) == 0, txt.count(CP)


def _lower_gossip(tree, comp, topology):
    from repro.comm.gossip import GossipConfig, GossipCtx, GossipState
    from repro.comm.topology import build_topology

    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    ctx = GossipCtx(topology=build_topology(topology, W_WORKERS),
                    cfg=GossipConfig(topology=topology),
                    state=GossipState.init(()))
    mem = jax.tree.map(jnp.zeros_like, tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        functools.partial(worker_compress_aggregate, comp=comp,
                          dp_axes=("data",), transport="gossip",
                          transport_ctx=ctx),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(spec, spec, P(), P(), P(), P()), axis_names={"data"},
        check_vma=False)
    return jax.jit(f).lower(tree, mem, jnp.float32(0.1)).as_text(), ctx


@pytest.mark.parametrize("topology,degree", [("ring", 2), ("exp", 5)])
def test_gossip_exchange_collective_counts(key, topology, degree):
    """Gossip lowers to exactly `degree` neighbor permutes and NOTHING
    global — no all_gather, no all_reduce (dense leaves ride the permuted
    payload buffer instead of a pmean)."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    txt, ctx = _lower_gossip(_tree(key), comp, topology)
    assert ctx.topology.degree == degree
    assert txt.count(CP) == degree, txt.count(CP)
    assert txt.count(AG) == 0, txt.count(AG)
    assert txt.count(AR) == 0, txt.count(AR)


def _lower_overlap(tree, comp, n_chunks, delay, mesh_shape=(W_WORKERS,),
                   axes=("data",)):
    from repro.comm.overlap import (OverlapConfig, OverlapCtx,
                                    init_overlap_state)

    mesh = jax.make_mesh(mesh_shape, axes)
    leaves = jax.tree.leaves(tree)
    st = init_overlap_state([x.shape for x in leaves],
                            [x.ndim >= 2 for x in leaves], comp,
                            abstract=True)
    st = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), st)
    cfg = OverlapConfig(n_chunks=n_chunks, delay=delay)
    mem = jax.tree.map(jnp.zeros_like, tree)
    spec = jax.tree.map(lambda _: P(), tree)

    # the carried state must be a traced INPUT: a zero-constant closure
    # would let XLA fold the delay=1 dense pmean away
    def worker(g, m, eta, s):
        return worker_compress_aggregate(
            g, m, eta, comp, axes, transport="overlap",
            transport_ctx=OverlapCtx(cfg=cfg, state=s))

    f = shard_map(
        worker, mesh=mesh,
        in_specs=(spec, spec, P(), jax.tree.map(lambda _: P(), st)),
        out_specs=(spec, spec, P(), P(), P(),
                   jax.tree.map(lambda _: P(), st)),
        axis_names=set(axes), check_vma=False)
    return jax.jit(f).lower(tree, mem, jnp.float32(0.1), st).as_text()


@pytest.mark.parametrize("mesh_shape,axes,n_chunks", [
    ((W_WORKERS,), ("data",), 1),
    ((W_WORKERS,), ("data",), 3),
    ((W_WORKERS,), ("data",), 7),
    ((4, 2), ("pod", "data"), 1),
    ((4, 2), ("pod", "data"), 3),
])
@pytest.mark.parametrize("delay", [0, 1])
def test_overlap_exchange_collective_counts(key, mesh_shape, axes,
                                            n_chunks, delay):
    """The overlap transport lowers to EXACTLY the ring schedule's
    ``collective_permute`` count (``n_permutes``: chunk count x (W-1) per
    dp axis, ring of rings) with ZERO all_gathers for the compressed
    leaves — a flat gather sneaking back in would serialize the exchange
    — and ONE all_reduce (the dense-leaf pmean)."""
    from repro.comm.ring import n_permutes

    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    tree = _tree(key)
    leaves = jax.tree.leaves(tree)
    plan = build_bucket_plan([x.shape for x in leaves],
                             [x.ndim >= 2 for x in leaves], comp)
    txt = _lower_overlap(tree, comp, n_chunks, delay, mesh_shape, axes)
    want = n_permutes(mesh_shape, plan.total_words, n_chunks)
    assert txt.count(CP) == want, (txt.count(CP), want)
    assert txt.count(AG) == 0, txt.count(AG)
    assert txt.count(AR) == 1, txt.count(AR)


def test_exchange_all_dense_single_pmean(key):
    comp = Compressor(method="none")
    txt = _lower_exchange(_tree(key), comp, "bucketed")
    assert txt.count(AG) == 0
    assert txt.count(AR) == 1


def _lower_train_step(transport, downlink="dense"):
    from repro.configs import get_smoke_config
    from repro.configs.base import (OptimizerConfig, RunConfig,
                                    ShapeConfig)
    from repro.core import ArmijoConfig
    from repro.compat import set_mesh
    from repro.launch.train_step import (build_train_step, init_opt_state,
                                         opt_state_shardings)
    from repro.models import build_model
    from repro.sharding import param_shardings

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64)
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
        optimizer=OptimizerConfig(kind="csgd_asss", armijo=ArmijoConfig(),
                                  compressor=comp, transport=transport,
                                  downlink=downlink))
    with set_mesh(mesh):
        params = m.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32)}
        st = init_opt_state(params, run, 4)
        st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
        step = build_train_step(m, run, mesh)(params, batch)
        txt = step.lower(params, st, batch).as_text()
    leaves = jax.tree.leaves(params)
    plan = build_bucket_plan([x.shape for x in leaves],
                             [x.ndim >= 2 for x in leaves], comp)
    return txt, plan


def test_train_step_all_gather_budget():
    """End to end: the lowered train step's all_gather count equals the
    bucket gather count (<= 2), where the per-leaf schedule pays one per
    compressed leaf."""
    txt, plan = _lower_train_step("bucketed")
    assert 1 <= txt.count(AG) == plan.n_gathers <= 2, txt.count(AG)

    ref, _ = _lower_train_step("perleaf")
    assert ref.count(AG) == len(plan.compressed_ids) > 2


def test_train_step_downlink_keeps_collective_budget():
    """End to end with ``downlink="compressed"``: the all_gather budget
    stays the bucket plan's gather count (<= 2) — the server-side
    recompression must never lower to an extra collective."""
    txt, plan = _lower_train_step("bucketed", downlink="compressed")
    assert 1 <= txt.count(AG) == plan.n_gathers <= 2, txt.count(AG)


# ---------------------------------------------------------------------------
# federated cohort tier (DESIGN.md §13): vmap must not multiply collectives
# ---------------------------------------------------------------------------

def _lower_cohort(key, comp, n_clients):
    from repro.fed.clients import cohort_compress_aggregate

    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    C = n_clients // W_WORKERS
    base = _tree(key)
    tree = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), base)
    mem = jax.tree.map(jnp.zeros_like, tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        lambda g, m, part: cohort_compress_aggregate(
            g, m, jnp.float32(0.1), comp, ("data",), part),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(jax.tree.map(lambda _: P(), base), spec, P(), P()),
        axis_names={"data"}, check_vma=False)
    part = jnp.ones((n_clients,), jnp.float32)
    return jax.jit(f).lower(tree, mem, part).as_text()


@pytest.mark.parametrize("n_clients", [8, 64, 256])
def test_cohort_exchange_collective_counts(key, n_clients):
    """The vmap'd cohort exchange keeps the O(1) bucketed schedule:
    exactly ONE all_gather (every client's payload in one fixed-shape
    block) and exactly ONE all_reduce (dense leaves + the eff-bytes
    counter), INDEPENDENT of how many clients each worker simulates."""
    comp = Compressor(gamma=0.05, method="topk", min_compress_size=64,
                      value_bits=8, use_kernel=False)
    txt = _lower_cohort(key, comp, n_clients)
    assert txt.count(AG) == 1, txt.count(AG)
    assert txt.count(AR) == 1, txt.count(AR)


def _lower_fed_train_step(n_clients):
    from repro.configs import get_smoke_config
    from repro.configs.base import (FederatedConfig, OptimizerConfig,
                                    RunConfig, ShapeConfig)
    from repro.core import ArmijoConfig
    from repro.compat import set_mesh
    from repro.launch.train_step import (build_train_step, init_opt_state,
                                         opt_state_shardings)
    from repro.models import build_model
    from repro.sharding import param_shardings

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64, use_kernel=False)
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, n_clients, "train"),
        optimizer=OptimizerConfig(
            kind="csgd_asss", armijo=ArmijoConfig(), compressor=comp,
            federated=FederatedConfig(n_clients=n_clients)))
    with set_mesh(mesh):
        params = m.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        batch = {"tokens": jnp.zeros((n_clients, 1, 32), jnp.int32),
                 "participation": jnp.ones((n_clients,), jnp.float32)}
        st = init_opt_state(params, run, 4)
        st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
        step = build_train_step(m, run, mesh)(params, batch)
        txt = step.lower(params, st, batch).as_text()
    leaves = jax.tree.leaves(params)
    plan = build_bucket_plan([x.shape for x in leaves],
                             [x.ndim >= 2 for x in leaves], comp)
    return txt, plan


def test_fed_train_step_collective_budget():
    """End to end: the federated train step's all_gather count equals the
    bucket plan's gather count — the SAME budget as the plain dp step —
    and stays constant as the cohort grows 8 -> 32 clients (vmap width
    never becomes collective count)."""
    txt8, plan = _lower_fed_train_step(8)
    txt32, _ = _lower_fed_train_step(32)
    assert 1 <= txt8.count(AG) == plan.n_gathers <= 2, txt8.count(AG)
    assert txt32.count(AG) == txt8.count(AG)
    assert txt32.count(AR) == txt8.count(AR)
