"""8-virtual-device parity for the gossip transport (DESIGN.md §12).

Three contracts:

* **EF / byte parity with bucketed** — gossip runs the IDENTICAL
  selection + encode stage (repro/core/leafmath.select_and_encode), so on
  identical per-worker inputs its EF memory and wire/effective byte
  counters must be BIT-EXACT against ``transport="bucketed"`` (telemetry
  <= 8 ulp, same caveat as test_bucketed_exchange.py).  The updates
  legitimately differ: neighborhood consensus mean vs global mean.
* **mixing-matrix simulation parity** — K full steps of the gossip
  optimizer on 8 virtual workers (per-worker quadratic dynamics
  ``g_i = x_i - c_i``) must track a collective-free NumPy/float64
  simulation that applies ``Topology.mixing_matrix()`` rows to the
  decoded payloads — proving the ppermute schedule + uniform Metropolis
  weights really implement the doubly-stochastic mix, EF recursion and
  AdaGossip step the docs claim (method="topk", value_bits=32 so the
  wire is value-exact and float64 is a valid reference).
* **consensus contraction** — repeated uncompressed ``gossip_mix``
  rounds contract the consensus error monotonically (spectral gap > 0)
  and match ``Topology.mix_reference`` to ~1e-6 absolute per round
  (same difference form, but XLA may contract ``x + w * acc`` into an
  fma, which shifts near-zero outputs by many ulp); a constant tree is
  a bit-exact fixed point (every permuted difference is literally
  zero).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm.gossip import (GossipConfig, GossipCtx, GossipState,
                               gossip_mix)
from repro.comm.topology import build_topology
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate
from repro.core.telemetry import CompressionTelemetry

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 5)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),   # stacked
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),        # dense
        "u": jax.random.normal(ks[3], (n_workers, 40)),        # dense
        "big": jax.random.normal(ks[4], (n_workers, 70000)),   # 32-bit idx
    }


def _run_bucketed(gtree, mtree, comp, eta=0.1):
    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    lead = jax.tree.map(lambda _: P("data"), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)
    tel_lead = jax.tree.map(lambda _: P("data"),
                            CompressionTelemetry.init(abstract=True))

    def worker(g, m):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        upd, newm, wire, eff, tel = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, ("data",), transport="bucketed")
        return (upd, jax.tree.map(lambda x: x[None], newm), wire,
                eff[None], jax.tree.map(lambda x: x[None], tel))

    f = shard_map(worker, mesh=mesh, in_specs=(lead, lead),
                  out_specs=(rep, lead, P(), P("data"), tel_lead),
                  axis_names={"data"}, check_vma=False)
    return jax.jit(f)(gtree, mtree)


def _run_gossip(gtree, mtree, comp, topology, eta=0.1):
    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    topo = build_topology(topology, W_WORKERS)
    cfg = GossipConfig(topology=topology)
    lead = jax.tree.map(lambda _: P("data"), gtree)
    tel_lead = jax.tree.map(lambda _: P("data"),
                            CompressionTelemetry.init(abstract=True))

    def worker(g, m, v):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        ctx = GossipCtx(topology=topo, cfg=cfg,
                        state=GossipState(v=v[0], lr=jnp.float32(0.0)))
        upd, newm, wire, eff, tel, st = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, ("data",), transport="gossip",
            transport_ctx=ctx)
        return (jax.tree.map(lambda x: x[None], upd),
                jax.tree.map(lambda x: x[None], newm), wire, eff[None],
                jax.tree.map(lambda x: x[None], tel),
                jax.tree.map(lambda x: x[None], st))

    f = shard_map(worker, mesh=mesh, in_specs=(lead, lead, P("data")),
                  out_specs=(lead, lead, P(), P("data"), tel_lead,
                             P("data")),
                  axis_names={"data"}, check_vma=False)
    return jax.jit(f)(gtree, mtree, jnp.zeros((W_WORKERS,), jnp.float32))


def _assert_tree_equal(a, b, msg, maxulp=0):
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if maxulp:
            np.testing.assert_array_max_ulp(np.asarray(u), np.asarray(v),
                                            maxulp=maxulp)
        else:
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=msg)


@pytest.mark.parametrize("topology", ["ring", "exp"])
@pytest.mark.parametrize("method,value_bits", [("block_topk", 8),
                                               ("topk", 32)])
def test_gossip_ef_bytes_match_bucketed(key, topology, method, value_bits):
    """Identical selection stage => bit-identical per-worker EF memory and
    byte counters, even though the consensus updates differ."""
    comp = Compressor(gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=value_bits)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    ref = _run_bucketed(gtree, mtree, comp)
    got = _run_gossip(gtree, mtree, comp, topology)
    _assert_tree_equal(ref[1], got[1], f"{topology}/{method}: EF memory")
    _assert_tree_equal(ref[2], got[2], f"{topology}/{method}: wire")
    _assert_tree_equal(ref[3], got[3], f"{topology}/{method}: eff")
    _assert_tree_equal(ref[4], got[4], "telemetry", maxulp=8)
    # the consensus mean is NOT the global mean on these graphs — the
    # parity above is a selection/EF contract, not update equality
    upd_ref = np.asarray(jax.tree.leaves(ref[0])[0])
    upd_got = np.asarray(jax.tree.leaves(got[0])[0])[0]
    assert not np.allclose(upd_ref, upd_got)


def _np_topk_decode(acc, k):
    """float64 reference of per_layer_topk + scatter: keep the k largest
    |entries| per row, zero the rest."""
    out = np.zeros_like(acc)
    for r in range(acc.shape[0]):
        idx = np.argsort(-np.abs(acc[r]))[:k]
        out[r, idx] = acc[r, idx]
    return out


@pytest.mark.parametrize("topology", ["ring", "exp"])
def test_gossip_steps_match_mixing_matrix_simulation(key, topology):
    """K optimizer steps on the mesh == collective-free float64 simulation
    driven by Topology.mixing_matrix()."""
    L, D, DB, K, eta = 4, 256, 48, 5, 0.1
    topo = build_topology(topology, W_WORKERS)
    cfg = GossipConfig(topology=topology)
    comp = Compressor(gamma=0.05, method="topk", value_bits=32,
                      min_compress_size=64)
    k = comp.k_for(D)
    ks = jax.random.split(key, 4)
    x0 = {"w": jax.random.normal(ks[0], (W_WORKERS, L, D)),
          "b": jax.random.normal(ks[1], (W_WORKERS, DB))}
    c = {"w": jax.random.normal(ks[2], (W_WORKERS, L, D)),
         "b": jax.random.normal(ks[3], (W_WORKERS, DB))}

    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    lead = jax.tree.map(lambda _: P("data"), x0)

    def worker(x, m, v, tgt):
        x = jax.tree.map(lambda t: t[0], x)
        m = jax.tree.map(lambda t: t[0], m)
        tgt = jax.tree.map(lambda t: t[0], tgt)
        g = jax.tree.map(jnp.subtract, x, tgt)
        ctx = GossipCtx(topology=topo, cfg=cfg,
                        state=GossipState(v=v[0], lr=jnp.float32(0.0)))
        upd, newm, _, _, _, st = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, ("data",), transport="gossip",
            transport_ctx=ctx)
        newx = jax.tree.map(jnp.subtract, x, upd)

        def lift(t):
            return jax.tree.map(lambda y: y[None], t)

        return lift(newx), lift(newm), st.v[None]

    step = jax.jit(shard_map(
        worker, mesh=mesh, in_specs=(lead, lead, P("data"), lead),
        out_specs=(lead, lead, P("data")), axis_names={"data"},
        check_vma=False))

    xs, ms = x0, jax.tree.map(jnp.zeros_like, x0)
    vs = jnp.zeros((W_WORKERS,), jnp.float32)
    for _ in range(K):
        xs, ms, vs = step(xs, ms, vs, c)

    # ---- float64 reference: mixing-matrix rows over decoded payloads ---
    Wmat = topo.mixing_matrix()                      # (n, n) float64
    xw = np.asarray(x0["w"], np.float64)
    xb = np.asarray(x0["b"], np.float64)
    cw = np.asarray(c["w"], np.float64)
    cb = np.asarray(c["b"], np.float64)
    mw = np.zeros_like(xw)
    v = np.zeros(W_WORKERS)
    n_tot = L * D + DB
    for _ in range(K):
        acc_w = mw + eta * (xw - cw)                 # (W, L, D)
        dec = np.stack([_np_topk_decode(acc_w[i], k)
                        for i in range(W_WORKERS)])
        acc_b = eta * (xb - cb)                      # dense EF stays zero
        mix_w = np.einsum("ij,jld->ild", Wmat, dec)
        mix_b = Wmat @ acc_b
        e_w, e_b = mix_w - dec, mix_b - acc_b
        err = (e_w.reshape(W_WORKERS, -1) ** 2).sum(1) \
            + (e_b ** 2).sum(1)
        v = cfg.beta * v + (1.0 - cfg.beta) * err / n_tot
        lr = np.minimum(cfg.lr_max, cfg.consensus_lr / (np.sqrt(v)
                                                        + cfg.eps))
        xw = xw - (dec + lr[:, None, None] * e_w)
        xb = xb - (acc_b + lr[:, None] * e_b)
        mw = acc_w - dec

    np.testing.assert_allclose(np.asarray(xs["w"]), xw, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(xs["b"]), xb, rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ms["w"]), mw, rtol=1e-4,
                               atol=1e-5)
    assert np.all(np.asarray(ms["b"]) == 0.0)        # dense: no EF
    np.testing.assert_allclose(np.asarray(vs), v, rtol=1e-4)


def _one_mix_round(tree, topo, lr=1.0):
    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    lead = jax.tree.map(lambda _: P("data"), tree)

    def w(t):
        t = jax.tree.map(lambda x: x[0], t)
        out = gossip_mix(t, topo, "data", lr=lr)
        return jax.tree.map(lambda x: x[None], out)

    f = shard_map(w, mesh=mesh, in_specs=(lead,), out_specs=lead,
                  axis_names={"data"}, check_vma=False)
    return jax.jit(f)(tree)


def _consensus_err(tree):
    return max(float(np.max(np.abs(x - x.mean(0))))
               for x in map(np.asarray, jax.tree.leaves(tree)))


@pytest.mark.parametrize("topology", ["ring", "exp"])
def test_gossip_mix_contracts_and_matches_reference(key, topology):
    """Uncompressed consensus rounds: monotone contraction toward the
    mean, few-ulp parity with Topology.mix_reference, and a bit-exact
    constant fixed point."""
    topo = build_topology(topology, W_WORKERS)
    ks = jax.random.split(key, 2)
    cur = {"a": jax.random.normal(ks[0], (W_WORKERS, 32)),
           "b": jax.random.normal(ks[1], (W_WORKERS, 3, 7))}
    errs = [_consensus_err(cur)]
    for _ in range(6):
        # reference from the SAME round input (cumulative comparison
        # would compound the per-round fma drift)
        ref = jax.tree.map(lambda z: topo.mix_reference(np.asarray(z)),
                           cur)
        cur = _one_mix_round(cur, topo)
        for u, v in zip(jax.tree.leaves(cur), jax.tree.leaves(ref)):
            np.testing.assert_allclose(
                np.asarray(u), v, rtol=1e-6, atol=1e-6,
                err_msg=f"{topology}: mix_reference parity")
        errs.append(_consensus_err(cur))
    assert all(b < a for a, b in zip(errs, errs[1:])), errs

    const = jax.tree.map(
        lambda x: jnp.broadcast_to(x[:1], x.shape) * 1.0, cur)
    mixed = _one_mix_round(const, topo)
    _assert_tree_equal(mixed, const, f"{topology}: constant fixed point")
