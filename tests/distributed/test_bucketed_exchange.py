"""8-virtual-device parity for the bucketed transport (DESIGN.md §11):
the bucketed exchange must be BIT-EXACT against the per-leaf reference
schedule on a real multi-worker mesh — updates, per-worker EF memory,
and byte counters — including heterogeneous per-worker k_t riding the
ragged count headers, on both (8,) and (4, 2) dp meshes.

Telemetry is pinned to <= 8 ulp instead: its ratios come from f32
reductions (``sum(moments[:, 0])`` etc.) whose inputs are bit-identical
across transports, but XLA does not pin f32 reduction/fusion order across
two different programs, and a handful of independent 1-ulp reduce
differences propagate through the sqrt/divide ratios (measured: up to
4 ulp under heterogeneous k_t)
(see DESIGN.md §11).  Everything a param update or byte counter touches
is elementwise or layout-preserving, hence exactly equal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate
from repro.core.telemetry import CompressionTelemetry

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 5)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),   # stacked
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),        # dense
        "u": jax.random.normal(ks[3], (n_workers, 40)),        # dense
        "big": jax.random.normal(ks[4], (n_workers, 70000)),   # 32-bit idx
    }


def _run(gtree, mtree, gammas, comp, transport,
         mesh_shape=(W_WORKERS,), axes=("data",), eta=0.1):
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)
    tel_lead = jax.tree.map(lambda _: P(lead_axis),
                            CompressionTelemetry.init(abstract=True))
    use_gamma = gammas is not None
    if gammas is None:
        gammas = jnp.zeros((W_WORKERS,), jnp.float32)

    def worker(g, m, gam):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        upd, newm, wire, eff, tel = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes),
            gamma_t=gam[0] if use_gamma else None, transport=transport)
        return (upd, jax.tree.map(lambda x: x[None], newm), wire,
                eff[None], jax.tree.map(lambda x: x[None], tel))

    f = shard_map(worker, mesh=mesh,
                  in_specs=(lead, lead, P(lead_axis)),
                  out_specs=(rep, lead, P(), P(lead_axis), tel_lead),
                  axis_names=set(axes), check_vma=False)
    return jax.jit(f)(gtree, mtree, gammas)


def _assert_tree_equal(a, b, msg, maxulp=0):
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if maxulp:
            np.testing.assert_array_max_ulp(np.asarray(u), np.asarray(v),
                                            maxulp=maxulp)
        else:
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v),
                                          err_msg=msg)


@pytest.mark.parametrize("method,value_bits,use_kernel", [
    ("block_topk", 8, True), ("block_topk", 32, False),
    ("topk", 16, True), ("topk", 32, True),
])
def test_bucketed_equals_perleaf_8workers(key, method, value_bits,
                                          use_kernel):
    comp = Compressor(gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=value_bits,
                      use_kernel=use_kernel)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    ref = _run(gtree, mtree, None, comp, "perleaf")
    got = _run(gtree, mtree, None, comp, "bucketed")
    for name, a, b in zip(("updates", "memory", "wire", "eff",
                           "telemetry"), ref, got):
        _assert_tree_equal(a, b, f"{method}/{value_bits}: {name}",
                           maxulp=8 if name == "telemetry" else 0)


@pytest.mark.parametrize("mesh_shape,axes", [
    ((W_WORKERS,), ("data",)), ((4, 2), ("pod", "data")),
])
def test_bucketed_heterogeneous_kt_bit_exact(key, mesh_shape, axes):
    """Eight workers, eight different k_t (the ragged headers inside the
    bucket), on single- and multi-axis dp meshes: every output of the
    bucketed transport is bit-identical to the per-leaf path."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size + 1),
                                    x.shape) * 0.1, gtree)
    gammas = jnp.linspace(comp.max_gamma / 8.0, comp.max_gamma,
                          W_WORKERS).astype(jnp.float32)
    ref = _run(gtree, mtree, gammas, comp, "perleaf", mesh_shape, axes)
    got = _run(gtree, mtree, gammas, comp, "bucketed", mesh_shape, axes)
    for name, a, b in zip(("updates", "memory", "wire", "eff",
                           "telemetry"), ref, got):
        _assert_tree_equal(a, b, f"{mesh_shape}: {name}",
                           maxulp=8 if name == "telemetry" else 0)
    # the per-worker effective bytes really are heterogeneous
    eff = np.asarray(got[3]).reshape(-1)
    assert eff[0] < eff[-1]
