"""8-virtual-device contracts for the hostile-wire layer (DESIGN.md §16).

The pinned guarantees:

* **faults-off bit-exactness** — the always-on verdict/quarantine layer
  is a bit-exact no-op on a clean wire: every transport's exchange
  produces identical updates, EF memory and byte counters whether the
  guards run or are compiled out (``guards_disabled()``), on (8,) and
  (4, 2) dp meshes (gossip is single-axis by construction, so it pins
  (8,) only).  Telemetry gets the usual <= 8 ulp allowance — the two
  arms are *different programs* and XLA does not pin f32
  reduction/fusion order across programs (same caveat as
  tests/distributed/test_bucketed_exchange.py); gossip updates get the
  same allowance because its AdaGossip consensus step is fed by a
  global f32 reduction (see ``_assert_outputs_equal``).
* **the "faulty" wrapper is inert outside its burst window** — same
  bit-exactness against the unwrapped transport.
* **campaign replay across mesh shapes** — the ``(seed, step, lane,
  row)`` keying makes an in-window campaign corrupt the same rows to
  the same effect on (8,) and (4, 2) meshes.
* **train-step invariance** — end to end, the guarded default (verdict
  layer + breaker) leaves parameters bit-identical to the unguarded
  legacy step on a clean run, and the lowered HLO carries EXACTLY the
  same collective counts per transport: the guards add zero
  collectives (``guards_disabled()`` is a trace-time switch, so each
  arm is traced/lowered inside its own context).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm.faults import FaultConfig, FaultCtx, guards_disabled
from repro.core import Compressor
from repro.core.dcsgd import worker_compress_aggregate
from repro.core.telemetry import CompressionTelemetry

W_WORKERS = 8

MESHES = [((W_WORKERS,), ("data",)), ((4, 2), ("pod", "data"))]

# gossip's ppermute schedule is single-axis by construction (it raises
# on multi-axis dp meshes), so it only rides the (8,) variant
TRANSPORT_MESHES = [
    (t, ms, ax)
    for t in ("bucketed", "perleaf", "gossip", "overlap")
    for ms, ax in MESHES
    if not (t == "gossip" and len(ms) > 1)
]


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),   # stacked
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),        # dense
    }


def _mem_tree(key, gtree):
    return jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size + 1),
                                    x.shape) * 0.1, gtree)


def _run(gtree, mtree, comp, transport, mesh_shape=(W_WORKERS,),
         axes=("data",), fault_cfg=None, step=0, eta=0.1):
    """One exchange on a real mesh; stateful transports get a fresh ctx,
    ``fault_cfg`` wraps the transport in "faulty".  Returns
    (upd, new_mem, wire, eff, telemetry) — carried transport state (and
    the faulty wrapper's passthrough) is dropped inside the worker."""
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)
    tel_lead = jax.tree.map(lambda _: P(lead_axis),
                            CompressionTelemetry.init(abstract=True))
    gossip = transport == "gossip"

    def inner_ctx():
        # built OUTSIDE the traced worker: init_overlap_state's geometry
        # bookkeeping needs concrete shapes, and closure constants are
        # identical across both comparison arms anyway
        if transport == "gossip":
            from repro.comm.gossip import (GossipConfig, GossipCtx,
                                           GossipState)
            from repro.comm.topology import build_topology
            return GossipCtx(topology=build_topology("ring", W_WORKERS),
                             cfg=GossipConfig(topology="ring"),
                             state=GossipState.init(()))
        if transport == "overlap":
            from repro.comm.overlap import (OverlapConfig, OverlapCtx,
                                            init_overlap_state)
            flat = jax.tree.leaves(jax.tree.map(lambda x: x[0], gtree))
            st = init_overlap_state([x.shape for x in flat],
                                    [x.ndim >= 2 for x in flat], comp)
            return OverlapCtx(cfg=OverlapConfig(n_chunks=2), state=st)
        return None

    ctx0 = inner_ctx()

    def worker(g, m):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        t_name, t_ctx = transport, ctx0
        if fault_cfg is not None:
            t_name = "faulty"
            t_ctx = FaultCtx(cfg=fault_cfg, step=jnp.int32(step),
                             inner=transport, inner_ctx=t_ctx)
        out = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes),
            transport=t_name, transport_ctx=t_ctx)
        upd, newm, wire, eff, tel = out[:5]
        if gossip:     # per-worker consensus update: export the lead axis
            upd = jax.tree.map(lambda x: x[None], upd)
        return (upd, jax.tree.map(lambda x: x[None], newm), wire,
                eff[None], jax.tree.map(lambda x: x[None], tel))

    f = shard_map(worker, mesh=mesh, in_specs=(lead, lead),
                  out_specs=(lead if gossip else rep, lead, P(),
                             P(lead_axis), tel_lead),
                  axis_names=set(axes), check_vma=False)
    return jax.jit(f)(gtree, mtree)


def _assert_outputs_equal(ref, got, msg, upd_maxulp=0):
    """Bit-exact everywhere; telemetry <= 8 ulp (module docstring).
    ``upd_maxulp`` relaxes the UPDATES only — needed for gossip, whose
    AdaGossip consensus step ``lr_t`` is fed by a global f32 reduction
    (``err_sq``) whose order XLA does not pin across two different
    programs, so every update coordinate inherits ~1 ulp of lr_t noise;
    gossip EF memory and byte counters stay exactly equal (they never
    touch lr_t)."""
    for name, a, b in zip(("updates", "memory", "wire", "eff",
                           "telemetry"), ref, got):
        maxulp = 8 if name == "telemetry" else (
            upd_maxulp if name == "updates" else 0)
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            if maxulp:
                np.testing.assert_array_max_ulp(np.asarray(u),
                                                np.asarray(v),
                                                maxulp=maxulp)
            else:
                np.testing.assert_array_equal(
                    np.asarray(u), np.asarray(v),
                    err_msg=f"{msg}: {name}")


@pytest.mark.parametrize("transport,mesh_shape,axes", TRANSPORT_MESHES)
def test_guarded_decode_bit_exact_on_clean_wire(key, transport,
                                                mesh_shape, axes):
    """The §16 faults-off guarantee, per transport, per mesh: the decode
    verdicts + quarantine change NOTHING on an honest wire."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = _mem_tree(key, gtree)
    guarded = _run(gtree, mtree, comp, transport, mesh_shape, axes)
    with guards_disabled():
        legacy = _run(gtree, mtree, comp, transport, mesh_shape, axes)
    _assert_outputs_equal(legacy, guarded,
                          f"{transport}@{mesh_shape} guarded-vs-legacy",
                          upd_maxulp=8 if transport == "gossip" else 0)
    # guards really ran: rows_quarantined exists and counted zero
    assert float(np.sum(np.asarray(guarded[4].rows_quarantined))) == 0.0


@pytest.mark.parametrize("mesh_shape,axes", MESHES)
@pytest.mark.parametrize("transport", ["bucketed", "perleaf"])
def test_faulty_wrapper_inert_outside_window(key, transport, mesh_shape,
                                             axes):
    """A hot campaign whose burst window excludes this step reproduces
    the unwrapped transport bit-for-bit on a real multi-worker mesh."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = _mem_tree(key, gtree)
    cfg = FaultConfig(p_bitflip=1.0, p_nonfinite=1.0, start_step=50)
    got = _run(gtree, mtree, comp, transport, mesh_shape, axes,
               fault_cfg=cfg, step=0)
    ref = _run(gtree, mtree, comp, transport, mesh_shape, axes)
    _assert_outputs_equal(ref, got, f"{transport}@{mesh_shape} inert")


def test_campaign_replays_bit_exact_across_mesh_shapes(key):
    """(seed, step, lane, row) keying is mesh-shape independent: the SAME
    campaign on (8,) and (4, 2) corrupts the same rows with the same
    outcome — updates, post-quarantine EF memory, quarantine counts."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = _mem_tree(key, gtree)
    cfg = FaultConfig(seed=11, p_nonfinite=0.6, p_zero_row=0.2)
    (m1, a1), (m2, a2) = MESHES
    ref = _run(gtree, mtree, comp, "bucketed", m1, a1, fault_cfg=cfg)
    got = _run(gtree, mtree, comp, "bucketed", m2, a2, fault_cfg=cfg)
    _assert_outputs_equal(ref, got, "campaign replay (8,) vs (4,2)")
    # the campaign really fired, and the guarded decode kept it finite
    assert float(np.sum(np.asarray(ref[4].rows_quarantined))) > 0.0
    for leaf in jax.tree.leaves(ref[:2]):
        assert np.all(np.isfinite(np.asarray(leaf)))
    # and the quarantined aggregate differs from the clean one
    clean = _run(gtree, mtree, comp, "bucketed", m1, a1)
    diff = any(np.any(np.asarray(u) != np.asarray(v))
               for u, v in zip(jax.tree.leaves(ref[0]),
                               jax.tree.leaves(clean[0])))
    assert diff


# ---------------------------------------------------------------------------
# train-step level: bit-exact params + unchanged collective budget
# ---------------------------------------------------------------------------

def _train_setup(transport, max_consecutive_skips=25):
    from repro.configs import get_smoke_config
    from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
    from repro.core import ArmijoConfig
    from repro.compat import set_mesh
    from repro.launch.train_step import (build_train_step, init_opt_state,
                                         opt_state_shardings)
    from repro.models import build_model
    from repro.sharding import param_shardings

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    comp = Compressor(gamma=0.1, method="block_topk", block=256,
                      min_compress_size=64)
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
        optimizer=OptimizerConfig(
            kind="csgd_asss", armijo=ArmijoConfig(), compressor=comp,
            transport=transport,
            max_consecutive_skips=max_consecutive_skips))
    with set_mesh(mesh):
        params = m.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, param_shardings(params, mesh))
        batch = {"tokens": jnp.ones((8, 32), jnp.int32)}
        st = init_opt_state(params, run, 4,
                            stacked_mask=m.stacked_mask(params))
        st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
        step = build_train_step(m, run, mesh)(params, batch)
    return step, params, st, batch, mesh


def _run_steps(transport, guarded, n=2):
    """n real steps; the unguarded arm is the pre-§16 legacy step —
    verdict layer traced out AND breaker off — so BOTH setup and
    execution (where jit actually traces) sit inside the context."""
    import contextlib

    from repro.compat import set_mesh

    ctx = contextlib.nullcontext() if guarded else guards_disabled()
    with ctx:
        step, params, st, batch, mesh = _train_setup(
            transport, max_consecutive_skips=25 if guarded else 0)
        with set_mesh(mesh):
            for _ in range(n):
                params, st, metrics = step(params, st, batch)
    return params, metrics


@pytest.mark.parametrize("transport", ["bucketed", "gossip"])
def test_train_step_guarded_bit_exact_params(transport):
    """Two full train steps, guarded default vs legacy unguarded: the
    parameter trajectory is bit-identical and the health counters report
    a clean run."""
    p_g, m_g = _run_steps(transport, guarded=True)
    p_u, _ = _run_steps(transport, guarded=False)
    for a, b in zip(jax.tree.leaves(p_g), jax.tree.leaves(p_u)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=transport)
    assert float(m_g["steps_skipped"]) == 0.0
    assert float(m_g["consecutive_skips"]) == 0.0
    assert float(m_g["rows_quarantined"]) == 0.0
    assert float(m_g["last_good_step"]) >= 0.0      # a good step wrote


AG = '"stablehlo.all_gather"'
AR = '"stablehlo.all_reduce"'
CP = '"stablehlo.collective_permute"'


@pytest.mark.parametrize("transport", ["bucketed", "perleaf", "gossip",
                                       "overlap"])
def test_train_step_guards_add_zero_collectives(transport):
    """The HLO pin: per transport, the guarded train step lowers to
    EXACTLY the legacy step's collective counts — the verdict layer and
    the breaker are collective-free by construction."""
    import contextlib

    def lower(guarded):
        ctx = contextlib.nullcontext() if guarded else guards_disabled()
        with ctx:
            step, params, st, batch, _ = _train_setup(
                transport, max_consecutive_skips=25 if guarded else 0)
            return step.lower(params, st, batch).as_text()

    g = lower(True)
    u = lower(False)
    for op in (AG, AR, CP):
        assert g.count(op) == u.count(op), (transport, op, g.count(op),
                                            u.count(op))
