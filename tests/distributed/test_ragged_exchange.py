"""8-virtual-device tests for RAGGED packed exchange (DESIGN.md §9): dp
workers carrying *different* per-round valid counts k_t round-trip through
the fixed-budget packed all_gather and aggregate correctly — the case the
static wire format of PR 2 could not express.

Every worker's payload buffer has the same static shape (the max_gamma
budget), but each row's count header word carries that worker's own k_t;
receivers decode each gathered row by its own header, so heterogeneous
compression levels need no ragged collective.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm import wire as wire_fmt
from repro.core import Compressor, tree_wire_bytes
from repro.core.compression import block_extract_sparse
from repro.core.dcsgd import (_per_layer_topk, _scatter_layers,
                              worker_compress_aggregate)

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),  # stacked L=2
        "v": jax.random.normal(ks[1], (n_workers, 3000,)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),       # dense pmean
    }


def _worker_gammas(comp, n_workers=W_WORKERS):
    """Distinct per-worker levels spanning the budget (incl. its edges)."""
    lo = comp.max_gamma / 8.0
    return jnp.linspace(lo, comp.max_gamma, n_workers).astype(jnp.float32)


def _run_workers(gtree, mtree, gammas, comp, eta=0.1):
    """worker_compress_aggregate under a real 8-way manual shard_map with a
    per-worker gamma_t carried in as a sharded (W,) array."""
    mesh = jax.make_mesh((W_WORKERS,), ("data",))
    lead = jax.tree.map(lambda _: P("data"), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)

    def worker(g, m, gam):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        upd, newm, wire, eff, _ = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, ("data",), gamma_t=gam[0])
        return (upd, jax.tree.map(lambda x: x[None], newm), wire,
                eff[None])

    f = shard_map(worker, mesh=mesh, in_specs=(lead, lead, P("data")),
                  out_specs=(rep, lead, P(), P("data")),
                  axis_names={"data"}, check_vma=False)
    return jax.jit(f)(gtree, mtree, gammas)


def _simulate(gtree, mtree, gammas, comp, eta):
    """Collective-free reference: per worker, mask to ITS k_t -> encode
    with ITS count -> decode -> scatter; then average across workers."""
    upds, mems = {}, {}
    for name in gtree:
        g_all, m_all = gtree[name], mtree[name]
        n_workers = g_all.shape[0]
        dense_sum = None
        mem_w = []
        for w in range(n_workers):
            g, m = g_all[w], m_all[w]
            g2 = g.reshape(g.shape[0], -1) if g.ndim >= 2 \
                else g.reshape(1, -1)
            m2 = m.reshape(g2.shape)
            L, d = g2.shape
            acc = m2.astype(jnp.float32) + eta * g2.astype(jnp.float32)
            if d < comp.min_compress_size or comp.sparse_k(d) >= d:
                dense = acc
                mem_w.append(jnp.zeros_like(m))
            else:
                if comp.method == "block_topk":
                    vals, idx = block_extract_sparse(acc, comp)
                else:
                    vals, idx = _per_layer_topk(acc, comp.k_for(d))
                spec = wire_fmt.WireSpec.for_row(comp, d)
                count = comp.block_k_t(gammas[w]) if spec.local \
                    else comp.k_t_for(d, gammas[w])
                payload = wire_fmt.encode_rows(
                    vals, idx, spec,
                    counts=jnp.broadcast_to(count, (L,)))
                assert payload.nbytes == L * comp.wire_bytes(d)
                v2, i2 = wire_fmt.decode_rows(payload, spec)
                dense = _scatter_layers(v2, i2, L, d, jnp.float32)
                mem_w.append((acc - dense).reshape(m.shape))
            dense_sum = dense if dense_sum is None else dense_sum + dense
        upds[name] = (dense_sum / n_workers).reshape(g_all.shape[1:])
        mems[name] = jnp.stack(mem_w)
    return upds, mems


@pytest.mark.parametrize("method,value_bits", [
    ("block_topk", 32), ("block_topk", 8), ("topk", 32), ("topk", 16),
])
def test_heterogeneous_kt_exchange_matches_simulation(key, method,
                                                      value_bits):
    """Eight workers, eight different k_t, one fixed-size all_gather: the
    distributed mean/EF state equal the per-worker simulation."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=value_bits)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    gammas = _worker_gammas(comp)
    upd, newm, wire, eff = _run_workers(gtree, mtree, gammas, comp)
    upd_ref, mem_ref = _simulate(gtree, mtree, gammas, comp, 0.1)
    for name in gtree:
        np.testing.assert_allclose(np.asarray(upd[name]),
                                   np.asarray(upd_ref[name]), atol=1e-6,
                                   err_msg=name)
        np.testing.assert_allclose(np.asarray(newm[name]),
                                   np.asarray(mem_ref[name]), atol=1e-6,
                                   err_msg=name)
    # the gathered buffer is still the full static budget for everyone ...
    squeezed = jax.tree.map(lambda x: x[0], gtree)
    assert int(wire) == tree_wire_bytes(squeezed, comp)
    # ... but effective bytes are per-worker and strictly increasing with
    # gamma_t (dense small leaves contribute a constant floor)
    eff = np.asarray(eff)
    assert eff.shape == (W_WORKERS,)
    assert np.all(np.diff(eff) >= 0) and eff[0] < eff[-1]
    assert eff[-1] <= float(wire)


def test_heterogeneous_kt_ef_identity(key):
    """Per worker at its own k_t: decode(own payload) + m' == m + eta*g,
    reconstructed from the distributed outputs alone."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(lambda x: jnp.zeros_like(x), gtree)
    gammas = _worker_gammas(comp)
    eta = 0.1
    upd, newm, _, _ = _run_workers(gtree, mtree, gammas, comp, eta=eta)
    for name in gtree:
        acc = eta * np.asarray(gtree[name], np.float32)   # m == 0
        own = acc - np.asarray(newm[name], np.float32)    # EF identity
        np.testing.assert_allclose(own.mean(axis=0), np.asarray(upd[name]),
                                   atol=1e-6, err_msg=name)
