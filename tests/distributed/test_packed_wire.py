"""8-virtual-device tests for the bit-packed wire exchange (DESIGN.md §8).

The acceptance contract: ``Compressor.wire_bytes`` equals the LITERAL byte
length of the uint32 payload that ``worker_compress_aggregate`` all-gathers
over the dp mesh axes, and the distributed mean/EF state equal a
per-worker single-device simulation of the same encode->gather->decode
pipeline, for every supported ``value_bits``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm import wire as wire_fmt
from repro.core import Compressor, tree_wire_bytes
from repro.core.compression import block_extract_sparse
from repro.core.dcsgd import (_per_layer_topk, _scatter_layers,
                              worker_compress_aggregate)

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    """Per-worker distinct gradients: leaves carry a leading worker axis."""
    ks = jax.random.split(key, 4)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),  # stacked L=2
        "v": jax.random.normal(ks[1], (n_workers, 3000)),
        # below the compression cutoff: ships dense via pmean
        "t": jax.random.normal(ks[2], (n_workers, 50)),
    }


def _run_workers(gtree, mtree, comp, eta=0.1, mesh_shape=(W_WORKERS,),
                 axes=("data",)):
    """worker_compress_aggregate under a real 8-way manual shard_map."""
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)
    rep = jax.tree.map(lambda _: P(), gtree)

    def worker(g, m):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        upd, newm, wire, eff, _ = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes))
        return upd, jax.tree.map(lambda x: x[None], newm), wire, eff

    f = shard_map(worker, mesh=mesh, in_specs=(lead, lead),
                  out_specs=(rep, lead, P(), P()), axis_names=set(axes),
                  check_vma=False)
    return jax.jit(f)(gtree, mtree)


def _simulate(gtree, mtree, comp, eta):
    """Single-device reference: per worker, compress -> encode -> decode ->
    scatter; then average.  Uses the same library codec, NO collectives."""
    upds, mems = {}, {}
    for name in gtree:
        g_all, m_all = gtree[name], mtree[name]
        n_workers = g_all.shape[0]
        dense_sum = None
        mem_w = []
        for w in range(n_workers):
            g, m = g_all[w], m_all[w]
            g2 = g.reshape(g.shape[0], -1) if g.ndim >= 2 else g.reshape(1, -1)
            m2 = m.reshape(g2.shape)
            L, d = g2.shape
            acc = m2.astype(jnp.float32) + eta * g2.astype(jnp.float32)
            if d < comp.min_compress_size or comp.sparse_k(d) >= d:
                dense = acc
                mem_w.append(jnp.zeros_like(m))
            else:
                if comp.method == "block_topk":
                    vals, idx = block_extract_sparse(acc, comp)
                else:
                    vals, idx = _per_layer_topk(acc, comp.k_for(d))
                spec = wire_fmt.WireSpec.for_row(comp, d)
                payload = wire_fmt.encode_rows(vals, idx, spec)
                # the acceptance criterion, on the actual buffer:
                assert payload.nbytes == L * comp.wire_bytes(d)
                assert payload.nbytes == L * spec.row_bytes
                v2, i2 = wire_fmt.decode_rows(payload, spec)
                dense = _scatter_layers(v2, i2, L, d, jnp.float32)
                mem_w.append((acc - dense).reshape(m.shape))
            dense_sum = dense if dense_sum is None else dense_sum + dense
        upds[name] = (dense_sum / n_workers).reshape(g_all.shape[1:])
        mems[name] = jnp.stack(mem_w)
    return upds, mems


@pytest.mark.parametrize("method,value_bits", [
    ("block_topk", 32), ("block_topk", 8), ("block_topk", 4),
    ("topk", 32), ("topk", 16),
])
def test_packed_exchange_matches_simulation(key, method, value_bits):
    comp = Compressor(gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=value_bits)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    upd, newm, wire, _ = _run_workers(gtree, mtree, comp)
    upd_ref, mem_ref = _simulate(gtree, mtree, comp, 0.1)

    squeezed = jax.tree.map(lambda x: x[0], gtree)
    assert int(wire) == tree_wire_bytes(squeezed, comp)

    for name in gtree:
        np.testing.assert_allclose(np.asarray(upd[name]),
                                   np.asarray(upd_ref[name]), atol=1e-6,
                                   err_msg=name)
        np.testing.assert_allclose(np.asarray(newm[name]),
                                   np.asarray(mem_ref[name]), atol=1e-6,
                                   err_msg=name)


def test_ef_identity_through_packed_exchange(key):
    """Per worker: decode(own payload) + m' == m + eta*g, reconstructed from
    the distributed outputs alone (update = mean of own contributions)."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(lambda x: jnp.zeros_like(x), gtree)
    eta = 0.1
    upd, newm, _, _ = _run_workers(gtree, mtree, comp, eta=eta)
    for name in gtree:
        acc = eta * np.asarray(gtree[name], np.float32)   # m == 0
        own = acc - np.asarray(newm[name], np.float32)    # EF identity
        np.testing.assert_allclose(own.mean(axis=0), np.asarray(upd[name]),
                                   atol=1e-6, err_msg=name)


def test_packed_exchange_two_axis_mesh(key):
    """('pod','data') dp axes: the gathered payload reshapes to one worker
    axis; accounting and parity hold on the 4x2 mesh."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(lambda x: jnp.zeros_like(x), gtree)
    upd, newm, wire, _ = _run_workers(gtree, mtree, comp, mesh_shape=(4, 2),
                                   axes=("pod", "data"))
    upd_ref, mem_ref = _simulate(gtree, mtree, comp, 0.1)
    squeezed = jax.tree.map(lambda x: x[0], gtree)
    assert int(wire) == tree_wire_bytes(squeezed, comp)
    for name in gtree:
        np.testing.assert_allclose(np.asarray(upd[name]),
                                   np.asarray(upd_ref[name]), atol=1e-6,
                                   err_msg=name)
        np.testing.assert_allclose(np.asarray(newm[name]),
                                   np.asarray(mem_ref[name]), atol=1e-6,
                                   err_msg=name)


def test_gathered_buffer_is_the_accounted_bytes(key):
    """The all_gather operand for a compressed leaf is a uint32 payload of
    exactly wire_bytes bytes — inspected in the jaxpr of the worker fn."""
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    d = 3000
    g = jax.random.normal(key, (d,))
    m = jnp.zeros((d,))
    mesh = jax.make_mesh((W_WORKERS,), ("data",))

    def worker(g, m):
        return worker_compress_aggregate(g, m, jnp.float32(0.1), comp,
                                         ("data",))

    f = shard_map(worker, mesh=mesh, in_specs=(P(), P()),
                  out_specs=(P(), P(), P(), P(), P()), axis_names={"data"},
                  check_vma=False)
    jaxpr = jax.make_jaxpr(f)(g, m)
    # the all_gather sits inside the shard_map sub-jaxpr, so check the
    # whole jaxpr text for a uint32 operand of the expected row width
    spec = wire_fmt.WireSpec.for_row(comp, d)
    txt = str(jaxpr)
    assert f"u32[1,{spec.row_words}]" in txt or \
        f"u32[{spec.row_words}]" in txt, txt[:2000]
    assert spec.row_bytes == comp.wire_bytes(d)
