"""8-virtual-device tests for compression telemetry (DESIGN.md §10): each
dp worker's :class:`CompressionTelemetry` is a function of ITS OWN
(gradient, EF memory, k_t) only — no collective — so the distributed
values must equal a collective-free per-worker simulation even when the
eight workers carry heterogeneous per-round compression levels, and the
pmean'd aggregate must not care how the workers are laid out on the mesh
or permuted across it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.comm import wire as wire_fmt
from repro.core import Compressor
from repro.core.compression import block_extract_sparse
from repro.core.dcsgd import (_per_layer_topk, _scatter_layers,
                              worker_compress_aggregate)

W_WORKERS = 8


def _worker_tree(key, n_workers=W_WORKERS):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 2, 2048)),  # stacked L=2
        "v": jax.random.normal(ks[1], (n_workers, 3000,)),
        "t": jax.random.normal(ks[2], (n_workers, 50)),       # dense pmean
    }


def _worker_gammas(comp, n_workers=W_WORKERS):
    lo = comp.max_gamma / 8.0
    return jnp.linspace(lo, comp.max_gamma, n_workers).astype(jnp.float32)


def _run_workers(gtree, mtree, gammas, comp, eta=0.1,
                 mesh_shape=(W_WORKERS,), axes=("data",)):
    """Per-worker telemetry (leading worker axis) + the pmean aggregate,
    under a real 8-way manual shard_map with per-worker gamma_t."""
    mesh = jax.make_mesh(mesh_shape, axes)
    lead_axis = axes[0] if len(axes) == 1 else tuple(axes)
    lead = jax.tree.map(lambda _: P(lead_axis), gtree)

    def worker(g, m, gam):
        g = jax.tree.map(lambda x: x[0], g)
        m = jax.tree.map(lambda x: x[0], m)
        *_, tel = worker_compress_aggregate(
            g, m, jnp.float32(eta), comp, tuple(axes), gamma_t=gam[0])
        agg = tel.pmean(tuple(axes))
        return jax.tree.map(lambda x: x[None], tel), agg

    f = shard_map(worker, mesh=mesh,
                  in_specs=(lead, lead, P(lead_axis)),
                  out_specs=(P(lead_axis), P()), axis_names=set(axes),
                  check_vma=False)
    per_worker, agg = jax.jit(f)(gtree, mtree, gammas)
    return (jax.tree.map(np.asarray, per_worker),
            jax.tree.map(np.asarray, agg))


def _simulate_telemetry(gtree, mtree, gammas, comp, eta):
    """Collective-free float64 reference: per worker, redo the leaf loop
    (encode at its OWN k_t -> decode -> residual) and form the four
    ratios from scratch — independent of core/telemetry.py's fused-sum
    bookkeeping."""
    n_workers = next(iter(gtree.values())).shape[0]
    out = {"ef_backlog": [], "cosine": [], "decode_error": [],
           "eff_gamma": []}
    for w in range(n_workers):
        g_sq = acc_sq = resid_sq = own_sq = dot = 0.0
        for name in gtree:
            g = np.asarray(gtree[name][w], np.float64)
            m = np.asarray(mtree[name][w], np.float64)
            g2 = g.reshape(g.shape[0], -1) if g.ndim >= 2 \
                else g.reshape(1, -1)
            m2 = m.reshape(g2.shape)
            L, d = g2.shape
            acc = m2 + eta * g2
            g_sq += float(np.sum(g2 * g2))
            acc_sq += float(np.sum(acc * acc))
            if d < comp.min_compress_size or comp.sparse_k(d) >= d:
                own = acc                       # ships dense: decode == acc
            else:
                accf = jnp.asarray(acc, jnp.float32)
                if comp.method == "block_topk":
                    vals, idx = block_extract_sparse(accf, comp)
                else:
                    vals, idx = _per_layer_topk(accf, comp.k_for(d))
                spec = wire_fmt.WireSpec.for_row(comp, d)
                count = comp.block_k_t(gammas[w]) if spec.local \
                    else comp.k_t_for(d, gammas[w])
                payload = wire_fmt.encode_rows(
                    vals, idx, spec, counts=jnp.broadcast_to(count, (L,)))
                v2, i2 = wire_fmt.decode_rows(payload, spec)
                own = np.asarray(
                    _scatter_layers(v2, i2, L, d, jnp.float32), np.float64)
            resid = acc - own
            resid_sq += float(np.sum(resid * resid))
            own_sq += float(np.sum(own * own))
            dot += float(np.sum(own * g2))
        out["ef_backlog"].append(np.sqrt(resid_sq / g_sq))
        out["cosine"].append(dot / np.sqrt(own_sq * g_sq))
        out["decode_error"].append(np.sqrt(resid_sq / acc_sq))
        out["eff_gamma"].append(1.0 - resid_sq / acc_sq)
    return {k: np.asarray(v) for k, v in out.items()}


@pytest.mark.parametrize("method,value_bits", [
    ("block_topk", 32), ("block_topk", 8), ("topk", 32),
])
def test_per_worker_telemetry_matches_simulation(key, method, value_bits):
    """Eight workers, eight different k_t: every worker's telemetry equals
    the collective-free reference computed from its own leaves alone."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method=method, block=512,
                      min_compress_size=64, value_bits=value_bits)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(
        lambda x: jax.random.normal(jax.random.fold_in(key, x.size),
                                    x.shape) * 0.1, gtree)
    gammas = _worker_gammas(comp)
    tel, agg = _run_workers(gtree, mtree, gammas, comp)
    ref = _simulate_telemetry(gtree, mtree, gammas, comp, 0.1)
    for field in ref:
        got = np.asarray(getattr(tel, field))
        assert got.shape == (W_WORKERS,)
        np.testing.assert_allclose(got, ref[field], rtol=1e-4, atol=1e-6,
                                   err_msg=field)
        # the aggregate is the plain worker mean
        np.testing.assert_allclose(np.asarray(getattr(agg, field)),
                                   ref[field].mean(), rtol=1e-4,
                                   atol=1e-6, err_msg=field)
    # heterogeneous k_t leave a visible footprint: the lowest-gamma worker
    # carries strictly more backlog than the full-budget one
    assert tel.ef_backlog[0] > tel.ef_backlog[-1]


def test_aggregate_permutation_invariant_across_meshes(key):
    """The psum'd aggregate must not depend on (a) how the 8 workers fold
    onto the dp mesh axes — (8,) vs (4, 2) — or (b) the order the workers
    are laid out in; per-worker telemetry must permute along."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=512, min_compress_size=64, value_bits=8)
    gtree = _worker_tree(key)
    mtree = jax.tree.map(lambda x: jnp.zeros_like(x), gtree)
    gammas = _worker_gammas(comp)

    tel_1d, agg_1d = _run_workers(gtree, mtree, gammas, comp)
    tel_2d, agg_2d = _run_workers(gtree, mtree, gammas, comp,
                                  mesh_shape=(4, 2), axes=("pod", "data"))
    # same per-worker values on both mesh layouts...
    for a, b in zip(jax.tree.leaves(tel_1d), jax.tree.leaves(tel_2d)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=0)
    # ... and mesh-layout-invariant aggregates (reduction order may differ)
    for a, b in zip(jax.tree.leaves(agg_1d), jax.tree.leaves(agg_2d)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    perm = np.asarray([3, 0, 7, 5, 1, 6, 2, 4])
    ptree = jax.tree.map(lambda x: x[perm], gtree)
    pmem = jax.tree.map(lambda x: x[perm], mtree)
    tel_p, agg_p = _run_workers(ptree, pmem, gammas[jnp.asarray(perm)], comp)
    for a, b in zip(jax.tree.leaves(tel_p), jax.tree.leaves(tel_1d)):
        np.testing.assert_allclose(a, b[perm], rtol=1e-6, atol=0)
    for a, b in zip(jax.tree.leaves(agg_p), jax.tree.leaves(agg_1d)):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
