"""tests/distributed — the repo's true multi-device tier.

Every test in this directory runs IN-PROCESS against 8 forced host devices
(no per-test subprocess round-trips like tests/test_distributed.py): the
process must be started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — use
``python tests/distributed/harness.py`` (which relaunches pytest with the
right environment and deterministic seeding) or the ``multidevice`` CI job.

Collected under fewer devices (the plain tier-1 run), everything here is
skipped so single-device runs stay fast.
"""
import os

import jax
import numpy as np
import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))


def pytest_collection_modifyitems(config, items):
    # NB: this hook sees the WHOLE session's items, not just this
    # directory's — scope by path or the main suite gets skipped too.
    n = jax.device_count()
    skip = pytest.mark.skip(
        reason=f"needs 8 virtual devices, have {n} "
               "(run tests/distributed/harness.py)")
    for item in items:
        if not str(item.fspath).startswith(_HERE):
            continue
        item.add_marker(pytest.mark.multidevice)
        if n < 8:
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _deterministic_seed():
    # harness.py pins PYTHONHASHSEED; this pins numpy's global stream so
    # any test-local rng use is reproducible across the 8-device runs
    np.random.seed(0)
    yield


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
