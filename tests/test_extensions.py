"""Beyond-paper extensions (paper §V future work + serving optimizations):
momentum, wire value quantization, int8 KV cache, local iterations."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (ArmijoConfig, Compressor, CSGDConfig, csgd_asss,
                        topk_select)
from repro.data.synthetic import interpolated_regression
from repro.models import build_model


def _problem(d=128, n=256, seed=0):
    A, b, _ = interpolated_regression(n, d, seed=seed)

    def bl(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)
    return bl


def _run(opt, bl, d=128, steps=300):
    w = jnp.zeros(d)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(0)
    for t in range(steps):
        w, st, aux = step(w, st, jnp.asarray(rng.integers(0, 256, 32)))
    return float(aux.loss)


def test_momentum_csgd_converges():
    """Heavy-ball + EF-compression (paper §V) converges when the scale is
    damped by ~(1-beta) — the velocity amplifies the effective step by
    1/(1-beta), so a=3*sigma*(1-beta)=0.03 is the momentum-adjusted analog
    of the paper's a=3*sigma (verified: a=0.3 un-damped diverges)."""
    bl = _problem()
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.03),
                     compressor=Compressor(gamma=0.05, min_compress_size=1),
                     momentum=0.9)
    loss = _run(csgd_asss(cfg), bl)
    assert np.isfinite(loss) and loss < 0.5, loss


def test_momentum_beats_plain_at_matched_scale():
    """At the damped scale, momentum reaches a lower loss than plain CSGD
    with the same tiny scale (acceleration), on this problem."""
    bl = _problem()
    base = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.03),
                      compressor=Compressor(gamma=0.05, min_compress_size=1))
    l_plain = _run(csgd_asss(base), bl)
    l_mom = _run(csgd_asss(base.replace(momentum=0.9)), bl)
    assert l_mom < l_plain, (l_mom, l_plain)


def test_value_quantization_converges():
    """8-bit wire values with EF error recycling: converges."""
    bl = _problem()
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=Compressor(gamma=0.05, min_compress_size=1,
                                           value_bits=8))
    loss = _run(csgd_asss(cfg), bl, steps=400)
    assert loss < 0.5, loss


def test_value_quantization_identity(key):
    """sent + residual == input, exactly, even with quantized values."""
    comp = Compressor(gamma=0.05, value_bits=8, min_compress_size=1)
    x = jax.random.normal(key, (4096,))
    sent, resid = comp.compress_dense(x)
    np.testing.assert_allclose(np.asarray(sent + resid), np.asarray(x),
                               atol=1e-6)
    # quantization bounded by top-value scale / 127
    s = topk_select(x, comp.k_for(4096))
    bound = float(jnp.max(jnp.abs(s.values))) / 127.0
    nz = np.nonzero(np.asarray(sent))[0]
    err = np.abs(np.asarray(sent)[nz] - np.asarray(x)[nz])
    assert np.all(err <= bound * 0.51 + 1e-7)


def test_wire_bytes_reflect_value_bits():
    comp32 = Compressor(gamma=0.01)
    comp8 = Compressor(gamma=0.01, value_bits=8)
    assert comp8.value_bytes == 1 and comp32.value_bytes == 4


@pytest.mark.parametrize("arch", ["yi-34b", "zamba2-7b",
                                  "granite-moe-1b-a400m"])
def test_int8_kv_cache_decode_close(arch, key):
    """int8 KV cache: decode logits within quantization tolerance of bf16
    cache; cache arrays actually int8."""
    B, S = 2, 32
    cfg = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    params = m.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 7), (B, S + 1), 0,
                              cfg.vocab_size)
    _, c1 = m.prefill(params, {"tokens": toks[:, :S]}, capacity=S + 2)
    lg1, _ = m.decode_step(params, toks[:, S:S + 1], c1, jnp.int32(S))
    _, c8 = m8.prefill(params, {"tokens": toks[:, :S]}, capacity=S + 2)
    assert c8.kv.k.dtype == jnp.int8
    assert c8.kv.k_scale.shape[-1] == 1
    lg8, _ = m8.decode_step(params, toks[:, S:S + 1], c8, jnp.int32(S))
    err = float(jnp.max(jnp.abs(lg1[..., :cfg.vocab_size]
                                - lg8[..., :cfg.vocab_size])))
    assert err < 0.5, err
    # same argmax (greedy decode unchanged at smoke scale)
    assert jnp.array_equal(jnp.argmax(lg1, -1), jnp.argmax(lg8, -1))


def test_local_steps_microbatch_mismatch_rejected_at_build_time():
    """local_steps consumes exactly one microbatch per local Armijo step;
    a mismatched microbatch count must fail in build_train_step with a
    clear message, not as an opaque assert inside the traced worker."""
    import pytest

    from repro.configs import get_smoke_config
    from repro.configs.base import OptimizerConfig, RunConfig, ShapeConfig
    from repro.core import ArmijoConfig, Compressor
    from repro.launch.train_step import build_train_step
    from repro.models import build_model

    cfg = get_smoke_config("qwen1.5-4b")
    m = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def mkrun(local_steps, microbatches):
        return RunConfig(
            model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
            optimizer=OptimizerConfig(
                kind="csgd_asss", armijo=ArmijoConfig(),
                compressor=Compressor(gamma=0.1, min_compress_size=64),
                local_steps=local_steps),
            microbatches=microbatches)

    with pytest.raises(ValueError, match="microbatches == local_steps"):
        build_train_step(m, mkrun(2, 4), mesh)
    with pytest.raises(ValueError, match="local_steps=3"):
        build_train_step(m, mkrun(3, 1), mesh)
    build_train_step(m, mkrun(2, 2), mesh)       # matched: builds fine


def test_local_steps_distributed():
    """Qsparse-local-style DCSGD-ASSS trains on an 8-device mesh."""
    import os
    import subprocess
    import sys
    import textwrap
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig, OptimizerConfig, ShapeConfig
        from repro.core import Compressor, ArmijoConfig
        from repro.models import build_model
        from repro.launch.train_step import build_train_step, init_opt_state, opt_state_shardings
        from repro.compat import set_mesh
        from repro.sharding import param_shardings
        from repro.data.synthetic import TokenPipeline
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("qwen1.5-4b")
        m = build_model(cfg)
        run = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
            optimizer=OptimizerConfig(kind="csgd_asss", armijo=ArmijoConfig(),
                compressor=Compressor(gamma=0.1, min_compress_size=64),
                local_steps=2),
            microbatches=2)
        pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
        with set_mesh(mesh):
            params = m.init(jax.random.PRNGKey(0))
            params = jax.device_put(params, param_shardings(params, mesh))
            st = init_opt_state(params, run, 4)
            st = jax.device_put(st, opt_state_shardings(st, params, mesh, run))
            step = None
            first = None
            for i in range(15):
                b = jax.device_put(pipe.batch(i), jax.tree.map(
                    lambda _: NamedSharding(mesh, P("data")), pipe.batch(i)))
                if step is None:
                    step = build_train_step(m, run, mesh)(params, b)
                params, st, metrics = step(params, st, b)
                if first is None:
                    first = float(metrics["loss"])
        last = float(metrics["loss"])
        print("LOCAL_STEPS", first, "->", last)
        assert last < first - 0.2, (first, last)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "LOCAL_STEPS" in r.stdout
