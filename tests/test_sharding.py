"""Partition-rule unit tests: param pspecs, 2D widening, cache pspecs."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.sharding import cache_pspecs, param_pspecs


def _find(specs, *path):
    node = specs
    for p in path:
        node = node[p]
    return node


def test_dense_param_rules(key):
    cfg = get_smoke_config("yi-34b")
    params = build_model(cfg).init(key)
    specs = param_pspecs(params)
    # column-parallel: qkv/mlp-in shard the output dim
    assert _find(specs, "blocks", "attn", "wq", "w") == P(None, None, "model")
    assert _find(specs, "blocks", "mlp", "wg") == P(None, None, "model")
    # row-parallel: output projections shard the input dim
    assert _find(specs, "blocks", "attn", "wo", "w") == P(None, "model", None)
    assert _find(specs, "blocks", "mlp", "wo") == P(None, "model", None)
    # vocab-parallel head, d_model-sharded embedding, replicated norms
    assert _find(specs, "lm_head", "w") == P(None, "model")
    assert _find(specs, "embed", "w") == P(None, "model")
    assert _find(specs, "final_norm", "w") == P(None)


def test_moe_param_rules(key):
    cfg = get_smoke_config("granite-moe-1b-a400m")
    params = build_model(cfg).init(key)
    specs = param_pspecs(params)
    # experts sharded on E (dim -3 of the stacked (L, E, D, F) leaf)
    assert _find(specs, "blocks", "moe", "wg") == P(None, "model", None, None)
    assert _find(specs, "blocks", "moe", "router", "w") == P(None, None, None)


def test_qkv_bias_sharded(key):
    cfg = get_smoke_config("qwen1.5-4b")
    params = build_model(cfg).init(key)
    specs = param_pspecs(params)
    assert _find(specs, "blocks", "attn", "wq", "b") == P(None, "model")


def test_two_d_widening(key):
    cfg = get_smoke_config("yi-34b")
    params = build_model(cfg).init(key)
    specs = param_pspecs(params, two_d=True)
    # big 2D+ leaves gain a data-axis dim; small leaves unchanged
    emb = _find(specs, "embed", "w")
    assert "data" in jax.tree.leaves(emb) or emb == P(None, "model") \
        or emb == P("data", "model")
    assert _find(specs, "final_norm", "w") == P(None)


def test_cache_pspecs_kv_and_ssm(key):
    cfg = get_smoke_config("zamba2-7b")
    model = build_model(cfg)
    cache = model.init_cache(4, 32)
    specs = cache_pspecs(cache, ("data",), ("model",))
    kv_spec = specs.kv.k
    # (G, B, S, H, hd): B over data, S over model
    assert kv_spec[-4] == "data" and kv_spec[-3] == "model"
    ssm_spec = specs.ssm.ssm
    assert ssm_spec[-4] == "data" and ssm_spec[-3] == "model"
    conv_spec = specs.ssm.conv
    assert conv_spec[-3] == "data" and conv_spec[-1] == "model"


def test_cache_pspecs_long_context():
    cfg = get_smoke_config("rwkv6-1.6b")
    model = build_model(cfg)
    cache = model.init_cache(1, 64)
    specs = cache_pspecs(cache, (), ("data", "model"))
    # batch=1: no dp sharding anywhere
    for leaf in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in [e for e in jax.tree.leaves(leaf) if e]
