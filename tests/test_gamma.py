"""Per-round gamma controller (core/gamma.py) + the adaptive-compression
golden convergence pairing (DESIGN.md §9).

The golden pairing runs the SAME seeded quadratic under (a) the paper's
fixed gamma = max_gamma and (b) the armijo-coupled adaptive schedule inside
the same budget, and asserts the adaptive run reaches the fixed run's loss
while logging strictly fewer cumulative ``effective_wire_bytes``.  Loss
comparison: within 5% plus an absolute allowance at the trajectory-noise
floor — near interpolation the per-run floor of this stochastic quadratic
jitters by tens of percent run-to-run, so the relative bound alone would be
a coin flip; the absolute term is calibrated to that floor (~2e-4) and
still fails hard if the controller or the ragged wire break convergence
(those failures are orders of magnitude, not percent).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArmijoConfig, CompressionTelemetry, Compressor,
                        CSGDConfig, GammaControllerConfig, SearchTelemetry,
                        csgd_asss, gamma_init, gamma_update)
from repro.data.synthetic import interpolated_regression

# ---------------------------------------------------------------------------
# controller unit tests
# ---------------------------------------------------------------------------


def test_config_validates_schedule():
    with pytest.raises(ValueError):
        GammaControllerConfig(schedule="bogus")


def test_resolve_defaults_from_compressor():
    comp = Compressor(gamma=0.02, max_gamma=0.08)
    g0, gmin, gmax = GammaControllerConfig().resolve(comp)
    assert g0 == 0.02
    assert gmax == 0.08                  # budget = geometry gamma
    assert gmin == pytest.approx(0.02 / 8)
    # explicit gamma_max never exceeds the wire budget
    _, _, gmax2 = GammaControllerConfig(gamma_max=0.5).resolve(comp)
    assert gmax2 == 0.08
    # non-adaptive compressor: budget is plain gamma
    assert GammaControllerConfig().resolve(Compressor(gamma=0.05))[2] == 0.05


def test_resolve_rejects_inverted_band():
    """An explicit gamma_min above the resolved gamma_max used to pass
    resolve() silently and pin every jnp.clip to gamma_max — the user's
    floor was unsatisfiable.  resolve() must raise instead."""
    comp = Compressor(gamma=0.02, max_gamma=0.08)
    with pytest.raises(ValueError, match="gamma_min"):
        GammaControllerConfig(gamma_min=0.5).resolve(comp)
    # the same inversion via an explicit gamma_max under the floor
    with pytest.raises(ValueError, match="gamma_min"):
        GammaControllerConfig(gamma_min=0.06, gamma_max=0.04).resolve(comp)
    # gamma_init goes through resolve, so the train-step init fails too
    with pytest.raises(ValueError, match="gamma_min"):
        gamma_init(GammaControllerConfig(gamma_min=0.5), comp)
    # boundary: gamma_min == gamma_max is a valid (degenerate) band
    g0, gmin, gmax = GammaControllerConfig(
        gamma_min=0.08, gamma_max=0.08).resolve(comp)
    assert g0 == gmin == gmax == 0.08


def test_fixed_schedule_is_constant():
    comp = Compressor(gamma=0.03, max_gamma=0.06)
    cfg = GammaControllerConfig(schedule="fixed")
    g = gamma_init(cfg, comp)
    for step in range(5):
        g = gamma_update(cfg, comp, g, jnp.int32(step))
    assert float(g) == pytest.approx(0.03)


def test_linear_schedule_ramps_to_budget():
    comp = Compressor(gamma=0.02, max_gamma=0.08)
    cfg = GammaControllerConfig(schedule="linear", ramp_steps=100)
    g0 = float(gamma_update(cfg, comp, jnp.float32(0.02), jnp.int32(0)))
    g50 = float(gamma_update(cfg, comp, jnp.float32(0.02), jnp.int32(50)))
    g100 = float(gamma_update(cfg, comp, jnp.float32(0.02), jnp.int32(100)))
    g999 = float(gamma_update(cfg, comp, jnp.float32(0.02), jnp.int32(999)))
    assert g0 == pytest.approx(0.02)
    assert g50 == pytest.approx(0.05)
    assert g100 == pytest.approx(0.08) == g999


def test_armijo_coupled_grow_shrink_and_clip():
    comp = Compressor(gamma=0.04, max_gamma=0.08)
    cfg = GammaControllerConfig(schedule="armijo-coupled", gamma_min=0.01,
                                grow=2.0, shrink=0.5, evals_hi=3.0,
                                evals_lo=2.0, alpha_collapse=0.5)

    def upd(g, alpha, alpha_prev, nev, ema):
        return float(gamma_update(
            cfg, comp, jnp.float32(g), jnp.int32(7),
            search=SearchTelemetry(
                alpha=jnp.float32(alpha), alpha_prev=jnp.float32(alpha_prev),
                n_evals=jnp.float32(nev), n_evals_ema=jnp.float32(ema))))

    # struggling search (eval EMA above threshold) -> grow
    assert upd(0.02, 0.1, 0.1, 4, 4.0) == pytest.approx(0.04)
    # alpha collapse vs the previous round -> grow
    assert upd(0.02, 0.04, 0.1, 2, 1.0) == pytest.approx(0.04)
    # instant accept with low EMA -> shrink
    assert upd(0.02, 0.1, 0.1, 1, 1.0) == pytest.approx(0.01)
    # neutral telemetry -> hold
    assert upd(0.02, 0.1, 0.1, 2, 2.5) == pytest.approx(0.02)
    # clipping into [gamma_min, budget]
    assert upd(0.06, 0.1, 0.1, 5, 5.0) == pytest.approx(0.08)
    assert upd(0.011, 0.1, 0.1, 1, 1.0) == pytest.approx(0.01)


def test_armijo_coupled_requires_telemetry():
    comp = Compressor(gamma=0.04, max_gamma=0.08)
    cfg = GammaControllerConfig(schedule="armijo-coupled")
    with pytest.raises(ValueError):
        gamma_update(cfg, comp, jnp.float32(0.04), jnp.int32(0))


def test_coupled_schedule_rejected_without_armijo():
    with pytest.raises(ValueError):
        CSGDConfig(armijo=None,
                   gamma_ctrl=GammaControllerConfig(
                       schedule="armijo-coupled"))


def _tel(backlog, cosine=1.0):
    return CompressionTelemetry(ef_backlog=jnp.float32(backlog),
                                cosine=jnp.float32(cosine),
                                decode_error=jnp.float32(0.0),
                                eff_gamma=jnp.float32(1.0))


def test_ef_coupled_hysteresis_band():
    """The ef-coupled state machine (DESIGN.md §10): grow above
    target+band, shrink below target-band (cosine healthy), hold inside
    the band, clip into [gamma_min, budget]."""
    comp = Compressor(gamma=0.04, max_gamma=0.08)
    cfg = GammaControllerConfig(schedule="ef-coupled", gamma_min=0.01,
                                grow=2.0, shrink=0.5,
                                ef_target=0.15, ef_band=0.05)

    def upd(g, backlog, cosine=1.0):
        return float(gamma_update(cfg, comp, jnp.float32(g), jnp.int32(3),
                                  compression=_tel(backlog, cosine)))

    assert upd(0.02, 0.30) == pytest.approx(0.04)      # over -> grow
    assert upd(0.02, 0.05) == pytest.approx(0.01)      # slack -> shrink
    assert upd(0.02, 0.15) == pytest.approx(0.02)      # in band -> hold
    # hysteresis edges: strictly-inside-band values hold
    assert upd(0.02, 0.199) == pytest.approx(0.02)
    assert upd(0.02, 0.101) == pytest.approx(0.02)
    # unhealthy cosine blocks the shrink even at low backlog
    assert upd(0.02, 0.05, cosine=-0.5) == pytest.approx(0.02)
    # diverging EF memory (non-finite backlog) always grows
    assert upd(0.02, float("nan")) == pytest.approx(0.04)
    assert upd(0.02, float("inf")) == pytest.approx(0.04)
    # clipping into [gamma_min, budget]
    assert upd(0.06, 0.40) == pytest.approx(0.08)
    assert upd(0.015, 0.01) == pytest.approx(0.01)


def test_ef_coupled_requires_telemetry_and_valid_band():
    comp = Compressor(gamma=0.04, max_gamma=0.08)
    with pytest.raises(ValueError):
        gamma_update(GammaControllerConfig(schedule="ef-coupled"), comp,
                     jnp.float32(0.04), jnp.int32(0))
    with pytest.raises(ValueError, match="hysteresis"):
        GammaControllerConfig(schedule="ef-coupled", ef_target=0.1,
                              ef_band=0.2)


# ---------------------------------------------------------------------------
# golden adaptive convergence (fixed seeds; ISSUE 3 acceptance pairing)
# ---------------------------------------------------------------------------

SEED = 0
D = 256
N = 512
STEPS = 900
BATCH = 32
GMAX = 0.04


def _run(cfg, steps=STEPS, tail=400):
    A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)

    def bl(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)

    @jax.jit
    def full_loss(w):
        return jnp.mean((A @ w - b) ** 2)

    opt = csgd_asss(cfg)
    w = jnp.zeros(D)
    st = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    rng = np.random.default_rng(SEED)
    wbar = np.zeros(D)
    navg = 0
    gammas = []
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, st, aux = step(w, st, idx)
        gammas.append(float(aux.gamma))
        if t >= steps - tail:           # Polyak tail average
            wbar += np.asarray(w)
            navg += 1
    # the run total rides in the state/aux now (ISSUE 4 satellite): one
    # number instead of re-summing the per-step metric
    cum_eff = float(aux.cum_eff_bytes)
    return float(full_loss(jnp.asarray(wbar / navg))), cum_eff, gammas


def test_armijo_coupled_matches_fixed_loss_with_fewer_bytes():
    """The acceptance pairing: armijo-coupled gamma inside the max_gamma
    budget reaches the fixed-gamma=max_gamma loss (5% + noise-floor
    allowance, see module docstring) while logging strictly fewer
    cumulative effective_wire_bytes."""
    fixed = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=GMAX, min_compress_size=1))
    loss_f, eff_f, gam_f = _run(fixed)

    coupled = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=GMAX, max_gamma=GMAX,
                              min_compress_size=1),
        gamma_ctrl=GammaControllerConfig(schedule="armijo-coupled",
                                         gamma_min=0.03))
    loss_c, eff_c, gam_c = _run(coupled)

    # both converge to the interpolation floor at all
    assert np.isfinite(loss_f) and loss_f < 1e-3, loss_f
    assert np.isfinite(loss_c) and loss_c < 1e-3, loss_c
    # coupled reaches the fixed-run loss: within 5% + the noise floor
    assert loss_c <= 1.05 * loss_f + 5e-4, (loss_c, loss_f)
    # ... while shipping strictly fewer effective bytes inside the SAME
    # static budget (fixed run: effective == budget every round)
    assert eff_c < eff_f, (eff_c, eff_f)
    # and the controller actually moved within [gamma_min, max_gamma]
    assert min(gam_c) >= 0.03 - 1e-6 and max(gam_c) <= GMAX + 1e-6
    assert min(gam_c) < GMAX - 1e-6
    assert all(abs(g - GMAX) < 1e-6 for g in gam_f)


def test_ef_coupled_matches_fixed_loss_with_fewer_bytes():
    """EF-coupled pairing at the SAME healthy starting gamma: couples to
    the compressor's own backlog signal, reaches the fixed-gamma loss
    (same 5% + noise-floor bound as the armijo pairing) while shipping
    strictly fewer cumulative effective bytes — and, unlike armijo-coupled,
    its shrink decisions are grounded in a signal that actually moves with
    gamma (the observability pair in test_golden_convergence.py pins the
    discriminating direction)."""
    fixed = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=GMAX, min_compress_size=1))
    loss_f, eff_f, gam_f = _run(fixed)

    coupled = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=GMAX, max_gamma=GMAX,
                              min_compress_size=1),
        gamma_ctrl=GammaControllerConfig(schedule="ef-coupled",
                                         gamma_min=0.01))
    loss_c, eff_c, gam_c = _run(coupled)

    assert np.isfinite(loss_f) and loss_f < 1e-3, loss_f
    assert np.isfinite(loss_c) and loss_c < 1e-3, loss_c
    assert loss_c <= 1.05 * loss_f + 5e-4, (loss_c, loss_f)
    assert eff_c < eff_f, (eff_c, eff_f)
    # the controller moved: it spent rounds strictly inside the budget
    assert min(gam_c) < GMAX - 1e-6
    assert max(gam_c) <= GMAX + 1e-6


def test_linear_schedule_strictly_fewer_bytes_same_budget():
    """Coarse-to-fine linear ramp: converges inside the budget with
    strictly fewer effective bytes (cheap sanity pairing for the second
    schedule; bounds loose)."""
    lin = CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
        compressor=Compressor(gamma=0.02, max_gamma=GMAX,
                              min_compress_size=1),
        gamma_ctrl=GammaControllerConfig(schedule="linear", ramp_steps=300))
    loss_l, eff_l, gam_l = _run(lin, steps=600, tail=150)
    assert np.isfinite(loss_l) and loss_l < 1e-2, loss_l
    assert gam_l[0] == pytest.approx(0.02)
    assert gam_l[-1] == pytest.approx(GMAX)
    # budget bytes for 600 steps at max gamma would be 600 * (k_max * 8):
    # the ramp must come in strictly under
    k_max = Compressor(gamma=GMAX, min_compress_size=1).k_for(D)
    budget_rows = 600 * _ragged_row_bytes(k_max)
    assert eff_l < budget_rows


def _ragged_row_bytes(k_max):
    """One (1, D)-leaf ragged row at full count: header + 16-bit idx +
    32-bit values (the quadratic's single leaf fits 16-bit indexing)."""
    iw = -(-k_max * 16 // 32)
    return 4 * (1 + iw + k_max)


def test_build_train_step_rejects_coupled_schedule_without_armijo():
    """Launch-path counterpart of the CSGDConfig validation: optimizer
    kinds that never run the Armijo search cannot drive the
    armijo-coupled schedule — fail at build time, not at trace time."""
    import jax
    from repro.configs.base import (OptimizerConfig, RunConfig, ShapeConfig,
                                    smoke_variant)
    from repro.configs import get_config
    from repro.launch.train_step import build_train_step
    from repro.models import build_model

    cfg = smoke_variant(get_config("qwen1.5-4b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 64, 4, "train"),
        optimizer=OptimizerConfig(
            kind="nonadaptive",
            gamma_controller=GammaControllerConfig(
                schedule="armijo-coupled")))
    with pytest.raises(ValueError, match="armijo-coupled"):
        build_train_step(build_model(cfg), run, mesh)
