"""Hostile-wire fuzz bodies (DESIGN.md §16).

Shared by two tiers: the hypothesis property tests in
``tests/test_property.py`` draw arbitrary geometry/seed combinations, and
the fixed-seed deterministic sweep in ``tests/test_faults.py`` drives the
same bodies without hypothesis (the container image may not ship the dev
extra).  The invariants under arbitrary uint32 garbage rows:

* decode never raises and never indexes out of bounds (a live value past
  the verdict layer always sits at an index in ``[0, d)``),
* nothing non-finite survives the verdict+quarantine layer,
* the verdict is always a well-defined (R,) bool,
* honest encodes are verdict-True everywhere and quarantine is a
  bit-exact no-op on them (the faults-off guarantee).
"""
import jax.numpy as jnp
import numpy as np

from repro.comm import wire as wire_fmt
from repro.core import Compressor
from repro.core.compression import block_extract_sparse

_R = 4                                  # garbage rows per example


def _spec(d: int, block: int, value_bits: int, adaptive: bool,
          method: str):
    comp = Compressor(gamma=0.05, max_gamma=0.05 if adaptive else 0.0,
                      method=method, block=block, min_compress_size=1,
                      value_bits=value_bits)
    return comp, wire_fmt.WireSpec.for_row(comp, d)


def _assert_decode_safe(payload, spec):
    """The §16 contract for ONE decoded payload, whatever its bits."""
    vals, idx = wire_fmt.decode_rows(payload, spec)
    verdict = wire_fmt.row_verdict(payload, spec, vals, idx)
    assert verdict.shape == (payload.shape[0],)
    assert verdict.dtype == jnp.bool_
    qv, qi = wire_fmt.quarantine_rows(vals, idx, verdict)
    v_np, i_np = np.asarray(qv), np.asarray(qi)
    # nothing non-finite past the verdict layer
    assert np.all(np.isfinite(v_np))
    # every LIVE value addresses a real coordinate (dead padding may keep
    # harmless clamped/zero indices; the scatter drops or zero-adds them)
    live = v_np != 0.0
    assert np.all((i_np[live] >= 0) & (i_np[live] < spec.d))
    # quarantined rows aggregate to exactly nothing
    bad = ~np.asarray(verdict)
    assert np.all(v_np[bad] == 0.0) and np.all(i_np[bad] == 0)
    # and the scatter-add the aggregators run stays finite end to end
    dense = jnp.zeros((spec.d,), jnp.float32).at[qi.reshape(-1)].add(
        qv.reshape(-1), mode="drop")
    assert np.all(np.isfinite(np.asarray(dense)))
    return vals, idx, verdict


def check_garbage_rows_decode_safe(seed: int, d: int, block: int,
                                   value_bits: int, adaptive: bool,
                                   method: str = "block_topk"):
    """Arbitrary uint32 rows — headers, counts, scales, fields all
    garbage — through decode + verdict + quarantine."""
    comp, spec = _spec(d, block, value_bits, adaptive, method)
    if spec is None:
        return                            # row ships dense: no payload
    rng = np.random.default_rng(seed)
    payload = jnp.asarray(rng.integers(0, 1 << 32, (_R, spec.row_words),
                                       dtype=np.uint32))
    _assert_decode_safe(payload, spec)


def check_honest_rows_verdict_clean(seed: int, d: int, block: int,
                                    value_bits: int, adaptive: bool,
                                    method: str = "block_topk"):
    """An honest encode is verdict-True on every row and quarantine
    passes it through bit-untouched (faults-off bit-exactness)."""
    comp, spec = _spec(d, block, value_bits, adaptive, method)
    if spec is None:
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((_R, d)).astype(np.float32))
    if method == "block_topk":
        vals, idx = block_extract_sparse(x, comp)
    else:
        from repro.core.dcsgd import _per_layer_topk
        vals, idx = _per_layer_topk(x, comp.k_for(d))
    counts = None
    if spec.ragged:
        counts = jnp.asarray(rng.integers(1, spec.full_count + 1, _R),
                             jnp.int32)
    payload = wire_fmt.encode_rows(vals, idx, spec, counts=counts)
    dvals, didx, verdict = _assert_decode_safe(payload, spec)
    assert np.all(np.asarray(verdict))
    qv, qi = wire_fmt.quarantine_rows(dvals, didx, verdict)
    np.testing.assert_array_equal(np.asarray(qv), np.asarray(dvals))
    np.testing.assert_array_equal(np.asarray(qi), np.asarray(didx))


def check_garbage_bucket_decode_safe(seed: int, value_bits: int,
                                     adaptive: bool):
    """Arbitrary garbage through the batched bucket decode: per-lane
    verdicts are well-formed and invalid rows come back quarantined."""
    from repro.comm.bucket import build_bucket_plan, decode_buckets

    rng = np.random.default_rng(seed)
    comp = Compressor(gamma=0.05, max_gamma=0.05 if adaptive else 0.0,
                      method="block_topk", block=256, min_compress_size=64,
                      value_bits=value_bits)
    shapes = [(2, int(rng.integers(64, 2048))), (int(rng.integers(64, 2048)),)]
    plan = build_bucket_plan(shapes, [True, False], comp)
    if not plan.total_words:
        return
    W = 2
    gathered = jnp.asarray(rng.integers(0, 1 << 32, (W, plan.total_words),
                                        dtype=np.uint32))
    decoded, verdicts = decode_buckets(plan, gathered, with_verdicts=True)
    for ln in plan.leaves:
        if ln.dense:
            assert decoded[ln.index] is None
            continue
        vals, idx = decoded[ln.index]
        v = verdicts[ln.index]
        assert v.shape == (W, ln.L) and v.dtype == jnp.bool_
        v_np, i_np = np.asarray(vals), np.asarray(idx)
        assert np.all(np.isfinite(v_np))
        live = v_np != 0.0
        d = ln.spec.d
        assert np.all((i_np[live] >= 0) & (i_np[live] < d))
        bad = ~np.asarray(v)
        assert np.all(v_np[bad] == 0.0) and np.all(i_np[bad] == 0)
