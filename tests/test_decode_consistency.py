"""Serving correctness: prefill(S) + decode(token) must reproduce the full
forward logits at position S — for every architecture family, over multiple
consecutive decode steps."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model

B, S, N_DECODE = 2, 32, 3


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    kb = jax.random.fold_in(key, 5)
    total = S + N_DECODE
    toks = jax.random.randint(kb, (B, total), 0, cfg.vocab_size)
    aux = {}
    if cfg.family == "vlm":
        aux["image_embed"] = jax.random.normal(kb, (B, cfg.n_patches,
                                                    cfg.d_model))
    if cfg.family == "encdec":
        aux["src_embed"] = jax.random.normal(kb, (B, 16, cfg.d_model))

    _, cache = model.prefill(params, {"tokens": toks[:, :S], **aux},
                             capacity=total + 2)
    for i in range(N_DECODE):
        cur = S + i
        lg_dec, cache = model.decode_step(params, toks[:, cur:cur + 1],
                                          cache, jnp.int32(cur))
        lg_full, _ = model.prefill(params, {"tokens": toks[:, :cur + 1],
                                            **aux}, capacity=total + 2)
        err = float(jnp.max(jnp.abs(lg_dec[..., :cfg.vocab_size]
                                    - lg_full[..., :cfg.vocab_size])))
        assert err < 2e-3, (arch, i, err)


@pytest.mark.parametrize("arch", ["yi-34b", "zamba2-7b"])
def test_sliding_window_decode_consistency(arch, key):
    """The long-context SWA variant must also be decode-consistent."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config(arch), sliding_window=16)
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 3), (B, S + 1), 0,
                              cfg.vocab_size)
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, capacity=S + 2)
    lg_dec, _ = model.decode_step(params, toks[:, S:S + 1], cache,
                                  jnp.int32(S))
    lg_full, _ = model.prefill(params, {"tokens": toks}, capacity=S + 2)
    err = float(jnp.max(jnp.abs(lg_dec - lg_full)))
    assert err < 2e-3, err
