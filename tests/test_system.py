"""End-to-end behaviour tests: the paper's headline claims at CPU scale,
exercised through the public API (build_model + csgd_asss + data pipeline)."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.paper_models import MLP_CONFIG, init_net, net_loss
from repro.core import (ArmijoConfig, Compressor, CSGDConfig, NonAdaptiveCSGD,
                        csgd_asss)
from repro.data.synthetic import (TokenPipeline, class_batch,
                                  teacher_classification)
from repro.models import build_model


def test_lm_trains_with_csgd_asss(key):
    """A small transformer LM's loss decreases under compressed adaptive
    training (the paper's setting transplanted to our production models)."""
    cfg = get_smoke_config("qwen1.5-4b")
    model = build_model(cfg)
    params = model.init(key)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=4)
    opt = csgd_asss(CSGDConfig(
        armijo=ArmijoConfig(),
        compressor=Compressor(gamma=0.1, min_compress_size=512)))
    st = opt.init(params)

    @jax.jit
    def step(p, s, batch):
        return opt.step(lambda pp: model.loss(pp, batch)[0], p, s)

    losses = []
    for i in range(30):
        params, st, aux = step(params, st, pipe.batch(i))
        losses.append(float(aux.loss))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_mlp_csgd_beats_nonadaptive_small_eta(key):
    """Paper Figs 1-3 shape: CSGD-ASSS (a=3sigma) vs non-adaptive eta=0.01
    on a realizable classification task at 10% compression."""
    x, y = teacher_classification(512, n_classes=10, image=False)
    cfg = MLP_CONFIG
    comp = Compressor(gamma=0.1, min_compress_size=512)

    def run(opt, steps=120):
        params = init_net(cfg, key)
        st = opt.init(params)

        @jax.jit
        def step(p, s, b):
            return opt.step(lambda pp: net_loss(cfg, pp, b), p, s)
        loss = None
        for i in range(steps):
            params, st, aux = step(params, st, class_batch(x, y, 64, i))
            loss = float(aux.loss)
        return loss

    l_ad = run(csgd_asss(CSGDConfig(armijo=ArmijoConfig(a_scale=0.3),
                                    compressor=comp)))
    l_na = run(NonAdaptiveCSGD(eta=0.01, compressor=comp))
    assert np.isfinite(l_ad)
    assert l_ad < l_na, (l_ad, l_na)


def test_train_cli_runs(tmp_path):
    """The launch driver end-to-end (single device, tiny model) incl.
    checkpoint write + metrics log."""
    import json
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out_json = str(tmp_path / "log.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen1.5-4b",
         "--smoke", "--steps", "8", "--seq-len", "64", "--global-batch", "2",
         "--mesh", "1x1", "--gamma", "0.1", "--log-every", "2",
         "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "4",
         "--out", out_json],
        capture_output=True, text=True, timeout=900, env=env, cwd=repo)
    assert r.returncode == 0, r.stderr[-2000:]
    log = json.load(open(out_json))
    assert log and np.isfinite(log[-1]["loss"])
    from repro.checkpoint import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path / "ck")) == 8
