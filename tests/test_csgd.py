"""CSGD-ASSS optimizer: convergence, scaling necessity, EF identity,
baseline comparisons — the paper's core claims at unit scale."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ArmijoConfig, Compressor, CSGDConfig, NonAdaptiveCSGD,
                        SGD, SLS, csgd_asss)
from repro.data.synthetic import interpolated_regression


def make_problem(n=512, d=256, std=1.0, seed=0):
    A, b, _ = interpolated_regression(n, d, feature_std=std, seed=seed)

    def batch_loss(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)
    return A, b, batch_loss


def run_opt(opt, batch_loss, d, steps=300, batch=32, seed=0):
    w = jnp.zeros(d)
    state = opt.init(w)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: batch_loss(ww, idx), w, s)

    rng = np.random.default_rng(seed)
    loss = None
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, 512, batch))
        w, state, aux = step(w, state, idx)
        loss = float(aux.loss)
        if not np.isfinite(loss) or loss > 1e12:
            break
    return loss, w, state


def test_csgd_asss_converges_interpolated():
    """Theorem 1 regime: convex + interpolation -> converges under
    compression.  gamma=4% on d=256 keeps k~=10 per step, the same
    selected-coordinate count as the paper's Fig-4 setup (d=1024, 1%) —
    the paper-exact d=1024/1% run is benchmarks/fig4_scaling_necessity."""
    A, b, bl = make_problem()
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=Compressor(gamma=0.04, min_compress_size=1))
    loss, _, st = run_opt(csgd_asss(cfg), bl, 256, steps=500)
    assert loss < 0.1, loss
    # paper §IV-B: about 2 stopping-condition evals per step
    assert float(st.n_evals_ema) < 4.0


def test_no_scaling_diverges():
    """Paper Fig. 4: without scaling (a=1) the loss blows up."""
    A, b, bl = make_problem(std=1.0)
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1),
                     compressor=Compressor(gamma=0.01, min_compress_size=1),
                     use_scaling=False)
    loss, _, _ = run_opt(csgd_asss(cfg), bl, 256, steps=150)
    assert (not np.isfinite(loss)) or loss > 100.0, loss


def test_csgd_beats_nonadaptive_small_eta():
    A, b, bl = make_problem()
    comp = Compressor(gamma=0.05, min_compress_size=1)
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=comp)
    l_ad, *_ = run_opt(csgd_asss(cfg), bl, 256, steps=300)
    l_na, *_ = run_opt(NonAdaptiveCSGD(eta=0.01, compressor=comp), bl, 256,
                       steps=300)
    assert l_ad < l_na, (l_ad, l_na)


def test_ef_memory_identity_lemma6():
    """Lemma 6: m_t == x_t - x_hat_t, with x_hat the uncompressed virtual
    iterate accumulating eta_t * grad_t."""
    A, b, bl = make_problem(d=128)
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=Compressor(gamma=0.05, min_compress_size=1))
    opt = csgd_asss(cfg)
    w = jnp.zeros(128)
    st = opt.init(w)
    xhat = w
    rng = np.random.default_rng(0)
    for t in range(25):
        idx = jnp.asarray(rng.integers(0, 512, 16))

        def loss_fn(ww, idx=idx):
            return bl(ww, idx)

        g = jax.grad(loss_fn)(w)
        w_new, st, aux = opt.step(loss_fn, w, st)
        xhat = xhat - aux.eta * g
        np.testing.assert_allclose(np.asarray(st.memory),
                                   np.asarray(w_new - xhat),
                                   atol=2e-5)
        w = w_new


def test_int8_ef_memory_still_converges():
    """Beyond-paper: quantized EF memory preserves convergence."""
    A, b, bl = make_problem()
    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=Compressor(gamma=0.05, min_compress_size=1),
                     ef_dtype="int8")
    loss, *_ = run_opt(csgd_asss(cfg), bl, 256, steps=400)
    assert loss < 1.0, loss


def test_sls_uncompressed_converges():
    A, b, bl = make_problem()
    loss, *_ = run_opt(SLS(ArmijoConfig(sigma=0.1, a_scale=1.0)), bl, 256,
                       steps=200)
    assert loss < 1e-2


def test_sgd_baseline_converges():
    A, b, bl = make_problem()
    loss, *_ = run_opt(SGD(eta=0.01), bl, 256, steps=300)
    assert loss < 1.0


def test_strongly_convex_linear_rate():
    """Theorem 2: with a strongly convex component, ||x_t - x*|| decays
    geometrically."""
    d = 64
    A, b, _ = interpolated_regression(256, d, seed=1)
    xstar = jnp.linalg.lstsq(A, b)[0]

    def bl(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2) + 0.05 * jnp.sum((w - xstar) ** 2)

    cfg = CSGDConfig(armijo=ArmijoConfig(sigma=0.1, a_scale=0.3),
                     compressor=Compressor(gamma=0.1, min_compress_size=1))
    opt = csgd_asss(cfg)
    w = jnp.zeros(d)
    st = opt.init(w)
    rng = np.random.default_rng(0)
    dists = []
    for t in range(400):
        idx = jnp.asarray(rng.integers(0, 256, 32))
        w, st, aux = opt.step(lambda ww: bl(ww, idx), w, st)
        if t % 100 == 99:
            dists.append(float(jnp.sum((w - xstar) ** 2)))
    assert dists[-1] < dists[0] * 0.05, dists
