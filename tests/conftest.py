"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see 1 device (the dry-run sets its own flags in-process, and
distributed tests spawn subprocesses with their own env)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _f32_default():
    # deterministic, CPU-friendly numerics for tests
    yield


def tree_allclose(a, b, **kw):
    oks = jax.tree.map(lambda x, y: np.allclose(x, y, **kw), a, b)
    return all(jax.tree.leaves(oks))
