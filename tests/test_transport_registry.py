"""The transport registry (repro/comm/transport.py) is the ONE source of
truth for transport names: registered schedules, CLI choices, config
validation, and every "unknown transport" error derive from it — the
duplicated ``("bucketed", "perleaf")`` literals are gone (DESIGN.md §12).
"""
import jax.numpy as jnp
import pytest

from repro.comm.transport import (get_transport, register_transport,
                                  transport_names,
                                  unknown_transport_message,
                                  validate_transport)


def test_registry_names_complete():
    assert transport_names() == ("bucketed", "faulty", "gossip", "overlap",
                                 "perleaf")


def test_registry_flags():
    assert not get_transport("bucketed").stateful
    assert not get_transport("perleaf").stateful
    assert get_transport("faulty").stateful
    assert get_transport("gossip").stateful
    assert get_transport("overlap").stateful
    for name in transport_names():
        tp = get_transport(name)
        assert tp.name == name and callable(tp.exchange)
        assert tp.description


def test_unknown_transport_message_lists_registered():
    msg = unknown_transport_message("nope")
    assert msg == ("unknown transport 'nope' "
                   "(want 'bucketed' | 'faulty' | 'gossip' | 'overlap' "
                   "| 'perleaf')")
    with pytest.raises(ValueError, match="'bucketed' | 'gossip'"):
        get_transport("nope")
    with pytest.raises(ValueError, match="unknown transport"):
        validate_transport("nope")


def test_optimizer_config_validates_via_registry():
    from repro.configs.base import OptimizerConfig
    OptimizerConfig(transport="gossip")          # registered: fine
    with pytest.raises(ValueError, match="unknown transport"):
        OptimizerConfig(transport="carrier-pigeon")


def test_cli_choices_come_from_registry():
    """The --transport choices in every entry point are derived, not
    spelled out — a new registered transport shows up everywhere."""
    import inspect

    from repro.launch import dryrun, train
    for mod in (train, dryrun):
        src = inspect.getsource(mod)
        assert "transport_names()" in src
        assert '["bucketed", "perleaf"]' not in src
        assert "('bucketed', 'perleaf')" not in src


def test_reregistration_idempotent_and_conflict_checked():
    fn = get_transport("bucketed").exchange
    # same function under the same name: a no-op (module reloads)
    assert register_transport("bucketed")(fn) is fn

    def imposter(*a, **k):                       # pragma: no cover
        raise AssertionError
    with pytest.raises(ValueError, match="already registered"):
        register_transport("bucketed")(imposter)


def test_overlap_config_validation():
    from repro.comm.overlap import OverlapConfig

    OverlapConfig()                              # defaults valid
    OverlapConfig(n_chunks=7, delay=0)
    with pytest.raises(ValueError, match="n_chunks"):
        OverlapConfig(n_chunks=0)
    with pytest.raises(ValueError, match="delay"):
        OverlapConfig(delay=2)


def test_overlap_rejects_federated_compose():
    """The cohort gather carries per-client rows on its own schedule —
    transport='overlap' must be rejected at config time, not deep in the
    worker body (DESIGN.md §13/§14)."""
    from repro.configs.base import FederatedConfig, OptimizerConfig

    with pytest.raises(ValueError, match="overlap"):
        OptimizerConfig(transport="overlap",
                        federated=FederatedConfig(n_clients=8))


def test_stateful_arity_enforced():
    """worker_compress_aggregate mirrors the registry's stateful flag:
    gossip demands a ctx, stateless transports reject one."""
    from repro.core.dcsgd import worker_compress_aggregate

    tree = {"v": jnp.zeros((3000,))}
    mem = {"v": jnp.zeros((3000,))}
    from repro.core import Compressor
    comp = Compressor(gamma=0.05, min_compress_size=64)
    with pytest.raises(ValueError, match="transport_ctx"):
        worker_compress_aggregate(tree, mem, jnp.float32(0.1), comp,
                                  ("data",), transport="gossip")
    with pytest.raises(ValueError, match="transport_ctx"):
        worker_compress_aggregate(tree, mem, jnp.float32(0.1), comp,
                                  ("data",), transport="bucketed",
                                  transport_ctx=object())
