"""Hostile-wire robustness units (DESIGN.md §16).

Covers the three layers in isolation:

* the seeded injector — ``(seed, step, lane, row)`` determinism, the
  burst window, per-slot targeting, and each fault class's signature;
* the verdict/quarantine layer — what each fault class does to
  ``row_verdict`` and what survives ``quarantine_rows`` (plus the
  fixed-seed drive of the tests/wire_fuzz.py bodies, so the fuzz
  invariants run even without the hypothesis dev extra);
* the step-level breaker — ``HealthState`` arithmetic,
  ``check_divergence`` and the typed :class:`DivergenceError`.

End-to-end composition (faults-off bit-exactness per transport, the HLO
collective pin, the golden convergence-under-burst pair) lives in
tests/distributed/ and tests/test_golden_convergence.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import faults, wire as wire_fmt
from repro.comm.faults import FaultConfig, FaultCtx
from repro.core import Compressor
from repro.core.compression import block_extract_sparse
from repro.core.health import (DivergenceError, HealthState, advance_health,
                               all_finite, check_divergence)

from wire_fuzz import (check_garbage_bucket_decode_safe,
                       check_garbage_rows_decode_safe,
                       check_honest_rows_verdict_clean)

D = 1280


def _encoded(seed=0, value_bits=32, adaptive=False, rows=4):
    """Honest (payload, spec) rows to corrupt."""
    comp = Compressor(gamma=0.05, max_gamma=0.05 if adaptive else 0.0,
                      method="block_topk", block=256, min_compress_size=1,
                      value_bits=value_bits)
    spec = wire_fmt.WireSpec.for_row(comp, D)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, D)).astype(np.float32))
    vals, idx = block_extract_sparse(x, comp)
    counts = None
    if spec.ragged:
        counts = jnp.full((rows,), spec.full_count, jnp.int32)
    return wire_fmt.encode_rows(vals, idx, spec, counts=counts), spec


def _corrupt(payload, spec, cfg, step=0, lane=0, rows_per_worker=1):
    with faults.active_faults(cfg, jnp.int32(step)):
        return faults.maybe_corrupt(payload, spec, lane, rows_per_worker)


# ---------------------------------------------------------------------------
# FaultConfig validation + composition rules
# ---------------------------------------------------------------------------

def test_fault_config_validation():
    assert not FaultConfig().enabled
    assert FaultConfig(p_bitflip=0.1).enabled
    with pytest.raises(ValueError, match="p_count"):
        FaultConfig(p_count=1.5)
    with pytest.raises(ValueError, match="p_nonfinite"):
        FaultConfig(p_nonfinite=-0.1)
    with pytest.raises(ValueError, match="start_step"):
        FaultConfig(p_bitflip=0.1, start_step=-1)


def test_optimizer_config_rejects_bad_fault_compositions():
    from repro.configs.base import OptimizerConfig
    on = FaultConfig(p_bitflip=0.1)
    OptimizerConfig(faults=on)                      # baseline composes
    with pytest.raises(ValueError, match="wire to corrupt"):
        OptimizerConfig(kind="sgd", faults=on)
    with pytest.raises(ValueError, match="downlink"):
        OptimizerConfig(faults=on, downlink="compressed")
    with pytest.raises(ValueError, match="shard_local_topk"):
        OptimizerConfig(faults=on, shard_local_topk=True)
    with pytest.raises(ValueError, match="max_consecutive_skips"):
        OptimizerConfig(max_consecutive_skips=-1)


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

def test_maybe_corrupt_is_identity_without_context():
    payload, spec = _encoded()
    out = faults.maybe_corrupt(payload, spec, 0, 1)
    assert out is payload                     # Python-level identity


def test_maybe_corrupt_identity_when_rates_zero():
    payload, spec = _encoded()
    out = _corrupt(payload, spec, FaultConfig())
    assert out is payload


def test_injector_deterministic_in_seed_step_lane():
    payload, spec = _encoded()
    cfg = FaultConfig(seed=3, p_bitflip=1.0)
    a = _corrupt(payload, spec, cfg, step=5, lane=2)
    b = _corrupt(payload, spec, cfg, step=5, lane=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the draw moves with every key component
    for kw in (dict(step=6, lane=2), dict(step=5, lane=3)):
        c = _corrupt(payload, spec, cfg, **kw)
        assert np.any(np.asarray(c) != np.asarray(a))
    d = _corrupt(payload, spec, FaultConfig(seed=4, p_bitflip=1.0),
                 step=5, lane=2)
    assert np.any(np.asarray(d) != np.asarray(a))


def test_burst_window_and_worker_targeting():
    payload, spec = _encoded(rows=4)
    cfg = FaultConfig(p_zero_row=1.0, start_step=10, n_steps=3)
    for step, hit in ((9, False), (10, True), (12, True), (13, False)):
        out = _corrupt(payload, spec, cfg, step=step)
        changed = np.any(np.asarray(out) != np.asarray(payload))
        assert changed == hit, step
    # rows_per_worker=2: slot 1 is rows 2..3 of the gathered stack
    tgt = FaultConfig(p_zero_row=1.0, worker=1)
    out = np.asarray(_corrupt(payload, spec, tgt, rows_per_worker=2))
    ref = np.asarray(payload)
    np.testing.assert_array_equal(out[:2], ref[:2])
    assert np.all(out[2:] == 0)


def test_bitflip_flips_exactly_one_bit_per_row():
    payload, spec = _encoded()
    out = _corrupt(payload, spec, FaultConfig(p_bitflip=1.0))
    diff = np.asarray(out) ^ np.asarray(payload)
    per_row = np.array([bin(int(w)).count("1")
                        for row in diff for w in row]).reshape(diff.shape)
    np.testing.assert_array_equal(per_row.sum(axis=1),
                                  np.ones(diff.shape[0]))


def test_zero_row_decodes_valid_and_contributes_nothing():
    """The dropped-worker fault: an all-zero row is NOT quarantined — it
    decodes cleanly to zero contribution (DESIGN.md §16 fault table)."""
    payload, spec = _encoded()
    out = _corrupt(payload, spec, FaultConfig(p_zero_row=1.0))
    assert np.all(np.asarray(out) == 0)
    vals, idx = wire_fmt.decode_rows(out, spec)
    assert np.all(np.asarray(vals) == 0.0)
    assert np.all(np.asarray(wire_fmt.row_verdict(out, spec, vals, idx)))


def test_count_fault_trips_verdict():
    payload, spec = _encoded(adaptive=True)
    assert spec.ragged
    out = _corrupt(payload, spec, FaultConfig(p_count=1.0))
    counts = np.asarray(out[:, 0]).astype(np.int64)
    assert np.all((counts == 0xFFFFFFFF)
                  | (counts == 2 * spec.full_count + 7))
    vals, idx = wire_fmt.decode_rows(out, spec)
    verdict = wire_fmt.row_verdict(out, spec, vals, idx)
    assert not np.any(np.asarray(verdict))
    qv, qi = wire_fmt.quarantine_rows(vals, idx, verdict)
    assert np.all(np.asarray(qv) == 0.0) and np.all(np.asarray(qi) == 0)


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
def test_nonfinite_fault_trips_verdict_each_width(value_bits):
    payload, spec = _encoded(value_bits=value_bits)
    out = _corrupt(payload, spec, FaultConfig(p_nonfinite=1.0))
    vals, idx = wire_fmt.decode_rows(out, spec)
    verdict = wire_fmt.row_verdict(out, spec, vals, idx)
    assert not np.any(np.asarray(verdict))
    qv, _ = wire_fmt.quarantine_rows(vals, idx, verdict)
    assert np.all(np.isfinite(np.asarray(qv)))
    assert np.all(np.asarray(qv) == 0.0)


# ---------------------------------------------------------------------------
# guards context
# ---------------------------------------------------------------------------

def test_guards_active_default_and_overrides():
    assert faults.guards_active()             # defensive decode is default
    with faults.guards_disabled():
        assert not faults.guards_active()
    assert faults.guards_active()
    with faults.active_faults(FaultConfig(p_bitflip=0.5), 0):
        assert faults.guards_active()
        assert faults.injection_active()
    with faults.active_faults(
            FaultConfig(p_bitflip=0.5, quarantine=False), 0):
        assert not faults.guards_active()     # the no-guards ablation arm
    assert not faults.injection_active()


# ---------------------------------------------------------------------------
# the "faulty" wrapper transport
# ---------------------------------------------------------------------------

def test_faulty_wrapper_rejects_self_and_missing_inner_ctx():
    from repro.comm.transport import get_transport
    t = get_transport("faulty")
    assert t.stateful
    cfg = FaultConfig(p_bitflip=0.5)
    with pytest.raises(ValueError, match="wrap itself"):
        t.exchange(None, None, None, None, None, ("data",), None, 1,
                   ctx=FaultCtx(cfg=cfg, step=0, inner="faulty"))
    with pytest.raises(ValueError, match="inner_ctx"):
        t.exchange(None, None, None, None, None, ("data",), None, 1,
                   ctx=FaultCtx(cfg=cfg, step=0, inner="overlap"))


def _one_worker_exchange(transport, transport_ctx, comp, seed=0):
    """Jitted 1-worker worker_compress_aggregate under shard_map."""
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.dcsgd import worker_compress_aggregate

    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(D).astype(np.float32))
    m = jnp.asarray(rng.standard_normal(D).astype(np.float32)) * 0.5
    mesh = jax.make_mesh((1,), ("data",))
    f = shard_map(
        lambda gg, mm: worker_compress_aggregate(
            gg, mm, jnp.float32(0.25), comp, ("data",),
            transport=transport, transport_ctx=transport_ctx),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(),
        axis_names={"data"})
    out = jax.jit(f)(g, m)
    return (g, m) + tuple(out)


@pytest.mark.parametrize("inner", ["bucketed", "perleaf"])
def test_faulty_wrapper_out_of_window_is_bit_exact(inner):
    """A campaign whose burst window excludes this step must reproduce the
    plain transport bit-for-bit (the masked injector adds no noise)."""
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=1)
    ctx = FaultCtx(cfg=FaultConfig(p_bitflip=1.0, p_nonfinite=1.0,
                                   start_step=100),
                   step=jnp.int32(0), inner=inner)
    got = _one_worker_exchange("faulty", ctx, comp)
    want = _one_worker_exchange(inner, None, comp)
    assert got[-1] == ()                     # stateless inner padded
    for a, b in zip(jax.tree.leaves(got[:-1]), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_faulty_wrapper_nonfinite_quarantines_own_row():
    """p_nonfinite=1.0 on a single worker: every payload row (all its
    own) is quarantined — the mean update is exactly zero and the leaf's
    EF residual freezes at the old memory (own-row freeze)."""
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=1)
    ctx = FaultCtx(cfg=FaultConfig(p_nonfinite=1.0),
                   step=jnp.int32(0), inner="bucketed")
    g, m, upd, m_new, wire_bytes, eff, tel, _ = \
        _one_worker_exchange("faulty", ctx, comp)
    assert np.all(np.asarray(upd) == 0.0)
    np.testing.assert_array_equal(np.asarray(m_new), np.asarray(m))
    assert float(tel.rows_quarantined) >= 1.0
    # control: the clean exchange moves both
    _, _, upd0, m0_new, *_ = _one_worker_exchange("bucketed", None, comp)
    assert np.any(np.asarray(upd0) != 0.0)
    assert np.any(np.asarray(m0_new) != np.asarray(m))


# ---------------------------------------------------------------------------
# health state + circuit breaker
# ---------------------------------------------------------------------------

def test_health_state_init_shapes():
    h = HealthState.init((4,))
    assert h.steps_skipped.shape == (4,)
    assert h.last_good_step.dtype == jnp.int32
    assert np.all(np.asarray(h.last_good_step) == -1)
    a = HealthState.init((4,), abstract=True)
    assert jax.tree.structure(a) == jax.tree.structure(h)
    for c, s in zip(jax.tree.leaves(h), jax.tree.leaves(a)):
        assert tuple(c.shape) == tuple(s.shape) and c.dtype == s.dtype


def test_advance_health_sequences():
    h = HealthState.init(())
    # good, good, skip, skip, good
    for step, ok, quar in ((0, True, 0.0), (1, True, 3.0), (2, False, 0.0),
                           (3, False, 2.0), (4, True, 0.0)):
        h = advance_health(h, jnp.bool_(ok), jnp.int32(step),
                           jnp.float32(quar))
    assert int(h.steps_skipped) == 2
    assert int(h.consecutive_skips) == 0      # reset by the final good step
    assert int(h.last_good_step) == 4
    assert float(h.rows_quarantined) == 5.0
    # an unbroken skip run accumulates
    for step in (5, 6, 7):
        h = advance_health(h, jnp.bool_(False), jnp.int32(step),
                           jnp.float32(0.0))
    assert int(h.consecutive_skips) == 3
    assert int(h.last_good_step) == 4


def test_all_finite():
    ok = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    assert bool(all_finite(ok))
    assert bool(all_finite(ok, jnp.float32(1.0)))
    assert not bool(all_finite(ok, {"c": jnp.array([1.0, jnp.nan])}))
    assert not bool(all_finite({"c": jnp.array([jnp.inf])}))


def test_check_divergence_raises_typed_error():
    m = {"step": 40, "consecutive_skips": 25, "last_good_step": 15}
    check_divergence(m, 0)                    # breaker disabled: no-op
    check_divergence(m, 26)                   # under threshold: no-op
    check_divergence({}, 25)                  # keys absent: no-op
    with pytest.raises(DivergenceError) as ei:
        check_divergence(m, 25)
    e = ei.value
    assert isinstance(e, RuntimeError)
    assert (e.step, e.last_good_step, e.consecutive, e.threshold) == \
        (40, 15, 25, 25)
    assert "last good step was 15" in str(e)


# ---------------------------------------------------------------------------
# fixed-seed drive of the fuzz bodies (tests/wire_fuzz.py) — the same
# invariants the hypothesis tier explores, runnable without the dev extra
# ---------------------------------------------------------------------------

_FUZZ_GRID = [(s, 193 + 331 * s, blk, vb, adaptive, method)
              for s, (blk, vb, adaptive, method) in enumerate(
                  [(64, 4, True, "block_topk"), (256, 8, False, "topk"),
                   (1024, 16, True, "block_topk"), (256, 32, False,
                                                    "block_topk"),
                   (64, 32, True, "topk"), (256, 4, False, "topk")])]


@pytest.mark.parametrize("seed,d,block,vb,adaptive,method", _FUZZ_GRID)
def test_garbage_rows_fixed_seeds(seed, d, block, vb, adaptive, method):
    check_garbage_rows_decode_safe(seed, d, block, vb, adaptive, method)
    check_honest_rows_verdict_clean(seed, d, block, vb, adaptive, method)


@pytest.mark.parametrize("seed", range(4))
def test_garbage_buckets_fixed_seeds(seed):
    check_garbage_bucket_decode_safe(seed, [4, 8, 16, 32][seed], seed % 2)
