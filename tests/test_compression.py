"""Unit tests for the top_k / block-local compression operators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Compressor, block_threshold, contraction_gamma,
                        sparse_to_dense, topk_select, tree_wire_bytes)


def test_topk_selects_largest_magnitudes(key):
    x = jax.random.normal(key, (1000,))
    s = topk_select(x, 10)
    dense = sparse_to_dense(s)
    kept = np.sort(np.abs(np.asarray(x)))[-10:]
    np.testing.assert_allclose(np.sort(np.abs(np.asarray(s.values))), kept,
                               rtol=1e-6)
    # kept values preserved exactly (biased operator, eq. (3))
    nz = np.nonzero(np.asarray(dense))[0]
    assert len(nz) == 10
    np.testing.assert_array_equal(np.asarray(dense)[nz],
                                  np.asarray(x)[nz])


def test_topk_k_greater_than_d(key):
    x = jax.random.normal(key, (5,))
    s = topk_select(x, 10)
    np.testing.assert_array_equal(np.asarray(sparse_to_dense(s)),
                                  np.asarray(x))


def test_small_leaves_uncompressed(key):
    comp = Compressor(gamma=0.01)
    x = jax.random.normal(key, (999,))      # < MIN_COMPRESS_SIZE
    sent, resid = comp.compress_dense(x)
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(x))
    assert float(jnp.sum(jnp.abs(resid))) == 0.0


def test_compress_dense_identity(key):
    """sent + residual == input, exactly (EF bookkeeping)."""
    comp = Compressor(gamma=0.05)
    x = jax.random.normal(key, (4096,))
    sent, resid = comp.compress_dense(x)
    np.testing.assert_allclose(np.asarray(sent + resid), np.asarray(x),
                               atol=1e-7)
    assert int(jnp.sum(sent != 0)) == comp.k_for(4096)


@pytest.mark.parametrize("gamma", [0.01, 0.1, 0.5])
def test_contraction_lemma7(key, gamma):
    """||x - top_k(x)||^2 <= (1-gamma)||x||^2 (paper Lemma 7)."""
    comp = Compressor(gamma=gamma)
    for i in range(5):
        x = jax.random.normal(jax.random.fold_in(key, i), (2048,))
        sent, resid = comp.compress_dense(x)
        lhs = float(jnp.sum(resid ** 2))
        rhs = (1 - comp.k_for(2048) / 2048) * float(jnp.sum(x ** 2))
        assert lhs <= rhs + 1e-5


def test_block_threshold_keeps_about_gamma(key):
    x = jax.random.normal(key, (8192,))
    tau = block_threshold(x, gamma=0.05, block=512)
    kept = int(jnp.sum(jnp.abs(x) >= tau))
    assert 0.05 * 8192 * 0.5 <= kept <= 0.05 * 8192 * 2.5


def test_block_topk_sparse_wire(key):
    comp = Compressor(gamma=0.05, method="block_topk", block=256)
    x = jax.random.normal(key, (4096,))
    s = comp.compress_sparse(x)
    # fixed wire size: k_b per block
    assert s.values.size == (4096 // 256) * max(1, round(0.05 * 256))
    dense = sparse_to_dense(s)
    # selected entries preserved exactly
    nz = np.nonzero(np.asarray(dense))[0]
    np.testing.assert_array_equal(np.asarray(dense)[nz], np.asarray(x)[nz])


def test_wire_bytes_accounting():
    comp = Compressor(gamma=0.01)
    tree = {"a": jnp.zeros((100000,)), "b": jnp.zeros((500,))}
    b = tree_wire_bytes(tree, comp)
    assert b == 1000 * 8 + 500 * 4  # k*(val+idx) + dense small leaf


def _run_worker(tree, comp, eta=0.1):
    """worker_compress_aggregate under a real 1-device shard_map (this also
    exercises the compat axis_size path of ``_dp_size``)."""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.dcsgd import worker_compress_aggregate

    mesh = jax.make_mesh((1,), ("data",))
    mem = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        partial(worker_compress_aggregate, comp=comp, dp_axes=("data",)),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(spec, spec, P(), P(), P()),
        axis_names={"data"})
    return jax.jit(f)(tree, mem, jnp.float32(eta))


@pytest.mark.parametrize("method", ["topk", "block_topk"])
@pytest.mark.parametrize("value_bits", [32, 8])
def test_wire_bytes_matches_worker_accounting(key, method, value_bits):
    """Compressor.wire_bytes == the bytes actually counted per step by
    worker_compress_aggregate, for every method/value_bits combination."""
    comp = Compressor(gamma=0.05, method=method, value_bits=value_bits,
                      min_compress_size=64, block=256)
    tree = {"a": jax.random.normal(key, (4096,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (50,)),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (1000,)),
            # stacked leaves: per-layer blocking/padding (d % block != 0)
            # and a per-layer size below the dense cutoff
            "s": jax.random.normal(jax.random.fold_in(key, 3), (4, 1300)),
            "t": jax.random.normal(jax.random.fold_in(key, 4), (4, 60))}
    _, _, wire, eff, _ = _run_worker(tree, comp)
    assert int(wire) == tree_wire_bytes(tree, comp)


def test_worker_aggregate_kernel_parity(key):
    """The fused-kernel block_topk path == the pure-jnp path (use_kernel
    escape hatch) on the same inputs: identical updates, EF memory, wire."""
    tree = {"w": jax.random.normal(key, (2, 2048)),   # stacked (L=2)
            "v": jax.random.normal(jax.random.fold_in(key, 1), (3000,))}
    def mk(use_kernel):
        return Compressor(gamma=0.05, method="block_topk", block=512,
                          min_compress_size=64, use_kernel=use_kernel)
    up_k, mem_k, wire_k, _, tel_k = _run_worker(tree, mk(True))
    up_j, mem_j, wire_j, _, tel_j = _run_worker(tree, mk(False))
    for a, b in zip(jax.tree.leaves(up_k), jax.tree.leaves(up_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    for a, b in zip(jax.tree.leaves(mem_k), jax.tree.leaves(mem_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    assert float(wire_k) == float(wire_j)
    # the fused-kernel telemetry moments equal the jnp path's reductions
    for a, b in zip(jax.tree.leaves(tel_k), jax.tree.leaves(tel_j)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_compress_dense_block_topk_kernel_identity(key):
    """Dense block_topk path (fused kernels by default): exact split and
    per-block keep budget."""
    comp = Compressor(gamma=0.05, method="block_topk", block=1024)
    x = jax.random.normal(key, (4096,))
    sent, resid = comp.compress_dense(x)
    np.testing.assert_array_equal(np.asarray(sent) + np.asarray(resid),
                                  np.asarray(x))
    per_block = np.count_nonzero(np.asarray(sent).reshape(4, 1024), axis=1)
    np.testing.assert_array_equal(per_block, np.full(4, comp.block_k()))
    # escape hatch still works (global-threshold jnp composition)
    sent2, resid2 = Compressor(gamma=0.05, method="block_topk", block=1024,
                               use_kernel=False).compress_dense(x)
    np.testing.assert_allclose(np.asarray(sent2 + resid2), np.asarray(x),
                               atol=1e-7)
    # multi-dim leaf whose last dim is no block multiple: both passes must
    # agree on one flattened block layout (regression)
    y = jax.random.normal(jax.random.fold_in(key, 9), (3, 1500))
    sent3, resid3 = comp.compress_dense(y)
    assert sent3.shape == y.shape
    np.testing.assert_array_equal(np.asarray(sent3) + np.asarray(resid3),
                                  np.asarray(y))


def test_support_mean_bitexact_at_full_support(key):
    """Satellite pin (DESIGN.md §13): when every participant ships every
    coordinate, the support count equals n_participants everywhere and the
    support-weighted mean IS the zero-averaging dense mean — the identical
    division on the identical operands, bit-exact."""
    from repro.fed.aggregate import (scatter_with_support,
                                     support_weighted_mean,
                                     zero_averaged_mean)
    N, L, d = 6, 3, 128
    vals = jax.random.normal(key, (N, L, d))      # nonzero a.s.
    idx = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), (N, L, d))
    weights = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    total, support = scatter_with_support(vals, idx, weights, L, d)
    n_part = jnp.sum(weights)
    np.testing.assert_array_equal(
        np.asarray(support), np.full((L, d), float(n_part), np.float32))
    np.testing.assert_array_equal(
        np.asarray(support_weighted_mean(total, support)),
        np.asarray(zero_averaged_mean(total, n_part)))
    # and with client-disjoint partial coverage they genuinely differ
    # (the zero-averaging defect exists): client i covers its own stripe,
    # so covered coordinates have support 1 or 2, never n_part
    k = d // 4
    pvals = vals[:, :, :k]
    pidx = (jnp.arange(k, dtype=jnp.int32)[None, None, :] * 4
            + jnp.arange(N, dtype=jnp.int32)[:, None, None] % 4)
    pidx = jnp.broadcast_to(pidx, (N, L, k))
    t2, s2 = scatter_with_support(pvals, pidx, weights, L, d)
    sup = np.asarray(support_weighted_mean(t2, s2))
    zav = np.asarray(zero_averaged_mean(t2, n_part))
    assert np.max(np.abs(sup - zav)) > 0.0


def test_cohort_support_equals_mean_at_budget(key):
    """End-to-end satellite pin: the cohort exchange at gamma=1.0 with
    32-bit values (every client sends every coordinate of every
    compressed leaf) produces bit-identical updates and EF memory under
    aggregation='support' and 'mean'."""
    from repro.fed.clients import cohort_compress_aggregate
    comp = Compressor(gamma=1.0, method="topk", min_compress_size=64,
                      use_kernel=False)
    C = 5
    grads = {"w": jax.random.normal(key, (C, 2, 256)),     # stacked lane
             "b": jax.random.normal(jax.random.fold_in(key, 1), (C, 40))}
    mem = jax.tree.map(jnp.zeros_like, grads)
    part = jnp.asarray([1, 0, 1, 1, 1], jnp.float32)
    out = {agg: cohort_compress_aggregate(
        grads, mem, jnp.float32(0.1), comp, None, part, aggregation=agg)
        for agg in ("support", "mean")}
    for a, b in zip(jax.tree.leaves(out["support"][:2]),
                    jax.tree.leaves(out["mean"][:2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(out["support"][2]) == float(out["mean"][2])  # wire


def test_contraction_gamma_metric(key):
    x = jax.random.normal(key, (2048,))
    comp = Compressor(gamma=0.1)
    sent, _ = comp.compress_dense(x)
    g = float(contraction_gamma(x, sent))
    assert g >= 0.1  # top-k keeps at least gamma of the energy
