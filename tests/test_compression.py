"""Unit tests for the top_k / block-local compression operators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Compressor, block_threshold, contraction_gamma,
                        sparse_to_dense, threshold_select, topk_select,
                        tree_wire_bytes)


def test_topk_selects_largest_magnitudes(key):
    x = jax.random.normal(key, (1000,))
    s = topk_select(x, 10)
    dense = sparse_to_dense(s)
    kept = np.sort(np.abs(np.asarray(x)))[-10:]
    np.testing.assert_allclose(np.sort(np.abs(np.asarray(s.values))), kept,
                               rtol=1e-6)
    # kept values preserved exactly (biased operator, eq. (3))
    nz = np.nonzero(np.asarray(dense))[0]
    assert len(nz) == 10
    np.testing.assert_array_equal(np.asarray(dense)[nz],
                                  np.asarray(x)[nz])


def test_topk_k_greater_than_d(key):
    x = jax.random.normal(key, (5,))
    s = topk_select(x, 10)
    np.testing.assert_array_equal(np.asarray(sparse_to_dense(s)),
                                  np.asarray(x))


def test_small_leaves_uncompressed(key):
    comp = Compressor(gamma=0.01)
    x = jax.random.normal(key, (999,))      # < MIN_COMPRESS_SIZE
    sent, resid = comp.compress_dense(x)
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(x))
    assert float(jnp.sum(jnp.abs(resid))) == 0.0


def test_compress_dense_identity(key):
    """sent + residual == input, exactly (EF bookkeeping)."""
    comp = Compressor(gamma=0.05)
    x = jax.random.normal(key, (4096,))
    sent, resid = comp.compress_dense(x)
    np.testing.assert_allclose(np.asarray(sent + resid), np.asarray(x),
                               atol=1e-7)
    assert int(jnp.sum(sent != 0)) == comp.k_for(4096)


@pytest.mark.parametrize("gamma", [0.01, 0.1, 0.5])
def test_contraction_lemma7(key, gamma):
    """||x - top_k(x)||^2 <= (1-gamma)||x||^2 (paper Lemma 7)."""
    comp = Compressor(gamma=gamma)
    for i in range(5):
        x = jax.random.normal(jax.random.fold_in(key, i), (2048,))
        sent, resid = comp.compress_dense(x)
        lhs = float(jnp.sum(resid ** 2))
        rhs = (1 - comp.k_for(2048) / 2048) * float(jnp.sum(x ** 2))
        assert lhs <= rhs + 1e-5


def test_block_threshold_keeps_about_gamma(key):
    x = jax.random.normal(key, (8192,))
    tau = block_threshold(x, gamma=0.05, block=512)
    kept = int(jnp.sum(jnp.abs(x) >= tau))
    assert 0.05 * 8192 * 0.5 <= kept <= 0.05 * 8192 * 2.5


def test_block_topk_sparse_wire(key):
    comp = Compressor(gamma=0.05, method="block_topk", block=256)
    x = jax.random.normal(key, (4096,))
    s = comp.compress_sparse(x)
    # fixed wire size: k_b per block
    assert s.values.size == (4096 // 256) * max(1, round(0.05 * 256))
    dense = sparse_to_dense(s)
    # selected entries preserved exactly
    nz = np.nonzero(np.asarray(dense))[0]
    np.testing.assert_array_equal(np.asarray(dense)[nz], np.asarray(x)[nz])


def test_wire_bytes_accounting():
    comp = Compressor(gamma=0.01)
    tree = {"a": jnp.zeros((100000,)), "b": jnp.zeros((500,))}
    b = tree_wire_bytes(tree, comp)
    assert b == 1000 * 8 + 500 * 4  # k*(val+idx) + dense small leaf


def test_contraction_gamma_metric(key):
    x = jax.random.normal(key, (2048,))
    comp = Compressor(gamma=0.1)
    sent, _ = comp.compress_dense(x)
    g = float(contraction_gamma(x, sent))
    assert g >= 0.1  # top-k keeps at least gamma of the energy
