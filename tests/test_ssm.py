"""SSM internals: chunked SSD scan vs sequential recurrence oracle; RWKV6
scan vs step-by-step decode; conv state continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm as ssm_mod
from repro.models import rwkv as rwkv_mod


def sequential_ssd(x, dt, A, Bm, Cm):
    """O(L) sequential oracle for the SSD recurrence."""
    Bb, L, H, P = x.shape
    N = Bm.shape[-1]
    S = np.zeros((Bb, H, P, N))
    ys = np.zeros((Bb, L, H, P))
    x, dt, A, Bm, Cm = map(np.asarray, (x, dt, A, Bm, Cm))
    for t in range(L):
        dA = np.exp(dt[:, t] * A)                        # (B, H)
        dx = dt[:, t][..., None] * x[:, t]               # (B, H, P)
        S = S * dA[..., None, None] + np.einsum("bn,bhp->bhpn", Bm[:, t], dx)
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], S)
    return ys, S


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_sequential(key, chunk):
    Bb, L, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (Bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bb, L, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (Bb, L, N)) * 0.5
    y, S = ssm_mod.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, S_ref = sequential_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-4)


def test_ssd_init_state_continuation(key):
    """Splitting a sequence in two with state carry == one pass."""
    Bb, L, H, P, N = 1, 16, 2, 4, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bb, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bb, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bb, L, N)) * 0.5
    y_full, S_full = ssm_mod.ssd_chunked(x, dt, A, Bm, Cm, 4)
    y1, S1 = ssm_mod.ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8],
                                 Cm[:, :8], 4)
    y2, S2 = ssm_mod.ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:],
                                 Cm[:, 8:], 4, init_state=S1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=1e-4)


def test_mamba_block_prefill_then_decode(key):
    cfg = get_smoke_config("zamba2-7b")
    p = ssm_mod.init_mamba2(key, cfg, jnp.float32)
    B, L = 2, 12
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, L, cfg.d_model))
    y_full, _ = ssm_mod.mamba2_block(p, x, cfg)
    # prefill on prefix, then decode the last token
    y_pre, st = ssm_mod.mamba2_block(p, x[:, :L - 1], cfg, return_state=True)
    y_dec, _ = ssm_mod.mamba2_decode(p, x[:, L - 1:], st, cfg)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, -1:]),
                               atol=1e-4)


def test_rwkv_scan_matches_stepwise(key):
    cfg = get_smoke_config("rwkv6-1.6b")
    p = rwkv_mod.init_rwkv6(key, cfg, jnp.float32)
    B, L = 2, 10
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, L, cfg.d_model))
    st0 = rwkv_mod.init_rwkv_state(cfg, B)
    y_full, _ = rwkv_mod.time_mix(p, x, cfg, st0)
    # token by token
    st = rwkv_mod.init_rwkv_state(cfg, B)
    outs = []
    for t in range(L):
        y, st = rwkv_mod.time_mix(p, x[:, t:t + 1], cfg, st)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4)


def test_rwkv_decay_in_unit_interval(key):
    """RWKV6 data-dependent decay must stay in (0, 1) for stability."""
    cfg = get_smoke_config("rwkv6-1.6b")
    p = rwkv_mod.init_rwkv6(key, cfg, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model)) * 3.0
    wdec = p["w_base"] + (jnp.tanh(x @ p["lora_A"]["w"])
                          @ p["lora_B"]["w"])
    w = jnp.exp(-jnp.exp(wdec))
    assert float(jnp.min(w)) > 0.0 and float(jnp.max(w)) < 1.0
