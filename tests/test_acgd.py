"""ACGD (core/acgd.py, arXiv 2002.11364 composed with EF): config
validation, single-step algebra, byte accounting, and the ISSUE 9 golden
convergence pairing vs the paper's scaled-step CSGD-ASSS.

Golden contract: same seeded interpolated quadratic, same compressor and
wire budget, Polyak tail average — ACGD's fixed-step Nesterov recursion
must land within the established 5% + noise-floor bound of the
Armijo-scaled run (``loss_a <= 1.05 * loss_c + 5e-4``, see
tests/test_gamma.py module docstring for the calibration of the absolute
term), and strictly beat its own momentum-free ablation so the
acceleration itself is pinned, not just the EF pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArmijoConfig, Compressor, CSGDConfig,
                        GammaControllerConfig, csgd_asss)
from repro.core.acgd import ACGD, AcgdConfig, AcgdState, acgd
from repro.data.synthetic import interpolated_regression

# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_config_validates_momentum_band():
    for bad in (-0.1, 1.0, 1.5):
        with pytest.raises(ValueError, match="momentum"):
            AcgdConfig(momentum=bad)
    # closed-left/open-right band: 0 (plain compressed GD) is legal
    assert AcgdConfig(momentum=0.0).momentum == 0.0
    assert AcgdConfig(momentum=0.99).momentum == 0.99


def test_config_rejects_armijo_coupled_schedule():
    with pytest.raises(ValueError, match="armijo-coupled"):
        AcgdConfig(gamma_ctrl=GammaControllerConfig(
            schedule="armijo-coupled"))
    # open-loop and telemetry-coupled schedules are fine
    AcgdConfig(compressor=Compressor(gamma=0.02, max_gamma=0.08),
               gamma_ctrl=GammaControllerConfig(schedule="ef-coupled"))


# ---------------------------------------------------------------------------
# single-step algebra
# ---------------------------------------------------------------------------


def _quad_loss(w):
    return 0.5 * jnp.sum(w ** 2)


def test_step_nesterov_and_ef_identity(key):
    """One jitted step reproduces the update equations exactly:
    v1 = mu*g, d1 = mu*v1 + g, sent + resid == eta*d1 (EF identity from a
    zero memory), params -= sent, velocity == v1."""
    cfg = AcgdConfig(compressor=Compressor(gamma=0.25, method="topk",
                                           min_compress_size=1),
                     eta=0.1, momentum=0.8)
    opt = ACGD(cfg)
    w0 = jax.random.normal(key, (64,))
    st = opt.init(w0)
    assert isinstance(st, AcgdState) and int(st.step) == 0
    w1, st1, aux = jax.jit(opt.step, static_argnums=0)(_quad_loss, w0, st)

    g = np.asarray(w0)                       # grad of 0.5||w||^2
    v1 = cfg.momentum * np.zeros_like(g) + g
    d1 = cfg.momentum * v1 + g
    acc = cfg.eta * d1
    sent = np.asarray(w0 - w1)               # applied update IS the wire
    resid = np.asarray(st1.memory)
    np.testing.assert_allclose(sent + resid, acc, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(st1.velocity), v1, rtol=1e-6)
    # top-k at gamma=0.25 really dropped coordinates into the memory
    assert np.count_nonzero(resid) == 48
    assert int(st1.step) == 1
    assert float(aux.loss) == pytest.approx(float(_quad_loss(w0)))


def test_bytes_accounting_static_and_adaptive(key):
    w0 = jax.random.normal(key, (1024,))
    static = AcgdConfig(compressor=Compressor(gamma=0.05,
                                              min_compress_size=1))
    opt = ACGD(static)
    _, st1, aux = opt.step(_quad_loss, w0, opt.init(w0))
    assert float(aux.eff_wire_bytes) == float(aux.wire_bytes)
    assert float(st1.cum_eff_bytes) == float(aux.eff_wire_bytes)

    adaptive = AcgdConfig(
        compressor=Compressor(gamma=0.01, max_gamma=0.05,
                              min_compress_size=1),
        gamma_ctrl=GammaControllerConfig(schedule="fixed", gamma0=0.01))
    opt = ACGD(adaptive)
    _, st1, aux = opt.step(_quad_loss, w0, opt.init(w0))
    # ragged counts at gamma0 < max_gamma: strictly under the static budget
    assert float(aux.eff_wire_bytes) < float(aux.wire_bytes)
    _, st2, aux2 = opt.step(_quad_loss, w0, st1)
    assert float(st2.cum_eff_bytes) == pytest.approx(
        float(aux.eff_wire_bytes) + float(aux2.eff_wire_bytes))


# ---------------------------------------------------------------------------
# golden convergence pairing vs scaled-step CSGD (fixed seeds)
# ---------------------------------------------------------------------------

SEED = 0
D = 256
N = 512
STEPS = 900
BATCH = 32
GAMMA = 0.04
ETA = 0.02
MU = 0.5


def _run(opt, steps=STEPS, tail=400):
    A, b, _ = interpolated_regression(N, D, feature_std=1.0, seed=SEED)

    def bl(w, idx):
        r = A[idx] @ w - b[idx]
        return jnp.mean(r ** 2)

    @jax.jit
    def full_loss(w):
        return jnp.mean((A @ w - b) ** 2)

    @jax.jit
    def step(w, s, idx):
        return opt.step(lambda ww: bl(ww, idx), w, s)

    w = jnp.zeros(D)
    st = opt.init(w)
    rng = np.random.default_rng(SEED)
    wbar = np.zeros(D)
    navg = 0
    for t in range(steps):
        idx = jnp.asarray(rng.integers(0, N, BATCH))
        w, st, aux = step(w, st, idx)
        if t >= steps - tail:            # Polyak tail average
            wbar += np.asarray(w)
            navg += 1
    return float(full_loss(jnp.asarray(wbar / navg))), \
        float(aux.cum_eff_bytes)


def test_golden_acgd_vs_scaled_step_csgd():
    comp = Compressor(gamma=GAMMA, min_compress_size=1)
    loss_c, bytes_c = _run(csgd_asss(CSGDConfig(
        armijo=ArmijoConfig(sigma=0.1, a_scale=0.3), compressor=comp)))
    loss_a, bytes_a = _run(acgd(AcgdConfig(
        compressor=comp, eta=ETA, momentum=MU)))

    # both reach the interpolation floor at all
    assert np.isfinite(loss_c) and loss_c < 1e-3, loss_c
    assert np.isfinite(loss_a) and loss_a < 1e-3, loss_a
    # the ISSUE 9 acceptance contract
    assert loss_a <= 1.05 * loss_c + 5e-4, (loss_a, loss_c)
    # identical compressor + fixed gamma -> identical wire budget: the
    # pairing compares convergence at EQUAL communication
    assert bytes_a == pytest.approx(bytes_c)


def test_golden_momentum_ablation():
    """Same eta, mu=0 (plain fixed-step compressed GD with EF): the
    Nesterov recursion must strictly improve the tail loss — pins the
    acceleration itself, not just the shared EF pipeline."""
    comp = Compressor(gamma=GAMMA, min_compress_size=1)
    loss_acc, _ = _run(acgd(AcgdConfig(compressor=comp, eta=ETA,
                                       momentum=MU)))
    loss_plain, _ = _run(acgd(AcgdConfig(compressor=comp, eta=ETA,
                                         momentum=0.0)))
    assert np.isfinite(loss_plain), loss_plain
    assert loss_acc < loss_plain, (loss_acc, loss_plain)
