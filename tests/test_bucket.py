"""Unit tests for the bucketed payload transport (DESIGN.md §11): plan
building, stream pack/unpack reflow, bucket encode/decode bit-parity with
the per-leaf codec, the bucket accounting contract, the `_scatter_layers`
arities, and 1-device transport parity of worker_compress_aggregate."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.comm import bucket as bucket_mod
from repro.comm import wire as wire_fmt
from repro.comm.bucket import (build_bucket_plan, decode_buckets,
                               encode_buckets)
from repro.comm.exchange import check_bucket_payload
from repro.core import Compressor
from repro.core.compression import block_extract_sparse, tree_wire_bytes
from repro.core.dcsgd import (_per_layer_topk, _scatter_layers,
                              worker_compress_aggregate)
from repro.kernels import ops


# ---------------------------------------------------------------------------
# plan building
# ---------------------------------------------------------------------------

def _shapes_stacked(tree):
    leaves = jax.tree.leaves(tree)
    return [x.shape for x in leaves], [x.ndim >= 2 for x in leaves]


def test_plan_groups_and_offsets():
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    shapes = [(3, 2048), (3000,), (50,), (2, 4, 300)]
    stacked = [True, False, False, True]
    plan = build_bucket_plan(shapes, stacked, comp)
    assert plan.dense_ids == (2,)                  # 50 < min_compress_size
    assert plan.compressed_ids == (0, 1, 3)
    # block_topk: every compressed leaf shares 16-bit local indices
    assert len(plan.buckets) == 1
    assert plan.buckets[0].index_bits == 16
    assert plan.buckets[0].leaf_ids == (0, 1, 3)
    assert plan.n_gathers == 1
    # the offset table is the in-order concatenation of exact payloads
    off = 0
    for ln in plan.leaves:
        if ln.dense:
            assert ln.words == 0
            continue
        assert ln.word_off == off
        assert ln.words == ln.L * ln.spec.row_words
        off += ln.words
    assert plan.total_words == off
    # ... and its byte total IS the per-leaf accounting
    tree = [jnp.zeros(s) for s in shapes]
    assert plan.total_words * 4 + 50 * 4 == tree_wire_bytes(tree, comp)


def test_plan_two_buckets_max():
    """Mixed 16/32-bit index layouts (flat topk straddling 2^16) make
    exactly two buckets — the only layout split a single Compressor can
    produce."""
    comp = Compressor(gamma=0.01, method="topk", min_compress_size=64)
    shapes = [(3000,), (70000,), (2048,), (100000,)]
    plan = build_bucket_plan(shapes, [False] * 4, comp)
    assert len(plan.buckets) == 2
    bits = {b.index_bits: b.leaf_ids for b in plan.buckets}
    assert bits[16] == (0, 2) and bits[32] == (1, 3)
    assert plan.n_gathers == 1                    # still ONE collective


def test_plan_geometry_matches_leaf_2d():
    assert bucket_mod.plan_geometry((3, 4, 5), True) == (3, 20)
    assert bucket_mod.plan_geometry((3, 4, 5), False) == (1, 60)
    assert bucket_mod.plan_geometry((7,), False) == (1, 7)
    assert bucket_mod.plan_geometry((7,), True) == (1, 7)


def test_plan_all_dense_has_no_gather():
    plan = build_bucket_plan([(10,), (20,)], [False, False],
                             Compressor(method="none"))
    assert plan.n_gathers == 0 and plan.total_words == 0
    assert plan.buckets == ()


# ---------------------------------------------------------------------------
# stream pack/unpack reflow (bucket-shaped launches)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8, 16, 32])
@pytest.mark.parametrize("n_words", [1, 7, 511, 512, 513, 2000])
def test_stream_pack_matches_rowwise(bits, n_words):
    """pack_fields_stream == row-by-row pack_fields on any word-aligned
    row structure (packing is word-local), across the WORD_CHUNK reflow
    boundary."""
    F = max(1, 32 // bits)
    rng = np.random.default_rng(bits * 10000 + n_words)
    fields = jnp.asarray(rng.integers(0, 1 << min(bits, 31),
                                      (n_words * F,), dtype=np.uint32))
    stream = ops.pack_fields_stream(fields, bits)
    assert stream.shape == (n_words,)
    # rows of 1 word each is the finest row structure
    rows = ops.pack_fields(fields.reshape(n_words, F), bits)
    np.testing.assert_array_equal(np.asarray(stream),
                                  np.asarray(rows).reshape(-1))
    back = ops.unpack_fields_stream(stream, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(fields))


def test_stream_pack_rejects_unaligned():
    with pytest.raises(ValueError, match="word-aligned"):
        ops.pack_fields_stream(jnp.zeros((3,), jnp.uint32), 16)


# ---------------------------------------------------------------------------
# bucket codec == per-leaf codec, bit for bit
# ---------------------------------------------------------------------------

def _leaf_rows(x, comp):
    """Per-leaf (vals, idx, spec) at the static budget, as dcsgd does."""
    if comp.method == "block_topk":
        vals, idx = block_extract_sparse(x, comp)
    else:
        vals, idx = _per_layer_topk(x, comp.k_for(x.shape[-1]))
    return vals, idx, wire_fmt.WireSpec.for_row(comp, x.shape[-1])


@pytest.mark.parametrize("method,value_bits", [
    ("block_topk", 4), ("block_topk", 8), ("block_topk", 32),
    ("topk", 16), ("topk", 32),
])
def test_bucket_encode_decode_equals_perleaf_codec(key, method, value_bits):
    """encode_buckets is the in-order concatenation of the EXACT per-leaf
    encode_rows payloads (no padding on the wire), and decode_buckets of a
    stacked 2-worker gather returns per-leaf arrays bit-identical to
    per-leaf decode_rows."""
    comp = Compressor(gamma=0.05, method=method, block=256,
                      min_compress_size=64, value_bits=value_bits)
    ks = jax.random.split(key, 3)
    leaves = [jax.random.normal(ks[0], (3, 1300)),
              jax.random.normal(ks[1], (1, 2048)),
              jax.random.normal(ks[2], (2, 70000) if method == "topk"
                                else (2, 4097))]
    plan = build_bucket_plan([x.shape for x in leaves], [True] * 3, comp)
    assert plan.dense_ids == ()
    rows = []
    perleaf = []
    for x in leaves:
        vals, idx, spec = _leaf_rows(x, comp)
        rows.append((vals, idx, None))
        perleaf.append((wire_fmt.encode_rows(vals, idx, spec), spec))
    payload = encode_buckets(plan, rows)
    check_bucket_payload(payload, plan, comp)
    np.testing.assert_array_equal(
        np.asarray(payload),
        np.concatenate([np.asarray(p).reshape(-1) for p, _ in perleaf]))

    # two "workers": this payload and a bit-twiddled sibling
    other = payload ^ jnp.uint32(0)
    gathered = jnp.stack([payload, other])
    decoded = decode_buckets(plan, gathered)
    for ln, (pay, spec) in zip(plan.leaves, perleaf):
        v_ref, i_ref = wire_fmt.decode_rows(pay, spec)
        v2, i2 = decoded[ln.index]
        assert v2.shape == (2, ln.L, spec.k)
        for w in range(2):
            np.testing.assert_array_equal(np.asarray(v2[w]),
                                          np.asarray(v_ref))
            np.testing.assert_array_equal(np.asarray(i2[w]),
                                          np.asarray(i_ref))


@pytest.mark.parametrize("value_bits", [4, 8, 16, 32])
def test_bucket_ragged_counts_roundtrip(key, value_bits):
    """Ragged buckets: per-leaf counts ride the header, the bucket codec
    masks exactly what per-leaf encode_rows/decode_rows mask."""
    comp = Compressor(gamma=0.05, max_gamma=0.05, method="block_topk",
                      block=256, min_compress_size=64,
                      value_bits=value_bits)
    ks = jax.random.split(key, 2)
    leaves = [jax.random.normal(ks[0], (3, 1300)),
              jax.random.normal(ks[1], (2, 2048))]
    plan = build_bucket_plan([x.shape for x in leaves], [True] * 2, comp)
    rng = np.random.default_rng(value_bits)
    rows, perleaf = [], []
    for x in leaves:
        vals, idx, spec = _leaf_rows(x, comp)
        counts = jnp.asarray(
            rng.integers(1, spec.full_count + 1, x.shape[0]), jnp.int32)
        rows.append((vals, idx, counts))
        perleaf.append((wire_fmt.encode_rows(vals, idx, spec,
                                             counts=counts), spec))
    payload = encode_buckets(plan, rows)
    np.testing.assert_array_equal(
        np.asarray(payload),
        np.concatenate([np.asarray(p).reshape(-1) for p, _ in perleaf]))
    decoded = decode_buckets(plan, payload[None])
    for ln, (pay, spec) in zip(plan.leaves, perleaf):
        v_ref, i_ref = wire_fmt.decode_rows(pay, spec)
        v2, i2 = decoded[ln.index]
        np.testing.assert_array_equal(np.asarray(v2[0]), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(i2[0]), np.asarray(i_ref))


def test_check_bucket_payload_catches_drift():
    comp = Compressor(gamma=0.05, method="block_topk", block=256,
                      min_compress_size=64)
    plan = build_bucket_plan([(3, 1300)], [True], comp)
    good = jnp.zeros((plan.total_words,), jnp.uint32)
    check_bucket_payload(good, plan, comp)
    with pytest.raises(ValueError, match="uint32"):
        check_bucket_payload(good.astype(jnp.int32), plan, comp)
    with pytest.raises(ValueError, match="plan says"):
        check_bucket_payload(jnp.zeros((plan.total_words + 1,),
                                       jnp.uint32), plan, comp)
    # accounting drift: a compressor whose wire_bytes disagrees with the
    # planned spec (different value width) must fail at trace time
    other = Compressor(gamma=0.05, method="block_topk", block=256,
                       min_compress_size=64, value_bits=8)
    with pytest.raises(ValueError, match="drift"):
        check_bucket_payload(good, plan, other)


# ---------------------------------------------------------------------------
# _scatter_layers arities (ISSUE 5 satellite: the 2-D pre-normalization
# was a no-op and the ndim handling duplicated)
# ---------------------------------------------------------------------------

def test_scatter_layers_2d():
    vals = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])          # (L=2, k=2)
    idx = jnp.asarray([[0, 3], [1, 1]], jnp.int32)
    out = _scatter_layers(vals, idx, 2, 4, jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray([[1.0, 0.0, 0.0, 2.0], [0.0, 7.0, 0.0, 0.0]]))


def test_scatter_layers_3d_sums_workers():
    vals = jnp.asarray([[[1.0, 2.0]], [[10.0, 20.0]]])    # (W=2, L=1, k=2)
    idx = jnp.asarray([[[0, 2]], [[2, 3]]], jnp.int32)
    out = _scatter_layers(vals, idx, 1, 4, jnp.float32)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray([[1.0, 0.0, 12.0, 20.0]]))


def test_scatter_layers_rejects_bad_rank():
    with pytest.raises(ValueError, match="expected"):
        _scatter_layers(jnp.zeros((4,)), jnp.zeros((4,), jnp.int32), 1, 8,
                        jnp.float32)


def test_scatter_layers_arities_agree(key):
    """(L, k) == (1, L, k)-with-W=1 and (W, L, k) == sum of per-worker
    (L, k) scatters."""
    vals = jax.random.normal(key, (3, 2, 7))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (3, 2, 7), 0, 32)
    tri = _scatter_layers(vals, idx, 2, 32, jnp.float32)
    acc = sum(_scatter_layers(vals[w], idx[w], 2, 32, jnp.float32)
              for w in range(3))
    np.testing.assert_allclose(np.asarray(tri), np.asarray(acc),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# transport parity of worker_compress_aggregate (1 device)
# ---------------------------------------------------------------------------

def _run_worker(tree, comp, transport, gamma_t=None, eta=0.7):
    from repro.compat import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    mem = jax.tree.map(lambda x: jnp.full_like(x, 0.05), tree)
    spec = jax.tree.map(lambda _: P(), tree)
    f = shard_map(
        functools.partial(worker_compress_aggregate, comp=comp,
                          dp_axes=("data",), gamma_t=gamma_t,
                          transport=transport),
        mesh=mesh, in_specs=(spec, spec, P()),
        out_specs=(spec, spec, P(), P(), P()), axis_names={"data"})
    return jax.jit(f)(tree, mem, jnp.float32(eta))


def _mixed_tree(key):
    ks = jax.random.split(key, 5)
    return {
        "a": jax.random.normal(ks[0], (3, 2048)),
        "b": jax.random.normal(ks[1], (3000,)),
        "tiny": jax.random.normal(ks[2], (50,)),          # dense pmean
        "c": jax.random.normal(ks[3], (2, 4, 300)),
        "big": jax.random.normal(ks[4], (70000,)),        # 32-bit (topk)
    }


@pytest.mark.parametrize("comp,gamma_t", [
    (Compressor(gamma=0.05, method="block_topk", block=512,
                min_compress_size=64, value_bits=8), None),
    (Compressor(gamma=0.05, method="block_topk", block=512,
                min_compress_size=64, value_bits=8, use_kernel=False),
     None),
    (Compressor(gamma=0.05, method="topk", min_compress_size=64,
                value_bits=16), None),
    (Compressor(gamma=0.05, max_gamma=0.05, method="block_topk", block=512,
                min_compress_size=64, value_bits=4), 0.02),
    (Compressor(gamma=0.05, max_gamma=0.05, method="topk",
                min_compress_size=64, value_bits=32), 0.013),
    (Compressor(method="none"), None),
])
def test_transport_parity_bit_exact(key, comp, gamma_t):
    """Bucketed == per-leaf: updates, new memory, and wire/effective
    bytes bit for bit; telemetry to <= 8 ulp (its f32 reduction order is
    not pinned across the two XLA programs — DESIGN.md §11)."""
    tree = _mixed_tree(key)
    gt = None if gamma_t is None else jnp.float32(gamma_t)
    ref = _run_worker(tree, comp, "perleaf", gt)
    got = _run_worker(tree, comp, "bucketed", gt)
    for name, a, b in zip(("updates", "memory", "wire", "eff", "tel"),
                          ref, got):
        for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            if name == "tel":
                np.testing.assert_array_max_ulp(np.asarray(u),
                                                np.asarray(v), maxulp=8)
            else:
                np.testing.assert_array_equal(np.asarray(u),
                                              np.asarray(v), err_msg=name)


def test_dense_byte_accounting_unified(key):
    """One accounting basis for every dense-shipping path (ISSUE 9 bugfix):
    byte counters charge ``size * itemsize`` of the f32 buffer the pmean
    actually moves — for ``dense_aggregate`` (which used to hard-code
    4 bytes/element) and for the transports' dense leaves alike, so the
    downlink's up/down byte split cannot drift between the two."""
    from repro.compat import shard_map
    from repro.core.dcsgd import dense_aggregate
    tree = {
        "w": jax.random.normal(key, (2, 128)).astype(jnp.bfloat16),
        "t": jnp.ones((50,), jnp.float16),
    }
    f32 = jnp.dtype(jnp.float32).itemsize
    n_elem = sum(x.size for x in jax.tree.leaves(tree))
    expect = float(n_elem * f32)
    # the inputs are half-width on purpose: the charged basis must be the
    # shipped f32 accumulate, NOT the input-grad itemsize
    assert expect != sum(x.size * x.dtype.itemsize
                         for x in jax.tree.leaves(tree))

    mesh = jax.make_mesh((1,), ("data",))
    spec = jax.tree.map(lambda _: P(), tree)
    upd, wire = jax.jit(shard_map(
        lambda g: dense_aggregate(g, jnp.float32(0.1), ("data",)),
        mesh=mesh, in_specs=(spec,), out_specs=(spec, P()),
        axis_names={"data"}))(tree)
    assert all(u.dtype == jnp.float32 for u in jax.tree.leaves(upd))
    assert float(wire) == expect

    # transports: min_compress_size above every leaf size ships all leaves
    # dense through the pmean branch — same basis for wire AND effective
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=10**6, value_bits=8)
    tree32 = jax.tree.map(lambda x: x.astype(jnp.float32), tree)
    for transport in ("perleaf", "bucketed"):
        _, _, wire_t, eff_t, _ = _run_worker(tree32, comp, transport)
        assert float(wire_t) == expect, transport
        assert float(eff_t) == expect, transport


def test_transport_rejects_unknown():
    tree = {"v": jnp.zeros((3000,))}
    with pytest.raises(ValueError, match="transport"):
        _run_worker(tree, Compressor(gamma=0.05, min_compress_size=64),
                    "carrier-pigeon")
