"""Kernel micro-benchmarks: jnp reference path timings on CPU, plus
ref-vs-fused comparisons for the EF-compression two-pass hot loop.

NOTE: off-TPU the Pallas kernels run in interpret mode; for the model-side
ops (attention/wkv — per-tile Python stepping) interpret timings are not
meaningful for TPU projection, so those time the jnp reference path only
(numerics are verified in tests/test_kernels.py).  The EF kernels evaluate
one vectorized tile per grid step, so their interpret timings are reported
side-by-side with the ref path — on TPU the fused path is the default
(kernels/dispatch.py) and saves one full accumulator round-trip through
HBM (2 reads + 2 writes vs 3+ reads of a naive composition).
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import emit

# Representative per-layer gradient shapes from the production configs
# (qwen1.5-4b attention qkv, its MLP hidden, granite-moe expert slab).
EF_LAYER_SHAPES = [
    ("attn_qkv_2.5kx2.5k", (2560, 2560)),
    ("mlp_2.5kx6.9k", (2560, 6912)),
    ("moe_expert_8x1kx2k", (8, 1024 * 2048)),
]


def timeit(f, *args, n=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.time()
    for _ in range(n):
        r = f(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.time() - t0) / n * 1e6


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    m = jax.random.normal(key, (1 << 20,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (1 << 20,))
    f_ef = jax.jit(lambda m, g: ops.ef_threshold_update(m, g, 0.1, 0.3))
    us = timeit(f_ef, m, g)
    emit("kernel_ef_update_1M_ref", us, "fused EF accumulate+sparsify")
    out["ef"] = us

    B, H, S, D = 1, 8, 1024, 128
    q = jax.random.normal(key, (B, H, S, D)) * 0.1
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, D))
    f_at = jax.jit(lambda q, k, v: ops.attention(q, k, v))
    us = timeit(f_at, q, k, v, n=5)
    emit("kernel_attention_1k_ref", us, "causal MHA 8hx1024x128")
    out["attn"] = us

    x = jax.random.normal(key, (4096, 2048))
    w = jnp.ones((2048,))
    f_rn = jax.jit(lambda x, w: ops.rms_norm(x, w))
    us = timeit(f_rn, x, w)
    emit("kernel_rmsnorm_4kx2k_ref", us, "fused rmsnorm")
    out["rmsnorm"] = us

    # ---- wire pack/unpack: ref vs pallas on a production payload shape ----
    # qwen1.5-4b MLP leaf at gamma=1%, value_bits=8: 2560 layer rows of
    # k=70 entries each -> 16-bit block-local indices + 8-bit values.
    from repro.kernels import ops as _ops
    R, k = 2560, 70
    fields16 = jax.random.randint(key, (R, k), 0, 1 << 16).astype(jnp.uint32)
    for bits in (8, 16):
        nwords = -(-k * bits // 32)
        words = jax.random.randint(jax.random.fold_in(key, bits),
                                   (R, nwords), 0, 1 << 30).astype(jnp.uint32)
        row = {}
        for impl in ("ref", "pallas"):
            f_p = jax.jit(lambda f, impl=impl, bits=bits:
                          _ops.pack_fields(f, bits, impl=impl))
            f_u = jax.jit(lambda w, impl=impl, bits=bits:
                          _ops.unpack_fields(w, k, bits, impl=impl))
            us_p = timeit(f_p, fields16)
            us_u = timeit(f_u, words)
            emit(f"kernel_wire_pack{bits}_{impl}", us_p,
                 f"bit-pack {R}x{k} {bits}b fields")
            emit(f"kernel_wire_unpack{bits}_{impl}", us_u,
                 f"bit-unpack {R}x{k} {bits}b fields")
            row[impl] = us_p + us_u
        row["ratio_ref_over_fused"] = row["ref"] / max(row["pallas"], 1e-9)
        out[f"wire_pack{bits}"] = row

    # ---- ref vs fused EF two-pass compression on paper layer shapes ----
    for si, (name, shape) in enumerate(EF_LAYER_SHAPES):
        m = jax.random.normal(key, shape)
        g = jax.random.normal(jax.random.fold_in(key, 100 + si), shape)
        row = {}
        for impl in ("ref", "pallas"):
            f = jax.jit(lambda m, g, impl=impl: ops.fused_ef_compress(
                m, g, 0.1, gamma=0.01, impl=impl))
            us = timeit(f, m, g, n=10)
            emit(f"kernel_ef2pass_{name}_{impl}", us,
                 f"fused two-pass EF, {m.size} elems")
            row[impl] = us
        row["ratio_ref_over_fused"] = row["ref"] / max(row["pallas"], 1e-9)
        out[f"ef2pass_{name}"] = row
    return out


if __name__ == "__main__":
    main()
