"""Kernel micro-benchmarks: jnp reference path timings on CPU, plus
ref-vs-fused comparisons for the EF-compression two-pass hot loop.

NOTE: off-TPU the Pallas kernels run in interpret mode; for the model-side
ops (attention/wkv — per-tile Python stepping) interpret timings are not
meaningful for TPU projection, so those time the jnp reference path only
(numerics are verified in tests/test_kernels.py).  The EF kernels evaluate
one vectorized tile per grid step, so their interpret timings are reported
side-by-side with the ref path — on TPU the fused path is the default
(kernels/dispatch.py) and saves one full accumulator round-trip through
HBM (2 reads + 2 writes vs 3+ reads of a naive composition).

Besides the CSV rows on stdout, a machine-readable ``BENCH_kernels.json``
is written at the repo root — one record per (op, backend, shape) with the
median per-call milliseconds — so the perf trajectory is diffable across
PRs.  ``--smoke`` shrinks shapes/iterations to a seconds-scale run (the CI
invocation); ``--out`` overrides the JSON path.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import emit

# Representative per-layer gradient shapes from the production configs
# (qwen1.5-4b attention qkv, its MLP hidden, granite-moe expert slab).
EF_LAYER_SHAPES = [
    ("attn_qkv_2.5kx2.5k", (2560, 2560)),
    ("mlp_2.5kx6.9k", (2560, 6912)),
    ("moe_expert_8x1kx2k", (8, 1024 * 2048)),
]
EF_LAYER_SHAPES_SMOKE = [
    ("attn_qkv_256x256", (256, 256)),
    ("mlp_256x688", (256, 688)),
]

_RECORDS: list[dict] = []


def timeit(f, *args, n=20):
    """(median, min) per-call microseconds over n timed calls (1 warm-up).

    Both statistics of the SAME window travel together into ``record`` —
    bench_diff compares min_ms across runs because load bursts on shared
    runners inflate a whole median window but rarely every single call.
    """
    jax.block_until_ready(f(*args))
    times = []
    for _ in range(n):
        t0 = time.time()
        jax.block_until_ready(f(*args))
        times.append(time.time() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, times[0] * 1e6


def record(op: str, backend: str, shape, us, note: str = "",
           min_us: float | None = None):
    """One BENCH_kernels.json record + the repo's CSV contract line.
    ``us`` is a (median, min) pair from :func:`timeit`, or a bare median
    with an explicit ``min_us`` (see timeit for why bench_diff keys off
    the window minimum)."""
    if isinstance(us, tuple):
        us, min_us = us
    if min_us is None:
        raise ValueError(f"record({op!r}): need a (median, min) timeit "
                         f"pair or an explicit min_us")
    _RECORDS.append({"op": op, "backend": backend,
                     "shape": list(shape) if not isinstance(shape, str)
                     else shape,
                     "median_ms": round(us / 1e3, 6),
                     "min_ms": round(min_us / 1e3, 6)})
    emit(f"kernel_{op}_{backend}", us, note or op)


def paired_ratio(f_num, f_den, args, n_pairs=12, repeats=3):
    """Robust wall-time ratio f_num/f_den: per-pair ratios of ADJACENT
    single calls (machine drift hits both sides of a pair equally), median
    per repeat, min over repeats (noise only inflates).  This is how the
    telemetry-fused EF op's "same streaming pass" claim is certified — two
    independently-timed medians are far too noisy on shared CI runners."""
    for f in (f_den, f_num):
        jax.block_until_ready(f(*args))
    meds = []
    for _ in range(repeats):
        ratios = []
        for _ in range(n_pairs):
            t0 = time.perf_counter()
            jax.block_until_ready(f_den(*args))
            td = time.perf_counter() - t0
            t0 = time.perf_counter()
            jax.block_until_ready(f_num(*args))
            ratios.append((time.perf_counter() - t0) / max(td, 1e-9))
        ratios.sort()
        meds.append(ratios[len(ratios) // 2])
    return min(meds)


def main(smoke: bool = False, out_path: str | None = None) -> dict:
    key = jax.random.PRNGKey(0)
    out = {}
    # smoke shapes are tiny, so more reps cost little and the medians are
    # stable enough for the bench-diff CI gate (benchmarks/bench_diff.py)
    n_heavy = 7 if smoke else 10
    n_light = 15 if smoke else 20

    ef_n = (1 << 14) if smoke else (1 << 20)
    m = jax.random.normal(key, (ef_n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (ef_n,))
    f_ef = jax.jit(lambda m, g: ops.ef_threshold_update(m, g, 0.1, 0.3))
    us = timeit(f_ef, m, g, n=n_light)
    record("ef_update", "default", (ef_n,), us,
           "fused EF accumulate+sparsify")
    out["ef"] = us[0]

    B, H, S, D = (1, 2, 128, 64) if smoke else (1, 8, 1024, 128)
    q = jax.random.normal(key, (B, H, S, D)) * 0.1
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, D))
    f_at = jax.jit(lambda q, k, v: ops.attention(q, k, v))
    us = timeit(f_at, q, k, v, n=n_heavy)
    record("attention", "ref", (B, H, S, D), us,
           f"causal MHA {H}hx{S}x{D}")
    out["attn"] = us[0]

    R_rn = 256 if smoke else 4096
    x = jax.random.normal(key, (R_rn, 2048))
    w = jnp.ones((2048,))
    f_rn = jax.jit(lambda x, w: ops.rms_norm(x, w))
    us = timeit(f_rn, x, w, n=n_light)
    record("rmsnorm", "ref", (R_rn, 2048), us, "fused rmsnorm")
    out["rmsnorm"] = us[0]

    # ---- wire pack/unpack: ref vs pallas on a production payload shape ----
    # qwen1.5-4b MLP leaf at gamma=1%, value_bits=8: 2560 layer rows of
    # k=70 entries each -> 16-bit block-local indices + 8-bit values.
    R, kk = (256, 70) if smoke else (2560, 70)
    fields16 = jax.random.randint(key, (R, kk), 0, 1 << 16) \
        .astype(jnp.uint32)
    for bits in (8, 16):
        nwords = -(-kk * bits // 32)
        words = jax.random.randint(jax.random.fold_in(key, bits),
                                   (R, nwords), 0, 1 << 30) \
            .astype(jnp.uint32)
        row = {}
        for impl in ("ref", "pallas"):
            f_p = jax.jit(lambda f, impl=impl, bits=bits:
                          ops.pack_fields(f, bits, impl=impl))
            f_u = jax.jit(lambda w, impl=impl, bits=bits:
                          ops.unpack_fields(w, kk, bits, impl=impl))
            us_p = timeit(f_p, fields16, n=n_light)
            us_u = timeit(f_u, words, n=n_light)
            record(f"wire_pack{bits}", impl, (R, kk), us_p,
                   f"bit-pack {R}x{kk} {bits}b fields")
            record(f"wire_unpack{bits}", impl, (R, nwords), us_u,
                   f"bit-unpack {R}x{kk} {bits}b fields")
            row[impl] = us_p[0] + us_u[0]
        row["ratio_ref_over_fused"] = row["ref"] / max(row["pallas"], 1e-9)
        out[f"wire_pack{bits}"] = row

    # ragged variant: counts-aware pack (valid-count masking on the same
    # streaming pass, DESIGN.md §9) vs the plain kernel
    counts = jax.random.randint(jax.random.fold_in(key, 77), (R,), 1, kk) \
        .astype(jnp.int32)
    for impl in ("ref", "pallas"):
        f_r = jax.jit(lambda f, c, impl=impl: ops.pack_fields(
            f, 8, counts=c, period=kk, impl=impl))
        us_r = timeit(f_r, fields16, counts, n=n_light)
        record("wire_pack8_ragged", impl, (R, kk), us_r,
               f"counts-masked bit-pack {R}x{kk} 8b fields")

    # ---- ref vs fused EF two-pass compression on paper layer shapes ----
    shapes = EF_LAYER_SHAPES_SMOKE if smoke else EF_LAYER_SHAPES
    for si, (name, shape) in enumerate(shapes):
        m = jax.random.normal(key, shape)
        g = jax.random.normal(jax.random.fold_in(key, 100 + si), shape)
        row = {}
        for impl in ("ref", "pallas"):
            f = jax.jit(lambda m, g, impl=impl: ops.fused_ef_compress(
                m, g, 0.1, gamma=0.01, impl=impl))
            us = timeit(f, m, g, n=n_heavy)
            record(f"ef2pass_{name}", impl, shape, us,
                   f"fused two-pass EF, {m.size} elems")
            row[impl] = us[0]
        row["ratio_ref_over_fused"] = row["ref"] / max(row["pallas"], 1e-9)
        out[f"ef2pass_{name}"] = row

        # telemetry-enabled pass 1 (DESIGN.md §10): the moments ride the
        # same streamed tile, so this op must track ef2pass_* within the
        # "fused telemetry" budget.  The certificate is the PAIRED ratio
        # record (ef2pass_tel_ratio_*, dimensionless, stored in the
        # median_ms field) — bench_diff gates it at <= 1.10x; the tel
        # median itself is recorded for the cross-run trajectory.
        f_t = jax.jit(lambda m, g: ops.fused_ef_compress(
            m, g, 0.1, gamma=0.01, telemetry=True, impl="pallas"))
        f_p = jax.jit(lambda m, g: ops.fused_ef_compress(
            m, g, 0.1, gamma=0.01, impl="pallas"))
        us_t = timeit(f_t, m, g, n=n_heavy)
        record(f"ef2pass_tel_{name}", "pallas", shape, us_t,
               f"fused two-pass EF + telemetry moments, {m.size} elems")
        ratio = paired_ratio(f_t, f_p, (m, g))
        record(f"ef2pass_tel_ratio_{name}", "pallas", shape, ratio * 1e3,
               "paired tel/plain wall-time ratio (x1000, dimensionless)",
               min_us=ratio * 1e3)
        out[f"ef2pass_tel_{name}"] = {
            "pallas": us_t[0], "ratio_tel_over_plain": ratio}

    # ---- bucketed vs per-leaf transport on a multi-leaf pytree ----------
    # The bucketed exchange (DESIGN.md §11) trades per-leaf collectives and
    # launches for O(1) coalesced ones; on CPU (one XLA program, no real
    # launch overhead) the win is per-leaf op dispatch, so the honest
    # workload is leaf-HEAVY: the unstacked-transformer shape regime the
    # tentpole targets (dozens-to-hundreds of per-row leaves).  The PAIRED
    # ratio is hard-gated at 1.0x by bench_diff — bucketed must never be
    # slower than the per-leaf reference it replaced.
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.core import Compressor
    from repro.core.dcsgd import worker_compress_aggregate

    n_leaves = 64 if smoke else 96
    tree = {f"w{i}": jax.random.normal(jax.random.fold_in(key, 300 + i),
                                       (1024,)) for i in range(n_leaves)}
    tree["s0"] = jax.random.normal(jax.random.fold_in(key, 400), (2, 1024))
    tree["s1"] = jax.random.normal(jax.random.fold_in(key, 401), (2, 1024))
    tree["dense"] = jax.random.normal(jax.random.fold_in(key, 402), (50,))
    mem = jax.tree.map(jnp.zeros_like, tree)
    eta = jnp.float32(0.1)
    comp = Compressor(gamma=0.05, method="block_topk", block=512,
                      min_compress_size=64, value_bits=8)
    tname = f"{n_leaves + 3}leaves"

    def _make_step(transport, ctx=None):
        mesh = jax.make_mesh((1,), ("data",))
        pspec = jax.tree.map(lambda _: P(), tree)
        n_out = 6 if ctx is not None else 5
        return jax.jit(shard_map(
            functools.partial(worker_compress_aggregate, comp=comp,
                              dp_axes=("data",), transport=transport,
                              transport_ctx=ctx),
            mesh=mesh, in_specs=(pspec, pspec, P()),
            out_specs=(pspec, pspec) + (P(),) * (n_out - 2),
            axis_names={"data"}))

    f_bucketed = _make_step("bucketed")
    f_perleaf = _make_step("perleaf")
    for impl, f in (("bucketed", f_bucketed), ("perleaf", f_perleaf)):
        us = timeit(f, tree, mem, eta, n=n_heavy)
        record("exchange_step", impl, tname, us,
               f"worker_compress_aggregate, {n_leaves + 3} leaves")
    # deeper pairing than the tel records: the 1.0x gate has no slack, so
    # min-over-5-repeats keeps a transient load burst from failing CI
    ratio = paired_ratio(f_bucketed, f_perleaf, (tree, mem, eta),
                         n_pairs=16, repeats=5)
    record(f"bucketed_vs_perleaf_step_{tname}", "default", tname,
           ratio * 1e3,
           "paired bucketed/perleaf wall-time ratio (x1000, dimensionless)",
           min_us=ratio * 1e3)
    out["bucketed_vs_perleaf"] = ratio

    # guarded vs unguarded decode (DESIGN.md §16): the always-on verdict/
    # quarantine layer vs the same exchange traced with the guards
    # compiled out (``guards_disabled()`` is a trace-time switch, so the
    # unguarded arm must compile INSIDE the context).  Hard-gated at
    # 1.05x by bench_diff: the hostile-wire defenses must stay ~free on
    # the clean-wire fast path.
    from repro.comm import faults

    with faults.guards_disabled():
        f_unguarded = _make_step("bucketed")
        jax.block_until_ready(f_unguarded(tree, mem, eta))
    us = timeit(f_unguarded, tree, mem, eta, n=n_heavy)
    record("exchange_step", "unguarded", tname, us,
           f"worker_compress_aggregate, guards compiled out, "
           f"{n_leaves + 3} leaves")
    ratio = paired_ratio(f_bucketed, f_unguarded, (tree, mem, eta),
                         n_pairs=16, repeats=5)
    record(f"guarded_vs_unguarded_step_{tname}", "default", tname,
           ratio * 1e3,
           "paired guarded/unguarded wall-time ratio "
           "(x1000, dimensionless)",
           min_us=ratio * 1e3)
    out["guarded_vs_unguarded"] = ratio

    # gossip vs bucketed on the same pytree (DESIGN.md §12): the single-
    # worker ring(1) graph is degree 0, so this prices the serverless
    # path's fixed overhead — same selection/encode stage plus the
    # self-row decode/consensus arithmetic, no collectives on either
    # side.  Recorded (not gated): the trajectory keeps the overhead
    # honest without a brittle cross-transport threshold.
    from repro.comm.gossip import GossipConfig, GossipCtx, GossipState
    from repro.comm.topology import build_topology
    ctx = GossipCtx(topology=build_topology("ring", 1),
                    cfg=GossipConfig(), state=GossipState.init(()))
    f_gossip = _make_step("gossip", ctx=ctx)
    us = timeit(f_gossip, tree, mem, eta, n=n_heavy)
    record("exchange_step", "gossip", tname, us,
           f"gossip worker_compress_aggregate, {n_leaves + 3} leaves")
    ratio = paired_ratio(f_gossip, f_bucketed, (tree, mem, eta),
                         n_pairs=16, repeats=5)
    record(f"gossip_vs_bucketed_step_{tname}", "default", tname,
           ratio * 1e3,
           "paired gossip/bucketed wall-time ratio (x1000, dimensionless)",
           min_us=ratio * 1e3)
    out["gossip_vs_bucketed"] = ratio

    # overlap vs bucketed on the same pytree (DESIGN.md §14).  The GATED
    # pair runs the transport at delay=0: the chunked-ring schedule as a
    # bit-exact drop-in for the flat bucketed gather — the same "not
    # slower than the path it replaced" claim the bucketed/perleaf gate
    # makes, measurable on a 1-worker mesh where both sides do identical
    # codec work.  delay=1 (the overlapped mode) is timed as its own
    # informational record: its extra cost here is exactly the
    # launch-free EF roundtrip that keeps the residual current under
    # staleness, while the hiding it buys — the collective running
    # concurrently with compute — needs a real network; XLA's CPU runtime
    # serializes collectives, so a single-device wall clock cannot see
    # it.  The carried state rides as a traced argument so XLA cannot
    # constant-fold the stale decode away.
    from repro.comm.overlap import (OverlapConfig, OverlapCtx,
                                    init_overlap_state)

    flat = jax.tree.leaves(tree)
    st = init_overlap_state([x.shape for x in flat],
                            [x.ndim >= 2 for x in flat], comp)
    mesh1 = jax.make_mesh((1,), ("data",))
    pspec1 = jax.tree.map(lambda _: P(), tree)
    st_spec = jax.tree.map(lambda _: P(), st)

    def _make_overlap(ov_cfg):
        return jax.jit(shard_map(
            lambda g, m, e, s: worker_compress_aggregate(
                g, m, e, comp, ("data",), transport="overlap",
                transport_ctx=OverlapCtx(cfg=ov_cfg, state=s)),
            mesh=mesh1, in_specs=(pspec1, pspec1, P(), st_spec),
            out_specs=(pspec1, pspec1) + (P(),) * 3 + (st_spec,),
            axis_names={"data"}))

    f_stale = _make_overlap(OverlapConfig(n_chunks=2, delay=1))
    us = timeit(f_stale, tree, mem, eta, st, n=n_heavy)
    record("exchange_step", "overlap", tname, us,
           f"overlap worker_compress_aggregate (delay=1), "
           f"{n_leaves + 3} leaves")
    f_ring = _make_overlap(OverlapConfig(n_chunks=2, delay=0))
    ratio = paired_ratio(f_ring,
                         lambda g, m, e, s: f_bucketed(g, m, e),
                         (tree, mem, eta, st), n_pairs=16, repeats=5)
    record(f"bucketed_vs_overlap_step_{tname}", "default", tname,
           ratio * 1e3,
           "paired overlap(delay=0)/bucketed wall-time ratio "
           "(x1000, dimensionless)",
           min_us=ratio * 1e3)
    out["bucketed_vs_overlap"] = ratio

    # compressed downlink vs dense return (DESIGN.md §15): the same
    # bucketed exchange with the physically-simulated server bolted on —
    # one extra compress + launch-free wire roundtrip per compressed
    # leaf group, zero extra collectives (HLO-pinned in
    # tests/distributed/test_hlo_collectives.py).  The paired
    # dense_vs_downlink factor is informational in bench_diff: the
    # replicated recompute is the price of halving the accounted link
    # bytes, a design trade rather than a fusion claim.
    from repro.comm.downlink import (DownlinkCtx, DownlinkResult,
                                     DownlinkState, init_downlink_state)

    dls = init_downlink_state([x.shape for x in flat],
                              [x.ndim >= 2 for x in flat], comp,
                              comp.gamma)
    dl_spec = DownlinkState(memory=P(), gamma=P())
    f_downlink = jax.jit(shard_map(
        lambda g, m, e, s: worker_compress_aggregate(
            g, m, e, comp, ("data",),
            downlink_ctx=DownlinkCtx(state=s)),
        mesh=mesh1, in_specs=(pspec1, pspec1, P(), dl_spec),
        out_specs=(pspec1, pspec1) + (P(),) * 3
        + (DownlinkResult(dl_spec, P(), P()),),
        axis_names={"data"}))
    us = timeit(f_downlink, tree, mem, eta, dls, n=n_heavy)
    record("downlink_step", "compressed", tname, us,
           f"worker_compress_aggregate + server recompression, "
           f"{n_leaves + 3} leaves")
    ratio = paired_ratio(lambda g, m, e, s: f_downlink(g, m, e, s),
                         lambda g, m, e, s: f_bucketed(g, m, e),
                         (tree, mem, eta, dls), n_pairs=16, repeats=5)
    record(f"dense_vs_downlink_step_{tname}", "default", tname,
           ratio * 1e3,
           "paired downlink/dense-return wall-time ratio "
           "(x1000, dimensionless)",
           min_us=ratio * 1e3)
    out["dense_vs_downlink"] = ratio

    # ---- federated cohort step (DESIGN.md §13) --------------------------
    # The vmap'd heterogeneous-client exchange, single device (dp_axes=
    # None: the whole cohort local, no collectives — what scales here is
    # the batched selection/encode, so clients/sec is the honest axis).
    # Informational in bench_diff: simulation throughput is a capacity
    # number, not a fusion claim.
    from repro.fed.clients import cohort_compress_aggregate

    comp_fed = Compressor(gamma=0.02, method="topk", min_compress_size=64,
                          value_bits=32, use_kernel=False, max_gamma=0.2)
    cohort_sizes = [16, 64] if smoke else [64, 256, 1024]
    f_fed = jax.jit(functools.partial(
        cohort_compress_aggregate, comp=comp_fed, dp_axes=None,
        aggregation="support"))
    for nc in cohort_sizes:
        gf = {"w": jax.random.normal(jax.random.fold_in(key, 500 + nc),
                                     (nc, 2, 1024)),
              "v": jax.random.normal(jax.random.fold_in(key, 501 + nc),
                                     (nc, 4096))}
        mf = jax.tree.map(jnp.zeros_like, gf)
        eta_c = jnp.full((nc,), 0.1, jnp.float32)
        gamma_c = jnp.linspace(0.02, 0.2, nc, dtype=jnp.float32)
        ones = jnp.ones((nc,), jnp.float32)
        us = timeit(lambda g, m, e, gc, p: f_fed(
            g, m, e, participation=p, gamma_c=gc),
            gf, mf, eta_c, gamma_c, ones, n=n_heavy)
        record(f"fed_cohort_step_{nc}c", "default", (nc,), us,
               f"cohort exchange, {nc} clients, "
               f"{nc / (us[0] / 1e6):,.0f} clients/s median")
        out[f"fed_cohort_step_{nc}c"] = us[0]

    path = out_path or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_kernels.json")
    with open(path, "w") as fh:
        json.dump({"backend": jax.default_backend(), "smoke": smoke,
                   "records": _RECORDS}, fh, indent=1)
    print(f"wrote {len(_RECORDS)} records -> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes/iterations (CI)")
    ap.add_argument("--out", default=None, help="JSON output path")
    a = ap.parse_args()
    main(smoke=a.smoke, out_path=a.out)
