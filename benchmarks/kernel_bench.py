"""Kernel micro-benchmarks: jnp reference path timings on CPU.

NOTE: the Pallas kernels only run in interpret mode on this CPU container
(Python-loop execution — timings are not meaningful for TPU projection);
we therefore time the jnp reference path (what the dry-run lowers) and
verify the Pallas kernels numerically elsewhere (tests/test_kernels.py).
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .common import emit


def timeit(f, *args, n=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.time()
    for _ in range(n):
        r = f(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.time() - t0) / n * 1e6


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    m = jax.random.normal(key, (1 << 20,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (1 << 20,))
    f_ef = jax.jit(lambda m, g: ops.ef_threshold_update(m, g, 0.1, 0.3))
    us = timeit(f_ef, m, g)
    emit("kernel_ef_update_1M_ref", us, "fused EF accumulate+sparsify")
    out["ef"] = us

    B, H, S, D = 1, 8, 1024, 128
    q = jax.random.normal(key, (B, H, S, D)) * 0.1
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, S, D))
    f_at = jax.jit(lambda q, k, v: ops.attention(q, k, v))
    us = timeit(f_at, q, k, v, n=5)
    emit("kernel_attention_1k_ref", us, "causal MHA 8hx1024x128")
    out["attn"] = us

    x = jax.random.normal(key, (4096, 2048))
    w = jnp.ones((2048,))
    f_rn = jax.jit(lambda x, w: ops.rms_norm(x, w))
    us = timeit(f_rn, x, w)
    emit("kernel_rmsnorm_4kx2k_ref", us, "fused rmsnorm")
    out["rmsnorm"] = us
    return out


if __name__ == "__main__":
    main()
