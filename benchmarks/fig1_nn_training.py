"""Paper Figs. 1-3 + Table I (CPU-scale stand-ins): CSGD-ASSS (a=3sigma)
vs non-adaptive compressed SGD (eta in {0.1, 0.05, 0.01}) on neural nets.

Paper hyperparameters kept: sigma=0.1, a=3sigma, omega=1.2, rho=0.8,
alpha_max0=0.1, batch 64, per-layer top_k, layers <1000 params
uncompressed.  Models are CPU-scale stand-ins (DESIGN.md §7): MLP + small
CNN on teacher-labelled 32x32x3 synthetic images (CIFAR geometry) and a
small transformer LM; compressions 1% (Fig 1), 4%/10% (Figs 2-3).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.paper_models import (CNN_CONFIG, MLP_CONFIG, init_net,
                                        net_loss)
from repro.core import (ArmijoConfig, Compressor, CSGDConfig, NonAdaptiveCSGD,
                        csgd_asss)
from repro.data.synthetic import (TokenPipeline, class_batch,
                                  teacher_classification)
from repro.models import build_model
from .common import emit, run_optimizer, trailing_mean

BATCH = 64          # paper batch size


def optimizers(gamma):
    comp = Compressor(gamma=gamma)
    return {
        "csgd_asss_3s": csgd_asss(CSGDConfig(
            armijo=ArmijoConfig(sigma=0.1, a_scale=0.3, omega=1.2, rho=0.8,
                                alpha0=0.1),
            compressor=comp)),
        "nonadap_0.1": NonAdaptiveCSGD(eta=0.1, compressor=comp),
        "nonadap_0.05": NonAdaptiveCSGD(eta=0.05, compressor=comp),
        "nonadap_0.01": NonAdaptiveCSGD(eta=0.01, compressor=comp),
    }


def bench_net(net_cfg, gamma, steps, key, image):
    x, y = teacher_classification(2048, n_classes=net_cfg.n_classes,
                                  seed=1, image=image)
    batches = [class_batch(x, y, BATCH, t) for t in range(steps)]
    results = {}
    for name, opt in optimizers(gamma).items():
        params = init_net(net_cfg, key)
        losses, us, _ = run_optimizer(
            opt, lambda p, b: net_loss(net_cfg, p, b), params, batches)
        final = trailing_mean(losses)
        emit(f"fig1_{net_cfg.kind}_g{gamma:g}_{name}", us,
             f"final_loss={final:.4f}")
        results[name] = final
    return results


def bench_lm(gamma, steps, key):
    cfg = get_smoke_config("qwen1.5-4b")
    model = build_model(cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=16)
    batches = [pipe.batch(t) for t in range(steps)]
    results = {}
    for name, opt in optimizers(gamma).items():
        params = model.init(key)
        losses, us, _ = run_optimizer(
            opt, lambda p, b: model.loss(p, b)[0], params, batches)
        final = trailing_mean(losses)
        emit(f"fig1_lm_g{gamma:g}_{name}", us, f"final_loss={final:.4f}")
        results[name] = final
    return results


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}
    # Fig 1 analogue: ~1% compression
    out["mlp_1pct"] = bench_net(MLP_CONFIG, 0.01, 150, key, image=False)
    # Figs 2/3 analogue: CNN at 4% and 10%
    out["cnn_4pct"] = bench_net(CNN_CONFIG, 0.04, 100, key, image=True)
    out["cnn_10pct"] = bench_net(CNN_CONFIG, 0.10, 100, key, image=True)
    # transformer LM at 10%
    out["lm_10pct"] = bench_lm(0.10, 80, key)

    wins = 0
    for task, res in out.items():
        best_na = min(v for k, v in res.items() if k.startswith("nonadap"))
        ad = res["csgd_asss_3s"]
        wins += ad <= best_na * 1.15
        emit(f"fig1_{task}_summary", 0.0,
             f"csgd={ad:.4f};best_nonadap={best_na:.4f};"
             f"beats_or_matches={ad <= best_na * 1.15}")
    emit("fig1_overall", 0.0, f"csgd_wins_or_matches={wins}/{len(out)}")
    return out


if __name__ == "__main__":
    main()
